"""L1 Pallas kernel vs pure-jnp oracle — the core correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import rbf, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


@pytest.mark.parametrize("n,m,d", [
    (64, 64, 16), (128, 128, 16), (128, 512, 16), (256, 512, 16),
    (512, 512, 16), (64, 512, 7), (128, 128, 1),
])
def test_rbf_matches_ref_shapes(n, m, d):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n * 1000 + m + d))
    x = _rand(k1, (n, d))
    z = _rand(k2, (m, d))
    got = rbf.rbf_matrix(x, z)
    want = ref.rbf_matrix_ref(x, z)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    n=st.sampled_from([8, 16, 32, 64, 128]),
    m=st.sampled_from([8, 16, 32, 64, 128, 512]),
    d=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([0.01, 1.0, 10.0]),
)
def test_rbf_matches_ref_hypothesis(n, m, d, seed, scale):
    """Hypothesis sweep over shapes/scales: Pallas tile decomposition is exact."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = _rand(k1, (n, d), scale)
    z = _rand(k2, (m, d), scale)
    got = np.asarray(rbf.rbf_matrix(x, z))
    want = np.asarray(ref.rbf_matrix_ref(x, z))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_rbf_self_diagonal_is_one():
    x = _rand(jax.random.PRNGKey(0), (128, 16))
    k = np.asarray(rbf.rbf_matrix(x, x))
    np.testing.assert_allclose(np.diag(k), np.ones(128), rtol=1e-5)


def test_rbf_symmetry():
    x = _rand(jax.random.PRNGKey(1), (128, 8))
    k = np.asarray(rbf.rbf_matrix(x, x))
    np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-6)


def test_rbf_range_and_monotone_decay():
    """Entries in (0, 1]; farther points have smaller kernel values."""
    x = jnp.zeros((8, 4), dtype=jnp.float32)
    z = jnp.stack([jnp.full((4,), i / 4.0, dtype=jnp.float32) for i in range(8)])
    k = np.asarray(rbf.rbf_matrix(x, z))
    assert (k > 0).all() and (k <= 1 + 1e-6).all()
    row = k[0]
    assert (np.diff(row) <= 1e-7).all(), "decay must be monotone in distance"


def test_rbf_zero_scaled_dims_ignored():
    """Dims scaled by inv_ls = 0 must not affect the kernel (padding contract)."""
    key = jax.random.PRNGKey(3)
    x = _rand(key, (64, 16))
    x_junk = x.at[:, 8:].set(_rand(jax.random.PRNGKey(9), (64, 8)) * 100.0)
    inv = jnp.concatenate([jnp.ones(8), jnp.zeros(8)]).astype(jnp.float32)
    k1 = np.asarray(rbf.rbf_matrix(x * inv, x * inv))
    k2 = np.asarray(rbf.rbf_matrix(x_junk * inv, x_junk * inv))
    np.testing.assert_allclose(k1, k2, rtol=1e-6)
