"""L2 GP programs (gp_fit + gp_acquire) vs the LAPACK-backed reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import linalg, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

AMP, NOISE, BETA = 1.0, 1e-3, 2.0


def _problem(seed, n_valid, n_slots, d_valid, m=64):
    """Random padded GP problem with the runtime's masking contract."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jnp.zeros((n_slots, model.MAX_DIM), dtype=jnp.float32)
    x = x.at[:n_valid, :d_valid].set(
        jax.random.uniform(keys[0], (n_valid, d_valid), dtype=jnp.float32))
    y = jnp.zeros((n_slots,), dtype=jnp.float32)
    y = y.at[:n_valid].set(jax.random.normal(keys[1], (n_valid,), dtype=jnp.float32))
    mask = jnp.concatenate(
        [jnp.ones(n_valid), jnp.zeros(n_slots - n_valid)]).astype(jnp.float32)
    xc = jnp.zeros((m, model.MAX_DIM), dtype=jnp.float32)
    xc = xc.at[:, :d_valid].set(
        jax.random.uniform(keys[2], (m, d_valid), dtype=jnp.float32))
    inv_ls = jnp.concatenate(
        [jnp.full((d_valid,), 3.0), jnp.zeros(model.MAX_DIM - d_valid)]
    ).astype(jnp.float32)
    params = jnp.array([AMP, NOISE, BETA], dtype=jnp.float32)
    return x, y, mask, xc, inv_ls, params


def _run_pair(x, y, mask, xc, inv_ls, params):
    alpha, l, logdet = model.gp_fit(x, y, mask, inv_ls, params)
    ucb, mean, var, w = model.gp_acquire(x, mask, xc, alpha, l, inv_ls, params)
    return ucb, mean, var, w, alpha, l, logdet


@pytest.mark.parametrize("n_valid,n_slots,d_valid", [
    (3, 64, 2), (20, 64, 7), (64, 64, 16), (50, 128, 4), (100, 128, 7),
])
def test_fit_acquire_matches_reference(n_valid, n_slots, d_valid):
    x, y, mask, xc, inv_ls, params = _problem(42, n_valid, n_slots, d_valid)
    ucb, mean, var, *_ = _run_pair(x, y, mask, xc, inv_ls, params)
    ucb_r, mean_r, var_r = ref.gp_posterior_ref(x, y, mask, xc, inv_ls, AMP, NOISE, BETA)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(mean_r), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(var), np.asarray(var_r), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(ucb), np.asarray(ucb_r), rtol=1e-3, atol=1e-3)


def test_padding_invariance():
    """Same valid data in 64 vs 128 slots must give identical posteriors."""
    x64, y64, m64, xc, inv_ls, params = _problem(7, 30, 64, 5)
    x128 = jnp.zeros((128, model.MAX_DIM), dtype=jnp.float32).at[:64].set(x64)
    y128 = jnp.zeros((128,), dtype=jnp.float32).at[:64].set(y64)
    m128 = jnp.zeros((128,), dtype=jnp.float32).at[:64].set(m64)
    u1, me1, v1, *_ = _run_pair(x64, y64, m64, xc, inv_ls, params)
    u2, me2, v2, *_ = _run_pair(x128, y128, m128, xc, inv_ls, params)
    np.testing.assert_allclose(np.asarray(me1), np.asarray(me2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(u1), np.asarray(u2), rtol=1e-4, atol=1e-5)


def test_padding_rows_have_zero_alpha():
    x, y, mask, xc, inv_ls, params = _problem(3, 10, 64, 3)
    alpha, l, _ = model.gp_fit(x, y, mask, inv_ls, params)
    np.testing.assert_allclose(np.asarray(alpha)[10:], 0.0, atol=1e-6)


def test_posterior_interpolates_training_points():
    """With tiny noise, the posterior mean at training inputs ~= y."""
    x, y, mask, _, inv_ls, params = _problem(11, 25, 64, 4)
    xc = jnp.zeros((64, model.MAX_DIM), dtype=jnp.float32).at[:25].set(x[:25])
    alpha, l, _ = model.gp_fit(x, y, mask, inv_ls, params)
    _, mean, var, _ = model.gp_acquire(x, mask, xc, alpha, l, inv_ls, params)
    np.testing.assert_allclose(np.asarray(mean)[:25], np.asarray(y)[:25],
                               rtol=5e-2, atol=5e-2)
    assert float(jnp.max(var[:25])) < 0.05, "variance must collapse at data"


def test_variance_far_from_data_approaches_prior():
    x, y, mask, _, inv_ls, params = _problem(13, 20, 64, 3)
    xc = jnp.full((64, model.MAX_DIM), 50.0, dtype=jnp.float32)  # far away
    alpha, l, _ = model.gp_fit(x, y, mask, inv_ls, params)
    _, mean, var, _ = model.gp_acquire(x, mask, xc, alpha, l, inv_ls, params)
    np.testing.assert_allclose(np.asarray(var), AMP, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(mean), 0.0, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1),
       n_valid=st.integers(min_value=2, max_value=60),
       d=st.integers(min_value=1, max_value=16))
def test_ucb_monotone_in_beta_hypothesis(seed, n_valid, d):
    x, y, mask, xc, inv_ls, _ = _problem(seed, n_valid, 64, d)
    p1 = jnp.array([AMP, NOISE, 1.0], dtype=jnp.float32)
    p2 = jnp.array([AMP, NOISE, 3.0], dtype=jnp.float32)
    alpha, l, _ = model.gp_fit(x, y, mask, inv_ls, p1)
    u1, _, _, _ = model.gp_acquire(x, mask, xc, alpha, l, inv_ls, p1)
    u2, _, _, _ = model.gp_acquire(x, mask, xc, alpha, l, inv_ls, p2)
    assert np.all(np.asarray(u2) >= np.asarray(u1) - 1e-6)


def test_w_output_consistent_with_kinv_oracle():
    """w = K^{-1} k_c — the contract the Rust hallucinator relies on.

    gp_acquire computes w by triangular solves against l; the retained
    spd_inverse_from_cholesky test oracle must agree.
    """
    x, y, mask, xc, inv_ls, params = _problem(17, 40, 64, 6)
    alpha, l, _ = model.gp_fit(x, y, mask, inv_ls, params)
    _, _, _, w = model.gp_acquire(x, mask, xc, alpha, l, inv_ls, params)
    xs = x * inv_ls[None, :]
    xcs = xc * inv_ls[None, :]
    kc = AMP * ref.rbf_matrix_ref(xs, xcs) * mask[:, None]
    kinv = linalg.spd_inverse_from_cholesky(l)
    np.testing.assert_allclose(np.asarray(w), np.asarray(kinv @ kc),
                               rtol=1e-4, atol=1e-4)


def test_logdet_positive_definite_sanity():
    x, y, mask, _, inv_ls, params = _problem(19, 30, 64, 4)
    _, _, logdet = model.gp_fit(x, y, mask, inv_ls, params)
    # K has unit diagonal + tiny noise; logdet must be finite and negative-ish
    assert np.isfinite(float(logdet))
    assert float(logdet) < 30.0
