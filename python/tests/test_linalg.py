"""Pure-HLO linalg (compile/linalg.py) vs LAPACK-backed jax.scipy."""

import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import linalg

jax.config.update("jax_platform_name", "cpu")


def _spd(key, n, cond_boost=1.0):
    a = jax.random.normal(key, (n, n), dtype=jnp.float32)
    return a @ a.T + cond_boost * n * jnp.eye(n, dtype=jnp.float32)


@pytest.mark.parametrize("n", [1, 2, 4, 8, 16, 64, 128])
def test_cholesky_matches_lapack(n):
    a = _spd(jax.random.PRNGKey(n), n)
    got = np.asarray(linalg.cholesky_lower(a))
    want = np.asarray(jnp.linalg.cholesky(a))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(min_value=1, max_value=96),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_cholesky_reconstructs(n, seed):
    a = _spd(jax.random.PRNGKey(seed), n)
    l = linalg.cholesky_lower(a)
    np.testing.assert_allclose(np.asarray(l @ l.T), np.asarray(a),
                               rtol=1e-3, atol=1e-3)
    # strictly upper part must be exactly zero
    lu = np.triu(np.asarray(l), k=1)
    assert np.all(lu == 0.0)


@pytest.mark.parametrize("n,m", [(4, 1), (16, 8), (64, 32), (128, 128)])
def test_solve_lower_matches_scipy(n, m):
    key = jax.random.PRNGKey(n * 100 + m)
    l = jnp.linalg.cholesky(_spd(key, n))
    b = jax.random.normal(jax.random.PRNGKey(m), (n, m), dtype=jnp.float32)
    got = np.asarray(linalg.solve_lower(l, b))
    want = np.asarray(jsl.solve_triangular(l, b, lower=True))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("n,m", [(4, 1), (16, 8), (64, 32), (128, 128)])
def test_solve_lower_t_matches_scipy(n, m):
    key = jax.random.PRNGKey(n * 7 + m)
    l = jnp.linalg.cholesky(_spd(key, n))
    b = jax.random.normal(jax.random.PRNGKey(m + 1), (n, m), dtype=jnp.float32)
    got = np.asarray(linalg.solve_lower_t(l, b))
    want = np.asarray(jsl.solve_triangular(l, b, trans="T", lower=True))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(min_value=1, max_value=64),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_spd_inverse(n, seed):
    a = _spd(jax.random.PRNGKey(seed), n)
    l = linalg.cholesky_lower(a)
    kinv = linalg.spd_inverse_from_cholesky(l)
    np.testing.assert_allclose(np.asarray(a @ kinv), np.eye(n),
                               rtol=2e-3, atol=2e-3)


def test_logdet_matches_slogdet():
    a = _spd(jax.random.PRNGKey(5), 32)
    l = linalg.cholesky_lower(a)
    got = float(linalg.logdet_from_cholesky(l))
    want = float(jnp.linalg.slogdet(a)[1])
    assert abs(got - want) < 1e-2 * max(1.0, abs(want))


def test_logdet_mask_ignores_padding():
    """Identity rows (padding) must contribute 0 to the masked logdet."""
    n, valid = 32, 20
    a = _spd(jax.random.PRNGKey(6), valid)
    big = jnp.eye(n, dtype=jnp.float32)
    big = big.at[:valid, :valid].set(a)
    mask = jnp.concatenate([jnp.ones(valid), jnp.zeros(n - valid)]).astype(jnp.float32)
    l = linalg.cholesky_lower(big)
    got = float(linalg.logdet_from_cholesky(l, mask))
    want = float(jnp.linalg.slogdet(a)[1])
    assert abs(got - want) < 1e-2 * max(1.0, abs(want))


def test_cholesky_degenerate_does_not_nan():
    """Singular input: clamped diagonal keeps the factor finite."""
    a = jnp.ones((8, 8), dtype=jnp.float32)  # rank-1, singular
    l = np.asarray(linalg.cholesky_lower(a))
    assert np.isfinite(l).all()


@pytest.mark.parametrize("n", [128, 192, 256])
def test_blocked_cholesky_matches_unblocked(n):
    """The blocked path (n % BLOCK == 0, n > BLOCK) must agree with both the
    unblocked loop and LAPACK."""
    a = _spd(jax.random.PRNGKey(n), n)
    blocked = np.asarray(linalg.cholesky_lower_blocked(a))
    unblocked = np.asarray(linalg.cholesky_lower_unblocked(a))
    lapack = np.asarray(jnp.linalg.cholesky(a))
    np.testing.assert_allclose(blocked, unblocked, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(blocked, lapack, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("n,m", [(128, 64), (192, 128), (256, 32)])
def test_blocked_solves_match_scipy(n, m):
    key = jax.random.PRNGKey(n + m)
    l = jnp.linalg.cholesky(_spd(key, n))
    b = jax.random.normal(jax.random.PRNGKey(m + 2), (n, m), dtype=jnp.float32)
    got_f = np.asarray(linalg.solve_lower_blocked(l, b))
    want_f = np.asarray(jsl.solve_triangular(l, b, lower=True))
    np.testing.assert_allclose(got_f, want_f, rtol=5e-3, atol=5e-3)
    got_b = np.asarray(linalg.solve_lower_t_blocked(l, b))
    want_b = np.asarray(jsl.solve_triangular(l, b, trans="T", lower=True))
    np.testing.assert_allclose(got_b, want_b, rtol=5e-3, atol=5e-3)
