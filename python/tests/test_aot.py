"""AOT lowering sanity: HLO text parses, is custom-call free, manifest sane."""

import json
import os
import re

import jax
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def lowered_small():
    """Lower the smallest variant once (cheap) for the text checks."""
    fit = jax.jit(model.gp_fit).lower(*model.fit_spec(64))
    acq = jax.jit(model.gp_acquire).lower(*model.acquire_spec(64))
    return aot.to_hlo_text(fit), aot.to_hlo_text(acq)


def test_no_custom_calls(lowered_small):
    fit_text, acq_text = lowered_small
    aot.check_no_custom_calls(fit_text, "gp_fit_n64")
    aot.check_no_custom_calls(acq_text, "gp_acquire_n64")


def test_hlo_entry_is_tuple(lowered_small):
    """return_tuple=True — the Rust side unwraps with to_tuple3/to_tuple4."""
    fit_text, acq_text = lowered_small
    assert "ENTRY" in fit_text and "ENTRY" in acq_text
    root_fit = [l for l in fit_text.splitlines() if "ROOT" in l]
    assert any("tuple" in l for l in root_fit), "fit root must be a tuple"


def test_fit_shapes_in_text(lowered_small):
    fit_text, _ = lowered_small
    assert re.search(r"f32\[64,16\]", fit_text), "x param shape missing"
    assert re.search(r"f32\[64,64\]", fit_text), "chol output shape missing"


def test_check_no_custom_calls_raises():
    bad = 'x = f32[2] custom-call(y), custom_call_target="lapack_spotrf_ffi"'
    with pytest.raises(RuntimeError):
        aot.check_no_custom_calls(bad, "bad")


def test_manifest_roundtrip(tmp_path):
    """Full lower_all on all variants; manifest must index every file."""
    manifest = aot.lower_all(str(tmp_path))
    for n, entry in manifest["programs"].items():
        for key in ("fit", "acquire"):
            p = tmp_path / entry[key]
            assert p.exists() and p.stat().st_size > 1000
    assert manifest["max_dim"] == model.MAX_DIM
    assert manifest["m_cand"] == model.M_CAND
    (tmp_path / "manifest.json").write_text(json.dumps(manifest))
    back = json.loads((tmp_path / "manifest.json").read_text())
    assert back == manifest
