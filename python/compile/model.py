"""Layer-2 JAX model: the GP-UCB surrogate as two AOT-exportable programs.

The MANGO optimizer's hot path is (1) fitting a GP posterior over the
observed (config, score) pairs and (2) scoring a large Monte-Carlo candidate
set with the UCB acquisition.  We split these into two programs so the cubic
fit runs once per posterior update while the matmul-only acquire runs per
candidate chunk (MXU-friendly, no sequential loops):

  gp_fit(x, y, mask, inv_ls, params)      -> (alpha, l, logdet)
  gp_acquire(x, mask, xc, alpha, l, inv_ls, params)
                                          -> (ucb, mean, var, w)

Static shapes (HLO is shape-monomorphic): N in N_VARIANTS observation slots,
D = MAX_DIM encoded feature slots, M = M_CAND candidate slots per acquire
call.  The Rust runtime pads + masks to the nearest variant and chunks
candidate sets.  Masking contract:

  * mask[i] = 1.0 for a real observation, 0.0 for padding;
  * padded rows of K are replaced by identity rows, padded y by 0, so alpha
    is exactly 0 there and they contribute nothing to the posterior;
  * unused feature dims carry inv_ls = 0 so they never affect distances.

The posterior is inverse-free: gp_fit returns the lower Cholesky factor
``l`` and gp_acquire computes ``w = K^{-1} k_c`` by two triangular solves
against it — no explicit K^{-1} is ever materialized (mirrors
rust/src/gp/fit_posterior; linalg.spd_inverse_from_cholesky survives only
as a test oracle).

``params`` packs [amp, noise, beta] to keep the artifact arity small.
The within-batch hallucination (GP-BUCB constant-liar) is a rank-1 update
performed by the Rust coordinator on ``w`` — see rust/src/gp/.
"""

import jax
import jax.numpy as jnp

from compile import linalg
from compile.kernels import rbf

# Static-shape configuration shared with the Rust runtime via the manifest.
MAX_DIM = 16
M_CAND = 512
N_VARIANTS = (64, 128, 256, 384, 512)


def gp_fit(x, y, mask, inv_ls, params):
    """Fit the GP posterior: returns (alpha, l, logdet).

    x: (n, MAX_DIM) encoded configs (unit-cube scaled), padded with zeros.
    y: (n,) normalized objective values (zero-mean/unit-var on valid rows).
    mask: (n,) 1.0 valid / 0.0 padding.
    inv_ls: (MAX_DIM,) per-dim inverse lengthscales (0 for unused dims).
    params: (3,) [amp, noise, _unused].

    ``l`` is the lower Cholesky factor of the regularized kernel; padded
    rows are identity rows of K, hence identity rows of l, so the
    triangular solves pass them through and alpha is exactly 0 there.
    """
    amp = params[0]
    noise = params[1]
    n = x.shape[0]
    xs = x * inv_ls[None, :]
    corr = rbf.rbf_matrix(xs, xs)
    m2 = mask[:, None] * mask[None, :]
    k = amp * corr * m2 + jnp.diag(noise * mask + (1.0 - mask))
    l = linalg.cholesky_lower(k)
    alpha = linalg.solve_lower_t(l, linalg.solve_lower(l, (y * mask)[:, None]))[:, 0]
    logdet = linalg.logdet_from_cholesky(l, mask)
    return alpha, l, logdet


def gp_acquire(x, mask, xc, alpha, l, inv_ls, params):
    """Score M_CAND candidates with posterior mean/var and UCB.

    Returns (ucb, mean, var, w) where w = K^{-1} k_c (needed by the Rust
    coordinator for GP-BUCB rank-1 hallucination updates), computed by two
    triangular solves against the Cholesky factor ``l`` — never from a
    materialized inverse.
    Maximization convention: the Rust side negates y for minimization.
    """
    amp = params[0]
    beta = params[2]
    xs = x * inv_ls[None, :]
    xcs = xc * inv_ls[None, :]
    kc = amp * rbf.rbf_matrix(xs, xcs) * mask[:, None]    # (n, m)
    mean = kc.T @ alpha                                    # (m,)
    w = linalg.solve_lower_t(l, linalg.solve_lower(l, kc))  # (n, m)
    var = jnp.maximum(amp - jnp.sum(kc * w, axis=0), 1e-10)
    ucb = mean + beta * jnp.sqrt(var)
    return ucb, mean, var, w


def fit_spec(n: int):
    """ShapeDtypeStructs for a gp_fit variant with n observation slots."""
    f = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, MAX_DIM), f),   # x
        jax.ShapeDtypeStruct((n,), f),           # y
        jax.ShapeDtypeStruct((n,), f),           # mask
        jax.ShapeDtypeStruct((MAX_DIM,), f),     # inv_ls
        jax.ShapeDtypeStruct((3,), f),           # params
    )


def acquire_spec(n: int, m: int = M_CAND):
    """ShapeDtypeStructs for a gp_acquire variant."""
    f = jnp.float32
    return (
        jax.ShapeDtypeStruct((n, MAX_DIM), f),   # x
        jax.ShapeDtypeStruct((n,), f),           # mask
        jax.ShapeDtypeStruct((m, MAX_DIM), f),   # xc
        jax.ShapeDtypeStruct((n,), f),           # alpha
        jax.ShapeDtypeStruct((n, n), f),         # l (lower Cholesky factor)
        jax.ShapeDtypeStruct((MAX_DIM,), f),     # inv_ls
        jax.ShapeDtypeStruct((3,), f),           # params
    )
