"""Pure-jnp correctness oracles for the Pallas kernels and the HLO linalg.

These are the reference implementations the pytest suite compares against:
no Pallas, no custom loops — the most obviously-correct spelling of each
computation.
"""

import jax.numpy as jnp
import jax.scipy.linalg as jsl


def rbf_matrix_ref(x_scaled, z_scaled):
    """O(n*m*d) dense reference for the RBF correlation matrix."""
    diff = x_scaled[:, None, :] - z_scaled[None, :, :]
    sq = jnp.sum(diff * diff, axis=-1)
    return jnp.exp(-0.5 * sq)


def gp_posterior_ref(x, y, mask, xc, inv_ls, amp, noise, beta):
    """Reference GP posterior + UCB with masking semantics.

    Identical contract to model.gp_fit + model.gp_acquire composed:
    masked rows contribute nothing, K gets identity rows in their place.
    Uses jax.scipy (LAPACK-backed) — fine for tests, not AOT-exportable.
    """
    xs = x * inv_ls[None, :]
    xcs = xc * inv_ls[None, :]
    m2 = mask[:, None] * mask[None, :]
    k = amp * rbf_matrix_ref(xs, xs) * m2 + jnp.diag(noise * mask + (1.0 - mask))
    l = jnp.linalg.cholesky(k)
    kc = amp * rbf_matrix_ref(xs, xcs) * mask[:, None]
    ym = y * mask
    alpha = jsl.cho_solve((l, True), ym)
    mean = kc.T @ alpha
    v = jsl.solve_triangular(l, kc, lower=True)
    var = jnp.maximum(amp - jnp.sum(v * v, axis=0), 1e-10)
    ucb = mean + beta * jnp.sqrt(var)
    return ucb, mean, var
