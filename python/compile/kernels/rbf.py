"""Layer-1 Pallas kernel: ARD-RBF pairwise kernel matrix.

The GP surrogate's compute hot-spot is the pairwise kernel matrix
``k(X1, X2)[i, j] = exp(-0.5 * ||x1_i - x2_j||^2)`` over *lengthscale-scaled*
inputs.  We compute it tiled with the classic decomposition

    ||a - b||^2 = ||a||^2 + ||b||^2 - 2 <a, b>

so the inner-product term is a single ``dot_general`` that maps onto the MXU
systolic array on a real TPU.  Tiles are sized for VMEM: with the default
(128, 128) blocks over D<=16 features, the three resident blocks are
128*16*4 B + 128*16*4 B + 128*128*4 B ~= 80 KiB, far under the ~16 MiB VMEM
budget; ``BlockSpec`` expresses the HBM<->VMEM schedule over the (i, j) grid.

``interpret=True`` is mandatory on this image: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret-mode lowers the kernel to plain
HLO ops that embed in the surrounding jitted computation (see
DESIGN.md section "Hardware-Adaptation").
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile edge.  128 matches both the MXU systolic dimension and the
# f32 VMEM tiling granularity (8, 128) on TPU.
BLOCK = 128


def _rbf_block_kernel(x_ref, z_ref, o_ref):
    """One (bn, bm) output tile of the RBF kernel matrix.

    x_ref: (bn, d) lengthscale-scaled rows, resident in VMEM.
    z_ref: (bm, d) lengthscale-scaled columns, resident in VMEM.
    o_ref: (bn, bm) output tile.
    """
    x = x_ref[...]
    z = z_ref[...]
    xx = jnp.sum(x * x, axis=1, keepdims=True)          # (bn, 1)
    zz = jnp.sum(z * z, axis=1, keepdims=True).T        # (1, bm)
    # The MXU-shaped term: contract the feature dimension of both operands.
    cross = jax.lax.dot_general(
        x,
        z,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                    # (bn, bm)
    sq = jnp.maximum(xx + zz - 2.0 * cross, 0.0)
    o_ref[...] = jnp.exp(-0.5 * sq)


def _block_edge(n: int) -> int:
    """Largest tile edge <= BLOCK that divides n (shapes here are powers of 2)."""
    b = min(n, BLOCK)
    while n % b != 0:
        b //= 2
    return max(b, 1)


def rbf_matrix(x_scaled: jax.Array, z_scaled: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Pairwise RBF correlation matrix over lengthscale-scaled inputs.

    Args:
      x_scaled: (n, d) float32, rows already divided by per-dim lengthscales.
      z_scaled: (m, d) float32.
      interpret: run the Pallas kernel in interpret mode (required on CPU).

    Returns:
      (n, m) float32 with entries exp(-0.5 * ||x_i - z_j||^2).
    """
    n, d = x_scaled.shape
    m, d2 = z_scaled.shape
    assert d == d2, f"feature dims differ: {d} vs {d2}"
    bn = _block_edge(n)
    bm = _block_edge(m)
    grid = (n // bn, m // bm)
    return pl.pallas_call(
        _rbf_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x_scaled, z_scaled)
