"""Pure-HLO dense linear algebra for the AOT path.

Why this exists: on CPU, ``jnp.linalg.cholesky`` / ``solve_triangular`` lower
to LAPACK *custom-calls* (``lapack_spotrf_ffi``, ``lapack_strsm_ffi``) that
are registered by jaxlib — the standalone xla_extension 0.5.1 used by the
Rust PJRT client cannot execute them.  These implementations use only core
HLO ops (while, gather, scatter, dot), so the lowered module round-trips
through HLO text and runs anywhere.

Algorithms are the vectorized column forms: each ``fori_loop`` iteration is
O(n) or O(n*m) dense work, so XLA compiles the loop body to tight native
code and the total cost matches the classic O(n^3) / O(n^2 m) counts.
"""

import jax
import jax.numpy as jnp

# Panel width for the blocked algorithms. All artifact variants are
# multiples of 64; other sizes fall back to the unblocked loops.
BLOCK = 64


def cholesky_lower_unblocked(a: jax.Array) -> jax.Array:
    """Lower-triangular Cholesky factor, one column per loop step.

    Column-by-column Cholesky–Banachiewicz: at step j the first j columns of
    ``l`` hold the final factor and the rest are zero, so the update
    ``v = a[:, j] - l @ l[j, :]`` needs no masking beyond zeroing the
    not-yet-written columns (they already are zero).

    Diagonal entries are clamped at 1e-12 before the sqrt so padded /
    near-singular inputs degrade gracefully instead of producing NaNs; a
    clamped pivot additionally zeroes its sub-diagonal column (the residual
    there is rounding noise — dividing it by ~1e-6 would inject huge
    off-diagonal entries), matching rust/src/linalg cholesky exactly.
    """
    n = a.shape[0]
    assert a.shape == (n, n)
    idx = jnp.arange(n)

    def body(j, l):
        lj = l[j, :]                       # row j: cols < j are final, >= j are 0
        v = a[:, j] - l @ lj               # (n,)
        d = jnp.sqrt(jnp.maximum(v[j], 1e-12))
        col = jnp.where((idx > j) & (v[j] >= 1e-12), v / d, 0.0)
        col = col.at[j].set(d)
        return l.at[:, j].set(col)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(a))


def _chol_block(a: jax.Array):
    """Unrolled Cholesky of one (BLOCK x BLOCK) diagonal panel.

    Indices are Python ints, so this traces to straight-line HLO with static
    slices — no while loop, XLA fuses it aggressively.

    Returns (l, clamped) where clamped[j] marks a pivot that hit the 1e-12
    clamp, so the caller's panel solve can zero the below-panel part of the
    column exactly like the unblocked form zeroes its sub-diagonal.
    """
    b = a.shape[0]
    idx = jnp.arange(b)
    l = jnp.zeros_like(a)
    clamped = []
    for j in range(b):
        lj = l[j, :]
        v = a[:, j] - l @ lj
        ok = v[j] >= 1e-12
        d = jnp.sqrt(jnp.maximum(v[j], 1e-12))
        col = jnp.where((idx > j) & ok, v / d, 0.0)
        col = col.at[j].set(d)
        l = l.at[:, j].set(col)
        clamped.append(~ok)
    return l, jnp.stack(clamped)


def _solve_right_lower_t(ark: jax.Array, lkk: jax.Array,
                         clamped: jax.Array) -> jax.Array:
    """Solve X @ Lkk^T = Ark for X (Ark: (r, b), Lkk lower-tri (b, b)).

    Unrolled forward substitution over the b panel columns; each step is a
    dense (r x j) @ (j,) matvec — MXU-shaped work, not gathers. Columns
    whose panel pivot clamped are zeroed instead of divided by ~1e-6
    (mirrors the unblocked form's rank-deficient handling).
    """
    b = lkk.shape[0]
    cols = []
    for j in range(b):
        acc = ark[:, j]
        if j > 0:
            x_prev = jnp.stack(cols, axis=1)       # (r, j)
            acc = acc - x_prev @ lkk[j, :j]
        cols.append(jnp.where(clamped[j], jnp.zeros_like(acc), acc / lkk[j, j]))
    return jnp.stack(cols, axis=1)


def cholesky_lower_blocked(a: jax.Array, jitter: float = 0.0) -> jax.Array:
    """Blocked right-looking Cholesky (panel BLOCK), core HLO ops only.

    Per panel: factor the diagonal block (straight-line), solve the
    sub-diagonal panel against it, then one dense trailing update
    ``A22 -= X X^T`` — the O(n³) bulk lands in dense XLA dot ops.

    §Perf NOTE: this is the right shape for a *real TPU* (MXU matmuls,
    compile once, cache). On the CPU testbed the straight-line unrolling
    inflates the n=512 HLO to ~5 MB and costs ~2 min of PJRT compilation,
    while the while-loop version executes within ~2-3x of it — so the AOT
    artifacts use [`cholesky_lower`] (the loop form). Kept and tested as
    the documented TPU lowering (see EXPERIMENTS.md §Perf iteration log).
    """
    n = a.shape[0]
    assert a.shape == (n, n)
    if jitter:
        a = a + jitter * jnp.eye(n, dtype=a.dtype)
    if n % BLOCK != 0 or n <= BLOCK:
        return cholesky_lower_unblocked(a)

    l = jnp.zeros_like(a)
    work = a
    for k in range(0, n, BLOCK):
        akk = jax.lax.dynamic_slice(work, (k, k), (BLOCK, BLOCK))
        lkk, clamped = _chol_block(akk)
        l = jax.lax.dynamic_update_slice(l, lkk, (k, k))
        rest = n - k - BLOCK
        if rest > 0:
            ark = jax.lax.dynamic_slice(work, (k + BLOCK, k), (rest, BLOCK))
            x = _solve_right_lower_t(ark, lkk, clamped)  # (rest, BLOCK)
            l = jax.lax.dynamic_update_slice(l, x, (k + BLOCK, k))
            att = jax.lax.dynamic_slice(work, (k + BLOCK, k + BLOCK), (rest, rest))
            att = att - x @ x.T
            work = jax.lax.dynamic_update_slice(work, att, (k + BLOCK, k + BLOCK))
    return l


def solve_lower_unblocked(l: jax.Array, b: jax.Array) -> jax.Array:
    """Solve L x = b by forward substitution (L lower-triangular, b (n, m)).

    Invariant: before step i, rows >= i of x are zero, so ``l[i, :] @ x``
    only picks up the already-computed prefix (entries of l above the
    diagonal are zero by construction).
    """
    n = l.shape[0]

    def body(i, x):
        xi = (b[i, :] - l[i, :] @ x) / l[i, i]
        return x.at[i, :].set(xi)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))


def solve_lower_t_unblocked(l: jax.Array, b: jax.Array) -> jax.Array:
    """Solve L^T x = b by back substitution (b (n, m))."""
    n = l.shape[0]

    def body(k, x):
        i = n - 1 - k
        xi = (b[i, :] - l[:, i] @ x) / l[i, i]
        return x.at[i, :].set(xi)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))


def _solve_panel_lower(lkk: jax.Array, rhs: jax.Array) -> jax.Array:
    """Solve Lkk X = rhs within one (BLOCK x BLOCK) panel, unrolled."""
    rows = []
    for i in range(lkk.shape[0]):
        acc = rhs[i, :]
        if i > 0:
            x_prev = jnp.stack(rows, axis=0)        # (i, m)
            acc = acc - lkk[i, :i] @ x_prev
        rows.append(acc / lkk[i, i])
    return jnp.stack(rows, axis=0)


def _solve_panel_lower_t(lkk: jax.Array, rhs: jax.Array) -> jax.Array:
    """Solve Lkk^T X = rhs within one panel, unrolled back substitution."""
    b = lkk.shape[0]
    rows = [None] * b
    computed = []                                   # rows i+1.. in order
    for i in reversed(range(b)):
        acc = rhs[i, :]
        if computed:
            x_next = jnp.stack(computed, axis=0)    # (b-1-i, m), rows i+1..b-1
            acc = acc - lkk[i + 1:, i] @ x_next
        rows[i] = acc / lkk[i, i]
        computed.insert(0, rows[i])
    return jnp.stack(rows, axis=0)


def solve_lower_blocked(l: jax.Array, b: jax.Array) -> jax.Array:
    """Blocked forward substitution: panel solves + dense panel matmuls.
    Same CPU-testbed caveat as [`cholesky_lower_blocked`].
    """
    n = l.shape[0]
    if n % BLOCK != 0 or n <= BLOCK:
        return solve_lower_unblocked(l, b)
    x = jnp.zeros_like(b)
    for k in range(0, n, BLOCK):
        rhs = b[k:k + BLOCK, :]
        if k > 0:
            rhs = rhs - l[k:k + BLOCK, :k] @ x[:k, :]
        xb = _solve_panel_lower(l[k:k + BLOCK, k:k + BLOCK], rhs)
        x = jax.lax.dynamic_update_slice(x, xb, (k, 0))
    return x


def solve_lower_t_blocked(l: jax.Array, b: jax.Array) -> jax.Array:
    """Blocked back substitution for L^T x = b (same caveat)."""
    n = l.shape[0]
    if n % BLOCK != 0 or n <= BLOCK:
        return solve_lower_t_unblocked(l, b)
    x = jnp.zeros_like(b)
    for k in reversed(range(0, n, BLOCK)):
        rhs = b[k:k + BLOCK, :]
        hi = k + BLOCK
        if hi < n:
            # L^T[k:k+B, hi:] = L[hi:, k:k+B]^T
            rhs = rhs - l[hi:, k:hi].T @ x[hi:, :]
        xb = _solve_panel_lower_t(l[k:hi, k:hi], rhs)
        x = jax.lax.dynamic_update_slice(x, xb, (k, 0))
    return x


def spd_inverse_from_cholesky(l: jax.Array) -> jax.Array:
    """K^{-1} = L^{-T} L^{-1} given the Cholesky factor L of K.

    Test oracle only: the L2 programs (compile/model.py) solve against L
    directly and never materialize an inverse.
    """
    n = l.shape[0]
    eye = jnp.eye(n, dtype=l.dtype)
    linv = solve_lower(l, eye)
    return solve_lower_t(l, linv)


def logdet_from_cholesky(l: jax.Array, mask: jax.Array | None = None) -> jax.Array:
    """log det K = 2 * sum log diag(L); masked rows (diag 1.0) contribute 0."""
    d = jnp.diagonal(l)
    logs = 2.0 * jnp.log(jnp.maximum(d, 1e-12))
    if mask is not None:
        logs = logs * mask
    return jnp.sum(logs)


# Default implementations used by the AOT artifacts: the loop forms (compact
# HLO, fast PJRT compile, within ~2-3x of the blocked execution on CPU).
def cholesky_lower(a: jax.Array, jitter: float = 0.0) -> jax.Array:
    """Lower Cholesky factor (loop form; see cholesky_lower_blocked)."""
    if jitter:
        a = a + jitter * jnp.eye(a.shape[0], dtype=a.dtype)
    return cholesky_lower_unblocked(a)


def solve_lower(l: jax.Array, b: jax.Array) -> jax.Array:
    """Solve L x = b (loop form)."""
    return solve_lower_unblocked(l, b)


def solve_lower_t(l: jax.Array, b: jax.Array) -> jax.Array:
    """Solve L^T x = b (loop form)."""
    return solve_lower_t_unblocked(l, b)
