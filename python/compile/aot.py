"""AOT-lower the L2 GP programs to HLO text for the Rust PJRT runtime.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs one file per (program, n) variant plus a manifest the Rust runtime
reads to pick shapes:

  artifacts/gp_fit_n{N}.hlo.txt
  artifacts/gp_acquire_n{N}.hlo.txt
  artifacts/manifest.json

Run via ``make artifacts`` (never on the request path).
"""

import argparse
import json
import os
import re

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust unwrap)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def check_no_custom_calls(text: str, name: str) -> None:
    """The whole point of compile/linalg.py: nothing jaxlib-specific inside."""
    hits = set(re.findall(r'custom_call_target="([^"]+)"', text))
    if hits:
        raise RuntimeError(f"{name}: HLO contains custom-calls {hits}; "
                           "these cannot run on the standalone PJRT client")


def lower_all(out_dir: str) -> dict:
    manifest = {
        "max_dim": model.MAX_DIM,
        "m_cand": model.M_CAND,
        # Schema tag checked by the Rust loader: the f32[n,n] fit output /
        # acquire input is the Cholesky factor, not K^{-1}.
        "posterior": "chol",
        "n_variants": list(model.N_VARIANTS),
        "programs": {},
    }
    for n in model.N_VARIANTS:
        fit = jax.jit(model.gp_fit).lower(*model.fit_spec(n))
        fit_text = to_hlo_text(fit)
        check_no_custom_calls(fit_text, f"gp_fit_n{n}")
        fit_path = f"gp_fit_n{n}.hlo.txt"
        with open(os.path.join(out_dir, fit_path), "w") as f:
            f.write(fit_text)

        acq = jax.jit(model.gp_acquire).lower(*model.acquire_spec(n))
        acq_text = to_hlo_text(acq)
        check_no_custom_calls(acq_text, f"gp_acquire_n{n}")
        acq_path = f"gp_acquire_n{n}.hlo.txt"
        with open(os.path.join(out_dir, acq_path), "w") as f:
            f.write(acq_text)

        manifest["programs"][str(n)] = {"fit": fit_path, "acquire": acq_path}
        print(f"n={n}: wrote {fit_path} ({len(fit_text)} chars), "
              f"{acq_path} ({len(acq_text)} chars)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = lower_all(args.out_dir)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
