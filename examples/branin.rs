//! Fig. 3 workload: the modified mixed discrete-continuous Branin function
//! (Halstrup 2016) — continuous x1, integer x2, categorical branch.
//! Compares Mango's hallucination batch algorithm against the TPE
//! (Hyperopt-substitute) baseline on the same budget.
//!
//! Run: `cargo run --release --example branin`

use mango::exp::workloads;
use mango::prelude::*;

fn run(kind: OptimizerKind, batch: usize, seed: u64) -> anyhow::Result<f64> {
    let workload = workloads::by_name("mixed_branin").unwrap();
    let config = TunerConfig {
        batch_size: batch,
        num_iterations: 40,
        optimizer: kind,
        backend: SurrogateBackend::Pjrt,
        scheduler: SchedulerKind::Threaded,
        workers: batch,
        seed,
        ..Default::default()
    };
    let mut tuner = Tuner::new(workload.space.clone(), config);
    let obj = workload.objective.clone();
    Ok(tuner.minimize(move |cfg| obj(cfg))?.best_objective)
}

fn main() -> anyhow::Result<()> {
    let optimum = workloads::by_name("mixed_branin").unwrap().optimum.unwrap();
    println!("modified Branin: known optimum {optimum:.5}\n");
    println!("{:<28}{:>12}{:>12}", "strategy", "best found", "regret");
    for (label, kind, batch) in [
        ("mango serial", OptimizerKind::Hallucination, 1),
        ("mango parallel (k=5)", OptimizerKind::Hallucination, 5),
        ("tpe serial", OptimizerKind::Tpe, 1),
        ("tpe parallel (k=5)", OptimizerKind::Tpe, 5),
        ("random", OptimizerKind::Random, 5),
    ] {
        // Average over 3 seeds for a stable quick demo.
        let mut sum = 0.0;
        for seed in [1, 2, 3] {
            sum += run(kind, batch, seed)?;
        }
        let best = sum / 3.0;
        println!("{label:<28}{best:>12.5}{:>12.5}", best - optimum);
    }
    println!("\n(Full 10-repeat figure: `cargo bench --bench fig3_branin`)");
    Ok(())
}
