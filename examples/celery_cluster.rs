//! End-to-end driver (the repo's full-stack validation): the paper's
//! Listing 4 deployment — a Celery-like distributed cluster with stragglers
//! and crashing workers — tuning a kNN classifier on wine
//! (`KNN_Celery.ipynb` analogue).
//!
//! Exercises every layer at once: L3 coordinator (batch optimizer +
//! fault-tolerant scheduler, partial `(evals, params)` results), L2/L1 GP
//! surrogate through PJRT (AOT JAX + Pallas artifacts), and the ML
//! substrate as the objective. Reports the accuracy curve, task-level
//! fault statistics and scheduler latency.
//!
//! Run: `cargo run --release --example celery_cluster`
#![allow(clippy::disallowed_methods)] // example wall-timing is clock-permitted (lint rule R1)

use mango::exp::workloads;
use mango::prelude::*;
use mango::scheduler::celery::{CelerySimConfig, CelerySimScheduler};
use mango::scheduler::Scheduler;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let workload = workloads::by_name("knn_wine").unwrap();

    // An 8-worker "cluster" with realistic failure modes.
    let cluster = CelerySimConfig {
        workers: 8,
        base_latency_ms: 3.0,
        straggler_prob: 0.10,
        straggler_factor: 10.0,
        crash_prob: 0.08,
        result_timeout: Duration::from_millis(500),
    };
    println!(
        "cluster: {} workers, {:.0}% crash, {:.0}% stragglers x{:.0}, timeout {:?}",
        cluster.workers,
        cluster.crash_prob * 100.0,
        cluster.straggler_prob * 100.0,
        cluster.straggler_factor,
        cluster.result_timeout
    );

    let mut scheduler = CelerySimScheduler::new(cluster, 99);
    let config = TunerConfig {
        batch_size: 8,
        num_iterations: 25,
        optimizer: OptimizerKind::Clustering,
        backend: SurrogateBackend::Pjrt,
        seed: 5,
        ..Default::default()
    };
    let mut tuner = Tuner::new(workload.space.clone(), config).with_callback(|rec| {
        println!(
            "batch {:>2}: {}/{} results arrived, best accuracy {:.4} ({:.0} ms)",
            rec.iteration + 1,
            rec.returned,
            rec.proposed,
            rec.best_so_far,
            rec.wall_ms
        );
    });

    let obj = workload.objective.clone();
    let t0 = std::time::Instant::now();
    let result = tuner.maximize_batch(|batch| scheduler.evaluate(&|c| obj(c), batch))?;
    let wall = t0.elapsed().as_secs_f64();

    let s = &scheduler.stats;
    println!("\n=== run summary ===");
    println!("best CV accuracy: {:.4}", result.best_objective);
    println!("best params:      {}", result.best_params);
    println!(
        "tasks: {} submitted, {} completed, {} crashed, {} straggled, {} timed out",
        s.submitted, s.completed, s.crashed, s.straggled, s.timed_out
    );
    println!(
        "fault tolerance: optimizer consumed {} partial results ({:.1}% loss) and still converged",
        result.evaluations,
        100.0 * (1.0 - result.evaluations as f64 / s.submitted as f64)
    );
    println!(
        "throughput: {:.1} evaluations/s over {:.1}s wall",
        result.evaluations as f64 / wall,
        wall
    );
    let mean_batch_ms: f64 = result.iterations.iter().map(|r| r.wall_ms).sum::<f64>()
        / result.iterations.len() as f64;
    println!("mean batch latency: {mean_batch_ms:.0} ms");
    Ok(())
}
