//! Quickstart: the paper's Listing 2 / `SVM_Example.ipynb` — tune an
//! RBF-SVM's (C, gamma) on the wine dataset with the default serial
//! scheduler and the PJRT (AOT JAX+Pallas) surrogate.
//!
//! Run: `cargo run --release --example quickstart`

use mango::ml::cv::cross_val_accuracy;
use mango::ml::svm::SvmClassifier;
use mango::ml::wine::default_wine;
use mango::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. Hyperparameter search space (Listing 2: uniform C, loguniform gamma).
    let space = SearchSpace::builder()
        .uniform("c", 0.01, 100.0)
        .loguniform("gamma", 1e-4, 1e3)
        .build();

    // 2. Objective: 3-fold CV accuracy on wine (fixed folds across configs).
    let data = default_wine();
    let objective = move |cfg: &Config| {
        let svm = SvmClassifier::from_config(cfg);
        let (c, g) = (svm.c, svm.gamma);
        Some(cross_val_accuracy(&data, 3, 1234, move || SvmClassifier::new(c, g)))
    };

    // 3. Tuner: 30 iterations of serial GP-UCB through the AOT artifacts.
    let config = TunerConfig {
        num_iterations: 30,
        optimizer: OptimizerKind::Hallucination,
        backend: SurrogateBackend::Pjrt,
        seed: 7,
        ..Default::default()
    };
    let mut tuner = Tuner::new(space, config).with_callback(|rec| {
        println!(
            "iter {:>2}: best CV accuracy so far = {:.4} ({:.0} ms)",
            rec.iteration + 1,
            rec.best_so_far,
            rec.wall_ms
        );
    });
    let result = tuner.maximize(objective)?;

    println!("\nbest accuracy: {:.4}", result.best_objective);
    println!("best params:   {}", result.best_params);
    println!("evaluations:   {}", result.evaluations);
    Ok(())
}
