//! Fig. 2 workload, single run: tune the GBT classifier (XGBoost
//! substitute) on wine over the paper's Listing 1 space, parallel batch of
//! 5 on the threaded scheduler.
//!
//! Run: `cargo run --release --example wine_gbt`

use mango::exp::workloads;
use mango::prelude::*;

fn main() -> anyhow::Result<()> {
    let workload = workloads::by_name("wine_gbt").expect("registered workload");
    println!(
        "search space: {} params, ~{:.0e} configurations (paper §1)",
        workload.space.len(),
        workload.space.cardinality_estimate()
    );

    let config = TunerConfig {
        batch_size: 5,
        num_iterations: 30,
        optimizer: OptimizerKind::Hallucination,
        scheduler: SchedulerKind::Threaded,
        workers: 5, // paper: max parallelism = batch size
        backend: SurrogateBackend::Pjrt,
        seed: 42,
        ..Default::default()
    };
    let mut tuner = Tuner::new(workload.space.clone(), config).with_callback(|rec| {
        if (rec.iteration + 1) % 5 == 0 {
            println!(
                "batch {:>2}: best CV accuracy = {:.4} ({} evals returned, {:.0} ms)",
                rec.iteration + 1,
                rec.best_so_far,
                rec.returned,
                rec.wall_ms
            );
        }
    });
    let obj = workload.objective.clone();
    let result = tuner.maximize(move |cfg| obj(cfg))?;

    println!("\nbest CV accuracy: {:.4}", result.best_objective);
    println!("best hyperparameters: {}", result.best_params);
    println!(
        "evaluations: {} over {} batches, wall {:.1}s",
        result.evaluations,
        result.iterations.len(),
        result.wall_ms / 1e3
    );
    Ok(())
}
