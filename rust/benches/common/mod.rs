//! Shared helpers for the figure/ablation bench harnesses.
#![allow(dead_code)] // shared across benches; each uses a subset
#![allow(clippy::disallowed_methods)] // bench timing is clock-permitted (lint rule R1)
//!
//! Env knobs (keep default runs fast; the paper-scale settings are noted in
//! EXPERIMENTS.md):
//!   MANGO_REPEATS  — trials per strategy (figures: paper uses 20 / 10)
//!   MANGO_ITERS    — optimizer iterations per trial
//!   MANGO_BACKEND  — pjrt | native

use mango::coordinator::TunerConfig;
use mango::exp::harness::{print_series, print_summary_row, run_trials, TrialSeries};
use mango::exp::workloads::Workload;
use mango::optimizer::{OptimizerKind, SurrogateBackend};
use mango::scheduler::SchedulerKind;

pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn backend() -> SurrogateBackend {
    match std::env::var("MANGO_BACKEND").as_deref() {
        Ok("native") => SurrogateBackend::Native,
        _ => SurrogateBackend::Pjrt,
    }
}

/// A named strategy row in a figure.
pub struct Strategy {
    pub label: &'static str,
    pub optimizer: OptimizerKind,
    pub batch_size: usize,
}

pub fn base_config(iters: usize, strategy: &Strategy) -> TunerConfig {
    TunerConfig {
        batch_size: strategy.batch_size,
        num_iterations: iters,
        optimizer: strategy.optimizer,
        backend: backend(),
        // Parallel batches use the threaded scheduler (paper: parallelism =
        // batch size); serial uses the serial scheduler.
        scheduler: if strategy.batch_size > 1 {
            SchedulerKind::Threaded
        } else {
            SchedulerKind::Serial
        },
        workers: strategy.batch_size,
        seed: 10_000,
        ..Default::default()
    }
}

/// Run every strategy and print both the CSV series and a summary table.
pub fn run_figure(
    figure: &str,
    workload: &Workload,
    strategies: &[Strategy],
    iters: usize,
    repeats: usize,
    checkpoints: &[usize],
) -> Vec<TrialSeries> {
    eprintln!(
        "[{figure}] workload={} iters={iters} repeats={repeats} backend={:?}",
        workload.name,
        backend()
    );
    println!("# {figure}: label,iteration,mean,std  ({repeats} trials)");
    let mut all = Vec::new();
    for s in strategies {
        let cfg = base_config(iters, s);
        let t = std::time::Instant::now();
        let series = run_trials(workload, &cfg, repeats, s.label).expect("trial run");
        eprintln!(
            "[{figure}] {}: {:.1}s total",
            s.label,
            t.elapsed().as_secs_f64()
        );
        print_series(&series);
        all.push(series);
    }
    println!("\n# summary: best-so-far at iterations {checkpoints:?}");
    for s in &all {
        print_summary_row(s, checkpoints);
    }
    all
}
