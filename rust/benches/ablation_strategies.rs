//! Ablation A4 (DESIGN.md): all four optimizers across the benchmark
//! function family at batch size 5 — which batch strategy wins where
//! (smooth vs rugged vs mixed-type landscapes)?
//!
//! Run: `cargo bench --bench ablation_strategies`

mod common;

use common::{env_usize, run_figure, Strategy};
use mango::exp::workloads;
use mango::optimizer::OptimizerKind;

fn main() {
    let iters = env_usize("MANGO_ITERS", 25);
    let repeats = env_usize("MANGO_REPEATS", 5);
    let strategies = [
        Strategy { label: "random k=5", optimizer: OptimizerKind::Random, batch_size: 5 },
        Strategy { label: "tpe k=5", optimizer: OptimizerKind::Tpe, batch_size: 5 },
        Strategy {
            label: "hallucination k=5",
            optimizer: OptimizerKind::Hallucination,
            batch_size: 5,
        },
        Strategy { label: "clustering k=5", optimizer: OptimizerKind::Clustering, batch_size: 5 },
    ];
    for name in ["branin", "mixed_branin", "cat_branin", "rosenbrock", "ackley", "hartmann6"] {
        let workload = workloads::by_name(name).unwrap();
        println!("\n## {name}");
        run_figure(
            &format!("ablation_strategies/{name}"),
            &workload,
            &strategies,
            iters,
            repeats,
            &[10, iters],
        );
    }
}
