//! Fig. 3 reproduction: the modified mixed discrete-continuous Branin
//! function (Halstrup 2016). Paper setup: serial and parallel regimes,
//! hallucination algorithm only for Mango, averaged over MANGO_REPEATS
//! trials (paper: 10).
//!
//! Run: `cargo bench --bench fig3_branin`
//! Paper scale: `MANGO_REPEATS=10 MANGO_ITERS=50 cargo bench --bench fig3_branin`

mod common;

use common::{env_usize, run_figure, Strategy};
use mango::exp::workloads;
use mango::optimizer::OptimizerKind;

fn main() {
    let iters = env_usize("MANGO_ITERS", 50);
    let repeats = env_usize("MANGO_REPEATS", 10);
    let workload = workloads::by_name("mixed_branin").unwrap();
    let strategies = [
        Strategy { label: "random", optimizer: OptimizerKind::Random, batch_size: 1 },
        Strategy { label: "hyperopt(tpe) serial", optimizer: OptimizerKind::Tpe, batch_size: 1 },
        Strategy {
            label: "mango serial",
            optimizer: OptimizerKind::Hallucination,
            batch_size: 1,
        },
        Strategy {
            label: "hyperopt(tpe) parallel k=5",
            optimizer: OptimizerKind::Tpe,
            batch_size: 5,
        },
        Strategy {
            label: "mango hallucination k=5",
            optimizer: OptimizerKind::Hallucination,
            batch_size: 5,
        },
    ];
    let checkpoints = [10, 20, 30, iters];
    let all = run_figure("fig3", &workload, &strategies, iters, repeats, &checkpoints);
    let optimum = workload.optimum.unwrap();
    println!("\n# regret vs known optimum {optimum:.5} at final iteration");
    for s in &all {
        let last = s.mean.last().copied().unwrap_or(f64::NAN);
        println!("{:<28} {:.5}", s.label, last - optimum);
    }
}
