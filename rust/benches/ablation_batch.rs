//! Ablation A1 (DESIGN.md): batch-size sweep for both Mango parallel
//! algorithms on the mixed Branin — how much does per-batch information
//! lag cost, and do k evaluations per batch still beat k serial ones on
//! wall-clock-per-improvement?
//!
//! Run: `cargo bench --bench ablation_batch`

mod common;

use common::{env_usize, run_figure, Strategy};
use mango::exp::workloads;
use mango::optimizer::OptimizerKind;

fn main() {
    let iters = env_usize("MANGO_ITERS", 30);
    let repeats = env_usize("MANGO_REPEATS", 5);
    let workload = workloads::by_name("mixed_branin").unwrap();
    let strategies = [
        Strategy { label: "hallucination k=1", optimizer: OptimizerKind::Hallucination, batch_size: 1 },
        Strategy { label: "hallucination k=2", optimizer: OptimizerKind::Hallucination, batch_size: 2 },
        Strategy { label: "hallucination k=5", optimizer: OptimizerKind::Hallucination, batch_size: 5 },
        Strategy { label: "hallucination k=10", optimizer: OptimizerKind::Hallucination, batch_size: 10 },
        Strategy { label: "clustering k=2", optimizer: OptimizerKind::Clustering, batch_size: 2 },
        Strategy { label: "clustering k=5", optimizer: OptimizerKind::Clustering, batch_size: 5 },
        Strategy { label: "clustering k=10", optimizer: OptimizerKind::Clustering, batch_size: 10 },
    ];
    let checkpoints = [5, 10, 20, iters];
    let all = run_figure("ablation_batch", &workload, &strategies, iters, repeats, &checkpoints);
    println!("\n# sample-efficiency: best-so-far per *evaluation* budget of 30");
    for s in &all {
        // iteration index whose cumulative evaluations first reach 30
        let k: usize = s.label.rsplit('=').next().unwrap().parse().unwrap();
        let idx = (30 / k).min(s.mean.len()).saturating_sub(1);
        println!("{:<22} {:.5}", s.label, s.mean[idx]);
    }
}
