//! Event-loop vs batch-barrier coordination under the Celery simulator's
//! straggler/crash fault model (ISSUE 1 acceptance benchmark).
//!
//! Same proposal budget (`iters x batch`), same 8-worker simulated cluster
//! with `straggler_prob = 0.3, straggler_factor = 8`:
//! * `mode = "sync"` — one barrier per batch: every straggler idles the
//!   other 7 workers until the batch (or the result timeout) ends.
//! * `mode = "async"` — the event loop refills the in-flight window as
//!   results trickle in, and retries crashed/timed-out tasks.
//!
//! A second workload measures trial-level pruning: a staged objective
//! (8 simulated epochs per trial, each costing wall-clock) under
//! `--pruner none` vs `median` vs `asha`, reporting the epochs of work
//! saved (in whole-evaluation units) and the best-found delta. Results
//! land in `BENCH_async_pruning.json`.
//!
//! Run: `cargo bench --bench async_vs_sync`
//! Knobs: MANGO_ITERS (8), MANGO_BATCH (8), MANGO_REPEATS (3),
//!        MANGO_TRIALS (24, pruning workload budget)
#![allow(clippy::disallowed_methods)] // bench timing is clock-permitted (lint rule R1)

use mango::coordinator::{ExecutionMode, Tuner, TunerConfig};
use mango::exp::workloads;
use mango::optimizer::prune::PrunerKind;
use mango::optimizer::{OptimizerKind, SurrogateBackend};
use mango::scheduler::celery::CelerySimConfig;
use mango::scheduler::SchedulerKind;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

struct Row {
    label: &'static str,
    wall_ms: f64,
    evals: f64,
    utilization: f64,
    queue_wait_ms: f64,
    retried: f64,
    lost: f64,
    best: f64,
}

fn run_mode(mode: ExecutionMode, iters: usize, batch: usize, repeats: usize) -> Row {
    let workload = workloads::by_name("branin").expect("branin workload");
    let workers = 8;
    let cluster = CelerySimConfig {
        workers,
        base_latency_ms: 20.0,
        straggler_prob: 0.3,
        straggler_factor: 8.0,
        crash_prob: 0.05,
        result_timeout: Duration::from_secs(2),
    };
    let (mut wall, mut evals, mut util, mut qwait, mut retried, mut lost, mut best) =
        (0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0);
    for r in 0..repeats {
        let cfg = TunerConfig {
            batch_size: batch,
            num_iterations: iters,
            optimizer: OptimizerKind::Hallucination,
            scheduler: SchedulerKind::Celery,
            workers,
            backend: SurrogateBackend::Native,
            seed: 1000 + r as u64,
            mode,
            celery: Some(cluster.clone()),
            ..Default::default()
        };
        let mut tuner = Tuner::new(workload.space.clone(), cfg);
        let obj = workload.objective.clone();
        let t = Instant::now();
        let result = tuner.minimize(move |c| obj(c)).expect("tuning run");
        wall += t.elapsed().as_secs_f64() * 1e3;
        evals += result.evaluations as f64;
        util += result.utilization(workers);
        if !result.completions.is_empty() {
            qwait += result.completions.iter().map(|c| c.queue_wait_ms).sum::<f64>()
                / result.completions.len() as f64;
        }
        retried += result.retried as f64;
        lost += result.lost as f64;
        best += result.best_objective;
    }
    let n = repeats as f64;
    Row {
        label: match mode {
            ExecutionMode::Sync => "sync (batch barrier)",
            ExecutionMode::Async => "async (event loop)",
        },
        wall_ms: wall / n,
        evals: evals / n,
        utilization: util / n,
        queue_wait_ms: qwait / n,
        retried: retried / n,
        lost: lost / n,
        best: best / n,
    }
}

/// Epochs per trial in the staged pruning workload.
const PRUNE_STEPS: u64 = 8;

struct PruneRow {
    label: &'static str,
    wall_ms: f64,
    evals: f64,
    pruned: f64,
    /// Epochs actually executed across the run (<= trials * PRUNE_STEPS).
    steps: f64,
    best: f64,
}

/// Staged-objective pruning workload: branin split into `PRUNE_STEPS`
/// simulated epochs (each costing real wall-clock), values ramping toward
/// the final objective so partial rankings track full rankings. Serial
/// async with window 1 — decisions are deterministic, so rows differ only
/// by pruner.
fn run_pruned(pruner: PrunerKind, label: &'static str, trials: usize, repeats: usize) -> PruneRow {
    let workload = workloads::by_name("branin").expect("branin workload");
    let step_cost = Duration::from_micros(500);
    let (mut wall, mut evals, mut pruned, mut steps, mut best) = (0.0, 0.0, 0.0, 0.0, 0.0);
    for r in 0..repeats {
        let cfg = TunerConfig {
            batch_size: 1,
            num_iterations: trials,
            optimizer: OptimizerKind::Hallucination,
            scheduler: SchedulerKind::Serial,
            workers: 1,
            backend: SurrogateBackend::Native,
            seed: 2000 + r as u64,
            mode: ExecutionMode::Async,
            async_window: 1,
            pruner,
            pruner_warmup: 2,
            asha_reduction: 2.0,
            ..Default::default()
        };
        let mut tuner = Tuner::new(workload.space.clone(), cfg);
        let obj = workload.objective.clone();
        let steps_run = AtomicU64::new(0);
        let t = Instant::now();
        let result = tuner
            .minimize_with_reports(|c, reporter| {
                let full = obj(c)?;
                for step in 0..PRUNE_STEPS {
                    std::thread::sleep(step_cost); // one simulated epoch
                    steps_run.fetch_add(1, Ordering::Relaxed);
                    let v = full * ((step + 1) as f64) / PRUNE_STEPS as f64;
                    if !reporter.report(step, v) {
                        return Some(v); // pruned: stop paying for epochs
                    }
                }
                Some(full)
            })
            .expect("pruning run");
        wall += t.elapsed().as_secs_f64() * 1e3;
        evals += result.evaluations as f64;
        pruned += result.pruned as f64;
        steps += steps_run.load(Ordering::Relaxed) as f64;
        best += result.best_objective;
    }
    let n = repeats as f64;
    PruneRow {
        label,
        wall_ms: wall / n,
        evals: evals / n,
        pruned: pruned / n,
        steps: steps / n,
        best: best / n,
    }
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Record the pruning rows (committed file starts as a flagged
/// placeholder; running the bench overwrites it with honest numbers).
fn write_pruning_json(rows: &[PruneRow], trials: usize) {
    let budget_steps = (trials as u64 * PRUNE_STEPS) as f64;
    let baseline_steps = rows[0].steps;
    let mut out = String::from("{\n  \"bench\": \"async_pruning\",\n");
    out.push_str(&format!("  \"trials\": {trials},\n  \"steps_per_trial\": {PRUNE_STEPS},\n"));
    out.push_str(&format!("  \"budget_steps\": {budget_steps},\n"));
    for r in rows {
        let key = if r.label == "none" { "none".to_string() } else { r.label.to_string() };
        out.push_str(&format!(
            "  \"{key}\": {{ \"wall_ms\": {}, \"steps\": {}, \"pruned\": {}, \
             \"evals_of_work_saved\": {}, \"best\": {} }},\n",
            json_num(r.wall_ms),
            json_num(r.steps),
            json_num(r.pruned),
            json_num((baseline_steps - r.steps) / PRUNE_STEPS as f64),
            json_num(r.best)
        ));
    }
    out.push_str("  \"note\": \"written by `cargo bench --bench async_vs_sync`\"\n}\n");
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_async_pruning.json");
    if let Err(e) = std::fs::write(&path, out) {
        eprintln!("[async_vs_sync] could not write {}: {e}", path.display());
    }
}

fn main() {
    let iters = env_usize("MANGO_ITERS", 8);
    let batch = env_usize("MANGO_BATCH", 8);
    let repeats = env_usize("MANGO_REPEATS", 3);
    eprintln!(
        "[async_vs_sync] branin, budget {} evals ({iters}x{batch}), 8 workers, \
         straggler_prob 0.3 x8, crash_prob 0.05, {repeats} repeats"
    );
    println!(
        "{:<22} {:>10} {:>8} {:>6} {:>11} {:>8} {:>6} {:>10}",
        "mode", "wall_ms", "evals", "util", "queue_ms", "retried", "lost", "best"
    );
    let rows = [
        run_mode(ExecutionMode::Sync, iters, batch, repeats),
        run_mode(ExecutionMode::Async, iters, batch, repeats),
    ];
    for r in &rows {
        println!(
            "{:<22} {:>10.0} {:>8.1} {:>6.2} {:>11.1} {:>8.1} {:>6.1} {:>10.4}",
            r.label, r.wall_ms, r.evals, r.utilization, r.queue_wait_ms, r.retried, r.lost,
            r.best
        );
    }
    let speedup = rows[0].wall_ms / rows[1].wall_ms.max(1e-9);
    println!("\n# async speedup over sync barrier: {speedup:.2}x wall-clock");
    println!(
        "# async completed {:.1} of {} budgeted evals (sync: {:.1} — losses are silent drops)",
        rows[1].evals,
        iters * batch,
        rows[0].evals
    );

    // ---- trial-level pruning: epochs of work saved vs `--pruner none` ----
    let trials = env_usize("MANGO_TRIALS", 24);
    eprintln!(
        "\n[async_vs_sync] staged branin, {trials} trials x {PRUNE_STEPS} epochs, \
         serial async, {repeats} repeats"
    );
    let prune_rows = [
        run_pruned(PrunerKind::None, "none", trials, repeats),
        run_pruned(PrunerKind::Median, "median", trials, repeats),
        run_pruned(PrunerKind::Asha, "asha", trials, repeats),
    ];
    println!(
        "\n{:<8} {:>10} {:>8} {:>8} {:>8} {:>12} {:>10}",
        "pruner", "wall_ms", "evals", "pruned", "epochs", "evals_saved", "best"
    );
    let baseline_steps = prune_rows[0].steps;
    for r in &prune_rows {
        println!(
            "{:<8} {:>10.0} {:>8.1} {:>8.1} {:>8.1} {:>12.2} {:>10.4}",
            r.label,
            r.wall_ms,
            r.evals,
            r.pruned,
            r.steps,
            (baseline_steps - r.steps) / PRUNE_STEPS as f64,
            r.best
        );
    }
    write_pruning_json(&prune_rows, trials);
}
