//! Event-loop vs batch-barrier coordination under the Celery simulator's
//! straggler/crash fault model (ISSUE 1 acceptance benchmark).
//!
//! Same proposal budget (`iters x batch`), same 8-worker simulated cluster
//! with `straggler_prob = 0.3, straggler_factor = 8`:
//! * `mode = "sync"` — one barrier per batch: every straggler idles the
//!   other 7 workers until the batch (or the result timeout) ends.
//! * `mode = "async"` — the event loop refills the in-flight window as
//!   results trickle in, and retries crashed/timed-out tasks.
//!
//! Run: `cargo bench --bench async_vs_sync`
//! Knobs: MANGO_ITERS (8), MANGO_BATCH (8), MANGO_REPEATS (3)
#![allow(clippy::disallowed_methods)] // bench timing is clock-permitted (lint rule R1)

use mango::coordinator::{ExecutionMode, Tuner, TunerConfig};
use mango::exp::workloads;
use mango::optimizer::{OptimizerKind, SurrogateBackend};
use mango::scheduler::celery::CelerySimConfig;
use mango::scheduler::SchedulerKind;
use std::time::{Duration, Instant};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

struct Row {
    label: &'static str,
    wall_ms: f64,
    evals: f64,
    utilization: f64,
    queue_wait_ms: f64,
    retried: f64,
    lost: f64,
    best: f64,
}

fn run_mode(mode: ExecutionMode, iters: usize, batch: usize, repeats: usize) -> Row {
    let workload = workloads::by_name("branin").expect("branin workload");
    let workers = 8;
    let cluster = CelerySimConfig {
        workers,
        base_latency_ms: 20.0,
        straggler_prob: 0.3,
        straggler_factor: 8.0,
        crash_prob: 0.05,
        result_timeout: Duration::from_secs(2),
    };
    let (mut wall, mut evals, mut util, mut qwait, mut retried, mut lost, mut best) =
        (0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0);
    for r in 0..repeats {
        let cfg = TunerConfig {
            batch_size: batch,
            num_iterations: iters,
            optimizer: OptimizerKind::Hallucination,
            scheduler: SchedulerKind::Celery,
            workers,
            backend: SurrogateBackend::Native,
            seed: 1000 + r as u64,
            mode,
            celery: Some(cluster.clone()),
            ..Default::default()
        };
        let mut tuner = Tuner::new(workload.space.clone(), cfg);
        let obj = workload.objective.clone();
        let t = Instant::now();
        let result = tuner.minimize(move |c| obj(c)).expect("tuning run");
        wall += t.elapsed().as_secs_f64() * 1e3;
        evals += result.evaluations as f64;
        util += result.utilization(workers);
        if !result.completions.is_empty() {
            qwait += result.completions.iter().map(|c| c.queue_wait_ms).sum::<f64>()
                / result.completions.len() as f64;
        }
        retried += result.retried as f64;
        lost += result.lost as f64;
        best += result.best_objective;
    }
    let n = repeats as f64;
    Row {
        label: match mode {
            ExecutionMode::Sync => "sync (batch barrier)",
            ExecutionMode::Async => "async (event loop)",
        },
        wall_ms: wall / n,
        evals: evals / n,
        utilization: util / n,
        queue_wait_ms: qwait / n,
        retried: retried / n,
        lost: lost / n,
        best: best / n,
    }
}

fn main() {
    let iters = env_usize("MANGO_ITERS", 8);
    let batch = env_usize("MANGO_BATCH", 8);
    let repeats = env_usize("MANGO_REPEATS", 3);
    eprintln!(
        "[async_vs_sync] branin, budget {} evals ({iters}x{batch}), 8 workers, \
         straggler_prob 0.3 x8, crash_prob 0.05, {repeats} repeats"
    );
    println!(
        "{:<22} {:>10} {:>8} {:>6} {:>11} {:>8} {:>6} {:>10}",
        "mode", "wall_ms", "evals", "util", "queue_ms", "retried", "lost", "best"
    );
    let rows = [
        run_mode(ExecutionMode::Sync, iters, batch, repeats),
        run_mode(ExecutionMode::Async, iters, batch, repeats),
    ];
    for r in &rows {
        println!(
            "{:<22} {:>10.0} {:>8.1} {:>6.2} {:>11.1} {:>8.1} {:>6.1} {:>10.4}",
            r.label, r.wall_ms, r.evals, r.utilization, r.queue_wait_ms, r.retried, r.lost,
            r.best
        );
    }
    let speedup = rows[0].wall_ms / rows[1].wall_ms.max(1e-9);
    println!("\n# async speedup over sync barrier: {speedup:.2}x wall-clock");
    println!(
        "# async completed {:.1} of {} budgeted evals (sync: {:.1} — losses are silent drops)",
        rows[1].evals,
        iters * batch,
        rows[0].evals
    );
}
