//! Ablation A2 (DESIGN.md): Monte-Carlo acquisition sample count — the
//! paper's heuristic (a function of #params and space complexity,
//! user-overridable) vs fixed sizes. Too few samples miss the acquisition
//! optimum; past a few thousand the curves saturate, which is what makes
//! the heuristic safe.
//!
//! Run: `cargo bench --bench ablation_mc`

mod common;

use common::{backend, env_usize};
use mango::coordinator::TunerConfig;
use mango::exp::harness::{print_series, print_summary_row, run_trials};
use mango::exp::workloads;
use mango::optimizer::OptimizerKind;

fn main() {
    let iters = env_usize("MANGO_ITERS", 25);
    let repeats = env_usize("MANGO_REPEATS", 5);
    for workload_name in ["branin", "hartmann6"] {
        let workload = workloads::by_name(workload_name).unwrap();
        println!(
            "# ablation_mc on {workload_name} (heuristic = {} samples): label,iteration,mean,std",
            workload.space.mc_samples_heuristic()
        );
        let mut all = Vec::new();
        for &(label, mc) in &[
            ("mc=64", 64usize),
            ("mc=256", 256),
            ("mc=1024", 1024),
            ("mc=heuristic", 0),
            ("mc=8192", 8192),
        ] {
            let cfg = TunerConfig {
                batch_size: 1,
                num_iterations: iters,
                optimizer: OptimizerKind::Hallucination,
                backend: backend(),
                mc_samples: mc,
                seed: 7_000,
                ..Default::default()
            };
            let label_full = format!("{workload_name}/{label}");
            let series = run_trials(&workload, &cfg, repeats, &label_full).expect("trials");
            print_series(&series);
            all.push(series);
        }
        println!("\n# summary at iterations [10, {iters}] (+ mean wall/trial)");
        for s in &all {
            print_summary_row(s, &[10, iters]);
        }
        println!();
    }
}
