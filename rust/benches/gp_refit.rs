//! gp_refit: incremental (rank-1 Cholesky append) vs from-scratch posterior
//! refits — the tentpole claim: at n=256 history with one new observation
//! arriving per scheduling round, the incremental path must be >= 5x
//! faster than refitting from scratch.
//!
//! Also times a k=4 append round (async event loops fold several
//! completions per poll) and cross-checks that the incremental factor
//! agrees with the scratch factor before trusting any timing.
//!
//! Run: `cargo bench --bench gp_refit`. Writes `BENCH_gp_refit.json` at the
//! repo root (overwriting the committed placeholder).

use mango::exp::benchkit::bench;
use mango::gp::{normalize_y, GpParams, NativeGp, Surrogate};
use mango::linalg::Matrix;
use mango::util::rng::Pcg64;

const N: usize = 256;
const D: usize = 7;

fn main() {
    let mut rng = Pcg64::new(7);
    let x = Matrix::from_fn(N, D, |_, _| rng.next_f64());
    let y_raw: Vec<f64> = (0..N)
        .map(|i| (9.0 * x.row(i)[0]).sin() + 0.2 * x.row(i)[1])
        .collect();
    let (y, _, _) = normalize_y(&y_raw);
    let params = GpParams::new(D);
    let mut gp = NativeGp;

    // Warm states over the first N-1 / N-4 observations: each timed
    // incremental round appends the remaining observations, which is the
    // per-round surrogate cost at event-loop steady state.
    let x_prev1 = Matrix::from_fn(N - 1, D, |i, j| x[(i, j)]);
    let (_, warm1) = gp
        .fit_incremental(&x_prev1, &y[..N - 1], &params, None)
        .expect("warm fit (k=1)");
    let x_prev4 = Matrix::from_fn(N - 4, D, |i, j| x[(i, j)]);
    let (_, warm4) = gp
        .fit_incremental(&x_prev4, &y[..N - 4], &params, None)
        .expect("warm fit (k=4)");

    // Correctness cross-check before trusting the timing.
    let scratch_fit = gp.fit(&x, &y, &params).unwrap();
    let (inc_fit, _) = gp
        .fit_incremental(&x, &y, &params, Some(warm1.clone()))
        .unwrap();
    let max_dev = scratch_fit
        .alpha
        .iter()
        .zip(&inc_fit.alpha)
        .map(|(p, q)| (p - q).abs())
        .fold(0.0f64, f64::max)
        .max(scratch_fit.chol.max_abs_diff(&inc_fit.chol));
    assert!(max_dev < 1e-8, "incremental deviates from scratch: {max_dev}");

    let scratch = bench(&format!("scratch fit n={N}"), 2, 25, || {
        std::hint::black_box(gp.fit(&x, &y, &params).unwrap());
    });
    // The state is moved in production; the per-iteration clone here is
    // charged to the incremental side (conservative).
    let inc1 = bench(&format!("incremental fit {}->{N} (1 append)", N - 1), 2, 25, || {
        let st = warm1.clone();
        std::hint::black_box(gp.fit_incremental(&x, &y, &params, Some(st)).unwrap());
    });
    let inc4 = bench(&format!("incremental fit {}->{N} (4 appends)", N - 4), 2, 25, || {
        let st = warm4.clone();
        std::hint::black_box(gp.fit_incremental(&x, &y, &params, Some(st)).unwrap());
    });

    let speedup1 = scratch.mean_us / inc1.mean_us.max(1e-9);
    let speedup4 = scratch.mean_us / inc4.mean_us.max(1e-9);
    println!("{}", scratch.row());
    println!("{}", inc1.row());
    println!("{}", inc4.row());
    println!("speedup (1 new obs/round): {speedup1:.1}x (target >= 5x at n={N})");
    println!("speedup (4 new obs/round): {speedup4:.1}x");

    let json = format!(
        "{{\n  \"bench\": \"gp_refit\",\n  \"n_history\": {N},\n  \"dims\": {D},\n  \
         \"scratch_fit_mean_us\": {:.1},\n  \"scratch_fit_p50_us\": {:.1},\n  \
         \"incremental_fit_1_append_mean_us\": {:.1},\n  \
         \"incremental_fit_1_append_p50_us\": {:.1},\n  \
         \"incremental_fit_4_appends_mean_us\": {:.1},\n  \
         \"speedup_1_append\": {:.2},\n  \"speedup_4_appends\": {:.2},\n  \
         \"target_speedup\": 5.0,\n  \"pass\": {},\n  \"max_abs_deviation\": {:e}\n}}\n",
        scratch.mean_us,
        scratch.p50_us,
        inc1.mean_us,
        inc1.p50_us,
        inc4.mean_us,
        speedup1,
        speedup4,
        speedup1 >= 5.0,
        max_dev,
    );
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_gp_refit.json");
    std::fs::write(out, &json).expect("write BENCH_gp_refit.json");
    println!("wrote {out}");
    assert!(
        speedup1 >= 5.0,
        "incremental refit speedup {speedup1:.1}x below the 5x target"
    );
}
