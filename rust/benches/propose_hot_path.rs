//! propose_hot_path: per-round propose-step latency — the tentpole claim
//! of the GEMM-ified surrogate hot path. Two measurements:
//!
//! 1. **Kernel build**: the GEMM-based `rbf_kernel` (squared-distance
//!    expansion + blocked `matmul_transb` + one elementwise `exp` pass)
//!    against the scalar per-pair baseline it replaced (kept here as the
//!    reference impl, out of the library hot path), with a correctness
//!    cross-check before any timing and a speedup assertion.
//! 2. **Full propose rounds**: `BayesianCore::fit_and_score` at cache
//!    steady state (the per-round cost the event loop pays) over
//!    n ∈ {64, 256} history rows, m ∈ {1k, 10k} MC candidates, and
//!    `proposal_threads` ∈ {1, 4}.
//!
//! Run: `cargo bench --bench propose_hot_path`. Writes `BENCH_propose.json`
//! at the repo root (overwriting the committed placeholder), mirroring the
//! `BENCH_gp_refit.json` format.

use mango::exp::benchkit::bench;
use mango::gp::kernel::{rbf_kernel, rbf_pair};
use mango::linalg::Matrix;
use mango::optimizer::bayesian::BayesianCore;
use mango::optimizer::{GpOptions, History};
use mango::space::SearchSpace;
use mango::util::rng::Pcg64;

const D: usize = 8;
/// Honest floor for the GEMM-vs-scalar kernel build: the elementwise exp
/// pass is common to both paths and bounds the attainable ratio; the madd
/// pipeline itself is several times faster.
const KERNEL_SPEEDUP_TARGET: f64 = 1.3;

/// Scalar reference: the element-wise closure the library used before the
/// GEMM path (one bounds-checked `rbf_pair` per entry). Kept in the bench
/// only — the `#[cfg(test)]`-style baseline the speedup is asserted against.
fn rbf_kernel_scalar(x: &Matrix, z: &Matrix, inv_ls: &[f64]) -> Matrix {
    Matrix::from_fn(x.rows(), z.rows(), |i, j| rbf_pair(x.row(i), z.row(j), inv_ls))
}

fn bench_space() -> SearchSpace {
    let mut b = SearchSpace::builder();
    for i in 0..D {
        b = b.uniform(&format!("x{i}"), 0.0, 1.0);
    }
    b.build()
}

fn bench_history(space: &SearchSpace, n: usize, seed: u64) -> History {
    let mut rng = Pcg64::new(seed);
    let mut h = History::new();
    for cfg in space.sample_n(&mut rng, n) {
        let v = (5.0 * cfg.get_f64("x0").unwrap()).sin() + 0.3 * cfg.get_f64("x1").unwrap();
        h.push(cfg, v);
    }
    h
}

fn main() {
    // ---- 1. kernel build: GEMM vs the scalar baseline ----
    let (kn, km) = (256usize, 10_000usize);
    let mut rng = Pcg64::new(11);
    let x = Matrix::from_fn(kn, D, |_, _| rng.next_f64());
    let xc = Matrix::from_fn(km, D, |_, _| rng.next_f64());
    let inv_ls = vec![1.0 / 0.3; D];

    // Correctness before timing: the GEMM path must match the oracle.
    let gemm = rbf_kernel(&x, &xc, &inv_ls);
    let scalar = rbf_kernel_scalar(&x, &xc, &inv_ls);
    let max_dev = gemm.max_abs_diff(&scalar);
    assert!(max_dev < 1e-12, "GEMM kernel deviates from the scalar oracle: {max_dev:e}");

    let t_scalar = bench(&format!("scalar rbf_kernel {kn}x{km}"), 1, 10, || {
        std::hint::black_box(rbf_kernel_scalar(&x, &xc, &inv_ls));
    });
    let t_gemm = bench(&format!("gemm   rbf_kernel {kn}x{km}"), 1, 10, || {
        std::hint::black_box(rbf_kernel(&x, &xc, &inv_ls));
    });
    let kernel_speedup = t_scalar.mean_us / t_gemm.mean_us.max(1e-9);
    println!("{}", t_scalar.row());
    println!("{}", t_gemm.row());
    println!("kernel speedup: {kernel_speedup:.2}x (target >= {KERNEL_SPEEDUP_TARGET}x)");

    // ---- 2. full propose rounds at cache steady state ----
    let space = bench_space();
    let mut round_rows = String::new();
    for n in [64usize, 256] {
        let history = bench_history(&space, n, n as u64);
        for m in [1_000usize, 10_000] {
            for threads in [1usize, 4] {
                let opts = GpOptions {
                    mc_samples: m,
                    proposal_threads: threads,
                    fixed_beta: Some(2.0),
                    ..Default::default()
                };
                let mut core =
                    BayesianCore::new(space.clone(), opts).expect("native core");
                let mut call_seed = 1000 + n as u64;
                let iters = if m >= 10_000 { 6 } else { 15 };
                let stats = bench(
                    &format!("fit_and_score n={n} m={m} threads={threads}"),
                    2,
                    iters,
                    || {
                        call_seed += 1;
                        let mut rng = Pcg64::new(call_seed);
                        std::hint::black_box(
                            core.fit_and_score(&history, 1, &mut rng).expect("fit_and_score"),
                        );
                    },
                );
                println!("{}", stats.row());
                if !round_rows.is_empty() {
                    round_rows.push_str(",\n");
                }
                round_rows.push_str(&format!(
                    "    {{\"n\": {n}, \"m\": {m}, \"threads\": {threads}, \
                     \"mean_us\": {:.1}, \"p50_us\": {:.1}}}",
                    stats.mean_us, stats.p50_us
                ));
            }
        }
    }

    let json = format!(
        "{{\n  \"bench\": \"propose_hot_path\",\n  \"dims\": {D},\n  \
         \"kernel\": {{\"n\": {kn}, \"m\": {km}, \"scalar_mean_us\": {:.1}, \
         \"gemm_mean_us\": {:.1}, \"speedup\": {:.2}, \
         \"target_speedup\": {KERNEL_SPEEDUP_TARGET}, \"pass\": {}, \
         \"max_abs_deviation\": {:e}}},\n  \"rounds\": [\n{}\n  ]\n}}\n",
        t_scalar.mean_us,
        t_gemm.mean_us,
        kernel_speedup,
        kernel_speedup >= KERNEL_SPEEDUP_TARGET,
        max_dev,
        round_rows,
    );
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_propose.json");
    std::fs::write(out, &json).expect("write BENCH_propose.json");
    println!("wrote {out}");
    assert!(
        kernel_speedup >= KERNEL_SPEEDUP_TARGET,
        "GEMM kernel speedup {kernel_speedup:.2}x below the {KERNEL_SPEEDUP_TARGET}x target"
    );
}
