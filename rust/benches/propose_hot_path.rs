//! propose_hot_path: per-round propose-step latency — the tentpole claim
//! of the GEMM-ified surrogate hot path. Two measurements:
//!
//! 1. **Kernel build**: the GEMM-based `rbf_kernel` (squared-distance
//!    expansion + blocked `matmul_transb` + one elementwise `exp` pass)
//!    against the scalar per-pair baseline it replaced (kept here as the
//!    reference impl, out of the library hot path), with a correctness
//!    cross-check before any timing and a speedup assertion.
//! 2. **Columnar candidate generation**: `SearchSpace::sample_columnar`
//!    against the legacy `sample_n` + `encode_batch` path it replaced, at
//!    m ∈ {10⁴, 10⁵} on a mixed (continuous/range/choice) space — the
//!    O(m·p) `String`/`Config` churn the columnar path eliminates — with a
//!    bit-identity cross-check before any timing.
//! 3. **Full propose rounds**: `BayesianCore::fit_and_score` at cache
//!    steady state (the per-round cost the event loop pays) over
//!    n ∈ {64, 256} history rows, m ∈ {1k, 10k} MC candidates, and
//!    `proposal_threads` ∈ {1, 4}.
//! 4. **Sharded scoring rounds**: the same propose step at m ∈ {10⁴, 10⁵}
//!    with `proposal_shards` ∈ {0 (local), 4 (threaded pool)} — the
//!    scheduler-sharded path the m ≥ 10⁵ regime uses.
//! 5. **Kernel profiles**: Exact vs Fast propose rounds at
//!    n ∈ {256, 1024} × m ∈ {10⁴, 10⁵} (tolerance cross-check before any
//!    timing), plus the distance-cache footprint per mode — dense f64 vs
//!    the tiled triangle at f64 and f32 element widths.
//!
//! Run: `cargo bench --bench propose_hot_path`. Writes `BENCH_propose.json`
//! at the repo root (overwriting the committed placeholder), mirroring the
//! `BENCH_gp_refit.json` format.

use mango::exp::benchkit::bench;
use mango::gp::kernel::{rbf_kernel, rbf_pair, sq_dist_from_parts};
use mango::gp::{KernelProfile, ShardExec};
use mango::linalg::{dot, dot_fast, Matrix};
use mango::optimizer::bayesian::{BayesianCore, TileElem, TiledDistCache};
use mango::optimizer::{GpOptions, History};
use mango::space::{Encoder, SearchSpace};
use mango::util::rng::Pcg64;

const D: usize = 8;
/// Honest floor for the GEMM-vs-scalar kernel build: the elementwise exp
/// pass is common to both paths and bounds the attainable ratio; the madd
/// pipeline itself is several times faster.
const KERNEL_SPEEDUP_TARGET: f64 = 1.3;
/// Fast-profile floor at the large-n regime (n = 1024, m = 1e5): the
/// chunked kernels + tiled cache must buy at least this much per round.
const FAST_SPEEDUP_TARGET: f64 = 1.5;
/// End-to-end Exact-vs-Fast tolerance: the kernel-level contract is 1e-10,
/// and one Cholesky solve over the perturbed Gram amplifies it by the
/// (noise-jittered) condition number — 1e-8 is the honest round-level
/// bound, the same one the integration tests assert.
const FAST_UCB_RTOL: f64 = 1e-8;

/// Scalar reference: the element-wise closure the library used before the
/// GEMM path (one bounds-checked `rbf_pair` per entry). Kept in the bench
/// only — the `#[cfg(test)]`-style baseline the speedup is asserted against.
fn rbf_kernel_scalar(x: &Matrix, z: &Matrix, inv_ls: &[f64]) -> Matrix {
    Matrix::from_fn(x.rows(), z.rows(), |i, j| rbf_pair(x.row(i), z.row(j), inv_ls))
}

fn bench_space() -> SearchSpace {
    let mut b = SearchSpace::builder();
    for i in 0..D {
        b = b.uniform(&format!("x{i}"), 0.0, 1.0);
    }
    b.build()
}

/// Mixed space for the generation bench: the legacy path's per-candidate
/// cost is dominated by `Config` allocation (one name `String` clone per
/// param) and, for choices, `ParamValue` clones — so the space mixes all
/// three param classes like the paper's XGBoost Listing 1.
fn gen_space() -> SearchSpace {
    let mut b = SearchSpace::builder();
    for i in 0..4 {
        b = b.uniform(&format!("u{i}"), 0.0, 1.0);
    }
    b = b.range("depth", 1, 32).range("estimators", 1, 300);
    b = b.choice("booster", &["gbtree", "gblinear", "dart"]);
    b = b.choice("growth", &["depthwise", "lossguide", "hist"]);
    b.build()
}

fn bench_history(space: &SearchSpace, n: usize, seed: u64) -> History {
    let mut rng = Pcg64::new(seed);
    let mut h = History::new();
    for cfg in space.sample_n(&mut rng, n) {
        let v = (5.0 * cfg.get_f64("x0").unwrap()).sin() + 0.3 * cfg.get_f64("x1").unwrap();
        h.push(cfg, v);
    }
    h
}

fn main() {
    // ---- 1. kernel build: GEMM vs the scalar baseline ----
    let (kn, km) = (256usize, 10_000usize);
    let mut rng = Pcg64::new(11);
    let x = Matrix::from_fn(kn, D, |_, _| rng.next_f64());
    let xc = Matrix::from_fn(km, D, |_, _| rng.next_f64());
    let inv_ls = vec![1.0 / 0.3; D];

    // Correctness before timing: the GEMM path must match the oracle.
    let gemm = rbf_kernel(&x, &xc, &inv_ls);
    let scalar = rbf_kernel_scalar(&x, &xc, &inv_ls);
    let max_dev = gemm.max_abs_diff(&scalar);
    assert!(max_dev < 1e-12, "GEMM kernel deviates from the scalar oracle: {max_dev:e}");

    let t_scalar = bench(&format!("scalar rbf_kernel {kn}x{km}"), 1, 10, || {
        std::hint::black_box(rbf_kernel_scalar(&x, &xc, &inv_ls));
    });
    let t_gemm = bench(&format!("gemm   rbf_kernel {kn}x{km}"), 1, 10, || {
        std::hint::black_box(rbf_kernel(&x, &xc, &inv_ls));
    });
    let kernel_speedup = t_scalar.mean_us / t_gemm.mean_us.max(1e-9);
    println!("{}", t_scalar.row());
    println!("{}", t_gemm.row());
    println!("kernel speedup: {kernel_speedup:.2}x (target >= {KERNEL_SPEEDUP_TARGET}x)");

    // ---- 2. columnar candidate generation vs the legacy Config path ----
    let gspace = gen_space();
    let genc = Encoder::new(&gspace);
    // Bit-identity cross-check before timing: same RNG stream, same
    // values, same encoded features.
    {
        let legacy = gspace.sample_n(&mut Pcg64::new(21), 2048);
        let legacy_enc = genc.encode_batch(&legacy);
        let set = gspace.sample_columnar(&mut Pcg64::new(21), 2048);
        assert_eq!(set.encoded(), legacy_enc.as_slice(), "columnar encoding deviates");
        for (i, want) in legacy.iter().enumerate() {
            assert_eq!(&set.config(i), want, "columnar candidate {i} deviates");
        }
    }
    let mut gen_rows = String::new();
    for m in [10_000usize, 100_000] {
        let iters = if m >= 100_000 { 5 } else { 12 };
        let mut seed = 400 + m as u64;
        let t_legacy = bench(&format!("legacy  sample_n+encode m={m}"), 1, iters, || {
            seed += 1;
            let mut rng = Pcg64::new(seed);
            let cfgs = gspace.sample_n(&mut rng, m);
            std::hint::black_box(genc.encode_batch(&cfgs));
        });
        let mut seed2 = 400 + m as u64;
        let t_columnar = bench(&format!("columnar sample_columnar m={m}"), 1, iters, || {
            seed2 += 1;
            let mut rng = Pcg64::new(seed2);
            std::hint::black_box(gspace.sample_columnar(&mut rng, m));
        });
        println!("{}", t_legacy.row());
        println!("{}", t_columnar.row());
        println!(
            "generation m={m}: {:.2}x vs legacy",
            t_legacy.mean_us / t_columnar.mean_us.max(1e-9)
        );
        if !gen_rows.is_empty() {
            gen_rows.push_str(",\n");
        }
        gen_rows.push_str(&format!(
            "    {{\"m\": {m}, \"legacy_mean_us\": {:.1}, \"columnar_mean_us\": {:.1}, \
             \"speedup\": {:.2}}}",
            t_legacy.mean_us,
            t_columnar.mean_us,
            t_legacy.mean_us / t_columnar.mean_us.max(1e-9)
        ));
    }

    // ---- 3. full propose rounds at cache steady state ----
    let space = bench_space();
    let mut round_rows = String::new();
    for n in [64usize, 256] {
        let history = bench_history(&space, n, n as u64);
        for m in [1_000usize, 10_000] {
            for threads in [1usize, 4] {
                let opts = GpOptions {
                    mc_samples: m,
                    proposal_threads: threads,
                    fixed_beta: Some(2.0),
                    ..Default::default()
                };
                let mut core =
                    BayesianCore::new(space.clone(), opts).expect("native core");
                let mut call_seed = 1000 + n as u64;
                let iters = if m >= 10_000 { 6 } else { 15 };
                let stats = bench(
                    &format!("fit_and_score n={n} m={m} threads={threads}"),
                    2,
                    iters,
                    || {
                        call_seed += 1;
                        let mut rng = Pcg64::new(call_seed);
                        std::hint::black_box(
                            core.fit_and_score(&history, 1, &mut rng).expect("fit_and_score"),
                        );
                    },
                );
                println!("{}", stats.row());
                if !round_rows.is_empty() {
                    round_rows.push_str(",\n");
                }
                round_rows.push_str(&format!(
                    "    {{\"n\": {n}, \"m\": {m}, \"threads\": {threads}, \
                     \"mean_us\": {:.1}, \"p50_us\": {:.1}}}",
                    stats.mean_us, stats.p50_us
                ));
            }
        }
    }

    // ---- 4. sharded scoring rounds at m ∈ {1e4, 1e5} ----
    // n = 64 history rows (the kc/w buffers at m = 1e5 already run ~100 MB;
    // the m axis, not n, is what sharding scales).
    let mut shard_rows = String::new();
    {
        let history = bench_history(&space, 64, 64);
        for m in [10_000usize, 100_000] {
            for shards in [0usize, 4] {
                let opts = GpOptions {
                    mc_samples: m,
                    proposal_threads: 4,
                    proposal_shards: shards,
                    shard_exec: ShardExec::Threaded,
                    fixed_beta: Some(2.0),
                    ..Default::default()
                };
                let mut core = BayesianCore::new(space.clone(), opts).expect("native core");
                let mut call_seed = 7000 + m as u64;
                let iters = if m >= 100_000 { 3 } else { 8 };
                let stats = bench(
                    &format!("fit_and_score n=64 m={m} shards={shards}"),
                    1,
                    iters,
                    || {
                        call_seed += 1;
                        let mut rng = Pcg64::new(call_seed);
                        std::hint::black_box(
                            core.fit_and_score(&history, 1, &mut rng).expect("fit_and_score"),
                        );
                    },
                );
                println!("{}", stats.row());
                if !shard_rows.is_empty() {
                    shard_rows.push_str(",\n");
                }
                shard_rows.push_str(&format!(
                    "    {{\"n\": 64, \"m\": {m}, \"shards\": {shards}, \
                     \"mean_us\": {:.1}, \"p50_us\": {:.1}}}",
                    stats.mean_us, stats.p50_us
                ));
            }
        }
    }

    // ---- 5. kernel profiles: Exact vs Fast rounds + cache footprints ----
    let mut profile_rows = String::new();
    let mut footprint_rows = String::new();
    let mut fast_speedup_large = f64::NAN;
    for n in [256usize, 1024] {
        let history = bench_history(&space, n, 2_000 + n as u64);

        // Cache footprint at this n: pure tile geometry, but the tiled
        // entries must match a sequential-dot scalar oracle before any
        // byte counting (the same ≤1e-10 contract the unit tests assert).
        {
            let enc = Encoder::new(&space);
            let flat = enc.encode_batch(history.configs());
            let hx = Matrix::from_vec(n, enc.dims(), flat);
            let norms: Vec<f64> =
                (0..n).map(|i| dot_fast(hx.row(i), hx.row(i))).collect();
            let mut t64 = TiledDistCache::new(TileElem::F64);
            t64.sync(&hx, &norms, 0);
            let mut t32 = TiledDistCache::new(TileElem::F32);
            t32.sync(&hx, &norms, 0);
            let mut worst = 0.0f64;
            for i in 0..n {
                for j in 0..n {
                    let want = sq_dist_from_parts(
                        dot(hx.row(i), hx.row(i)),
                        dot(hx.row(j), hx.row(j)),
                        dot(hx.row(i), hx.row(j)),
                    );
                    let dev = (t64.get(i, j) - want).abs() / want.abs().max(1.0);
                    worst = worst.max(dev);
                }
            }
            assert!(worst <= 1e-10, "tiled f64 D^2 deviates from the dot oracle: {worst:e}");
            let dense = n * n * 8;
            let f32_ratio = t32.footprint_bytes() as f64 / dense as f64;
            assert!(
                f32_ratio <= 0.55,
                "tiled f32 footprint {:.3} of dense exceeds the 55% budget at n={n}",
                f32_ratio
            );
            println!(
                "dist cache n={n}: dense {dense} B, tiled f64 {} B, tiled f32 {} B ({:.1}%)",
                t64.footprint_bytes(),
                t32.footprint_bytes(),
                100.0 * f32_ratio
            );
            if !footprint_rows.is_empty() {
                footprint_rows.push_str(",\n");
            }
            footprint_rows.push_str(&format!(
                "    {{\"n\": {n}, \"dense_f64_bytes\": {dense}, \
                 \"tiled_f64_bytes\": {}, \"tiled_f32_bytes\": {}, \
                 \"f32_over_dense\": {:.4}}}",
                t64.footprint_bytes(),
                t32.footprint_bytes(),
                f32_ratio
            ));
        }

        for m in [10_000usize, 100_000] {
            let mk_core = |profile: KernelProfile| {
                let opts = GpOptions {
                    mc_samples: m,
                    proposal_threads: 1,
                    fixed_beta: Some(2.0),
                    kernel_profile: profile,
                    ..Default::default()
                };
                BayesianCore::new(space.clone(), opts).expect("native core")
            };
            let mut exact = mk_core(KernelProfile::Exact);
            let mut fast = mk_core(KernelProfile::Fast);
            // Same seed → same candidate stream; the profiles must agree
            // to the round-level tolerance before any timing.
            let se = exact.fit_and_score(&history, 1, &mut Pcg64::new(31)).unwrap();
            let sf = fast.fit_and_score(&history, 1, &mut Pcg64::new(31)).unwrap();
            assert_eq!(se.xc, sf.xc, "profiles must score the same candidates");
            let mut max_rel = 0.0f64;
            for (a, b) in se.acq.ucb.iter().zip(sf.acq.ucb.iter()) {
                max_rel = max_rel.max((a - b).abs() / a.abs().max(1.0));
            }
            assert!(
                max_rel <= FAST_UCB_RTOL,
                "fast-profile ucb deviates from exact: {max_rel:e} (n={n} m={m})"
            );
            drop((se, sf));

            let iters = if m >= 100_000 || n >= 1024 { 3 } else { 8 };
            let mut seed_e = 9_000 + (n + m) as u64;
            let t_exact =
                bench(&format!("fit_and_score exact n={n} m={m}"), 1, iters, || {
                    seed_e += 1;
                    let mut rng = Pcg64::new(seed_e);
                    std::hint::black_box(
                        exact.fit_and_score(&history, 1, &mut rng).expect("fit_and_score"),
                    );
                });
            let mut seed_f = 9_000 + (n + m) as u64;
            let t_fast =
                bench(&format!("fit_and_score fast  n={n} m={m}"), 1, iters, || {
                    seed_f += 1;
                    let mut rng = Pcg64::new(seed_f);
                    std::hint::black_box(
                        fast.fit_and_score(&history, 1, &mut rng).expect("fit_and_score"),
                    );
                });
            let speedup = t_exact.mean_us / t_fast.mean_us.max(1e-9);
            if n == 1024 && m == 100_000 {
                fast_speedup_large = speedup;
            }
            println!("{}", t_exact.row());
            println!("{}", t_fast.row());
            println!(
                "profile n={n} m={m}: fast {speedup:.2}x vs exact (max rel dev {max_rel:e})"
            );
            if !profile_rows.is_empty() {
                profile_rows.push_str(",\n");
            }
            profile_rows.push_str(&format!(
                "    {{\"n\": {n}, \"m\": {m}, \"exact_mean_us\": {:.1}, \
                 \"fast_mean_us\": {:.1}, \"speedup\": {:.2}, \"max_rel_dev\": {:e}}}",
                t_exact.mean_us, t_fast.mean_us, speedup, max_rel
            ));
        }
    }

    let json = format!(
        "{{\n  \"bench\": \"propose_hot_path\",\n  \"dims\": {D},\n  \
         \"kernel\": {{\"n\": {kn}, \"m\": {km}, \"scalar_mean_us\": {:.1}, \
         \"gemm_mean_us\": {:.1}, \"speedup\": {:.2}, \
         \"target_speedup\": {KERNEL_SPEEDUP_TARGET}, \"pass\": {}, \
         \"max_abs_deviation\": {:e}}},\n  \"generation\": [\n{}\n  ],\n  \
         \"rounds\": [\n{}\n  ],\n  \"sharded_rounds\": [\n{}\n  ],\n  \
         \"profiles\": [\n{}\n  ],\n  \
         \"cache_footprint\": [\n{}\n  ],\n  \
         \"fast_speedup_target\": {FAST_SPEEDUP_TARGET},\n  \
         \"fast_pass\": {}\n}}\n",
        t_scalar.mean_us,
        t_gemm.mean_us,
        kernel_speedup,
        kernel_speedup >= KERNEL_SPEEDUP_TARGET,
        max_dev,
        gen_rows,
        round_rows,
        shard_rows,
        profile_rows,
        footprint_rows,
        fast_speedup_large >= FAST_SPEEDUP_TARGET,
    );
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_propose.json");
    std::fs::write(out, &json).expect("write BENCH_propose.json");
    println!("wrote {out}");
    assert!(
        kernel_speedup >= KERNEL_SPEEDUP_TARGET,
        "GEMM kernel speedup {kernel_speedup:.2}x below the {KERNEL_SPEEDUP_TARGET}x target"
    );
    assert!(
        fast_speedup_large >= FAST_SPEEDUP_TARGET,
        "fast profile {fast_speedup_large:.2}x at n=1024 m=1e5 below the \
         {FAST_SPEEDUP_TARGET}x target"
    );
}
