//! Ablation A3 (DESIGN.md): the adaptive exploration schedule vs fixed UCB
//! beta values. The paper claims adaptive exploitation/exploration (a
//! function of space size, evaluations, batch size) as a feature; this
//! harness quantifies it against constant-beta GP-UCB.
//!
//! Run: `cargo bench --bench ablation_beta`

mod common;

use common::{backend, env_usize};
use mango::exp::workloads;
use mango::optimizer::{
    bayesian::BayesianCore, hallucinate::HallucinationOptimizer, BatchOptimizer, GpOptions,
    History,
};
use mango::util::rng::Pcg64;
use mango::util::stats;

fn run_one(fixed_beta: Option<f64>, workload_name: &str, iters: usize, seed: u64) -> Vec<f64> {
    let workload = workloads::by_name(workload_name).unwrap();
    let opts = GpOptions { backend: backend(), fixed_beta, ..Default::default() };
    let core = BayesianCore::new(workload.space.clone(), opts).unwrap();
    let mut opt = HallucinationOptimizer::new(core);
    let mut rng = Pcg64::new(seed);
    let mut history = History::new();
    let mut best = f64::INFINITY;
    let mut series = Vec::with_capacity(iters);
    for _ in 0..iters {
        let batch = opt.propose(&history, 1, &mut rng).unwrap();
        for cfg in batch {
            let v = (workload.objective)(&cfg).unwrap();
            best = best.min(v);
            history.push(cfg, -v); // maximization convention internally
        }
        series.push(best);
    }
    series
}

fn main() {
    let iters = env_usize("MANGO_ITERS", 25);
    let repeats = env_usize("MANGO_REPEATS", 5);
    for workload_name in ["mixed_branin", "hartmann6"] {
        println!("# ablation_beta on {workload_name}: label,iteration,mean");
        let mut rows = Vec::new();
        for &(label, beta) in &[
            ("beta=0.5", Some(0.5)),
            ("beta=1.0", Some(1.0)),
            ("beta=2.0", Some(2.0)),
            ("beta=4.0", Some(4.0)),
            ("adaptive", None),
        ] {
            let trials: Vec<Vec<f64>> = (0..repeats)
                .map(|r| run_one(beta, workload_name, iters, 31 + 1000 * r as u64))
                .collect();
            let mean = stats::mean_series(&trials);
            for (i, m) in mean.iter().enumerate() {
                println!("{workload_name}/{label},{},{m:.6}", i + 1);
            }
            rows.push((label, mean));
        }
        println!("\n# final best-so-far (lower is better)");
        for (label, mean) in &rows {
            println!("{label:<12} {:.5}", mean.last().unwrap());
        }
        println!();
    }
}
