//! Fig. 2 reproduction: tuning the XGBoost-substitute GBT classifier on the
//! wine dataset (Listing 1 search space). Strategies exactly as the paper's
//! figure: random, Hyperopt(TPE) serial + parallel, Mango serial, and both
//! Mango parallel algorithms with batch size 5. Results averaged over
//! MANGO_REPEATS trials (paper: 20). "Number of iterations" = batches.
//!
//! Run: `cargo bench --bench fig2_xgb`
//! Paper scale: `MANGO_REPEATS=20 MANGO_ITERS=60 cargo bench --bench fig2_xgb`

mod common;

use common::{env_usize, run_figure, Strategy};
use mango::exp::workloads;
use mango::optimizer::OptimizerKind;

fn main() {
    let iters = env_usize("MANGO_ITERS", 60);
    let repeats = env_usize("MANGO_REPEATS", 5);
    let workload = workloads::by_name("wine_gbt").unwrap();
    let strategies = [
        Strategy { label: "random", optimizer: OptimizerKind::Random, batch_size: 1 },
        Strategy { label: "hyperopt(tpe) serial", optimizer: OptimizerKind::Tpe, batch_size: 1 },
        Strategy {
            label: "mango serial",
            optimizer: OptimizerKind::Hallucination,
            batch_size: 1,
        },
        Strategy {
            label: "hyperopt(tpe) parallel k=5",
            optimizer: OptimizerKind::Tpe,
            batch_size: 5,
        },
        Strategy {
            label: "mango hallucination k=5",
            optimizer: OptimizerKind::Hallucination,
            batch_size: 5,
        },
        Strategy {
            label: "mango clustering k=5",
            optimizer: OptimizerKind::Clustering,
            batch_size: 5,
        },
    ];
    let checkpoints = [10, 20, 40, iters];
    run_figure("fig2", &workload, &strategies, iters, repeats, &checkpoints);
}
