//! Perf bench: micro-timings of every hot-path stage, per layer — feeds
//! EXPERIMENTS.md §Perf. Not a figure; a profiler.
//!
//! Rows:
//!   L2/L1 via PJRT: gp_fit / gp_acquire per variant (steady state,
//!                   compile excluded) vs the native-Rust GP oracle
//!   L3: hallucination step, MC candidate sampling + encoding, TPE propose,
//!       scheduler dispatch overhead (serial / threaded / celery, no-op
//!       objective), end-to-end tuner iteration on branin
//!
//! Run: `cargo bench --bench perf_hotpath`

use mango::exp::benchkit::bench;
use mango::exp::workloads;
use mango::gp::update::BatchHallucinator;
use mango::gp::{normalize_y, GpParams, NativeGp, Surrogate};
use mango::linalg::Matrix;
use mango::optimizer::{BatchOptimizer, History};
use mango::runtime::PjrtSurrogate;
use mango::scheduler::{self, SchedulerKind};
use mango::space::{Config, Encoder};
use mango::util::rng::Pcg64;

fn gp_inputs(n: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>, Matrix) {
    let mut rng = Pcg64::new(seed);
    let x = Matrix::from_fn(n, d, |_, _| rng.next_f64());
    let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let xc = Matrix::from_fn(512, d, |_, _| rng.next_f64());
    let (yn, _, _) = normalize_y(&y);
    (x, yn, xc)
}

fn main() {
    let d = 7;
    let params = GpParams::new(d);
    println!("# layer L2/L1 (PJRT artifacts, steady state) vs native oracle");
    let mut pjrt = PjrtSurrogate::from_default_artifacts().expect("run `make artifacts`");
    let mut native = NativeGp;
    for n in [64usize, 128, 256, 384, 512] {
        let (x, yn, xc) = gp_inputs(n, d, n as u64);
        // warmup includes compile; bench excludes it
        let fit = pjrt.fit(&x, &yn, &params).unwrap();
        println!("{}", bench(&format!("pjrt gp_fit n={n}"), 2, 15, || {
            std::hint::black_box(pjrt.fit(&x, &yn, &params).unwrap());
        }).row());
        println!("{}", bench(&format!("pjrt gp_acquire n={n} m=512"), 2, 15, || {
            std::hint::black_box(pjrt.acquire(&x, &fit, &xc, &params).unwrap());
        }).row());
        let nfit = native.fit(&x, &yn, &params).unwrap();
        println!("{}", bench(&format!("native gp_fit n={n}"), 1, 5, || {
            std::hint::black_box(native.fit(&x, &yn, &params).unwrap());
        }).row());
        println!("{}", bench(&format!("native gp_acquire n={n} m=512"), 1, 5, || {
            std::hint::black_box(native.acquire(&x, &nfit, &xc, &params).unwrap());
        }).row());
    }

    println!("\n# layer L3: batch selection and sampling");
    let (x, yn, xc) = gp_inputs(256, d, 1);
    let fit = pjrt.fit(&x, &yn, &params).unwrap();
    let acq = pjrt.acquire(&x, &fit, &xc, &params).unwrap();
    println!("{}", bench("hallucinate 5-batch from 512 cands (n=256)", 2, 20, || {
        let mut h = BatchHallucinator::new(&x, &xc, &acq, &params);
        for _ in 0..5 {
            std::hint::black_box(h.select_next());
        }
    }).row());

    let space = mango::space::xgboost_space();
    let encoder = Encoder::new(&space);
    let mut rng = Pcg64::new(2);
    println!("{}", bench("MC sample+encode 3000 configs (xgb space)", 2, 20, || {
        let cands = space.sample_n(&mut rng, 3000);
        std::hint::black_box(encoder.encode_batch(&cands));
    }).row());

    let mut tpe = mango::optimizer::tpe::TpeOptimizer::new(space.clone());
    let mut hist = History::new();
    let mut rng2 = Pcg64::new(3);
    for cfg in space.sample_n(&mut rng2, 100) {
        let v = cfg.get_f64("learning_rate").unwrap();
        hist.push(cfg, v);
    }
    println!("{}", bench("tpe propose k=5 (100 obs)", 2, 20, || {
        std::hint::black_box(tpe.propose(&hist, 5, &mut rng2).unwrap());
    }).row());

    println!("\n# layer L3: scheduler dispatch overhead (no-op objective, batch=8)");
    let batch: Vec<Config> = space.sample_n(&mut rng2, 8);
    for kind in [SchedulerKind::Serial, SchedulerKind::Threaded, SchedulerKind::Celery] {
        let mut sched = scheduler::build(kind, 8, 1);
        println!("{}", bench(&format!("{:?} dispatch 8 no-op tasks", kind), 3, 30, || {
            std::hint::black_box(sched.evaluate(&|_| Some(1.0), &batch));
        }).row());
    }

    println!("\n# end-to-end: one tuner iteration (branin, pjrt, k=5)");
    let workload = workloads::by_name("branin").unwrap();
    let cfg = mango::coordinator::TunerConfig {
        batch_size: 5,
        num_iterations: 20,
        backend: mango::optimizer::SurrogateBackend::Pjrt,
        scheduler: SchedulerKind::Threaded,
        workers: 5,
        seed: 4,
        ..Default::default()
    };
    let obj = workload.objective.clone();
    println!("{}", bench("tuner 20 iters branin k=5 (pjrt)", 1, 3, || {
        let mut tuner = mango::coordinator::Tuner::new(workload.space.clone(), cfg.clone());
        let obj = obj.clone();
        std::hint::black_box(tuner.minimize(move |c| obj(c)).unwrap());
    }).row());
}
