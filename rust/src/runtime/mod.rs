//! PJRT runtime: loads the AOT-compiled L2/L1 artifacts (HLO text) and runs
//! them on the request path. Python never executes here — `make artifacts`
//! is the only place JAX runs.
//!
//! * [`artifact`] — manifest parsing + variant selection (static shapes).
//! * [`pjrt`] — the [`PjrtSurrogate`]: [`crate::gp::Surrogate`] implemented
//!   by compiling `gp_fit_n*.hlo.txt` / `gp_acquire_n*.hlo.txt` once per
//!   variant and executing them with padded/masked inputs.

pub mod artifact;
pub mod pjrt;

pub use artifact::{ArtifactManifest, Variant};
pub use pjrt::PjrtSurrogate;

/// Default artifacts directory (relative to the repo root / cwd), override
/// with `MANGO_ARTIFACTS`.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("MANGO_ARTIFACTS") {
        return p.into();
    }
    // Walk up from cwd so examples/benches/tests all find the repo root.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
