//! Artifact manifest: what `python/compile/aot.py` produced, and which
//! static-shape variant serves a given observation count.

use crate::config::json::{parse, Json};
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// One static-shape variant of the GP programs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Variant {
    /// Observation slots (rows of x / y / mask).
    pub n: usize,
    pub fit_path: PathBuf,
    pub acquire_path: PathBuf,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub max_dim: usize,
    pub m_cand: usize,
    /// Variants sorted ascending by n.
    pub variants: Vec<Variant>,
    pub dir: PathBuf,
}

impl ArtifactManifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let j = parse(&text).with_context(|| format!("parsing {path:?}"))?;
        Self::from_json(&j, dir)
    }

    pub fn from_json(j: &Json, dir: &Path) -> Result<Self> {
        let max_dim = j
            .get("max_dim")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing max_dim"))?;
        let m_cand = j
            .get("m_cand")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing m_cand"))?;
        // Schema guard: the inverse-free posterior changed the meaning of
        // the f32[n,n] fit output / acquire input (K^{-1} -> Cholesky L)
        // without changing its shape, so stale artifacts would execute
        // silently with wrong numerics. Refuse anything but the current
        // schema tag.
        let posterior = j.get("posterior").and_then(Json::as_str);
        anyhow::ensure!(
            posterior == Some("chol"),
            "artifact manifest schema mismatch: expected posterior=\"chol\" \
             (gp_fit emits / gp_acquire consumes the Cholesky factor), found \
             {posterior:?} — regenerate with `make artifacts`"
        );
        let programs = j
            .get("programs")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing programs"))?;
        let mut variants = Vec::new();
        for (n_str, entry) in programs {
            let n: usize = n_str.parse().with_context(|| format!("bad variant key {n_str}"))?;
            let fit = entry
                .get("fit")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("variant {n}: missing fit"))?;
            let acq = entry
                .get("acquire")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("variant {n}: missing acquire"))?;
            variants.push(Variant {
                n,
                fit_path: dir.join(fit),
                acquire_path: dir.join(acq),
            });
        }
        anyhow::ensure!(!variants.is_empty(), "manifest has no variants");
        variants.sort_by_key(|v| v.n);
        for v in &variants {
            anyhow::ensure!(v.fit_path.exists(), "missing artifact {:?}", v.fit_path);
            anyhow::ensure!(v.acquire_path.exists(), "missing artifact {:?}", v.acquire_path);
        }
        Ok(Self { max_dim, m_cand, variants, dir: dir.to_path_buf() })
    }

    /// Smallest variant with capacity for `n_obs` observations.
    pub fn variant_for(&self, n_obs: usize) -> Result<&Variant> {
        self.variants
            .iter()
            .find(|v| v.n >= n_obs)
            .ok_or_else(|| {
                anyhow!(
                    "no artifact variant can hold {n_obs} observations (max {}); \
                     the tuner caps history at the largest variant",
                    self.variants.last().map(|v| v.n).unwrap_or(0)
                )
            })
    }

    /// Largest observation capacity across variants.
    pub fn max_obs(&self) -> usize {
        self.variants.last().map(|v| v.n).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn touch(dir: &Path, name: &str) {
        std::fs::write(dir.join(name), "HloModule x").unwrap();
    }

    #[test]
    fn loads_and_selects_variants() {
        let tmp = std::env::temp_dir().join(format!("mango_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        for n in [64, 128] {
            touch(&tmp, &format!("gp_fit_n{n}.hlo.txt"));
            touch(&tmp, &format!("gp_acquire_n{n}.hlo.txt"));
        }
        write_manifest(
            &tmp,
            r#"{"max_dim":16,"m_cand":512,"posterior":"chol","n_variants":[64,128],"programs":{
                "64":{"fit":"gp_fit_n64.hlo.txt","acquire":"gp_acquire_n64.hlo.txt"},
                "128":{"fit":"gp_fit_n128.hlo.txt","acquire":"gp_acquire_n128.hlo.txt"}}}"#,
        );
        let m = ArtifactManifest::load(&tmp).unwrap();
        assert_eq!(m.max_dim, 16);
        assert_eq!(m.variants.len(), 2);
        assert_eq!(m.variant_for(1).unwrap().n, 64);
        assert_eq!(m.variant_for(64).unwrap().n, 64);
        assert_eq!(m.variant_for(65).unwrap().n, 128);
        assert!(m.variant_for(129).is_err());
        assert_eq!(m.max_obs(), 128);
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn missing_file_is_an_error() {
        let tmp = std::env::temp_dir().join(format!("mango_manifest_bad_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        write_manifest(
            &tmp,
            r#"{"max_dim":16,"m_cand":512,"posterior":"chol","programs":{
                "64":{"fit":"nope.hlo.txt","acquire":"nope2.hlo.txt"}}}"#,
        );
        assert!(ArtifactManifest::load(&tmp).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn legacy_kinv_manifest_is_rejected() {
        // Pre-inverse-free artifacts emitted K^{-1} in the same f32[n,n]
        // slot now holding the Cholesky factor; loading them must fail
        // loudly, not execute with silently wrong posteriors.
        let tmp = std::env::temp_dir().join(format!("mango_manifest_old_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        touch(&tmp, "gp_fit_n64.hlo.txt");
        touch(&tmp, "gp_acquire_n64.hlo.txt");
        write_manifest(
            &tmp,
            r#"{"max_dim":16,"m_cand":512,"programs":{
                "64":{"fit":"gp_fit_n64.hlo.txt","acquire":"gp_acquire_n64.hlo.txt"}}}"#,
        );
        let err = ArtifactManifest::load(&tmp).unwrap_err();
        assert!(err.to_string().contains("posterior"), "got: {err}");
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn real_artifacts_manifest_parses_if_present() {
        let dir = crate::runtime::default_artifacts_dir();
        if dir.join("manifest.json").exists() {
            let m = ArtifactManifest::load(&dir).unwrap();
            assert!(m.max_obs() >= 128);
            assert_eq!(m.max_dim, 16);
        }
    }
}
