//! The production surrogate backend: AOT artifacts executed via PJRT.
//!
//! Two compilations of this module exist:
//!
//! * `--features pjrt-xla` — the real thing: wraps the `xla` crate (PJRT C
//!   API): `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//!   `compile` → `execute`. The `xla` crate is not vendored in the offline
//!   registry, so this path additionally requires adding the dependency.
//! * default — a native-delegating fallback: the same [`PjrtSurrogate`] API
//!   backed by [`crate::gp::NativeGp`], which mirrors the L2 JAX programs
//!   numerically (`python/compile/model.py`). Chunking accounting
//!   (`acquire_calls`) and the artifact-capacity contract are preserved so
//!   coordinator/optimizer behavior is identical either way.

#[cfg(feature = "pjrt-xla")]
mod xla_impl {
    //! Each static-shape variant is compiled once **per thread,
    //! process-wide** (the PJRT wrappers are not `Send`, so the executable
    //! cache is thread-local; the experiment harness runs hundreds of tuner
    //! instances on one thread and pays compilation exactly once per
    //! variant — §Perf: this was a ~400 ms/tuner win). Fits and acquires pad
    //! inputs to the variant's slots and mask the padding (the L2 programs
    //! give padded rows identity kernel rows, so they contribute nothing —
    //! see `python/compile/model.py`).

    use crate::gp::{AcquireOut, CholeskyState, FitOut, GpParams, Surrogate};
    use crate::linalg::Matrix;
    use crate::runtime::artifact::ArtifactManifest;
    use anyhow::{Context, Result};
    use std::cell::RefCell;
    use std::collections::BTreeMap;
    use std::path::{Path, PathBuf};
    use std::rc::Rc;

    thread_local! {
        /// One PJRT CPU client per thread (executables are tied to a client).
        static CLIENT: RefCell<Option<Rc<xla::PjRtClient>>> = const { RefCell::new(None) };
        /// Compiled-executable cache keyed by artifact path.
        static EXE_CACHE: RefCell<BTreeMap<PathBuf, Rc<xla::PjRtLoadedExecutable>>> =
            RefCell::new(BTreeMap::new());
    }

    fn thread_client() -> Result<Rc<xla::PjRtClient>> {
        CLIENT.with(|c| {
            let mut c = c.borrow_mut();
            if c.is_none() {
                *c = Some(Rc::new(
                    xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
                ));
            }
            Ok(c.as_ref().unwrap().clone())
        })
    }

    fn compile_cached(
        client: &xla::PjRtClient,
        path: &Path,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        EXE_CACHE.with(|cache| {
            if let Some(exe) = cache.borrow().get(path) {
                return Ok(exe.clone());
            }
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("loading {path:?}"))?;
            let exe = Rc::new(
                client
                    .compile(&xla::XlaComputation::from_proto(&proto))
                    .with_context(|| format!("compiling {path:?}"))?,
            );
            crate::log_debug!("compiled PJRT executable {path:?}");
            cache.borrow_mut().insert(path.to_path_buf(), exe.clone());
            Ok(exe)
        })
    }

    /// Compiled (fit, acquire) executables for one variant.
    struct CompiledVariant {
        n: usize,
        fit: Rc<xla::PjRtLoadedExecutable>,
        acquire: Rc<xla::PjRtLoadedExecutable>,
    }

    /// PJRT-backed [`Surrogate`].
    pub struct PjrtSurrogate {
        #[allow(dead_code)] // keeps the client alive alongside its executables
        client: Rc<xla::PjRtClient>,
        manifest: ArtifactManifest,
        compiled: BTreeMap<usize, CompiledVariant>,
        /// Counters for the perf pass (EXPERIMENTS.md §Perf).
        pub fit_calls: u64,
        pub acquire_calls: u64,
    }

    impl PjrtSurrogate {
        /// Create from the default artifacts directory (see
        /// [`crate::runtime::default_artifacts_dir`]).
        pub fn from_default_artifacts() -> Result<Self> {
            Self::new(&crate::runtime::default_artifacts_dir())
        }

        pub fn new(artifacts_dir: &Path) -> Result<Self> {
            let manifest = ArtifactManifest::load(artifacts_dir)?;
            let client = thread_client()?;
            Ok(Self { client, manifest, compiled: BTreeMap::new(), fit_calls: 0, acquire_calls: 0 })
        }

        pub fn manifest(&self) -> &ArtifactManifest {
            &self.manifest
        }

        /// Largest observation count the artifacts support.
        pub fn max_obs(&self) -> usize {
            self.manifest.max_obs()
        }

        fn compiled_for(&mut self, n_obs: usize) -> Result<&CompiledVariant> {
            let variant = self.manifest.variant_for(n_obs)?.clone();
            if !self.compiled.contains_key(&variant.n) {
                let fit = compile_cached(&self.client, &variant.fit_path)?;
                let acquire = compile_cached(&self.client, &variant.acquire_path)?;
                self.compiled.insert(variant.n, CompiledVariant { n: variant.n, fit, acquire });
            }
            Ok(&self.compiled[&variant.n])
        }

        /// Pad an encoded (rows x cols) matrix into `slots x max_dim` f32.
        fn pad_rows(&self, x: &Matrix, slots: usize) -> Vec<f32> {
            let d = self.manifest.max_dim;
            let mut out = vec![0f32; slots * d];
            for i in 0..x.rows() {
                for j in 0..x.cols() {
                    out[i * d + j] = x[(i, j)] as f32;
                }
            }
            out
        }

        fn inv_ls_literal(&self, params: &GpParams) -> xla::Literal {
            let d = self.manifest.max_dim;
            let mut v = vec![0f32; d];
            for (i, &il) in params.inv_lengthscale.iter().take(d).enumerate() {
                v[i] = il as f32;
            }
            xla::Literal::vec1(&v)
        }

        fn params_literal(params: &GpParams) -> xla::Literal {
            xla::Literal::vec1(&[params.amp as f32, params.noise as f32, params.beta as f32])
        }
    }

    fn lit_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
    }

    impl Surrogate for PjrtSurrogate {
        fn fit(&mut self, x: &Matrix, y: &[f64], params: &GpParams) -> Result<FitOut> {
            let n = x.rows();
            anyhow::ensure!(y.len() == n, "y length mismatch");
            anyhow::ensure!(
                x.cols() <= self.manifest.max_dim,
                "encoded dim {} exceeds artifact max_dim {}",
                x.cols(),
                self.manifest.max_dim
            );
            let d = self.manifest.max_dim;
            let inv_ls = self.inv_ls_literal(params);
            let x_pad = {
                let cv_n = self.manifest.variant_for(n)?.n;
                self.pad_rows(x, cv_n)
            };
            let cv = self.compiled_for(n)?;
            let slots = cv.n;

            let mut y_pad = vec![0f32; slots];
            let mut mask = vec![0f32; slots];
            for i in 0..n {
                y_pad[i] = y[i] as f32;
                mask[i] = 1.0;
            }

            let args = [
                lit_2d(&x_pad, slots, d)?,
                xla::Literal::vec1(&y_pad),
                xla::Literal::vec1(&mask),
                inv_ls,
                Self::params_literal(params),
            ];
            let result = cv.fit.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
            let (alpha_l, chol_l, logdet_l) = result.to_tuple3()?;
            let alpha_f32 = alpha_l.to_vec::<f32>()?;
            let chol_f32 = chol_l.to_vec::<f32>()?;
            let logdet = logdet_l.to_vec::<f32>()?[0] as f64;

            self.fit_calls += 1;
            let alpha = alpha_f32[..n].iter().map(|&v| v as f64).collect();
            let chol = Matrix::from_fn(n, n, |i, j| chol_f32[i * slots + j] as f64);
            Ok(FitOut { alpha, chol, logdet })
        }

        /// The factorization lives inside the AOT program — there is no
        /// host-side append path, so incremental requests pay a full
        /// artifact fit and just rebuild the state for the caller's cache.
        fn fit_incremental(
            &mut self,
            x: &Matrix,
            y: &[f64],
            params: &GpParams,
            _state: Option<CholeskyState>,
        ) -> Result<(FitOut, CholeskyState)> {
            let fit = Surrogate::fit(self, x, y, params)?;
            let state = CholeskyState::from_fit(x, &fit, params);
            Ok((fit, state))
        }

        fn max_obs(&self) -> usize {
            self.manifest.max_obs()
        }

        fn acquire(
            &mut self,
            x: &Matrix,
            fit: &FitOut,
            xc: &Matrix,
            params: &GpParams,
        ) -> Result<AcquireOut> {
            let n = x.rows();
            let m = xc.rows();
            anyhow::ensure!(fit.alpha.len() == n, "fit/x size mismatch");
            let d = self.manifest.max_dim;
            let m_cand = self.manifest.m_cand;
            let inv_ls_lit = self.inv_ls_literal(params);
            let params_lit = Self::params_literal(params);
            let x_pad = {
                let cv_n = self.manifest.variant_for(n)?.n;
                self.pad_rows(x, cv_n)
            };
            let cv = self.compiled_for(n)?;
            let slots = cv.n;

            // Observation-side literals are invariant across candidate chunks:
            // build them once (§Perf: the factor alone is slots² floats).
            let x_lit = lit_2d(&x_pad, slots, d)?;
            let mut mask = vec![0f32; slots];
            let mut alpha_pad = vec![0f32; slots];
            for i in 0..n {
                mask[i] = 1.0;
                alpha_pad[i] = fit.alpha[i] as f32;
            }
            let mask_lit = xla::Literal::vec1(&mask);
            let alpha_lit = xla::Literal::vec1(&alpha_pad);
            // Padded rows carry an identity factor row (diag 1) so the
            // in-program triangular solves pass them through untouched.
            let mut chol_pad = vec![0f32; slots * slots];
            for i in n..slots {
                chol_pad[i * slots + i] = 1.0;
            }
            for i in 0..n {
                for j in 0..n {
                    chol_pad[i * slots + j] = fit.chol[(i, j)] as f32;
                }
            }
            let chol_lit = lit_2d(&chol_pad, slots, slots)?;

            let mut ucb = Vec::with_capacity(m);
            let mut mean = Vec::with_capacity(m);
            let mut var = Vec::with_capacity(m);
            let mut w = Matrix::zeros(n, m);
            let mut calls = 0u64;

            // Chunk the candidate set into m_cand-sized acquire calls.
            let mut xc_pad = vec![0f32; m_cand * d];
            let mut start = 0;
            while start < m {
                let count = (m - start).min(m_cand);
                xc_pad.fill(0.0);
                for c in 0..count {
                    for j in 0..xc.cols() {
                        xc_pad[c * d + j] = xc[(start + c, j)] as f32;
                    }
                }
                let xc_lit = lit_2d(&xc_pad, m_cand, d)?;
                let args: [&xla::Literal; 7] =
                    [&x_lit, &mask_lit, &xc_lit, &alpha_lit, &chol_lit, &inv_ls_lit, &params_lit];
                let result = cv.acquire.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
                let (ucb_l, mean_l, var_l, w_l) = result.to_tuple4()?;
                let ucb_c = ucb_l.to_vec::<f32>()?;
                let mean_c = mean_l.to_vec::<f32>()?;
                let var_c = var_l.to_vec::<f32>()?;
                let w_c = w_l.to_vec::<f32>()?;
                for c in 0..count {
                    ucb.push(ucb_c[c] as f64);
                    mean.push(mean_c[c] as f64);
                    var.push(var_c[c] as f64);
                    for i in 0..n {
                        w[(i, start + c)] = w_c[i * m_cand + c] as f64;
                    }
                }
                calls += 1;
                start += count;
            }
            self.acquire_calls += calls;
            Ok(AcquireOut { ucb, mean, var, w })
        }

        fn name(&self) -> &'static str {
            "pjrt"
        }
    }
}

#[cfg(feature = "pjrt-xla")]
pub use xla_impl::PjrtSurrogate;

#[cfg(not(feature = "pjrt-xla"))]
mod fallback {
    //! Native-delegating stand-in compiled when the `xla` crate is absent.
    //! Honors the artifact contract where it can: the manifest (if present)
    //! bounds observation counts and sets the candidate chunk size, and
    //! `acquire_calls` counts chunks exactly as the real backend would.

    use crate::gp::{AcquireOut, CholeskyState, FitOut, GpParams, NativeGp, Surrogate};
    use crate::linalg::Matrix;
    use crate::runtime::artifact::ArtifactManifest;
    use anyhow::Result;
    use std::path::Path;

    /// Capacity assumed when no artifact manifest is on disk (matches the
    /// largest generated variant, `gp_fit_n512`).
    const DEFAULT_MAX_OBS: usize = 512;
    /// Candidate-chunk size assumed without a manifest.
    const DEFAULT_M_CAND: usize = 512;

    pub struct PjrtSurrogate {
        manifest: Option<ArtifactManifest>,
        native: NativeGp,
        m_cand: usize,
        max_obs: usize,
        pub fit_calls: u64,
        pub acquire_calls: u64,
    }

    impl PjrtSurrogate {
        pub fn from_default_artifacts() -> Result<Self> {
            Self::new(&crate::runtime::default_artifacts_dir())
        }

        /// Unlike the real backend, a *missing* manifest is not an error:
        /// the fallback still serves `SurrogateBackend::Pjrt` requests via
        /// the native oracle (the two agree numerically by construction).
        /// A manifest that is present but invalid — including the stale
        /// kinv-era schema the `posterior` tag guards against — still
        /// fails loudly, exactly like the real backend would, instead of
        /// silently substituting assumed defaults for the artifact set's
        /// real capacity.
        pub fn new(artifacts_dir: &Path) -> Result<Self> {
            let manifest = if artifacts_dir.join("manifest.json").exists() {
                Some(ArtifactManifest::load(artifacts_dir)?)
            } else {
                None
            };
            let m_cand = manifest.as_ref().map(|m| m.m_cand).unwrap_or(DEFAULT_M_CAND);
            let max_obs = manifest.as_ref().map(|m| m.max_obs()).unwrap_or(DEFAULT_MAX_OBS);
            Ok(Self {
                manifest,
                native: NativeGp,
                m_cand,
                max_obs,
                fit_calls: 0,
                acquire_calls: 0,
            })
        }

        pub fn manifest(&self) -> Option<&ArtifactManifest> {
            self.manifest.as_ref()
        }

        /// Largest observation count the (real or assumed) artifacts support.
        pub fn max_obs(&self) -> usize {
            self.max_obs
        }
    }

    impl Surrogate for PjrtSurrogate {
        fn fit(&mut self, x: &Matrix, y: &[f64], params: &GpParams) -> Result<FitOut> {
            anyhow::ensure!(
                x.rows() <= self.max_obs,
                "{} observations exceed artifact capacity {}",
                x.rows(),
                self.max_obs
            );
            self.fit_calls += 1;
            self.native.fit(x, y, params)
        }

        /// Incremental fits delegate to the native engine (the fallback
        /// shares its numerics), under the same artifact-capacity contract.
        fn fit_incremental(
            &mut self,
            x: &Matrix,
            y: &[f64],
            params: &GpParams,
            state: Option<CholeskyState>,
        ) -> Result<(FitOut, CholeskyState)> {
            anyhow::ensure!(
                x.rows() <= self.max_obs,
                "{} observations exceed artifact capacity {}",
                x.rows(),
                self.max_obs
            );
            self.fit_calls += 1;
            self.native.fit_incremental(x, y, params, state)
        }

        /// The fallback's kernel build is host-side, so the shared
        /// squared-distance cache applies exactly as it does natively
        /// (the real artifact backend ignores it — its kernel lives inside
        /// the compiled program).
        fn fit_incremental_shared(
            &mut self,
            x: &Matrix,
            y: &[f64],
            params: &GpParams,
            state: Option<CholeskyState>,
            sq_dists: Option<&Matrix>,
        ) -> Result<(FitOut, CholeskyState)> {
            anyhow::ensure!(
                x.rows() <= self.max_obs,
                "{} observations exceed artifact capacity {}",
                x.rows(),
                self.max_obs
            );
            self.fit_calls += 1;
            self.native.fit_incremental_shared(x, y, params, state, sq_dists)
        }

        fn consumes_shared_dists(&self) -> bool {
            self.native.consumes_shared_dists()
        }

        fn max_obs(&self) -> usize {
            self.max_obs
        }

        fn acquire(
            &mut self,
            x: &Matrix,
            fit: &FitOut,
            xc: &Matrix,
            params: &GpParams,
        ) -> Result<AcquireOut> {
            // One simulated execute per m_cand-sized candidate chunk.
            self.acquire_calls += (xc.rows().max(1) as u64).div_ceil(self.m_cand as u64);
            self.native.acquire(x, fit, xc, params)
        }

        fn name(&self) -> &'static str {
            "pjrt-fallback"
        }
    }
}

#[cfg(not(feature = "pjrt-xla"))]
pub use fallback::PjrtSurrogate;
