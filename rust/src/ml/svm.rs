//! RBF-kernel SVM (squared-hinge, one-vs-rest) for the paper's Listing 2 /
//! `SVM_Example.ipynb` workload. Trained by gradient descent in the kernel
//! dual coefficients — a compact substitute for libsvm's SMO that exposes
//! the same two hyperparameters (`C`, `gamma`) with the same qualitative
//! response surface (DESIGN.md §2).

use super::dataset::Dataset;
use super::Classifier;
use crate::space::Config;

pub struct SvmClassifier {
    pub c: f64,
    pub gamma: f64,
    epochs: usize,
    /// Per-class dual-ish coefficients over training points + bias.
    coef: Vec<Vec<f64>>,
    bias: Vec<f64>,
    train_x: Vec<Vec<f64>>,
    stats: Vec<(f64, f64)>,
    n_classes: usize,
}

impl SvmClassifier {
    pub fn new(c: f64, gamma: f64) -> Self {
        assert!(c > 0.0 && gamma > 0.0);
        Self {
            c,
            gamma,
            epochs: 120,
            coef: Vec::new(),
            bias: Vec::new(),
            train_x: Vec::new(),
            stats: Vec::new(),
            n_classes: 0,
        }
    }

    /// Listing 2 mapping: `c` uniform, `gamma` loguniform.
    pub fn from_config(cfg: &Config) -> Self {
        Self::new(
            cfg.get_f64("c").unwrap_or(1.0).max(1e-3),
            cfg.get_f64("gamma").unwrap_or(0.1).max(1e-6),
        )
    }

    fn standardize(&self, row: &[f64]) -> Vec<f64> {
        row.iter()
            .enumerate()
            .map(|(j, &v)| {
                let (m, s) = self.stats[j];
                (v - m) / s
            })
            .collect()
    }

    fn kernel(&self, a: &[f64], b: &[f64]) -> f64 {
        let sq: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
        (-self.gamma * sq).exp()
    }

    /// Decision value for class k on a standardized row.
    fn decision(&self, k: usize, q: &[f64]) -> f64 {
        let mut s = self.bias[k];
        for (i, x) in self.train_x.iter().enumerate() {
            let a = self.coef[k][i];
            if a != 0.0 {
                s += a * self.kernel(q, x);
            }
        }
        s
    }
}

impl Classifier for SvmClassifier {
    fn fit(&mut self, data: &Dataset, train_idx: &[usize]) {
        self.n_classes = data.n_classes;
        let n = train_idx.len();
        let d = data.n_features();
        let nf = n as f64;
        self.stats = (0..d)
            .map(|j| {
                let mean: f64 = train_idx.iter().map(|&i| data.x[(i, j)]).sum::<f64>() / nf;
                let var: f64 =
                    train_idx.iter().map(|&i| (data.x[(i, j)] - mean).powi(2)).sum::<f64>() / nf;
                (mean, var.sqrt().max(1e-12))
            })
            .collect();
        self.train_x = train_idx.iter().map(|&i| self.standardize(data.row(i))).collect();

        // Precompute the Gram matrix (n <= few hundred).
        let mut gram = vec![0.0; n * n];
        for i in 0..n {
            for j in i..n {
                let k = self.kernel(&self.train_x[i], &self.train_x[j]);
                gram[i * n + j] = k;
                gram[j * n + i] = k;
            }
        }

        self.coef = vec![vec![0.0; n]; self.n_classes];
        self.bias = vec![0.0; self.n_classes];
        // Functional gradient descent on regularized logistic loss:
        //   L = (1/n) Σ log(1 + e^{-y f_i}) + (λ/2n) αᵀKα,  λ = 1/C.
        // Step in function space (precondition by K): α -= lr (g + λα/n),
        // where g_i = -y_i σ(-y_i f_i)/n. Bounded gradients -> stable for
        // any C, unlike raw squared-hinge steps.
        let lambda = 1.0 / self.c;
        let lr = 2.0;
        for k in 0..self.n_classes {
            let ys: Vec<f64> = train_idx
                .iter()
                .map(|&i| if data.y[i] == k { 1.0 } else { -1.0 })
                .collect();
            for _ in 0..self.epochs {
                // f = K α + b (recomputed; n is small).
                let mut f = vec![self.bias[k]; n];
                for i in 0..n {
                    let a = self.coef[k][i];
                    if a != 0.0 {
                        for j in 0..n {
                            f[j] += a * gram[j * n + i];
                        }
                    }
                }
                let mut db = 0.0;
                for i in 0..n {
                    let s = 1.0 / (1.0 + (ys[i] * f[i]).exp()); // σ(-y f)
                    let g = -ys[i] * s / nf;
                    self.coef[k][i] -= lr * (g + lambda * self.coef[k][i] / nf);
                    db += g;
                }
                self.bias[k] -= lr * db;
            }
        }
    }

    fn predict_one(&self, row: &[f64]) -> usize {
        let q = self.standardize(row);
        let scores: Vec<f64> = (0..self.n_classes).map(|k| self.decision(k, &q)).collect();
        crate::util::stats::argmax(&scores).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::cv::cross_val_accuracy;
    use crate::ml::wine::default_wine;

    #[test]
    fn svm_reasonable_on_wine() {
        let data = default_wine();
        let acc = cross_val_accuracy(&data, 3, 5, || SvmClassifier::new(10.0, 0.05));
        assert!(acc > 0.82, "SVM accuracy {acc}");
    }

    #[test]
    fn extreme_gamma_overfits_to_chance() {
        // gamma huge -> kernel ~ identity -> no generalization.
        let data = default_wine();
        let good = cross_val_accuracy(&data, 3, 5, || SvmClassifier::new(10.0, 0.05));
        let bad = cross_val_accuracy(&data, 3, 5, || SvmClassifier::new(10.0, 1000.0));
        assert!(good > bad + 0.15, "good {good} vs bad {bad}");
    }

    #[test]
    fn from_config_clamps() {
        let svm = SvmClassifier::from_config(&Config::default());
        assert_eq!(svm.c, 1.0);
        assert_eq!(svm.gamma, 0.1);
    }
}
