//! Dense classification datasets + stratified splitting.

use crate::linalg::Matrix;
use crate::util::rng::Pcg64;

/// A labelled dataset: row-major features + integer class labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Matrix,
    pub y: Vec<usize>,
    pub n_classes: usize,
    pub feature_names: Vec<String>,
}

impl Dataset {
    pub fn new(x: Matrix, y: Vec<usize>, n_classes: usize) -> Self {
        assert_eq!(x.rows(), y.len(), "feature/label count mismatch");
        assert!(y.iter().all(|&c| c < n_classes), "label out of range");
        let d = x.cols();
        Self {
            x,
            y,
            n_classes,
            feature_names: (0..d).map(|i| format!("f{i}")).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn n_features(&self) -> usize {
        self.x.cols()
    }

    pub fn row(&self, i: usize) -> &[f64] {
        self.x.row(i)
    }

    /// Standardize features to zero mean / unit variance in place
    /// (returns per-feature (mean, std) for applying to new data).
    pub fn standardize(&mut self) -> Vec<(f64, f64)> {
        let n = self.len() as f64;
        let d = self.n_features();
        let mut stats = Vec::with_capacity(d);
        for j in 0..d {
            let mean: f64 = (0..self.len()).map(|i| self.x[(i, j)]).sum::<f64>() / n;
            let var: f64 =
                (0..self.len()).map(|i| (self.x[(i, j)] - mean).powi(2)).sum::<f64>() / n;
            let std = var.sqrt().max(1e-12);
            for i in 0..self.len() {
                self.x[(i, j)] = (self.x[(i, j)] - mean) / std;
            }
            stats.push((mean, std));
        }
        stats
    }

    /// Stratified k-fold indices: each fold preserves class proportions.
    /// Returns `k` (train, test) index pairs.
    pub fn stratified_kfold(&self, k: usize, rng: &mut Pcg64) -> Vec<(Vec<usize>, Vec<usize>)> {
        assert!(k >= 2, "need at least 2 folds");
        // Shuffle indices within each class, then deal them round-robin.
        let mut fold_of = vec![0usize; self.len()];
        for class in 0..self.n_classes {
            let mut idx: Vec<usize> =
                (0..self.len()).filter(|&i| self.y[i] == class).collect();
            rng.shuffle(&mut idx);
            for (pos, &i) in idx.iter().enumerate() {
                fold_of[i] = pos % k;
            }
        }
        (0..k)
            .map(|f| {
                let test: Vec<usize> =
                    (0..self.len()).filter(|&i| fold_of[i] == f).collect();
                let train: Vec<usize> =
                    (0..self.len()).filter(|&i| fold_of[i] != f).collect();
                (train, test)
            })
            .collect()
    }

    /// Per-class sample counts.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut c = vec![0; self.n_classes];
        for &y in &self.y {
            c[y] += 1;
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let x = Matrix::from_fn(12, 2, |i, j| (i * 2 + j) as f64);
        let y = vec![0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2];
        Dataset::new(x, y, 3)
    }

    #[test]
    fn kfold_partitions_and_stratifies() {
        let d = tiny();
        let mut rng = Pcg64::new(1);
        let folds = d.stratified_kfold(4, &mut rng);
        assert_eq!(folds.len(), 4);
        let mut seen = vec![false; 12];
        for (train, test) in &folds {
            assert_eq!(train.len() + test.len(), 12);
            // test fold has one sample of each class
            let classes: Vec<usize> = test.iter().map(|&i| d.y[i]).collect();
            for c in 0..3 {
                assert_eq!(classes.iter().filter(|&&x| x == c).count(), 1);
            }
            for &i in test {
                assert!(!seen[i], "index {i} in two test folds");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut d = tiny();
        d.standardize();
        for j in 0..2 {
            let mean: f64 = (0..12).map(|i| d.x[(i, j)]).sum::<f64>() / 12.0;
            let var: f64 = (0..12).map(|i| d.x[(i, j)].powi(2)).sum::<f64>() / 12.0;
            assert!(mean.abs() < 1e-10);
            assert!((var - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn class_counts() {
        assert_eq!(tiny().class_counts(), vec![4, 4, 4]);
    }

    #[test]
    #[should_panic(expected = "label out of range")]
    fn bad_labels_rejected() {
        Dataset::new(Matrix::zeros(2, 1), vec![0, 5], 3);
    }
}
