//! k-fold cross-validation — the objective function of the paper's Fig. 2
//! workload is mean CV accuracy of a classifier on wine.

use super::dataset::Dataset;
use super::metrics::accuracy;
use super::Classifier;
use crate::util::rng::Pcg64;

/// Mean stratified k-fold CV accuracy for a classifier factory.
///
/// `make` builds a fresh classifier per fold (classifiers are stateful).
/// The fold assignment derives from `seed`, so a fixed seed gives every
/// hyperparameter configuration the identical folds — the paper's setup.
pub fn cross_val_accuracy<C: Classifier>(
    data: &Dataset,
    k: usize,
    seed: u64,
    make: impl Fn() -> C,
) -> f64 {
    let mut rng = Pcg64::new(seed ^ 0xC0DE_F01D);
    let folds = data.stratified_kfold(k, &mut rng);
    let mut accs = Vec::with_capacity(k);
    for (train, test) in folds {
        let mut clf = make();
        clf.fit(data, &train);
        let pred = clf.predict(data, &test);
        let truth: Vec<usize> = test.iter().map(|&i| data.y[i]).collect();
        accs.push(accuracy(&truth, &pred));
    }
    crate::util::stats::mean(&accs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    /// Classifier that memorizes the majority class.
    struct Majority {
        class: usize,
    }

    impl Classifier for Majority {
        fn fit(&mut self, data: &Dataset, train_idx: &[usize]) {
            let mut counts = vec![0usize; data.n_classes];
            for &i in train_idx {
                counts[data.y[i]] += 1;
            }
            self.class = crate::util::stats::argmax(
                &counts.iter().map(|&c| c as f64).collect::<Vec<_>>(),
            )
            .unwrap();
        }

        fn predict_one(&self, _row: &[f64]) -> usize {
            self.class
        }
    }

    #[test]
    fn majority_classifier_gets_base_rate() {
        // 8 of class 0, 4 of class 1 -> majority accuracy ~ 2/3.
        let x = Matrix::zeros(12, 1);
        let y = vec![0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1];
        let d = Dataset::new(x, y, 2);
        let acc = cross_val_accuracy(&d, 4, 0, || Majority { class: 0 });
        assert!((acc - 8.0 / 12.0).abs() < 1e-9, "acc {acc}");
    }

    #[test]
    fn same_seed_same_folds() {
        let d = crate::ml::wine::generate(1, 1.6);
        let a = cross_val_accuracy(&d, 5, 42, || Majority { class: 0 });
        let b = cross_val_accuracy(&d, 5, 42, || Majority { class: 0 });
        assert_eq!(a, b);
    }
}
