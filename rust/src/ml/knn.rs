//! k-nearest-neighbours classifier (the paper's `KNN_Celery.ipynb` workload).

use super::dataset::Dataset;
use super::Classifier;
use crate::space::Config;

/// Distance weighting mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Weighting {
    Uniform,
    Distance,
}

/// kNN with Minkowski-p distance over standardized features.
pub struct KnnClassifier {
    pub k: usize,
    pub weighting: Weighting,
    pub p: f64,
    train: Vec<(Vec<f64>, usize)>,
    stats: Vec<(f64, f64)>,
    n_classes: usize,
}

impl KnnClassifier {
    pub fn new(k: usize, weighting: Weighting, p: f64) -> Self {
        assert!(k >= 1 && p > 0.0);
        Self { k, weighting, p, train: Vec::new(), stats: Vec::new(), n_classes: 0 }
    }

    /// Tuner mapping: `n_neighbors`, `weights` in {uniform, distance}, `p`.
    pub fn from_config(cfg: &Config) -> Self {
        let k = cfg.get_i64("n_neighbors").unwrap_or(5).max(1) as usize;
        let weighting = match cfg.get_str("weights") {
            Some("distance") => Weighting::Distance,
            _ => Weighting::Uniform,
        };
        let p = cfg.get_f64("p").unwrap_or(2.0).max(0.5);
        Self::new(k, weighting, p)
    }

    fn dist(&self, a: &[f64], b: &[f64]) -> f64 {
        let s: f64 = a
            .iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs().powf(self.p))
            .sum();
        s.powf(1.0 / self.p)
    }

    fn standardize(&self, row: &[f64]) -> Vec<f64> {
        row.iter()
            .enumerate()
            .map(|(j, &v)| {
                let (m, s) = self.stats[j];
                (v - m) / s
            })
            .collect()
    }
}

impl Classifier for KnnClassifier {
    fn fit(&mut self, data: &Dataset, train_idx: &[usize]) {
        self.n_classes = data.n_classes;
        let n = train_idx.len() as f64;
        let d = data.n_features();
        self.stats = (0..d)
            .map(|j| {
                let mean: f64 = train_idx.iter().map(|&i| data.x[(i, j)]).sum::<f64>() / n;
                let var: f64 =
                    train_idx.iter().map(|&i| (data.x[(i, j)] - mean).powi(2)).sum::<f64>() / n;
                (mean, var.sqrt().max(1e-12))
            })
            .collect();
        self.train = train_idx
            .iter()
            .map(|&i| (self.standardize(data.row(i)), data.y[i]))
            .collect();
    }

    fn predict_one(&self, row: &[f64]) -> usize {
        let q = self.standardize(row);
        let mut dists: Vec<(f64, usize)> =
            self.train.iter().map(|(x, y)| (self.dist(&q, x), *y)).collect();
        let k = self.k.min(dists.len());
        dists.select_nth_unstable_by(k.saturating_sub(1), |a, b| a.0.total_cmp(&b.0));
        let mut votes = vec![0.0; self.n_classes];
        for &(d, y) in dists.iter().take(k) {
            let w = match self.weighting {
                Weighting::Uniform => 1.0,
                Weighting::Distance => 1.0 / (d + 1e-9),
            };
            votes[y] += w;
        }
        crate::util::stats::argmax(&votes).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::cv::cross_val_accuracy;
    use crate::ml::wine::default_wine;
    use crate::space::ParamValue;

    #[test]
    fn knn_does_well_on_wine() {
        let data = default_wine();
        let acc =
            cross_val_accuracy(&data, 5, 3, || KnnClassifier::new(7, Weighting::Distance, 2.0));
        assert!(acc > 0.85, "kNN accuracy {acc}");
    }

    #[test]
    fn k_one_memorizes_training_data() {
        let data = default_wine();
        let idx: Vec<usize> = (0..data.len()).collect();
        let mut knn = KnnClassifier::new(1, Weighting::Uniform, 2.0);
        knn.fit(&data, &idx);
        let pred = knn.predict(&data, &idx);
        assert_eq!(pred, data.y, "1-NN must be perfect on its own train set");
    }

    #[test]
    fn from_config_defaults_and_mapping() {
        let cfg = Config::new(vec![
            ("n_neighbors".into(), ParamValue::Int(11)),
            ("weights".into(), ParamValue::Str("distance".into())),
            ("p".into(), ParamValue::F64(1.0)),
        ]);
        let knn = KnnClassifier::from_config(&cfg);
        assert_eq!(knn.k, 11);
        assert_eq!(knn.weighting, Weighting::Distance);
        assert_eq!(knn.p, 1.0);
        let d = KnnClassifier::from_config(&Config::default());
        assert_eq!(d.k, 5);
        assert_eq!(d.weighting, Weighting::Uniform);
    }
}
