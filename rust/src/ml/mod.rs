//! ML substrate: everything the paper's evaluation workloads need, built
//! from scratch — datasets ([`dataset`], [`wine`]), classifiers
//! ([`gbt`] = the XGBoost substitute, [`knn`], [`svm`]), cross-validation
//! ([`cv`]) and metrics ([`metrics`]).

pub mod cv;
pub mod dataset;
pub mod gbt;
pub mod knn;
pub mod metrics;
pub mod svm;
pub mod wine;

pub use dataset::Dataset;

/// A trainable multi-class classifier over dense feature rows.
pub trait Classifier {
    /// Fit on rows `x[train_idx]` with labels `y[train_idx]`.
    fn fit(&mut self, data: &Dataset, train_idx: &[usize]);

    /// Predict the class of one feature row.
    fn predict_one(&self, row: &[f64]) -> usize;

    /// Predict classes for a set of rows of `data`.
    fn predict(&self, data: &Dataset, idx: &[usize]) -> Vec<usize> {
        idx.iter().map(|&i| self.predict_one(data.row(i))).collect()
    }
}
