//! Gradient-boosted-trees classifier — the in-repo XGBoost substitute
//! (DESIGN.md §2). Exposes exactly the hyperparameters of the paper's
//! Listing 1 with the same semantics:
//!
//! * `learning_rate` — shrinkage per boosting round,
//! * `gamma` — minimum split gain (xgboost's min_split_loss),
//! * `max_depth` — tree depth limit,
//! * `n_estimators` — boosting rounds,
//! * `booster` — `gbtree` | `gblinear` | `dart`.
//!
//! Multi-class softmax objective: per round, one regression tree (or linear
//! update) per class on the gradient/hessian pairs, exactly xgboost's
//! formulation: gain = ½[G_L²/(H_L+λ) + G_R²/(H_R+λ) − G²/(H+λ)] − γ,
//! leaf weight = −G/(H+λ). Trees are histogram-based (16 quantile bins) —
//! the response surface to hyperparameters is what Fig. 2 measures, and it
//! is preserved; absolute training speed is what the histogram buys.

mod linear;
mod tree;

pub use tree::RegressionTree;

use super::dataset::Dataset;
use super::Classifier;
use crate::space::Config;
use crate::util::rng::Pcg64;
use linear::LinearBooster;
use tree::{BinnedFeatures, TreeBuilder};

/// Which additive booster to use per round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Booster {
    GbTree,
    GbLinear,
    Dart,
}

impl Booster {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "gbtree" => Some(Booster::GbTree),
            "gblinear" => Some(Booster::GbLinear),
            "dart" => Some(Booster::Dart),
            _ => None,
        }
    }
}

/// GBT hyperparameters (defaults mirror xgboost's).
#[derive(Clone, Debug)]
pub struct GbtParams {
    pub learning_rate: f64,
    pub gamma: f64,
    pub max_depth: usize,
    pub n_estimators: usize,
    pub booster: Booster,
    pub reg_lambda: f64,
    /// DART dropout probability per existing tree.
    pub dart_rate: f64,
    pub seed: u64,
}

impl Default for GbtParams {
    fn default() -> Self {
        Self {
            learning_rate: 0.3,
            gamma: 0.0,
            max_depth: 6,
            n_estimators: 100,
            booster: Booster::GbTree,
            reg_lambda: 1.0,
            dart_rate: 0.1,
            seed: 0,
        }
    }
}

impl GbtParams {
    /// Build from a tuner [`Config`] using the paper's Listing 1 names.
    pub fn from_config(cfg: &Config) -> Self {
        let mut p = Self::default();
        if let Some(v) = cfg.get_f64("learning_rate") {
            // lr = 0 learns nothing; clamp to a tiny positive step.
            p.learning_rate = v.max(1e-3);
        }
        if let Some(v) = cfg.get_f64("gamma") {
            p.gamma = v.max(0.0);
        }
        if let Some(v) = cfg.get_i64("max_depth") {
            p.max_depth = v.max(1) as usize;
        }
        if let Some(v) = cfg.get_i64("n_estimators") {
            p.n_estimators = v.max(1) as usize;
        }
        if let Some(s) = cfg.get_str("booster") {
            p.booster = Booster::from_str(s).unwrap_or(Booster::GbTree);
        }
        p
    }
}

/// The fitted model: per-class additive ensembles.
pub struct GbtClassifier {
    params: GbtParams,
    n_classes: usize,
    /// trees[k] = (scale, tree) list for class k (scale carries DART norm).
    trees: Vec<Vec<(f64, RegressionTree)>>,
    linear: Option<LinearBooster>,
    base_score: Vec<f64>,
}

impl GbtClassifier {
    pub fn new(params: GbtParams) -> Self {
        Self { params, n_classes: 0, trees: Vec::new(), linear: None, base_score: Vec::new() }
    }

    /// Per-class raw scores (before softmax) for one row.
    fn raw_scores(&self, row: &[f64]) -> Vec<f64> {
        let mut f = self.base_score.clone();
        for k in 0..self.n_classes {
            for (scale, t) in &self.trees[k] {
                f[k] += scale * t.predict(row);
            }
        }
        if let Some(lin) = &self.linear {
            let lf = lin.predict(row);
            for k in 0..self.n_classes {
                f[k] += lf[k];
            }
        }
        f
    }

    /// Softmax class probabilities for one row.
    pub fn predict_proba(&self, row: &[f64]) -> Vec<f64> {
        softmax(&self.raw_scores(row))
    }

    fn fit_trees(&mut self, data: &Dataset, train_idx: &[usize], dart: bool) {
        let k_classes = self.n_classes;
        let n = train_idx.len();
        let binned = BinnedFeatures::build(data, train_idx, 16);
        let mut rng = Pcg64::new(self.params.seed ^ 0x6B7);
        // Cached per-tree predictions on the train rows: pred[k][ti][i].
        // Lets gbtree update scores incrementally and DART recompute scores
        // under arbitrary dropout/rescale without touching raw features.
        let mut tree_pred: Vec<Vec<Vec<f64>>> = vec![Vec::new(); k_classes];
        // f[k][i]: raw score of train sample i for class k (no dropout).
        let mut f = vec![vec![0.0f64; n]; k_classes];

        for _round in 0..self.params.n_estimators {
            // DART: sample per-class dropout sets over existing trees.
            let dropped: Vec<Vec<usize>> = (0..k_classes)
                .map(|k| {
                    if dart {
                        (0..self.trees[k].len())
                            .filter(|_| rng.next_f64() < self.params.dart_rate)
                            .collect()
                    } else {
                        Vec::new()
                    }
                })
                .collect();

            // Scores used for this round's gradients (dropout applied).
            let mut use_f = f.clone();
            for k in 0..k_classes {
                for &ti in &dropped[k] {
                    let scale = self.trees[k][ti].0;
                    for i in 0..n {
                        use_f[k][i] -= scale * tree_pred[k][ti][i];
                    }
                }
            }

            // Softmax probabilities per sample.
            let mut probs = vec![vec![0.0f64; n]; k_classes];
            for i in 0..n {
                let scores: Vec<f64> = (0..k_classes).map(|k| use_f[k][i]).collect();
                let p = softmax(&scores);
                for k in 0..k_classes {
                    probs[k][i] = p[k];
                }
            }

            for k in 0..k_classes {
                // Gradient/hessian of softmax cross-entropy.
                let mut grad = vec![0.0; n];
                let mut hess = vec![0.0; n];
                for (i, &ri) in train_idx.iter().enumerate() {
                    let p = probs[k][i];
                    let y = if data.y[ri] == k { 1.0 } else { 0.0 };
                    grad[i] = p - y;
                    hess[i] = (p * (1.0 - p)).max(1e-16);
                }
                let tree = TreeBuilder {
                    max_depth: self.params.max_depth,
                    gamma: self.params.gamma,
                    reg_lambda: self.params.reg_lambda,
                    min_child_weight: 1e-3,
                }
                .build(&binned, &grad, &hess);
                let new_pred: Vec<f64> =
                    train_idx.iter().map(|&ri| tree.predict(data.row(ri))).collect();

                let n_drop = dropped[k].len();
                let eff_scale = if n_drop > 0 {
                    // DART normalization: dropped trees shrink by d/(d+1),
                    // the new tree lands with lr/(d+1).
                    let factor = n_drop as f64 / (n_drop as f64 + 1.0);
                    for &ti in &dropped[k] {
                        let old_scale = self.trees[k][ti].0;
                        let delta = old_scale * (factor - 1.0);
                        for i in 0..n {
                            f[k][i] += delta * tree_pred[k][ti][i];
                        }
                        self.trees[k][ti].0 *= factor;
                    }
                    self.params.learning_rate / (n_drop as f64 + 1.0)
                } else {
                    self.params.learning_rate
                };
                for i in 0..n {
                    f[k][i] += eff_scale * new_pred[i];
                }
                self.trees[k].push((eff_scale, tree));
                tree_pred[k].push(new_pred);
            }
        }
    }
}

impl Classifier for GbtClassifier {
    fn fit(&mut self, data: &Dataset, train_idx: &[usize]) {
        self.n_classes = data.n_classes;
        self.trees = vec![Vec::new(); data.n_classes];
        self.linear = None;
        self.base_score = vec![0.0; data.n_classes];
        match self.params.booster {
            Booster::GbLinear => {
                let mut lin = LinearBooster::new(
                    data.n_features(),
                    data.n_classes,
                    self.params.learning_rate,
                    self.params.reg_lambda,
                );
                lin.fit(data, train_idx, self.params.n_estimators);
                self.linear = Some(lin);
            }
            Booster::GbTree => self.fit_trees(data, train_idx, false),
            Booster::Dart => self.fit_trees(data, train_idx, true),
        }
    }

    fn predict_one(&self, row: &[f64]) -> usize {
        let scores = self.raw_scores(row);
        crate::util::stats::argmax(&scores).unwrap_or(0)
    }
}

fn softmax(scores: &[f64]) -> Vec<f64> {
    let max = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = scores.iter().map(|s| (s - max).exp()).collect();
    let sum: f64 = exps.iter().sum();
    exps.iter().map(|e| e / sum).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::cv::cross_val_accuracy;
    use crate::ml::wine::default_wine;
    use crate::space::ParamValue;

    fn fit_predict_acc(params: GbtParams) -> f64 {
        let data = default_wine();
        cross_val_accuracy(&data, 3, 7, || GbtClassifier::new(params.clone()))
    }

    #[test]
    fn gbtree_beats_chance_comfortably() {
        let acc = fit_predict_acc(GbtParams {
            n_estimators: 60,
            max_depth: 4,
            learning_rate: 0.3,
            ..Default::default()
        });
        assert!(acc > 0.82, "gbtree CV accuracy {acc}");
    }

    #[test]
    fn gblinear_works_on_nearly_linear_data() {
        let acc = fit_predict_acc(GbtParams {
            booster: Booster::GbLinear,
            n_estimators: 80,
            learning_rate: 0.3,
            ..Default::default()
        });
        assert!(acc > 0.78, "gblinear CV accuracy {acc}");
    }

    #[test]
    fn dart_comparable_to_gbtree() {
        let acc = fit_predict_acc(GbtParams {
            booster: Booster::Dart,
            n_estimators: 60,
            max_depth: 4,
            ..Default::default()
        });
        assert!(acc > 0.78, "dart CV accuracy {acc}");
    }

    #[test]
    fn hyperparameters_move_the_response_surface() {
        // Terrible config must clearly underperform a good one — this is the
        // property Fig. 2's tuning curves rely on.
        let bad = fit_predict_acc(GbtParams {
            learning_rate: 1e-3,
            n_estimators: 2,
            max_depth: 1,
            ..Default::default()
        });
        let good = fit_predict_acc(GbtParams {
            learning_rate: 0.3,
            n_estimators: 80,
            max_depth: 4,
            ..Default::default()
        });
        assert!(good > bad + 0.1, "good {good} vs bad {bad}");
    }

    #[test]
    fn gamma_prunes_to_stumps() {
        // Huge gamma forbids all splits -> ~chance accuracy.
        let acc = fit_predict_acc(GbtParams { gamma: 1e9, ..Default::default() });
        assert!(acc < 0.70, "gamma=1e9 should cripple the model, got {acc}");
    }

    #[test]
    fn from_config_maps_listing1_names() {
        let cfg = Config::new(vec![
            ("learning_rate".into(), ParamValue::F64(0.12)),
            ("gamma".into(), ParamValue::F64(2.5)),
            ("max_depth".into(), ParamValue::Int(7)),
            ("n_estimators".into(), ParamValue::Int(55)),
            ("booster".into(), ParamValue::Str("dart".into())),
        ]);
        let p = GbtParams::from_config(&cfg);
        assert_eq!(p.learning_rate, 0.12);
        assert_eq!(p.gamma, 2.5);
        assert_eq!(p.max_depth, 7);
        assert_eq!(p.n_estimators, 55);
        assert_eq!(p.booster, Booster::Dart);
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }
}
