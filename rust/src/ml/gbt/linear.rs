//! `gblinear` booster: additive linear model trained by cyclic coordinate
//! Newton steps on the softmax objective (xgboost's linear updater).

use crate::ml::dataset::Dataset;

pub struct LinearBooster {
    n_features: usize,
    n_classes: usize,
    learning_rate: f64,
    reg_lambda: f64,
    /// weights[k * (d + 1) + j], last column is the bias.
    weights: Vec<f64>,
    /// feature standardization (mean, std) captured at fit time.
    stats: Vec<(f64, f64)>,
}

impl LinearBooster {
    pub fn new(n_features: usize, n_classes: usize, learning_rate: f64, reg_lambda: f64) -> Self {
        Self {
            n_features,
            n_classes,
            learning_rate,
            reg_lambda,
            weights: vec![0.0; n_classes * (n_features + 1)],
            stats: vec![(0.0, 1.0); n_features],
        }
    }

    #[inline]
    fn w(&self, k: usize, j: usize) -> f64 {
        self.weights[k * (self.n_features + 1) + j]
    }

    fn standardized(&self, row: &[f64], j: usize) -> f64 {
        let (m, s) = self.stats[j];
        (row[j] - m) / s
    }

    /// Raw per-class scores for one row.
    pub fn predict(&self, row: &[f64]) -> Vec<f64> {
        (0..self.n_classes)
            .map(|k| {
                let mut s = self.w(k, self.n_features); // bias
                for j in 0..self.n_features {
                    s += self.w(k, j) * self.standardized(row, j);
                }
                s
            })
            .collect()
    }

    pub fn fit(&mut self, data: &Dataset, train_idx: &[usize], rounds: usize) {
        let n = train_idx.len();
        let d = self.n_features;
        // Standardize features over the training rows (gblinear needs it).
        for j in 0..d {
            let mean: f64 = train_idx.iter().map(|&i| data.x[(i, j)]).sum::<f64>() / n as f64;
            let var: f64 = train_idx
                .iter()
                .map(|&i| (data.x[(i, j)] - mean).powi(2))
                .sum::<f64>()
                / n as f64;
            self.stats[j] = (mean, var.sqrt().max(1e-12));
        }
        // Cache standardized training matrix.
        let mut xstd = vec![0.0; n * d];
        for (r, &i) in train_idx.iter().enumerate() {
            for j in 0..d {
                xstd[r * d + j] = self.standardized(data.row(i), j);
            }
        }
        // f[k][i]: current raw scores.
        let mut f = vec![vec![0.0f64; n]; self.n_classes];
        for _ in 0..rounds {
            // softmax probabilities
            let mut probs = vec![vec![0.0f64; n]; self.n_classes];
            for i in 0..n {
                let mx = (0..self.n_classes).map(|k| f[k][i]).fold(f64::NEG_INFINITY, f64::max);
                let mut z = 0.0;
                for k in 0..self.n_classes {
                    let e = (f[k][i] - mx).exp();
                    probs[k][i] = e;
                    z += e;
                }
                for k in 0..self.n_classes {
                    probs[k][i] /= z;
                }
            }
            for k in 0..self.n_classes {
                // bias + cyclic coordinate Newton updates
                let mut gsum = 0.0;
                let mut hsum = 0.0;
                for (i, &ri) in train_idx.iter().enumerate() {
                    let y = if data.y[ri] == k { 1.0 } else { 0.0 };
                    gsum += probs[k][i] - y;
                    hsum += (probs[k][i] * (1.0 - probs[k][i])).max(1e-16);
                }
                let db = -self.learning_rate * gsum / (hsum + self.reg_lambda);
                self.weights[k * (d + 1) + d] += db;
                for i in 0..n {
                    f[k][i] += db;
                }
                for j in 0..d {
                    let mut gj = 0.0;
                    let mut hj = 0.0;
                    for (i, &ri) in train_idx.iter().enumerate() {
                        let y = if data.y[ri] == k { 1.0 } else { 0.0 };
                        let g = probs[k][i] - y;
                        let h = (probs[k][i] * (1.0 - probs[k][i])).max(1e-16);
                        let x = xstd[i * d + j];
                        gj += g * x;
                        hj += h * x * x;
                    }
                    gj += self.reg_lambda * self.w(k, j);
                    let dw = -self.learning_rate * gj / (hj + self.reg_lambda);
                    self.weights[k * (d + 1) + j] += dw;
                    for i in 0..n {
                        f[k][i] += dw * xstd[i * d + j];
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    #[test]
    fn separates_linear_classes() {
        // Two linearly separable blobs in 2-D.
        let n = 60;
        let x = Matrix::from_fn(n, 2, |i, j| {
            let c = if i < n / 2 { -1.0 } else { 1.0 };
            c * (1.0 + j as f64) + ((i * 7 + j * 3) % 11) as f64 * 0.02
        });
        let y: Vec<usize> = (0..n).map(|i| usize::from(i >= n / 2)).collect();
        let data = Dataset::new(x, y.clone(), 2);
        let idx: Vec<usize> = (0..n).collect();
        let mut lin = LinearBooster::new(2, 2, 0.5, 1.0);
        lin.fit(&data, &idx, 30);
        let mut hits = 0;
        for i in 0..n {
            let scores = lin.predict(data.row(i));
            let pred = usize::from(scores[1] > scores[0]);
            hits += usize::from(pred == y[i]);
        }
        assert!(hits as f64 / n as f64 > 0.95, "hits {hits}/{n}");
    }

    #[test]
    fn zero_rounds_predicts_zero() {
        let lin = LinearBooster::new(3, 2, 0.3, 1.0);
        let s = lin.predict(&[1.0, 2.0, 3.0]);
        assert_eq!(s, vec![0.0, 0.0]);
    }
}
