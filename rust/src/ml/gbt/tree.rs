//! Histogram-based regression trees for the GBT booster.
//!
//! Features are pre-binned into quantile bins once per fit
//! ([`BinnedFeatures`]); each node accumulates per-bin (G, H) and scans
//! bin boundaries for the xgboost gain. Split thresholds are stored as raw
//! feature values, so prediction needs no binning.

use crate::ml::dataset::Dataset;

/// Quantile-binned view of the training rows.
pub struct BinnedFeatures {
    /// bins[i * d + j]: bin index of train sample i, feature j.
    bins: Vec<u8>,
    /// edges[j][b]: raw-value upper edge of bin b for feature j; splitting
    /// at bin b sends `value <= edges[j][b]` left.
    edges: Vec<Vec<f64>>,
    pub n_rows: usize,
    pub n_features: usize,
    pub n_bins: usize,
}

impl BinnedFeatures {
    /// Quantile-bin `train_idx` rows of `data` into at most `n_bins` bins.
    pub fn build(data: &Dataset, train_idx: &[usize], n_bins: usize) -> Self {
        assert!(n_bins >= 2 && n_bins <= 256);
        let n = train_idx.len();
        let d = data.n_features();
        let mut edges = Vec::with_capacity(d);
        for j in 0..d {
            let mut vals: Vec<f64> = train_idx.iter().map(|&i| data.x[(i, j)]).collect();
            vals.sort_by(|a, b| a.total_cmp(b));
            let mut e = Vec::with_capacity(n_bins);
            for b in 1..n_bins {
                let pos = (b * n) / n_bins;
                let v = vals[pos.min(n - 1)];
                if e.last().map_or(true, |&last| v > last) {
                    e.push(v);
                }
            }
            edges.push(e); // possibly fewer edges if feature has few values
        }
        let mut bins = vec![0u8; n * d];
        for (i, &ri) in train_idx.iter().enumerate() {
            for j in 0..d {
                let v = data.x[(ri, j)];
                // bin = count of edges strictly below v.
                let b = edges[j].partition_point(|&e| e < v);
                bins[i * d + j] = b as u8;
            }
        }
        Self { bins, edges, n_rows: n, n_features: d, n_bins }
    }

    #[inline]
    fn bin(&self, i: usize, j: usize) -> usize {
        self.bins[i * self.n_features + j] as usize
    }
}

/// A fitted regression tree (array-encoded).
#[derive(Clone, Debug)]
pub struct RegressionTree {
    nodes: Vec<Node>,
}

#[derive(Clone, Debug)]
enum Node {
    Leaf { value: f64 },
    Split { feature: usize, threshold: f64, left: usize, right: usize },
}

impl RegressionTree {
    /// Predict the leaf value for a raw feature row.
    pub fn predict(&self, row: &[f64]) -> f64 {
        let mut idx = 0;
        loop {
            match &self.nodes[idx] {
                Node::Leaf { value } => return *value,
                Node::Split { feature, threshold, left, right } => {
                    idx = if row[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn depth(&self) -> usize {
        fn go(nodes: &[Node], i: usize) -> usize {
            match &nodes[i] {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => 1 + go(nodes, *left).max(go(nodes, *right)),
            }
        }
        go(&self.nodes, 0)
    }
}

/// xgboost-style tree construction parameters.
pub struct TreeBuilder {
    pub max_depth: usize,
    /// Minimum split gain (xgboost min_split_loss).
    pub gamma: f64,
    pub reg_lambda: f64,
    pub min_child_weight: f64,
}

impl TreeBuilder {
    /// Fit a tree to (grad, hess) over the binned training rows.
    pub fn build(&self, b: &BinnedFeatures, grad: &[f64], hess: &[f64]) -> RegressionTree {
        assert_eq!(grad.len(), b.n_rows);
        assert_eq!(hess.len(), b.n_rows);
        let idx: Vec<u32> = (0..b.n_rows as u32).collect();
        let mut nodes = Vec::new();
        self.grow(b, grad, hess, idx, 0, &mut nodes);
        RegressionTree { nodes }
    }

    /// Returns the node index of the subtree root.
    fn grow(
        &self,
        b: &BinnedFeatures,
        grad: &[f64],
        hess: &[f64],
        idx: Vec<u32>,
        depth: usize,
        nodes: &mut Vec<Node>,
    ) -> usize {
        let g_sum: f64 = idx.iter().map(|&i| grad[i as usize]).sum();
        let h_sum: f64 = idx.iter().map(|&i| hess[i as usize]).sum();
        let leaf = |nodes: &mut Vec<Node>| {
            let value = -g_sum / (h_sum + self.reg_lambda);
            nodes.push(Node::Leaf { value });
            nodes.len() - 1
        };
        if depth >= self.max_depth || idx.len() < 2 {
            return leaf(nodes);
        }

        // Best split across features/bins by xgboost gain.
        let parent_score = g_sum * g_sum / (h_sum + self.reg_lambda);
        let mut best: Option<(f64, usize, usize)> = None; // (gain, feature, bin)
        let mut gh = vec![(0.0f64, 0.0f64); b.n_bins];
        for j in 0..b.n_features {
            if b.edges[j].is_empty() {
                continue;
            }
            for e in gh.iter_mut() {
                *e = (0.0, 0.0);
            }
            for &i in &idx {
                let bin = b.bin(i as usize, j);
                gh[bin].0 += grad[i as usize];
                gh[bin].1 += hess[i as usize];
            }
            let (mut gl, mut hl) = (0.0, 0.0);
            // Split after bin `s`: left = bins <= s (edge s exists for s < edges.len()).
            for s in 0..b.edges[j].len() {
                gl += gh[s].0;
                hl += gh[s].1;
                let (gr, hr) = (g_sum - gl, h_sum - hl);
                if hl < self.min_child_weight || hr < self.min_child_weight {
                    continue;
                }
                let gain = 0.5
                    * (gl * gl / (hl + self.reg_lambda) + gr * gr / (hr + self.reg_lambda)
                        - parent_score)
                    - self.gamma;
                if gain > 0.0 && best.map_or(true, |(bg, _, _)| gain > bg) {
                    best = Some((gain, j, s));
                }
            }
        }

        let Some((_, feature, split_bin)) = best else {
            return leaf(nodes);
        };
        let threshold = b.edges[feature][split_bin];
        let (mut li, mut ri) = (Vec::new(), Vec::new());
        for &i in &idx {
            if b.bin(i as usize, feature) <= split_bin {
                li.push(i);
            } else {
                ri.push(i);
            }
        }
        debug_assert!(!li.is_empty() && !ri.is_empty());
        let node_pos = nodes.len();
        nodes.push(Node::Leaf { value: 0.0 }); // placeholder
        let left = self.grow(b, grad, hess, li, depth + 1, nodes);
        let right = self.grow(b, grad, hess, ri, depth + 1, nodes);
        nodes[node_pos] = Node::Split { feature, threshold, left, right };
        node_pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn step_data(n: usize) -> (Dataset, Vec<f64>, Vec<f64>) {
        // y = 1 for x > 0.5 else -1, single feature.
        let x = Matrix::from_fn(n, 1, |i, _| i as f64 / n as f64);
        let labels: Vec<usize> = (0..n).map(|i| usize::from(i as f64 / n as f64 > 0.5)).collect();
        let d = Dataset::new(x, labels.clone(), 2);
        let grad: Vec<f64> = labels.iter().map(|&l| if l == 1 { -1.0 } else { 1.0 }).collect();
        let hess = vec![1.0; n];
        (d, grad, hess)
    }

    fn builder() -> TreeBuilder {
        TreeBuilder { max_depth: 3, gamma: 0.0, reg_lambda: 1.0, min_child_weight: 1e-3 }
    }

    #[test]
    fn learns_step_function() {
        let (d, grad, hess) = step_data(64);
        let idx: Vec<usize> = (0..64).collect();
        let b = BinnedFeatures::build(&d, &idx, 16);
        let tree = builder().build(&b, &grad, &hess);
        // -grad/(h+λ): left region ~ -1 * n/(n+1) < 0, right > 0 — in
        // gradient-boosting convention, prediction = -grad direction.
        assert!(tree.predict(&[0.1]) < -0.3);
        assert!(tree.predict(&[0.9]) > 0.3);
    }

    #[test]
    fn depth_limit_respected() {
        let (d, grad, hess) = step_data(128);
        let idx: Vec<usize> = (0..128).collect();
        let b = BinnedFeatures::build(&d, &idx, 16);
        for depth in 1..5 {
            let t = TreeBuilder { max_depth: depth, ..builder() }.build(&b, &grad, &hess);
            assert!(t.depth() <= depth, "depth {} > {}", t.depth(), depth);
        }
    }

    #[test]
    fn huge_gamma_yields_single_leaf() {
        let (d, grad, hess) = step_data(64);
        let idx: Vec<usize> = (0..64).collect();
        let b = BinnedFeatures::build(&d, &idx, 16);
        let t = TreeBuilder { gamma: 1e12, ..builder() }.build(&b, &grad, &hess);
        assert_eq!(t.n_nodes(), 1);
    }

    #[test]
    fn constant_feature_no_split() {
        let x = Matrix::from_fn(32, 1, |_, _| 1.0);
        let dset = Dataset::new(x, vec![0; 32], 1);
        let idx: Vec<usize> = (0..32).collect();
        let b = BinnedFeatures::build(&dset, &idx, 16);
        let t = builder().build(&b, &vec![1.0; 32], &vec![1.0; 32]);
        assert_eq!(t.n_nodes(), 1);
    }

    #[test]
    fn binning_respects_order() {
        let x = Matrix::from_fn(100, 1, |i, _| i as f64);
        let dset = Dataset::new(x, vec![0; 100], 1);
        let idx: Vec<usize> = (0..100).collect();
        let b = BinnedFeatures::build(&dset, &idx, 8);
        let mut last = 0;
        for i in 0..100 {
            let bin = b.bin(i, 0);
            assert!(bin >= last, "bins must be monotone in value");
            last = bin;
        }
        assert!(last >= 6, "should use most of the 8 bins, got max {last}");
    }

    #[test]
    fn leaf_value_is_newton_step() {
        // One node, grads sum G=6, hess sum H=2, lambda=1 -> -6/3 = -2.
        let x = Matrix::from_fn(2, 1, |_, _| 1.0);
        let dset = Dataset::new(x, vec![0, 0], 1);
        let b = BinnedFeatures::build(&dset, &[0, 1], 4);
        let t = builder().build(&b, &[2.0, 4.0], &[1.0, 1.0]);
        assert!((t.predict(&[1.0]) + 2.0).abs() < 1e-12);
    }
}
