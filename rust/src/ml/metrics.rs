//! Classification metrics.

/// Fraction of matching predictions.
pub fn accuracy(y_true: &[usize], y_pred: &[usize]) -> f64 {
    assert_eq!(y_true.len(), y_pred.len());
    if y_true.is_empty() {
        return 0.0;
    }
    let hits = y_true.iter().zip(y_pred).filter(|(a, b)| a == b).count();
    hits as f64 / y_true.len() as f64
}

/// Row = true class, column = predicted class.
pub fn confusion_matrix(y_true: &[usize], y_pred: &[usize], n_classes: usize) -> Vec<Vec<usize>> {
    let mut m = vec![vec![0usize; n_classes]; n_classes];
    for (&t, &p) in y_true.iter().zip(y_pred) {
        m[t][p] += 1;
    }
    m
}

/// Macro-averaged F1 score.
pub fn macro_f1(y_true: &[usize], y_pred: &[usize], n_classes: usize) -> f64 {
    let cm = confusion_matrix(y_true, y_pred, n_classes);
    let mut f1s = Vec::with_capacity(n_classes);
    for c in 0..n_classes {
        let tp = cm[c][c] as f64;
        let fp: f64 = (0..n_classes).filter(|&r| r != c).map(|r| cm[r][c] as f64).sum();
        let fn_: f64 = (0..n_classes).filter(|&p| p != c).map(|p| cm[c][p] as f64).sum();
        let precision = if tp + fp > 0.0 { tp / (tp + fp) } else { 0.0 };
        let recall = if tp + fn_ > 0.0 { tp / (tp + fn_) } else { 0.0 };
        let f1 = if precision + recall > 0.0 {
            2.0 * precision * recall / (precision + recall)
        } else {
            0.0
        };
        f1s.push(f1);
    }
    f1s.iter().sum::<f64>() / n_classes as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[0, 1, 2, 1], &[0, 1, 1, 1]), 0.75);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn confusion_matrix_counts() {
        let cm = confusion_matrix(&[0, 0, 1, 1], &[0, 1, 1, 1], 2);
        assert_eq!(cm, vec![vec![1, 1], vec![0, 2]]);
    }

    #[test]
    fn perfect_f1_is_one() {
        let y = [0, 1, 2, 0, 1, 2];
        assert!((macro_f1(&y, &y, 3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn f1_degrades_with_errors() {
        let yt = [0, 0, 1, 1];
        let yp = [0, 1, 0, 1];
        let f1 = macro_f1(&yt, &yp, 2);
        assert!((f1 - 0.5).abs() < 1e-12);
    }
}
