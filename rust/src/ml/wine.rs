//! Wine-like dataset generator (substitution for UCI wine, see DESIGN.md §2).
//!
//! The UCI wine dataset is 178 samples x 13 chemical features, 3 cultivars
//! with counts (59, 71, 48). This generator reproduces those shapes and the
//! published per-class feature statistics (means/spreads from the UCI
//! summary), with controlled between-class overlap so that classifier
//! accuracy responds to hyperparameters the way Fig. 2's response surface
//! does: bad configs ~0.6-0.85, tuned configs >= 0.95.
//!
//! Deterministic given a seed — every Fig. 2 repeat sees the same data.

use super::dataset::Dataset;
use crate::linalg::Matrix;
use crate::util::rng::Pcg64;

/// Feature names of the UCI wine dataset.
pub const FEATURES: [&str; 13] = [
    "alcohol",
    "malic_acid",
    "ash",
    "alcalinity",
    "magnesium",
    "total_phenols",
    "flavanoids",
    "nonflavanoid_phenols",
    "proanthocyanins",
    "color_intensity",
    "hue",
    "od280_od315",
    "proline",
];

/// Per-class feature means, shaped on the UCI wine class statistics.
const CLASS_MEANS: [[f64; 13]; 3] = [
    // cultivar 1 (n=59): high alcohol, high flavanoids, high proline
    [13.74, 2.01, 2.46, 17.0, 106.3, 2.84, 2.98, 0.29, 1.90, 5.53, 1.06, 3.16, 1115.0],
    // cultivar 2 (n=71): low alcohol, low color intensity
    [12.28, 1.93, 2.24, 20.2, 94.5, 2.26, 2.08, 0.36, 1.63, 3.09, 1.06, 2.79, 519.0],
    // cultivar 3 (n=48): high malic acid, high color, low flavanoids
    [13.15, 3.33, 2.44, 21.4, 99.3, 1.68, 0.78, 0.45, 1.15, 7.40, 0.68, 1.68, 630.0],
];

/// Per-feature standard deviations (shared across classes; inflated by
/// `overlap` to control class separability).
const FEATURE_STD: [f64; 13] =
    [0.46, 0.99, 0.27, 3.3, 14.3, 0.55, 0.70, 0.12, 0.55, 1.6, 0.20, 0.50, 210.0];

/// Class sizes of the real dataset.
pub const CLASS_SIZES: [usize; 3] = [59, 71, 48];

/// Generate the wine-like dataset. `overlap` >= 1.0 widens class spread
/// (1.6 gives a Fig.2-like accuracy dynamic range; 1.0 is nearly separable).
pub fn generate(seed: u64, overlap: f64) -> Dataset {
    let n: usize = CLASS_SIZES.iter().sum();
    let mut rng = Pcg64::new(seed ^ SEED_SALT);
    let mut x = Matrix::zeros(n, 13);
    let mut y = Vec::with_capacity(n);
    let mut row = 0;
    for (class, &size) in CLASS_SIZES.iter().enumerate() {
        for _ in 0..size {
            for j in 0..13 {
                let mut v = rng.normal_scaled(CLASS_MEANS[class][j], FEATURE_STD[j] * overlap);
                // Heavier tails on a few features (real wine data is skewed):
                if j == 1 || j == 9 || j == 12 {
                    v += rng.normal().abs() * FEATURE_STD[j] * 0.4 * overlap;
                }
                // physical floors
                v = v.max(0.01);
                x[(row, j)] = v;
            }
            y.push(class);
            row += 1;
        }
    }
    // Shuffle rows so folds don't align with generation order.
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let xs = Matrix::from_fn(n, 13, |i, j| x[(order[i], j)]);
    let ys: Vec<usize> = order.iter().map(|&i| y[i]).collect();
    let mut d = Dataset::new(xs, ys, 3);
    d.feature_names = FEATURES.iter().map(|s| s.to_string()).collect();
    d
}

/// The default wine dataset used by Fig. 2 (seed 0, overlap 1.45 —
/// calibrated so the GBT's random-config CV accuracy spreads ~0.65–0.94
/// with a rare >0.93 top: tuned configs clearly separate from untuned,
/// matching Fig. 2's dynamic range).
pub fn default_wine() -> Dataset {
    generate(0, 1.45)
}

/// Seed salt so wine data streams never collide with tuner RNG streams.
const SEED_SALT: u64 = 0x5749_4E45; // "WINE"

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_uci_wine() {
        let d = default_wine();
        assert_eq!(d.len(), 178);
        assert_eq!(d.n_features(), 13);
        assert_eq!(d.n_classes, 3);
        let mut counts = d.class_counts();
        counts.sort_unstable();
        assert_eq!(counts, vec![48, 59, 71]);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(7, 1.6);
        let b = generate(7, 1.6);
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.y, b.y);
        let c = generate(8, 1.6);
        assert_ne!(a.x.data(), c.x.data());
    }

    #[test]
    fn class_means_preserved_roughly() {
        let d = generate(3, 1.0);
        // mean proline of class 0 should be far above class 1 (1115 vs 519)
        let m = |class: usize, j: usize| {
            let idx: Vec<usize> = (0..d.len()).filter(|&i| d.y[i] == class).collect();
            idx.iter().map(|&i| d.x[(i, j)]).sum::<f64>() / idx.len() as f64
        };
        assert!(m(0, 12) > m(1, 12) + 300.0);
        assert!(m(2, 6) < m(0, 6) - 1.0, "flavanoids separate class 3");
    }

    #[test]
    fn features_physical() {
        let d = default_wine();
        assert!(d.x.data().iter().all(|&v| v > 0.0), "all features positive");
    }
}
