//! Tuning run results: per-iteration records + the final summary.

use crate::config::json::Json;
use crate::space::Config;

/// What happened in one optimizer iteration (one batch).
#[derive(Clone, Debug)]
pub struct IterationRecord {
    pub iteration: usize,
    /// Configurations proposed this iteration.
    pub proposed: usize,
    /// Evaluations that actually returned (partial results!).
    pub returned: usize,
    /// Best objective seen so far (user sense).
    pub best_so_far: f64,
    /// Wall time of this iteration in ms (propose + evaluate).
    pub wall_ms: f64,
}

/// Final result of a tuning run (user objective sense throughout).
#[derive(Clone, Debug)]
pub struct TuningResult {
    pub best_params: Config,
    pub best_objective: f64,
    /// All completed evaluations in arrival order.
    pub history: Vec<(Config, f64)>,
    /// Best-so-far after each iteration — the paper's figures' y-axis.
    pub best_series: Vec<f64>,
    pub iterations: Vec<IterationRecord>,
    pub evaluations: usize,
    pub wall_ms: f64,
}

impl TuningResult {
    /// Machine-readable dump (CLI --json).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("best_params", self.best_params.to_json()),
            ("best_objective", Json::Num(self.best_objective)),
            ("evaluations", Json::Num(self.evaluations as f64)),
            ("iterations", Json::Num(self.iterations.len() as f64)),
            ("wall_ms", Json::Num(self.wall_ms)),
            (
                "best_series",
                Json::Arr(self.best_series.iter().map(|&v| Json::Num(v)).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamValue;

    #[test]
    fn json_dump_contains_series() {
        let r = TuningResult {
            best_params: Config::new(vec![("x".into(), ParamValue::F64(1.0))]),
            best_objective: 2.0,
            history: vec![],
            best_series: vec![1.0, 2.0],
            iterations: vec![],
            evaluations: 2,
            wall_ms: 3.5,
        };
        let j = r.to_json();
        assert_eq!(j.get("best_objective").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("best_series").unwrap().as_arr().unwrap().len(), 2);
    }
}
