//! Tuning run results: per-iteration records, per-completion telemetry
//! (async mode), and the final summary.

use crate::config::json::Json;
use crate::scheduler::AsyncStats;
use crate::space::Config;

/// What happened in one optimizer iteration. In sync mode an iteration is
/// one batch (barrier); in async mode it is one *concluded* proposal —
/// a completion that delivered a value, failed, or exhausted its retries.
#[derive(Clone, Debug)]
pub struct IterationRecord {
    pub iteration: usize,
    /// Configurations proposed this iteration.
    pub proposed: usize,
    /// Evaluations that actually returned (partial results!).
    pub returned: usize,
    /// Best objective seen so far (user sense).
    pub best_so_far: f64,
    /// Wall time in ms: propose + evaluate (sync), or the concluded task's
    /// end-to-end latency — queue wait + eval (async).
    pub wall_ms: f64,
}

/// How one async completion concluded (see [`CompletionRecord`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompletionOutcome {
    /// Delivered a value into the history.
    Done,
    /// The objective declined (`None`); not retried.
    Failed,
    /// Lost (crash/timeout) with retries exhausted.
    Lost,
    /// Lost but resubmitted — a later record concludes the same proposal.
    Resubmitted,
    /// Cancelled by the pruner on an intermediate report; its censored
    /// value (worst-seen policy) may still have entered the history.
    Pruned,
}

/// Per-completion telemetry from the async event loop (queue wait, eval
/// wall, retry count) — one record per completion event, including the
/// `Resubmitted` intermediates of retried proposals.
#[derive(Clone, Debug)]
pub struct CompletionRecord {
    pub task_id: u64,
    /// Submit → evaluation start (broker queue + simulated network).
    pub queue_wait_ms: f64,
    /// Time inside the objective.
    pub eval_ms: f64,
    /// Retries consumed by this proposal so far.
    pub retries: usize,
    pub outcome: CompletionOutcome,
}

/// Final result of a tuning run (user objective sense throughout).
#[derive(Clone, Debug)]
pub struct TuningResult {
    pub best_params: Config,
    pub best_objective: f64,
    /// All completed evaluations in arrival order.
    pub history: Vec<(Config, f64)>,
    /// Best-so-far after each iteration — the paper's figures' y-axis.
    pub best_series: Vec<f64>,
    pub iterations: Vec<IterationRecord>,
    pub evaluations: usize,
    pub wall_ms: f64,
    /// Async mode: one record per completion event (empty in sync mode).
    pub completions: Vec<CompletionRecord>,
    /// Async mode: the scheduler's own counters.
    pub scheduler_stats: Option<AsyncStats>,
    /// Async mode: lost evaluations that were resubmitted.
    pub retried: u64,
    /// Async mode: proposals abandoned after exhausting their retries.
    pub lost: u64,
    /// Async mode: trials cancelled early by the configured pruner.
    pub pruned: u64,
    /// Async mode: intermediate reports received (and journaled, when a
    /// journal is attached). Zero unless the objective calls
    /// `TrialReporter::report` under an active pruner.
    pub reports: u64,
    /// GP distance-cache lifecycle counters `(builds, appends, evicts)`:
    /// full rebuilds, prefix-reusing appends, and (Fast profile) tiles
    /// dropped by truncate-and-regrow. All zeros for optimizers without a
    /// distance cache. Surfaced so cache-thrash regressions (every round
    /// rebuilding instead of appending) are observable instead of silent.
    pub dist_cache: (u64, u64, u64),
    /// Async mode: the run hit its stall patience (`--stall-timeout-ms`)
    /// with work still in flight and returned partial results instead of
    /// aborting. The abandoned tasks are counted in `lost`.
    pub stalled: bool,
    /// The journal hit an I/O error under `--journal-on-error degrade`:
    /// the run finished, but the journal on disk is a truncated prefix and
    /// must not be resumed as if complete.
    pub journal_degraded: bool,
}

impl TuningResult {
    /// Machine-readable dump (CLI --json).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("best_params", self.best_params.to_json()),
            ("best_objective", Json::Num(self.best_objective)),
            ("evaluations", Json::Num(self.evaluations as f64)),
            ("iterations", Json::Num(self.iterations.len() as f64)),
            ("wall_ms", Json::Num(self.wall_ms)),
            (
                "best_series",
                Json::Arr(self.best_series.iter().map(|&v| Json::Num(v)).collect()),
            ),
            (
                "dist_cache",
                Json::obj(vec![
                    ("builds", Json::Num(self.dist_cache.0 as f64)),
                    ("appends", Json::Num(self.dist_cache.1 as f64)),
                    ("evicts", Json::Num(self.dist_cache.2 as f64)),
                ]),
            ),
            ("stalled", Json::Bool(self.stalled)),
            ("journal_degraded", Json::Bool(self.journal_degraded)),
        ];
        if let Some(stats) = &self.scheduler_stats {
            fields.push(("retried", Json::Num(self.retried as f64)));
            fields.push(("lost", Json::Num(self.lost as f64)));
            fields.push(("pruned", Json::Num(self.pruned as f64)));
            fields.push(("reports", Json::Num(self.reports as f64)));
            fields.push((
                "scheduler",
                Json::obj(vec![
                    ("submitted", Json::Num(stats.submitted as f64)),
                    ("completed", Json::Num(stats.completed as f64)),
                    ("failed", Json::Num(stats.failed as f64)),
                    ("lost", Json::Num(stats.lost as f64)),
                    ("cancelled", Json::Num(stats.cancelled as f64)),
                    ("max_in_flight", Json::Num(stats.max_in_flight as f64)),
                ]),
            ));
            let n = self.completions.len().max(1) as f64;
            let mean_queue: f64 =
                self.completions.iter().map(|c| c.queue_wait_ms).sum::<f64>() / n;
            let mean_eval: f64 = self.completions.iter().map(|c| c.eval_ms).sum::<f64>() / n;
            fields.push(("mean_queue_wait_ms", Json::Num(mean_queue)));
            fields.push(("mean_eval_ms", Json::Num(mean_eval)));
        }
        Json::obj(fields)
    }

    /// Worker-utilization estimate for async runs: total objective time
    /// over `workers x` run wall time. 1.0 = the pool never idled.
    pub fn utilization(&self, workers: usize) -> f64 {
        if self.wall_ms <= 0.0 || workers == 0 {
            return 0.0;
        }
        let busy: f64 = self.completions.iter().map(|c| c.eval_ms).sum();
        busy / (self.wall_ms * workers as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamValue;

    fn base_result() -> TuningResult {
        TuningResult {
            best_params: Config::new(vec![("x".into(), ParamValue::F64(1.0))]),
            best_objective: 2.0,
            history: vec![],
            best_series: vec![1.0, 2.0],
            iterations: vec![],
            evaluations: 2,
            wall_ms: 3.5,
            completions: vec![],
            scheduler_stats: None,
            retried: 0,
            lost: 0,
            pruned: 0,
            reports: 0,
            dist_cache: (0, 0, 0),
            stalled: false,
            journal_degraded: false,
        }
    }

    #[test]
    fn json_dump_contains_series() {
        let j = base_result().to_json();
        assert_eq!(j.get("best_objective").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("best_series").unwrap().as_arr().unwrap().len(), 2);
        assert!(j.get("scheduler").is_none(), "sync dumps omit async fields");
    }

    #[test]
    fn json_dump_surfaces_degradation_flags() {
        let j = base_result().to_json();
        assert_eq!(j.get("stalled").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("journal_degraded").unwrap().as_bool(), Some(false));
        let mut r = base_result();
        r.stalled = true;
        r.journal_degraded = true;
        let j = r.to_json();
        assert_eq!(j.get("stalled").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("journal_degraded").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn json_dump_contains_dist_cache_counters() {
        let mut r = base_result();
        r.dist_cache = (2, 5, 3);
        let j = r.to_json();
        let dc = j.get("dist_cache").unwrap();
        assert_eq!(dc.get("builds").unwrap().as_f64(), Some(2.0));
        assert_eq!(dc.get("appends").unwrap().as_f64(), Some(5.0));
        assert_eq!(dc.get("evicts").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn json_dump_includes_async_telemetry() {
        let mut r = base_result();
        r.scheduler_stats = Some(AsyncStats { submitted: 4, completed: 2, ..Default::default() });
        r.retried = 1;
        r.lost = 1;
        r.pruned = 3;
        r.reports = 7;
        r.completions = vec![
            CompletionRecord {
                task_id: 0,
                queue_wait_ms: 2.0,
                eval_ms: 10.0,
                retries: 0,
                outcome: CompletionOutcome::Done,
            },
            CompletionRecord {
                task_id: 1,
                queue_wait_ms: 4.0,
                eval_ms: 20.0,
                retries: 1,
                outcome: CompletionOutcome::Lost,
            },
        ];
        let j = r.to_json();
        assert_eq!(j.get("retried").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("lost").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("pruned").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("reports").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("scheduler").unwrap().get("submitted").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get("mean_queue_wait_ms").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("mean_eval_ms").unwrap().as_f64(), Some(15.0));
    }

    #[test]
    fn utilization_is_busy_over_capacity() {
        let mut r = base_result();
        r.wall_ms = 100.0;
        r.completions = vec![CompletionRecord {
            task_id: 0,
            queue_wait_ms: 0.0,
            eval_ms: 50.0,
            retries: 0,
            outcome: CompletionOutcome::Done,
        }];
        assert!((r.utilization(2) - 0.25).abs() < 1e-12);
        assert_eq!(r.utilization(0), 0.0);
    }
}
