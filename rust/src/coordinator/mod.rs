//! The coordinator: [`Tuner`] ties the search space, a parallel optimizer,
//! and a scheduler into the paper's workflow (Fig. 1) in one of two modes:
//!
//! * **sync** — propose a batch → schedule evaluations → absorb (possibly
//!   partial) results → repeat (one barrier per batch).
//! * **async** — an event loop over the submit/poll scheduler contract:
//!   keep a bounded in-flight window full, fold in each completion as it
//!   arrives, retry lost work, and record per-completion telemetry.

mod results;
// Clock-permitted module (lint rule R1): per-completion telemetry in the
// event loop reads the clock by design; lifts the clippy.toml
// disallowed-methods backstop.
#[allow(clippy::disallowed_methods)]
mod tuner;

pub use results::{CompletionOutcome, CompletionRecord, IterationRecord, TuningResult};
pub use tuner::{ExecutionMode, ObjectiveFn, ReplayMode, Tuner, TunerConfig};
