//! The coordinator: [`Tuner`] ties the search space, a parallel optimizer,
//! and a scheduler into the paper's workflow (Fig. 1): propose a batch →
//! schedule evaluations → absorb (possibly partial) results → repeat.

mod results;
mod tuner;

pub use results::{IterationRecord, TuningResult};
pub use tuner::{ObjectiveFn, Tuner, TunerConfig};
