//! The [`Tuner`]: MANGO's user-facing entry point.
//!
//! Two execution modes share the optimizer/scheduler/space plumbing:
//!
//! * **`mode = "sync"`** (default) — the paper's Fig. 1 workflow: propose a
//!   batch → schedule → absorb (possibly partial) results → repeat. One
//!   barrier per batch; Fig. 2/3 parity semantics.
//! * **`mode = "async"`** — an event-loop coordinator over the
//!   [`AsyncScheduler`](crate::scheduler::AsyncScheduler) submit/poll
//!   contract: a bounded in-flight window (`async_window`) is kept full;
//!   each completion immediately updates the history and triggers a
//!   replacement proposal conditioned on the configs still in flight
//!   ([`BatchOptimizer::propose_pending`]), so stragglers never idle the
//!   rest of the pool. Lost evaluations (worker crash / result timeout)
//!   are retried up to `max_retries` times; per-completion telemetry
//!   (queue wait, eval wall, retries) lands in
//!   [`TuningResult::completions`]. The total evaluation budget is
//!   `num_iterations * batch_size` — identical to sync mode.
//!
//! **Crash safety.** [`Tuner::with_journal`] records every run event to an
//! append-only JSONL journal ([`crate::persist`]): the header (space
//! fingerprint, full config, seed, sense), each proposal (sync: with the
//! shared RNG state and optimizer rounds counter after the propose), each
//! submission, and each completion including `Lost` fates and retries.
//! [`Tuner::resume_from`] rebuilds a tuner from the journal and continues
//! where the process died: history, telemetry, and retry counters are
//! replayed; in-flight-at-crash configs are re-enqueued in their original
//! order with their surviving retry budget; the optimizer is rehydrated
//! (adaptive-beta clock + an incrementally rebuilt GP `CholeskyState`,
//! bit-identical to the crashed process's); and the scheduler's task-id
//! counter continues past the journaled high-water mark. With a fixed seed
//! and a deterministic scheduler, crash-at-any-point + resume reproduces
//! the uninterrupted run's best config and `History` exactly
//! (`rust/tests/recovery.rs`). Journal appends are flushed per line, so a
//! kill loses at most the in-flight batch (sync) or nothing that had
//! completed (async).

use super::results::{CompletionOutcome, CompletionRecord, IterationRecord, TuningResult};
use crate::config::settings::RunConfig;
use crate::optimizer::prune::{self, Pruner, PrunerKind, ReportBook};
use crate::optimizer::{self, BatchOptimizer, GpOptions, History, OptimizerKind, SurrogateBackend};
use crate::persist::{
    self, AsyncReplay, EventOutcome, JournalEvent, JournalFault, JournalLayout, JournalPolicy,
    RecoveredRun, Replay, RunHeader, SegmentOpts, SegmentedWriter, SenseTag, SyncReplay,
};
use crate::scheduler::{
    self, AsyncScheduler, BatchResult, Completion, CompletionStatus, LossReason, ReportSink,
    SchedulerKind, SubmitMeta, TaskId, TrialReporter,
};
use crate::space::{Config, SearchSpace};
use crate::util::rng::Pcg64;
use crate::util::stats;
use crate::util::timer::Stopwatch;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Per-config objective closure type (boxed form used by the CLI).
pub type ObjectiveFn = Box<dyn Fn(&Config) -> Option<f64> + Sync>;

/// How evaluations are coordinated: batch barriers or the event loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutionMode {
    /// One barrier per batch (the paper's semantics).
    Sync,
    /// Submit/poll event loop with a bounded in-flight window.
    Async,
}

impl ExecutionMode {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "sync" => Some(Self::Sync),
            "async" => Some(Self::Async),
            _ => None,
        }
    }

    /// Inverse of [`from_str`](Self::from_str) (journal header round trip).
    pub fn as_str(self) -> &'static str {
        match self {
            Self::Sync => "sync",
            Self::Async => "async",
        }
    }
}

/// How completions are ordered into the async fold (`--replay`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplayMode {
    /// Fold completions in arrival order (the default) — byte-identical
    /// to the pre-knob event loop. Crash+resume equality holds only on
    /// deterministic schedulers (serial; quiet celery-sim).
    Wallclock,
    /// Drain completions through a reorder buffer and fold in canonical
    /// ascending-task-id order, one journaled fold epoch per fold, with
    /// admission (the in-flight window) alternating fold-one-then-refill.
    /// best/`history`/`best_series` and every pruning decision become
    /// byte-identical run-to-run on serial, threaded, *and* celery-sim —
    /// and a crash+resume at any event boundary equals a seed-matched
    /// uninterrupted run.
    Stable,
}

impl ReplayMode {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "wallclock" => Some(Self::Wallclock),
            "stable" => Some(Self::Stable),
            _ => None,
        }
    }

    /// Inverse of [`from_str`](Self::from_str) (journal header round trip).
    pub fn as_str(self) -> &'static str {
        match self {
            Self::Wallclock => "wallclock",
            Self::Stable => "stable",
        }
    }
}

/// How long one event-loop poll waits before re-checking the window.
const POLL_TIMEOUT: Duration = Duration::from_millis(25);

/// Tuner configuration — the paper's user-controlled options (§2.4).
#[derive(Clone, Debug)]
pub struct TunerConfig {
    pub batch_size: usize,
    pub num_iterations: usize,
    pub initial_random: usize,
    pub optimizer: OptimizerKind,
    pub scheduler: SchedulerKind,
    pub workers: usize,
    /// 0 = the space's Monte-Carlo heuristic.
    pub mc_samples: usize,
    pub seed: u64,
    pub backend: SurrogateBackend,
    pub tune_lengthscale: bool,
    /// Stop after this many iterations without improvement (None = never;
    /// `Some(0)` is clamped to `Some(1)` — the journal header encodes
    /// "disabled" as 0, so 0 cannot also mean "stop immediately" without
    /// a resumed run silently losing its early stop). Async mode counts
    /// `early_stop * batch_size` concluded proposals.
    pub early_stop: Option<usize>,
    /// Largest history the surrogate sees (PJRT artifacts cap at 512).
    pub max_surrogate_obs: usize,
    /// Batch barriers (paper) or the submit/poll event loop.
    pub mode: ExecutionMode,
    /// Async mode: in-flight window size; 0 = max(batch_size, workers).
    pub async_window: usize,
    /// Async mode: resubmissions allowed per lost evaluation.
    pub max_retries: usize,
    /// Worker threads for Monte-Carlo candidate scoring (native backend;
    /// 0 = one per core). Byte-identical output for every setting — a
    /// wall-clock knob, never a numerics knob.
    pub proposal_threads: usize,
    /// Scoring shards shipped through the scheduler's worker-pool
    /// machinery per propose round (native backend). 0 = local-only
    /// scoring (`proposal_threads` over scoped threads), byte-for-byte
    /// today's behavior; n ≥ 1 splits the candidate set into n fixed
    /// chunks executed as pool jobs under this run's scheduler kind
    /// (serial / threaded / celery-sim incl. its fault fates). Output is
    /// byte-identical for every `proposal_shards` × `proposal_threads` ×
    /// scheduler setting.
    pub proposal_shards: usize,
    /// Propose-hot-path arithmetic profile: `Exact` (default) keeps every
    /// bit-exactness contract; `Fast` swaps in SIMD-friendly chunked
    /// kernels and the tiled distance cache — run-to-run deterministic and
    /// threads/shards-invariant, but not bit-equal to `Exact`.
    pub kernel_profile: crate::gp::KernelProfile,
    /// Journal durability: fsync after every n appends (0 = flush-only,
    /// the default — survives a process kill but a machine crash can lose
    /// recent events).
    pub fsync_every_n: usize,
    /// Trial-level early stopping rule applied to intermediate reports
    /// (async mode only; `None` keeps today's path byte-identical).
    pub pruner: PrunerKind,
    /// Reports a trial must produce before the pruner engages (median
    /// rule), and the first ASHA rung's resource milestone.
    pub pruner_warmup: usize,
    /// ASHA reduction factor η (rungs at warmup·η^k; must be > 1).
    pub asha_reduction: f64,
    /// Async completion-fold ordering ([`ReplayMode`]; `--replay`).
    pub replay: ReplayMode,
    /// What a journal append failure does mid-run
    /// ([`JournalPolicy`]; `--journal-on-error`): fail-stop (default)
    /// aborts with the I/O error; degrade logs it, stops journaling,
    /// finishes the run, and sets [`TuningResult::journal_degraded`].
    pub journal_on_error: JournalPolicy,
    /// Base delay in ms before a lost evaluation's resubmission executes
    /// (`--retry-backoff-ms`): bounded exponential per attempt with
    /// seeded jitter, journaled per submission so a resume re-applies the
    /// exact schedule. 0 (default) = immediate re-enqueue, byte-identical
    /// to the pre-knob path.
    pub retry_backoff_ms: f64,
    /// Async stall patience in ms (`--stall-timeout-ms`): if nothing
    /// completes for this long while work is in flight (a worker died
    /// without reporting — the in-repo schedulers themselves never go
    /// silent), the run journals terminal `stalled` events for the
    /// outstanding tasks, drains, and returns partial results with
    /// [`TuningResult::stalled`] set, instead of aborting. 0 = wait
    /// forever.
    pub stall_timeout_ms: u64,
    /// Journal segment rotation (`--journal-segment-events`): seal and
    /// rotate to a new segment file every n events. 0 (default) keeps the
    /// single-file layout, byte-identical to the pre-segmentation journal
    /// apart from the schema version.
    pub journal_segment_events: usize,
    /// Sealed segments compaction leaves behind the active one
    /// (`--journal-keep-segments`) — the warm tail a resume replays
    /// event-by-event instead of from the checkpoint.
    pub journal_keep_segments: usize,
    /// Compact the sealed prefix into a checkpoint before reopening the
    /// journal on resume (`--compact-on-resume`). No-op on single-file
    /// journals.
    pub compact_on_resume: bool,
    /// Override the Celery simulator's fault/latency model.
    pub celery: Option<scheduler::celery::CelerySimConfig>,
}

impl Default for TunerConfig {
    fn default() -> Self {
        Self {
            batch_size: 1,
            num_iterations: 60,
            initial_random: 2,
            optimizer: OptimizerKind::Hallucination,
            scheduler: SchedulerKind::Serial,
            workers: 1,
            mc_samples: 0,
            seed: 0,
            backend: SurrogateBackend::Pjrt,
            tune_lengthscale: false,
            early_stop: None,
            max_surrogate_obs: 512,
            mode: ExecutionMode::Sync,
            async_window: 0,
            max_retries: 2,
            proposal_threads: 1,
            proposal_shards: 0,
            kernel_profile: crate::gp::KernelProfile::Exact,
            fsync_every_n: 0,
            pruner: PrunerKind::None,
            pruner_warmup: 1,
            asha_reduction: 3.0,
            replay: ReplayMode::Wallclock,
            journal_on_error: JournalPolicy::FailStop,
            retry_backoff_ms: 0.0,
            stall_timeout_ms: 3_600_000,
            journal_segment_events: 0,
            journal_keep_segments: 2,
            compact_on_resume: false,
            celery: None,
        }
    }
}

impl TunerConfig {
    /// Build from the JSON-level [`RunConfig`].
    pub fn from_run_config(rc: &RunConfig) -> Result<Self> {
        Ok(Self {
            batch_size: rc.batch_size,
            num_iterations: rc.num_iterations,
            initial_random: rc.initial_random,
            optimizer: OptimizerKind::from_str(&rc.optimizer)
                .ok_or_else(|| anyhow!("bad optimizer {}", rc.optimizer))?,
            scheduler: SchedulerKind::from_str(&rc.scheduler)
                .ok_or_else(|| anyhow!("bad scheduler {}", rc.scheduler))?,
            workers: rc.workers.max(1),
            mc_samples: rc.mc_samples,
            seed: rc.seed,
            backend: SurrogateBackend::from_str(&rc.backend)
                .ok_or_else(|| anyhow!("bad backend {}", rc.backend))?,
            tune_lengthscale: rc.tune_lengthscale,
            early_stop: match rc.early_stop {
                0 => None,
                n => Some(n),
            },
            max_surrogate_obs: rc.max_surrogate_obs,
            mode: ExecutionMode::from_str(&rc.mode)
                .ok_or_else(|| anyhow!("bad mode {}", rc.mode))?,
            async_window: rc.async_window,
            max_retries: rc.max_retries,
            proposal_threads: rc.proposal_threads,
            proposal_shards: rc.proposal_shards,
            kernel_profile: crate::gp::KernelProfile::from_str(&rc.kernel_profile)
                .ok_or_else(|| anyhow!("bad kernel_profile {}", rc.kernel_profile))?,
            fsync_every_n: rc.fsync_every_n,
            pruner: PrunerKind::from_str(&rc.pruner)
                .ok_or_else(|| anyhow!("bad pruner {}", rc.pruner))?,
            pruner_warmup: rc.pruner_warmup,
            asha_reduction: rc.asha_reduction,
            replay: ReplayMode::from_str(&rc.replay)
                .ok_or_else(|| anyhow!("bad replay {}", rc.replay))?,
            journal_on_error: JournalPolicy::from_str(&rc.journal_on_error)
                .ok_or_else(|| anyhow!("bad journal_on_error {}", rc.journal_on_error))?,
            retry_backoff_ms: rc.retry_backoff_ms,
            stall_timeout_ms: rc.stall_timeout_ms,
            journal_segment_events: rc.journal_segment_events,
            journal_keep_segments: rc.journal_keep_segments,
            compact_on_resume: rc.compact_on_resume,
            celery: None,
        })
    }

    /// Inverse of [`from_run_config`](Self::from_run_config): the JSON-level
    /// form recorded in the journal header so `Tuner::resume_from` can
    /// rebuild the tuner without the caller re-specifying anything. The
    /// `celery` fault-model override is not part of `RunConfig`; it rides
    /// in its own journal-header field (`RunHeader::celery`) and
    /// `resume_from` re-applies it from there.
    pub fn to_run_config(&self) -> RunConfig {
        RunConfig {
            batch_size: self.batch_size,
            num_iterations: self.num_iterations,
            initial_random: self.initial_random,
            optimizer: self.optimizer.as_str().into(),
            scheduler: self.scheduler.as_str().into(),
            workers: self.workers,
            mc_samples: self.mc_samples,
            seed: self.seed,
            backend: self.backend.as_str().into(),
            tune_lengthscale: self.tune_lengthscale,
            // 0 encodes "disabled"; Some(0) is clamped so the round trip
            // cannot turn a configured early stop into no early stop.
            early_stop: self.early_stop.map_or(0, |n| n.max(1)),
            max_surrogate_obs: self.max_surrogate_obs,
            mode: self.mode.as_str().into(),
            async_window: self.async_window,
            max_retries: self.max_retries,
            proposal_threads: self.proposal_threads,
            proposal_shards: self.proposal_shards,
            kernel_profile: self.kernel_profile.as_str().into(),
            fsync_every_n: self.fsync_every_n,
            pruner: self.pruner.as_str().into(),
            pruner_warmup: self.pruner_warmup,
            asha_reduction: self.asha_reduction,
            replay: self.replay.as_str().into(),
            journal_on_error: self.journal_on_error.as_str().into(),
            retry_backoff_ms: self.retry_backoff_ms,
            stall_timeout_ms: self.stall_timeout_ms,
            journal_segment_events: self.journal_segment_events,
            journal_keep_segments: self.journal_keep_segments,
            compact_on_resume: self.compact_on_resume,
            journal: String::new(),
            resume: false,
        }
    }

    /// Effective in-flight window for async mode.
    fn window(&self) -> usize {
        let auto = self.batch_size.max(self.workers);
        let w = if self.async_window == 0 { auto } else { self.async_window };
        w.max(1)
    }
}

/// Objective sense.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Sense {
    Maximize,
    Minimize,
}

impl Sense {
    fn tag(self) -> SenseTag {
        match self {
            Sense::Maximize => SenseTag::Maximize,
            Sense::Minimize => SenseTag::Minimize,
        }
    }
}

/// Coordinator-side record of one in-flight evaluation.
struct PendingTask {
    config: Config,
    retries: usize,
    /// Stable proposal id — survives restarts (task ids are per-submission
    /// and change when a lost/recovered task is re-enqueued; the journal
    /// keys a proposal's lifecycle by `pid`).
    pid: u64,
}

/// The coordinator's journal handle: the writer (if journaling) plus the
/// append-failure policy. `FailStop` propagates the first
/// [`crate::persist::JournalError`] and aborts the run; `Degrade` logs it
/// once, drops the writer — the bytes already on disk stay a valid,
/// resumable prefix — and keeps tuning with `degraded` surfaced as
/// [`TuningResult::journal_degraded`].
struct JournalSink {
    writer: Option<SegmentedWriter>,
    policy: JournalPolicy,
    degraded: bool,
}

impl JournalSink {
    fn new(writer: Option<SegmentedWriter>, policy: JournalPolicy) -> Self {
        Self { writer, policy, degraded: false }
    }

    fn append(&mut self, event: &JournalEvent) -> Result<()> {
        let Some(w) = self.writer.as_mut() else { return Ok(()) };
        match w.append(event) {
            Ok(()) => Ok(()),
            Err(e) => match self.policy {
                JournalPolicy::FailStop => Err(e.into()),
                JournalPolicy::Degrade => {
                    crate::log_warn!(
                        "journal degraded, run continues without persistence: {e}"
                    );
                    self.writer = None;
                    self.degraded = true;
                    Ok(())
                }
            },
        }
    }
}

/// Stable-mode reorder buffer between `AsyncScheduler::poll` and the fold
/// (`--replay stable`). Completions are absorbed in whatever order the
/// scheduler delivered them and released strictly in ascending task id:
/// [`pop_ready`](Self::pop_ready) yields the frontier task iff its
/// completion has arrived. Resubmissions get fresh (higher) task ids, so
/// the frontier never waits on an id that will not complete — early-stop
/// cancellations are always a queued suffix of the in-flight ids and are
/// removed from `pending` before the frontier could reach them, and a
/// worker bailout or stall tears the buffer down wholesale.
struct Sequencer {
    buffer: BTreeMap<TaskId, Completion>,
    /// The fold frontier: next task id eligible to fold. Doubles as the
    /// pruning-visibility cutoff journaled on each admission.
    fold_next: TaskId,
}

impl Sequencer {
    fn new(fold_next: TaskId) -> Self {
        Self { buffer: BTreeMap::new(), fold_next }
    }

    fn absorb(&mut self, completions: Vec<Completion>) {
        for c in completions {
            self.buffer.insert(c.id, c);
        }
    }

    /// Is the frontier completion already buffered (i.e. a fold is
    /// unblocked right now)?
    fn has_ready(&self) -> bool {
        self.buffer.contains_key(&self.fold_next)
    }

    /// Release the frontier completion if it has arrived, advancing the
    /// frontier past it.
    fn pop_ready(&mut self) -> Option<Completion> {
        let c = self.buffer.remove(&self.fold_next)?;
        self.fold_next += 1;
        Some(c)
    }

    /// Drop every buffered completion (bailout/stall teardown: the
    /// outstanding tasks are being concluded as lost).
    fn clear(&mut self) {
        self.buffer.clear();
    }
}

/// Stable-mode fate key: one independent fault-model RNG stream per
/// (proposal, attempt), so a resumed run re-derives the crashed run's
/// exact celery-sim fates no matter how many sequential draws either
/// process happened to make.
fn stable_fate_key(pid: u64, attempt: usize) -> u64 {
    pid.wrapping_mul(1 << 20).wrapping_add(attempt as u64)
}

/// Deterministic retry backoff for `attempt` (1-based): bounded
/// exponential over the configured base (cap 2^6) with seeded jitter in
/// `[delay/2, delay)`. The jitter draws from a fresh RNG stream keyed by
/// (seed, pid, attempt) — order-independent, so the journaled value a
/// resume re-applies is exactly what an uninterrupted run would compute.
/// A base of 0 (the default) returns 0 without touching any RNG.
fn retry_backoff_ms(cfg: &TunerConfig, pid: u64, attempt: usize) -> f64 {
    if cfg.retry_backoff_ms <= 0.0 {
        return 0.0;
    }
    let delay = cfg.retry_backoff_ms * f64::powi(2.0, attempt.saturating_sub(1).min(6) as i32);
    let mut rng = Pcg64::new(
        cfg.seed ^ 0xBACC_0FF ^ pid.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ attempt as u64,
    );
    delay / 2.0 + rng.next_f64() * (delay / 2.0)
}

/// Append one best-so-far point and update the no-improvement streak.
/// Shared by the live loops AND the journal replays: all four sites must
/// perform the identical comparison, or a resumed run's early-stop
/// trajectory could silently diverge from the uninterrupted run it is
/// required to reproduce.
fn push_best_point(
    sense: Sense,
    best_series: &mut Vec<f64>,
    user_best: f64,
    since_improvement: &mut usize,
) {
    best_series.push(user_best);
    let improved = best_series.len() < 2
        || match sense {
            Sense::Maximize => {
                best_series[best_series.len() - 1] > best_series[best_series.len() - 2]
            }
            Sense::Minimize => {
                best_series[best_series.len() - 1] < best_series[best_series.len() - 2]
            }
        };
    *since_improvement = if improved { 0 } else { *since_improvement + 1 };
}

/// One intermediate report as drained by the event loop for journaling:
/// `value` is in the user's sense (what the objective reported), `pruned`
/// is the decision the pruner took at this report.
struct ReportRec {
    pid: u64,
    task: TaskId,
    step: u64,
    value: f64,
    pruned: bool,
}

/// Shared pruning state behind the coordinator's mutex.
struct PruneState {
    /// Internal-sense (maximization, NaN-folded) report streams — the only
    /// input the pure pruning rules see.
    book: ReportBook,
    /// Live task → proposal mapping (reports arrive keyed by task id; the
    /// journal and the book key by pid, which survives resubmissions).
    task_to_pid: BTreeMap<TaskId, u64>,
    /// Reports not yet journaled, in arrival order.
    log: Vec<ReportRec>,
    /// pid → (at_step, last user-sense value) for every pruned trial.
    pruned: BTreeMap<u64, (u64, f64)>,
    /// pid → task id of its latest (re)submission; survives conclusion.
    /// Stable mode's visibility predicate: pid q is visible to a task
    /// admitted at cutoff c iff `pid_last_task[q] < c` — q's final attempt
    /// folded before that task was admitted, so q's stream is complete and
    /// identical in every run.
    pid_last_task: BTreeMap<u64, TaskId>,
    /// task → stable-mode admission cutoff (the fold frontier at submit
    /// time, journaled on `async_submit`). Absent in wallclock mode: the
    /// pruner sees the whole book, byte-for-byte the pre-knob behavior.
    task_cutoff: BTreeMap<TaskId, TaskId>,
}

/// The coordinator's pruning state machine: worker threads stream
/// intermediate metrics into [`ReportSink::on_report`]; the event loop
/// registers/concludes tasks, drains the report log for journaling, and
/// consults the pruned set when folding completions. Decisions are pure
/// functions of the (deterministically ordered) report book, so a journal
/// replay through the same rule reproduces every decision bit-for-bit.
struct PruneCoordinator {
    pruner: Box<dyn Pruner>,
    minimize: bool,
    state: Mutex<PruneState>,
}

impl PruneCoordinator {
    fn new(pruner: Box<dyn Pruner>, minimize: bool) -> Self {
        Self {
            pruner,
            minimize,
            state: Mutex::new(PruneState {
                book: ReportBook::new(),
                task_to_pid: BTreeMap::new(),
                log: Vec::new(),
                pruned: BTreeMap::new(),
                pid_last_task: BTreeMap::new(),
                task_cutoff: BTreeMap::new(),
            }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, PruneState> {
        // A poisoned lock means a worker panicked mid-report; the scope
        // join will surface that panic — keep serving the state meanwhile.
        match self.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Register a (re)submission. `cutoff` is the stable-mode admission
    /// cutoff (`None` in wallclock mode: decisions see the whole book).
    fn register(&self, task: TaskId, pid: u64, cutoff: Option<TaskId>) {
        let mut st = self.lock();
        // Mirror replay semantics: a (re)submitted trial re-reports from
        // scratch, so any stream from a lost prior attempt is discarded.
        st.book.reset(pid);
        st.task_to_pid.insert(task, pid);
        st.pid_last_task.insert(pid, task);
        if let Some(c) = cutoff {
            st.task_cutoff.insert(task, c);
        }
    }

    fn conclude(&self, task: TaskId) {
        let mut st = self.lock();
        st.task_to_pid.remove(&task);
        st.task_cutoff.remove(&task);
    }

    fn drain_log(&self) -> Vec<ReportRec> {
        std::mem::take(&mut self.lock().log)
    }

    fn pruned_info(&self, pid: u64) -> Option<(u64, f64)> {
        self.lock().pruned.get(&pid).copied()
    }

    /// Seed the book from journal-replayed reports (user-sense values, in
    /// journal order) so post-resume decisions see exactly what the
    /// crashed process saw. Only concluded proposals' streams are seeded —
    /// in-flight-at-crash trials re-execute and re-report from scratch.
    fn seed(&self, reports: &[(u64, u64, f64, bool)]) {
        let mut st = self.lock();
        for &(pid, step, value, pruned) in reports {
            let internal = if self.minimize { -value } else { value };
            st.book.push(pid, step, stats::nan_as_worst(internal));
            if pruned {
                st.pruned.insert(pid, (step, value));
            }
        }
    }

    /// Seed the last-attempt map from the replay (stable mode): each
    /// concluded pid's final task id, so post-resume visibility predicates
    /// agree exactly with the crashed process's. In-flight-at-crash pids
    /// re-register at re-enqueue time under their fresh (higher) ids.
    fn seed_pid_last(&self, entries: &[(u64, u64)]) {
        let mut st = self.lock();
        for &(pid, task) in entries {
            st.pid_last_task.insert(pid, task);
        }
    }
}

impl ReportSink for PruneCoordinator {
    fn on_report(&self, task: TaskId, step: u64, value: f64) -> bool {
        let mut guard = self.lock();
        let st = &mut *guard;
        let Some(&pid) = st.task_to_pid.get(&task) else {
            return true; // unknown task (already concluded): ignore
        };
        if st.pruned.contains_key(&pid) {
            return false; // decided: keep telling the worker to stop
        }
        let internal = if self.minimize { -value } else { value };
        st.book.push(pid, step, stats::nan_as_worst(internal));
        // Stable mode: the decision sees only its own stream plus the
        // streams of pids whose final attempt folded before this task was
        // admitted — a wall-clock-independent view, so the decision comes
        // out identical run-to-run and across crash+resume. Wallclock
        // (no cutoff registered) keeps the whole-book comparison,
        // byte-for-byte the pre-knob behavior.
        let decision = match st.task_cutoff.get(&task).copied() {
            Some(cutoff) => {
                let pid_last = &st.pid_last_task;
                let view = st
                    .book
                    .filtered(pid, |q| pid_last.get(&q).map_or(false, |&last| last < cutoff));
                self.pruner.should_prune(pid, &view)
            }
            None => self.pruner.should_prune(pid, &st.book),
        };
        st.log.push(ReportRec { pid, task, step, value, pruned: decision });
        if decision {
            st.pruned.insert(pid, (step, value));
        }
        !decision
    }
}

/// The paper's Fig. 1 coordinator.
pub struct Tuner {
    space: SearchSpace,
    config: TunerConfig,
    /// Optional per-iteration callback (progress bars, early inspection).
    /// On a resumed run it fires only for newly executed iterations.
    callback: Option<Box<dyn FnMut(&IterationRecord)>>,
    /// Journal file for crash-safe runs (None = no persistence).
    journal_path: Option<PathBuf>,
    /// Replayed state from `resume_from`, consumed by the next run.
    recovered: Option<RecoveredRun>,
    /// Failing-writer test double: `(appends, kind)` applied to the journal
    /// writer on open ([`with_journal_fault`](Self::with_journal_fault)).
    journal_fault: Option<(usize, JournalFault)>,
    /// Rotation-seam test double: fail the next segment-seal append with
    /// this fault ([`with_rotation_fault`](Self::with_rotation_fault)).
    rotation_fault: Option<JournalFault>,
}

impl Tuner {
    pub fn new(space: SearchSpace, config: TunerConfig) -> Self {
        Self {
            space,
            config,
            callback: None,
            journal_path: None,
            recovered: None,
            journal_fault: None,
            rotation_fault: None,
        }
    }

    /// Register a per-iteration callback.
    pub fn with_callback(mut self, cb: impl FnMut(&IterationRecord) + 'static) -> Self {
        self.callback = Some(Box::new(cb));
        self
    }

    /// Record this run to an append-only journal at `path` so it can be
    /// resumed after a crash ([`Tuner::resume_from`]). Starting a run
    /// truncates any existing file at `path` — resuming, not restarting,
    /// requires going through `resume_from`.
    pub fn with_journal(mut self, path: impl Into<PathBuf>) -> Self {
        self.journal_path = Some(path.into());
        self
    }

    /// Compact the journal's sealed segment prefix into a checkpoint
    /// before reopening it on resume (`--compact-on-resume`). Only
    /// meaningful on a tuner built by [`resume_from`](Self::resume_from)
    /// over a segmented journal; a no-op everywhere else.
    pub fn with_compact_on_resume(mut self, on: bool) -> Self {
        self.config.compact_on_resume = on;
        self
    }

    /// Override the sealed-segment retention window for this process: how
    /// many sealed segments compaction leaves uncompacted behind the
    /// active one. Normally restored from the journal header on resume;
    /// this setter lets a resume shrink a long-retention journal
    /// (`--journal-keep-segments` together with `--resume`).
    pub fn with_keep_segments(mut self, n: usize) -> Self {
        self.config.journal_keep_segments = n;
        self
    }

    /// Override the Celery simulator's fault/latency model. Journaled runs
    /// record it in the header and [`Tuner::resume_from`] re-applies it
    /// automatically; this setter is for fresh runs and for deliberately
    /// changing the simulated cluster on resume.
    pub fn with_celery(mut self, celery: Option<scheduler::celery::CelerySimConfig>) -> Self {
        self.config.celery = celery;
        self
    }

    /// Failing-writer test double: let `appends` more journal event
    /// appends succeed, then fail every later one with `kind` — exercising
    /// the [`TunerConfig::journal_on_error`] policy at every append site
    /// without a real full disk. Test hook, not part of the public API.
    #[doc(hidden)]
    pub fn with_journal_fault(mut self, appends: usize, kind: JournalFault) -> Self {
        self.journal_fault = Some((appends, kind));
        self
    }

    /// Failing-writer test double for the rotation seam specifically: make
    /// the next segment-seal append fail with `kind`, exercising the
    /// [`TunerConfig::journal_on_error`] policy mid-rotation (the one
    /// append site a count-based [`with_journal_fault`](Self::with_journal_fault)
    /// cannot target deterministically). Test hook, not public API.
    #[doc(hidden)]
    pub fn with_rotation_fault(mut self, kind: JournalFault) -> Self {
        self.rotation_fault = Some(kind);
        self
    }

    /// Rebuild a tuner from a crash-truncated run journal. The journal
    /// header supplies the full [`TunerConfig`] (the caller only re-supplies
    /// the space, which is validated against the journaled fingerprint and
    /// refused on mismatch). The next `maximize`/`minimize` call (it must
    /// match the journaled sense) replays the journal and continues the
    /// run: with a fixed seed and a deterministic scheduler the final
    /// result is identical to an uninterrupted run's.
    pub fn resume_from(space: SearchSpace, path: &Path) -> Result<Self> {
        let rec = persist::recover(path)?;
        rec.validate_space(&space)?;
        let mut config = TunerConfig::from_run_config(&rec.header.run)?;
        // The Celery fault-model override is journaled in the header
        // (schema v2): re-apply it so a resumed run simulates the exact
        // cluster the crashed run configured instead of reverting to
        // defaults. `with_celery` remains available to override afresh.
        config.celery = rec.header.celery.clone();
        Ok(Self {
            space,
            config,
            callback: None,
            journal_path: Some(path.to_path_buf()),
            recovered: Some(rec),
            journal_fault: None,
            rotation_fault: None,
        })
    }

    pub fn config(&self) -> &TunerConfig {
        &self.config
    }

    /// Maximize a per-config objective using the configured scheduler
    /// (dispatches on [`TunerConfig::mode`]).
    pub fn maximize<F>(&mut self, objective: F) -> Result<TuningResult>
    where
        F: Fn(&Config) -> Option<f64> + Sync,
    {
        self.run_objective(Sense::Maximize, &|c, _| objective(c))
    }

    /// Minimize a per-config objective.
    pub fn minimize<F>(&mut self, objective: F) -> Result<TuningResult>
    where
        F: Fn(&Config) -> Option<f64> + Sync,
    {
        self.run_objective(Sense::Minimize, &|c, _| objective(c))
    }

    /// Maximize an objective that streams intermediate metrics through a
    /// [`TrialReporter`] — the trial-level early-stopping form: call
    /// `reporter.report(step, value)` between training stages and treat a
    /// `false` return as "pruned, stop now". With
    /// [`TunerConfig::pruner`] = [`PrunerKind::None`] the reports are
    /// accepted and discarded and the run is byte-identical to
    /// [`maximize`](Self::maximize).
    pub fn maximize_with_reports<F>(&mut self, objective: F) -> Result<TuningResult>
    where
        F: Fn(&Config, &TrialReporter) -> Option<f64> + Sync,
    {
        self.run_objective(Sense::Maximize, &objective)
    }

    /// Minimize with an intermediate-report channel
    /// ([`maximize_with_reports`](Self::maximize_with_reports)).
    pub fn minimize_with_reports<F>(&mut self, objective: F) -> Result<TuningResult>
    where
        F: Fn(&Config, &TrialReporter) -> Option<f64> + Sync,
    {
        self.run_objective(Sense::Minimize, &objective)
    }

    /// Open the journal writer (fresh or resumed) and take the replay
    /// state. Refuses a sense that contradicts the journal header. With
    /// `compact_on_resume` the sealed segment prefix is folded into a
    /// checkpoint *before* the writer reopens, and the journal is
    /// re-recovered so the layout, valid length, and replay all describe
    /// the compacted on-disk state (the replay itself is unchanged —
    /// checkpoint equivalence is a journal invariant, not a hope).
    fn prepare_journal(
        &mut self,
        sense: Sense,
    ) -> Result<(Option<SegmentedWriter>, Option<Replay>)> {
        let mut recovered = self.recovered.take();
        if let Some(rec) = &recovered {
            anyhow::ensure!(
                rec.header.sense == sense.tag(),
                "journal records a {} run — call the matching method on the resumed tuner",
                rec.header.sense.as_str()
            );
        }
        if self.config.compact_on_resume {
            if let (Some(path), Some(rec)) = (&self.journal_path, &recovered) {
                if matches!(rec.layout, JournalLayout::Segmented { .. })
                    && persist::compact(path, self.config.journal_keep_segments)?
                {
                    recovered = Some(persist::recover(path)?);
                }
            }
        }
        let opts = SegmentOpts {
            segment_events: self.config.journal_segment_events,
            keep_segments: self.config.journal_keep_segments,
            fsync_every_n: self.config.fsync_every_n,
        };
        let mut journal = match (&self.journal_path, &recovered) {
            (Some(path), Some(rec)) => {
                Some(SegmentedWriter::resume(path, &rec.layout, rec.valid_len, opts)?)
            }
            (Some(path), None) => Some(SegmentedWriter::create(
                path,
                &RunHeader {
                    space_fp: self.space.fingerprint(),
                    sense: sense.tag(),
                    run: self.config.to_run_config(),
                    celery: self.config.celery.clone(),
                },
                opts,
            )?),
            (None, Some(_)) => {
                return Err(anyhow!("recovered state without a journal path (use resume_from)"))
            }
            (None, None) => None,
        };
        if let (Some((appends, kind)), Some(w)) = (self.journal_fault, journal.as_mut()) {
            w.inject_fault_after(appends, kind);
        }
        if let (Some(kind), Some(w)) = (self.rotation_fault, journal.as_mut()) {
            w.inject_rotation_fault(kind);
        }
        Ok((journal, recovered.map(|r| r.replay)))
    }

    fn run_objective(
        &mut self,
        sense: Sense,
        objective: &(dyn Fn(&Config, &TrialReporter) -> Option<f64> + Sync),
    ) -> Result<TuningResult> {
        let (writer, replay) = self.prepare_journal(sense)?;
        let journal = JournalSink::new(writer, self.config.journal_on_error);
        match self.config.mode {
            ExecutionMode::Sync => {
                let rep = match replay {
                    None => None,
                    Some(Replay::Sync(s)) => Some(s),
                    Some(Replay::Async(_)) => {
                        return Err(anyhow!("async-mode journal cannot resume a sync run"))
                    }
                };
                let mut sched = scheduler::build_custom(
                    self.config.scheduler,
                    self.config.workers,
                    self.config.seed,
                    self.config.celery.clone(),
                );
                // Sync mode has no report channel: a detached reporter
                // swallows any reports the objective emits.
                let plain = |c: &Config| objective(c, &TrialReporter::detached());
                self.run_sync(sense, &mut |batch| sched.evaluate(&plain, batch), journal, rep)
            }
            ExecutionMode::Async => {
                let rep = match replay {
                    None => None,
                    Some(Replay::Async(a)) => Some(a),
                    Some(Replay::Sync(_)) => {
                        return Err(anyhow!("sync-mode journal cannot resume an async run"))
                    }
                };
                self.run_async(sense, objective, journal, rep)
            }
        }
    }

    /// Maximize with a user-supplied *batch* objective — the paper's
    /// decoupling: bring any scheduling framework by consuming the whole
    /// batch yourself and returning (possibly partial) `(evals, params)`.
    /// Always batch-synchronous regardless of [`TunerConfig::mode`].
    pub fn maximize_batch<F>(&mut self, mut batch_objective: F) -> Result<TuningResult>
    where
        F: FnMut(&[Config]) -> BatchResult,
    {
        self.run_batch_mode(Sense::Maximize, &mut batch_objective)
    }

    /// Minimize with a user-supplied batch objective.
    pub fn minimize_batch<F>(&mut self, mut batch_objective: F) -> Result<TuningResult>
    where
        F: FnMut(&[Config]) -> BatchResult,
    {
        self.run_batch_mode(Sense::Minimize, &mut batch_objective)
    }

    fn run_batch_mode(
        &mut self,
        sense: Sense,
        evaluate: &mut dyn FnMut(&[Config]) -> BatchResult,
    ) -> Result<TuningResult> {
        let (writer, replay) = self.prepare_journal(sense)?;
        let journal = JournalSink::new(writer, self.config.journal_on_error);
        let rep = match replay {
            None => None,
            Some(Replay::Sync(s)) => Some(s),
            Some(Replay::Async(_)) => {
                return Err(anyhow!(
                    "async-mode journal cannot resume a batch-objective (sync) run"
                ))
            }
        };
        self.run_sync(sense, evaluate, journal, rep)
    }

    fn gp_options(&self) -> GpOptions {
        GpOptions {
            backend: self.config.backend,
            mc_samples: self.config.mc_samples,
            initial_random: self.config.initial_random,
            tune_lengthscale: self.config.tune_lengthscale,
            proposal_threads: self.config.proposal_threads,
            proposal_shards: self.config.proposal_shards,
            kernel_profile: self.config.kernel_profile,
            // Scoring shards execute under the same scheduler model as the
            // objective evaluations — including the Celery simulator's
            // fault fates (shard losses are retried; output byte-identical
            // for every setting).
            shard_exec: match self.config.scheduler {
                SchedulerKind::Serial => crate::gp::ShardExec::Serial,
                SchedulerKind::Threaded => crate::gp::ShardExec::Threaded,
                SchedulerKind::Celery => crate::gp::ShardExec::CelerySim {
                    config: self.config.celery.clone().unwrap_or(
                        scheduler::celery::CelerySimConfig {
                            workers: self.config.workers,
                            ..Default::default()
                        },
                    ),
                    seed: self.config.seed,
                },
            },
            ..Default::default()
        }
    }

    /// The batch-synchronous coordinator (one barrier per iteration),
    /// with optional journaling and journal replay.
    fn run_sync(
        &mut self,
        sense: Sense,
        evaluate: &mut dyn FnMut(&[Config]) -> BatchResult,
        mut journal: JournalSink,
        replay: Option<SyncReplay>,
    ) -> Result<TuningResult> {
        let cfg = self.config.clone();
        anyhow::ensure!(
            cfg.pruner == PrunerKind::None,
            "pruner '{}' requires async mode (sync batches have no report channel)",
            cfg.pruner.as_str()
        );
        let early_stop = cfg.early_stop.map(|n| n.max(1));
        let opts = self.gp_options();
        let mut optimizer: Box<dyn BatchOptimizer> =
            optimizer::build(cfg.optimizer, &self.space, &opts)?;
        let mut rng = Pcg64::new(cfg.seed.wrapping_mul(0x9E37_79B9).wrapping_add(1));

        let total = Stopwatch::start();
        let mut history = History::new(); // maximization convention
        let mut user_history: Vec<(Config, f64)> = Vec::new();
        let mut best_series = Vec::with_capacity(cfg.num_iterations);
        let mut iterations = Vec::with_capacity(cfg.num_iterations);
        let mut since_improvement = 0usize;
        let mut best_so_far = f64::NEG_INFINITY; // internal sense
        let mut returned_total = 0usize; // running count: O(1) per iteration
        let mut start_iter = 0usize;
        let mut partial: Option<persist::recover::PartialRound> = None;

        // ---- journal replay: pure data reconstruction, no re-evaluation ----
        if let Some(rep) = replay {
            for (cfg_done, v) in rep.history {
                let internal = match sense {
                    Sense::Maximize => v,
                    Sense::Minimize => -v,
                };
                best_so_far = best_so_far.max(internal);
                history.push(cfg_done.clone(), internal);
                user_history.push((cfg_done, v));
            }
            for r in &rep.rounds_done {
                push_best_point(sense, &mut best_series, r.best, &mut since_improvement);
                iterations.push(IterationRecord {
                    iteration: r.iter,
                    proposed: r.proposed,
                    returned: r.returned,
                    best_so_far: r.best,
                    wall_ms: r.wall_ms,
                });
            }
            returned_total = history.len();
            start_iter = rep.rounds_done.len();
            if let Some(state) = rep.rng_state {
                rng = Pcg64::from_state(state);
            }
            partial = rep.partial;
            let cap = cfg.max_surrogate_obs.min(optimizer.surrogate_capacity());
            optimizer.rehydrate(&history.recent(cap), rep.rounds)?;
            crate::log_info!(
                "resumed sync run: {start_iter} iterations / {} evaluations replayed{}",
                history.len(),
                if partial.is_some() { ", completing a partial batch" } else { "" }
            );
        }

        // A run that had already met its early-stop condition resumes into
        // an immediate stop (unless a partial batch still needs finishing).
        let already_stopped = partial.is_none()
            && early_stop.map_or(false, |stop| !best_series.is_empty() && since_improvement >= stop);

        if !already_stopped {
            for iteration in start_iter..cfg.num_iterations {
                let it_timer = Stopwatch::start();
                // A partial iteration (crash mid-batch) re-uses its
                // journaled batch and skips the propose; otherwise propose
                // and journal the post-propose RNG/rounds state.
                let (batch, pre_evals) = match partial.take() {
                    Some(p) => (p.batch, p.evals),
                    None => {
                        let cap = cfg.max_surrogate_obs.min(optimizer.surrogate_capacity());
                        let opt_view = history.recent(cap);
                        let batch = optimizer.propose(&opt_view, cfg.batch_size, &mut rng)?;
                        anyhow::ensure!(!batch.is_empty(), "optimizer proposed an empty batch");
                        journal.append(&JournalEvent::SyncPropose {
                            iter: iteration,
                            rounds: optimizer.rounds(),
                            rng: rng.state(),
                            configs: batch.clone(),
                        })?;
                        (batch, Vec::new())
                    }
                };

                // Only the batch members without a journaled result are
                // (re-)evaluated; on a fresh iteration that is all of them.
                let mut matched = vec![false; batch.len()];
                for (cfg_done, _) in &pre_evals {
                    let Some(i) = (0..batch.len()).find(|&i| !matched[i] && batch[i] == *cfg_done)
                    else {
                        return Err(anyhow!(
                            "journaled evaluation does not match the proposed batch \
                             (journal corrupted?)"
                        ));
                    };
                    matched[i] = true;
                }
                let remaining: Vec<Config> = batch
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| !matched[*i])
                    .map(|(_, c)| c.clone())
                    .collect();
                let result =
                    if remaining.is_empty() { BatchResult::default() } else { evaluate(&remaining) };
                anyhow::ensure!(
                    result.evals.len() == result.params.len(),
                    "objective returned misaligned evals/params"
                );

                // Absorb replayed results first (their journal lines already
                // exist), then the fresh ones (journaled now) — for the
                // serial scheduler this reproduces the uninterrupted
                // arrival order exactly.
                for (cfg_done, v) in pre_evals {
                    let Some(v) = v else { continue };
                    let internal = match sense {
                        Sense::Maximize => v,
                        Sense::Minimize => -v,
                    };
                    best_so_far = best_so_far.max(internal);
                    history.push(cfg_done.clone(), internal);
                    user_history.push((cfg_done, v));
                }
                for (cfg_done, v) in result.params.into_iter().zip(result.evals) {
                    anyhow::ensure!(v.is_finite(), "objective returned a non-finite value");
                    journal.append(&JournalEvent::SyncEval {
                        iter: iteration,
                        config: cfg_done.clone(),
                        value: Some(v),
                    })?;
                    let internal = match sense {
                        Sense::Maximize => v,
                        Sense::Minimize => -v,
                    };
                    best_so_far = best_so_far.max(internal);
                    history.push(cfg_done.clone(), internal);
                    user_history.push((cfg_done, v));
                }

                let user_best = match sense {
                    Sense::Maximize => best_so_far,
                    Sense::Minimize => -best_so_far,
                };
                push_best_point(sense, &mut best_series, user_best, &mut since_improvement);
                let record = IterationRecord {
                    iteration,
                    proposed: batch.len(),
                    returned: history.len() - returned_total,
                    best_so_far: user_best,
                    wall_ms: it_timer.elapsed_ms(),
                };
                returned_total = history.len();
                journal.append(&JournalEvent::SyncRound {
                    iter: iteration,
                    proposed: record.proposed,
                    returned: record.returned,
                    best: user_best,
                    wall_ms: record.wall_ms,
                })?;
                if let Some(cb) = &mut self.callback {
                    cb(&record);
                }
                crate::log_debug!(
                    "iter {iteration}: proposed {} returned {} best {:.6}",
                    record.proposed,
                    record.returned,
                    user_best
                );
                iterations.push(record);
                // Early stopping on no improvement (streak maintained by
                // push_best_point above).
                if let Some(stop) = early_stop {
                    if since_improvement >= stop {
                        crate::log_info!("early stop after {iteration} iterations");
                        break;
                    }
                }
            }
        }

        let (best_cfg, best_internal) = history
            .best()
            .ok_or_else(|| anyhow!("no evaluation ever succeeded"))?;
        let best_objective = match sense {
            Sense::Maximize => best_internal,
            Sense::Minimize => -best_internal,
        };
        Ok(TuningResult {
            best_params: best_cfg.clone(),
            best_objective,
            evaluations: user_history.len(),
            history: user_history,
            best_series,
            iterations,
            wall_ms: total.elapsed_ms(),
            completions: Vec::new(),
            scheduler_stats: None,
            retried: 0,
            lost: 0,
            pruned: 0,
            reports: 0,
            stalled: false,
            journal_degraded: journal.degraded,
            dist_cache: optimizer.dist_cache_stats(),
        })
    }

    /// The asynchronous coordinator: spawn the scheduler's workers on a
    /// scope that lives exactly as long as the run, then drive the event
    /// loop against the submit/poll contract.
    fn run_async(
        &mut self,
        sense: Sense,
        objective: &(dyn Fn(&Config, &TrialReporter) -> Option<f64> + Sync),
        journal: JournalSink,
        replay: Option<AsyncReplay>,
    ) -> Result<TuningResult> {
        let cfg = self.config.clone();
        let opts = self.gp_options();
        let mut optimizer = optimizer::build(cfg.optimizer, &self.space, &opts)?;
        let space = self.space.clone();
        // Task ids continue past the crashed run's high-water mark.
        let first_id = replay.as_ref().map_or(0, |r| r.next_task_id);
        // The pruning state machine (`--pruner none` builds nothing: the
        // report channel stays sinkless and the event loop takes exactly
        // the pre-pruning path).
        let coordinator: Option<Arc<PruneCoordinator>> =
            prune::build_pruner(cfg.pruner, cfg.pruner_warmup, cfg.asha_reduction)
                .map(|p| Arc::new(PruneCoordinator::new(p, sense == Sense::Minimize)));
        if let (Some(pc), Some(rep)) = (&coordinator, &replay) {
            pc.seed(&rep.reports);
            pc.seed_pid_last(&rep.pid_last_task);
        }
        let sink: Option<Arc<dyn ReportSink>> =
            coordinator.as_ref().map(|pc| pc.clone() as Arc<dyn ReportSink>);
        // The task-id-aware form the schedulers execute: each evaluation
        // gets a reporter keyed to its task id, routing reports back here.
        let task_objective = move |id: TaskId, c: &Config| {
            let reporter = TrialReporter::new(id, sink.clone());
            objective(c, &reporter)
        };
        std::thread::scope(|scope| {
            let mut sched = scheduler::build_async_from(
                cfg.scheduler,
                cfg.workers,
                cfg.seed,
                cfg.celery.clone(),
                scope,
                &task_objective,
                first_id,
            );
            self.event_loop(
                sense,
                &cfg,
                &space,
                optimizer.as_mut(),
                sched.as_mut(),
                coordinator.as_deref(),
                journal,
                replay,
            )
        })
    }

    /// One replacement proposal, conditioned on the in-flight set. Each
    /// proposal draws from its own seed-derived RNG stream (keyed by its
    /// index), so the stream is independent of how completions happened to
    /// be grouped into polls. Returns `Ok(None)` when every candidate the
    /// optimizer and the space can produce is already in flight (tiny
    /// discrete spaces) — the caller then waits for a completion to free a
    /// point instead of double-submitting one.
    fn propose_one(
        cfg: &TunerConfig,
        space: &SearchSpace,
        optimizer: &mut dyn BatchOptimizer,
        history: &History,
        pending: &BTreeMap<u64, PendingTask>,
        proposal_idx: u64,
    ) -> Result<Option<Config>> {
        let pending_cfgs: Vec<Config> = pending.values().map(|p| p.config.clone()).collect();
        // Leave surrogate room for the hallucinated pending observations,
        // inside the backend's actual capacity (Surrogate::max_obs).
        let cap = cfg
            .max_surrogate_obs
            .min(optimizer.surrogate_capacity())
            .saturating_sub(pending_cfgs.len())
            .max(1);
        let opt_view = history.recent(cap);
        let mut rng = Pcg64::new(
            cfg.seed
                .wrapping_mul(0x9E37_79B9)
                .wrapping_add(0xA5F0_0000)
                .wrapping_add(proposal_idx),
        );
        let mut proposal = optimizer
            .propose_pending(&opt_view, &pending_cfgs, 1, &mut rng)?
            .into_iter()
            .next()
            .unwrap_or_else(|| space.sample(&mut rng));
        // Hard guarantee: never submit a config already in flight.
        let mut tries = 0;
        while pending_cfgs.contains(&proposal) {
            if tries >= 32 {
                return Ok(None); // space saturated by the in-flight window
            }
            proposal = space.sample(&mut rng);
            tries += 1;
        }
        Ok(Some(proposal))
    }

    /// The event loop: keep `window` evaluations in flight; fold each
    /// completion into the history the moment it arrives; retry lost work.
    /// With a pruning coordinator, intermediate reports are journaled as
    /// they drain (always before the reporting trial's terminal event) and
    /// pruned trials conclude as `Pruned` with a censored history entry.
    #[allow(clippy::too_many_arguments)]
    fn event_loop(
        &mut self,
        sense: Sense,
        cfg: &TunerConfig,
        space: &SearchSpace,
        optimizer: &mut dyn BatchOptimizer,
        sched: &mut dyn AsyncScheduler,
        prune_coord: Option<&PruneCoordinator>,
        mut journal: JournalSink,
        replay: Option<AsyncReplay>,
    ) -> Result<TuningResult> {
        let budget = cfg.num_iterations * cfg.batch_size;
        let window = cfg.window().min(budget.max(1));
        let early_stop_events = cfg.early_stop.map(|n| (n.max(1) * cfg.batch_size).max(1));

        let total = Stopwatch::start();
        let mut history = History::new(); // maximization convention
        let mut user_history: Vec<(Config, f64)> = Vec::new();
        let mut best_series = Vec::with_capacity(budget);
        let mut iterations = Vec::with_capacity(budget);
        let mut completion_log: Vec<CompletionRecord> = Vec::new();
        let mut pending: BTreeMap<u64, PendingTask> = BTreeMap::new();
        let mut proposals_made = 0usize;
        let mut proposed_since_record = 0usize;
        let mut best_so_far = f64::NEG_INFINITY; // internal sense
        let mut worst_so_far = f64::INFINITY; // internal sense (censoring)
        let mut since_improvement = 0usize;
        let mut stopped_early = false;
        let mut retried = 0u64;
        let mut lost = 0u64;
        let mut pruned_count = 0u64;
        let mut reports_count = 0u64;
        // The id the scheduler will assign to the next submission. Task
        // registration with the pruning coordinator must happen *before*
        // submit returns — a pool worker can start executing (and
        // reporting) the moment the task is enqueued — so each submit
        // site registers under this predicted id and then verifies it.
        let mut next_task_id: u64 = replay.as_ref().map_or(0, |r| r.next_task_id);
        let mut last_progress = std::time::Instant::now();
        let stable = cfg.replay == ReplayMode::Stable;
        // Stable mode: the reorder buffer. The fold frontier starts at the
        // first id this process can see complete — a resume has already
        // folded everything below the journaled high-water mark or is
        // about to re-enqueue it under fresh ids at or above it.
        let mut seq = Sequencer::new(next_task_id);
        // Stable mode: fold-epoch counter (continues the journal's on
        // resume — contiguity is audited by the replay).
        let mut epoch_seq: u64 = 0;
        let stall_timeout =
            (cfg.stall_timeout_ms > 0).then(|| Duration::from_millis(cfg.stall_timeout_ms));
        let mut stalled = false;

        // ---- journal replay: pure data reconstruction, no re-evaluation ----
        if let Some(rep) = replay {
            let mut done_values = rep.history.into_iter();
            for t in &rep.terminals {
                // `contributed` covers Done and Pruned-with-censored-value
                // terminals: exactly the conclusions that pushed a history
                // entry in the original run.
                let returned = t.contributed;
                if returned {
                    let Some((cfg_done, v)) = done_values.next() else {
                        return Err(anyhow!("journal replay: missing value for a Done event"));
                    };
                    let internal = match sense {
                        Sense::Maximize => v,
                        Sense::Minimize => -v,
                    };
                    best_so_far = best_so_far.max(internal);
                    worst_so_far = worst_so_far.min(internal);
                    history.push(cfg_done.clone(), internal);
                    user_history.push((cfg_done, v));
                }
                let user_best = match sense {
                    Sense::Maximize => best_so_far,
                    Sense::Minimize => -best_so_far,
                };
                push_best_point(sense, &mut best_series, user_best, &mut since_improvement);
                iterations.push(IterationRecord {
                    iteration: iterations.len(),
                    proposed: t.proposed_before,
                    returned: usize::from(returned),
                    best_so_far: user_best,
                    wall_ms: t.wall_ms,
                });
                // Latch early stop exactly like the live loop: once the
                // streak hits the threshold the run stops proposing for
                // good, even though later drained in-flight completions may
                // reset the streak (a crash after such a completion must
                // not un-stop the resumed run).
                if let Some(stop) = early_stop_events {
                    if since_improvement >= stop {
                        stopped_early = true;
                    }
                }
            }
            for e in rep.completion_log {
                completion_log.push(CompletionRecord {
                    task_id: e.task,
                    queue_wait_ms: e.queue_ms,
                    eval_ms: e.eval_ms,
                    retries: e.retries,
                    outcome: match e.outcome {
                        EventOutcome::Done(_) => CompletionOutcome::Done,
                        EventOutcome::Failed => CompletionOutcome::Failed,
                        EventOutcome::Lost(_) => CompletionOutcome::Lost,
                        EventOutcome::Resubmitted(_) => CompletionOutcome::Resubmitted,
                        EventOutcome::Pruned { .. } => CompletionOutcome::Pruned,
                    },
                });
            }
            retried = rep.retried;
            lost = rep.lost;
            pruned_count = rep.pruned;
            // Only concluded proposals' reports replay (in-flight trials
            // re-execute and re-report), so the resumed counter converges
            // on the uninterrupted run's.
            reports_count = rep.reports.len() as u64;
            proposals_made = rep.proposals_made as usize;
            proposed_since_record = rep.trailing_proposed;
            epoch_seq = rep.epochs;
            // A journal that already recorded a stall keeps the flag: the
            // resumed trajectory includes the abandoned tasks.
            stalled = rep.stalled;
            // Warm the optimizer over the view its *first post-resume fit*
            // will actually cover: with work still in flight that is the
            // constant-liar `[history + pending]` matrix over the
            // pending-clamped window (mirroring `propose_one`), so the
            // first liar fit pays the append path instead of a scratch
            // refactorization.
            let pending_cfgs: Vec<Config> =
                rep.pending.iter().map(|p| p.config.clone()).collect();
            let cap = cfg
                .max_surrogate_obs
                .min(optimizer.surrogate_capacity())
                .saturating_sub(pending_cfgs.len())
                .max(1);
            optimizer.rehydrate_pending(&history.recent(cap), &pending_cfgs, rep.rounds)?;
            // Re-enqueue in-flight-at-crash work in its original submit
            // order, with the retry budget it had already consumed.
            let re_enqueued = rep.pending.len();
            for p in rep.pending {
                // The re-enqueued attempt keeps its ORIGINAL journaled
                // admission cutoff and backoff — the decisions and delays
                // of the resumed trajectory must match the ones the
                // uninterrupted run derived at the original admission.
                if let Some(pc) = prune_coord {
                    pc.register(next_task_id, p.pid, stable.then_some(p.cutoff));
                }
                let meta = SubmitMeta {
                    backoff: Duration::from_secs_f64(p.backoff_ms / 1e3),
                    fate_key: stable.then(|| stable_fate_key(p.pid, p.retries)),
                };
                let ids = sched.submit_with(std::slice::from_ref(&p.config), &meta);
                anyhow::ensure!(ids.len() == 1, "scheduler must assign one id per config");
                anyhow::ensure!(
                    prune_coord.is_none() || ids[0] == next_task_id,
                    "scheduler assigned task id {} (expected {next_task_id}): \
                     pruning requires sequential task ids",
                    ids[0]
                );
                next_task_id = ids[0] + 1;
                journal.append(&JournalEvent::AsyncSubmit {
                    pid: p.pid,
                    task: ids[0],
                    retries: p.retries,
                    cutoff: p.cutoff,
                    backoff_ms: p.backoff_ms,
                })?;
                pending.insert(ids[0], PendingTask { config: p.config, retries: p.retries, pid: p.pid });
            }
            crate::log_info!(
                "resumed async run: {} conclusions / {} evaluations replayed, \
                 {re_enqueued} in-flight configs re-enqueued",
                iterations.len(),
                history.len()
            );
        }

        loop {
            // ---- refill: keep the in-flight window full ----
            while !stopped_early && pending.len() < window && proposals_made < budget {
                let pid = proposals_made as u64;
                let Some(proposal) =
                    Self::propose_one(cfg, space, optimizer, &history, &pending, pid)?
                else {
                    // Every distinct config is in flight: wait for a
                    // completion to free a point before proposing again.
                    break;
                };
                journal.append(&JournalEvent::AsyncPropose {
                    pid,
                    rounds: optimizer.rounds(),
                    config: proposal.clone(),
                })?;
                // The admission cutoff: the fold frontier at submit time —
                // stable mode's pruning-visibility horizon, journaled so a
                // resume re-derives identical decisions (0 and unused in
                // wallclock mode).
                let cutoff = if stable { seq.fold_next } else { 0 };
                // Register before submit: a pool worker may begin executing
                // (and reporting) the instant the task hits the queue.
                if let Some(pc) = prune_coord {
                    pc.register(next_task_id, pid, stable.then_some(cutoff));
                }
                let meta = SubmitMeta {
                    backoff: Duration::ZERO,
                    fate_key: stable.then(|| stable_fate_key(pid, 0)),
                };
                let ids = sched.submit_with(std::slice::from_ref(&proposal), &meta);
                anyhow::ensure!(ids.len() == 1, "scheduler must assign one id per config");
                anyhow::ensure!(
                    prune_coord.is_none() || ids[0] == next_task_id,
                    "scheduler assigned task id {} (expected {next_task_id}): \
                     pruning requires sequential task ids",
                    ids[0]
                );
                next_task_id = ids[0] + 1;
                journal.append(&JournalEvent::AsyncSubmit {
                    pid,
                    task: ids[0],
                    retries: 0,
                    cutoff,
                    backoff_ms: 0.0,
                })?;
                pending.insert(ids[0], PendingTask { config: proposal, retries: 0, pid });
                proposals_made += 1;
                proposed_since_record += 1;
            }

            if pending.is_empty() {
                break; // budget exhausted (or early-stopped), nothing in flight
            }

            // ---- wait for completions ----
            // Stable mode with an unblocked frontier: don't sleep — fold
            // it now and only then admit the next proposal. This fold-one-
            // then-refill alternation is what makes proposal k condition on
            // exactly max(0, k - window) folds in every run, on every
            // scheduler.
            let timeout = if stable && seq.has_ready() { Duration::ZERO } else { POLL_TIMEOUT };
            let completions: Vec<Completion> = sched.poll(timeout);
            // Journal intermediate reports before folding this poll's
            // completions: a worker pushes its reports before it sends the
            // completion, so draining here keeps every `async_report` line
            // ahead of its trial's `async_complete` — the order the replay
            // relies on.
            if let Some(pc) = prune_coord {
                for r in pc.drain_log() {
                    journal.append(&JournalEvent::AsyncReport {
                        pid: r.pid,
                        task: r.task,
                        step: r.step,
                        value: r.value,
                        pruned: r.pruned,
                    })?;
                    reports_count += 1;
                }
            }
            if !completions.is_empty() {
                last_progress = std::time::Instant::now();
            }
            // ---- admit to the fold ----
            // Wallclock: this poll's whole batch in arrival order — the
            // pre-knob path byte-for-byte. Stable: absorb into the reorder
            // buffer and release at most the frontier completion.
            let to_fold: Vec<Completion> = if stable {
                seq.absorb(completions);
                seq.pop_ready().into_iter().collect()
            } else {
                completions
            };
            if to_fold.is_empty() {
                if sched.in_flight() == 0 {
                    // Every worker died without reporting (worker panic):
                    // the scheduler has lost track of the outstanding
                    // work and no retry can land. Conclude each in-flight
                    // proposal as a journaled `Lost(Crashed)` terminal —
                    // so a later resume agrees with this process about
                    // what was returned, instead of re-enqueueing
                    // proposals this run already counted as lost and
                    // silently diverging from the result it reported.
                    //
                    // Stable mode: buffered completions can no longer be
                    // ordered (their frontier blocker died with the
                    // workers) — tear the buffer down and conclude every
                    // outstanding task, in one final fold epoch.
                    if stable {
                        journal.append(&JournalEvent::AsyncEpoch { seq: epoch_seq })?;
                        epoch_seq += 1;
                        seq.clear();
                    }
                    let crashed: Vec<(u64, PendingTask)> =
                        std::mem::take(&mut pending).into_iter().collect();
                    for (task_id, task) in crashed {
                        journal.append(&JournalEvent::AsyncComplete {
                            pid: task.pid,
                            task: task_id,
                            retries: task.retries,
                            outcome: EventOutcome::Lost(LossReason::Crashed),
                            queue_ms: 0.0,
                            eval_ms: 0.0,
                        })?;
                        lost += 1;
                        completion_log.push(CompletionRecord {
                            task_id,
                            queue_wait_ms: 0.0,
                            eval_ms: 0.0,
                            retries: task.retries,
                            outcome: CompletionOutcome::Lost,
                        });
                        let user_best = match sense {
                            Sense::Maximize => best_so_far,
                            Sense::Minimize => -best_so_far,
                        };
                        push_best_point(sense, &mut best_series, user_best, &mut since_improvement);
                        let record = IterationRecord {
                            iteration: iterations.len(),
                            proposed: proposed_since_record,
                            returned: 0,
                            best_so_far: user_best,
                            wall_ms: 0.0,
                        };
                        proposed_since_record = 0;
                        if let Some(cb) = &mut self.callback {
                            cb(&record);
                        }
                        iterations.push(record);
                    }
                    break;
                }
                if let Some(timeout) = stall_timeout {
                    if last_progress.elapsed() >= timeout {
                        // Nothing has completed within the stall window but
                        // the scheduler still claims in-flight work: a
                        // worker went silent. Degrade instead of aborting —
                        // conclude every outstanding task with a journaled
                        // terminal `stalled` event (a resume will not
                        // re-enqueue them, mirroring this run giving up on
                        // them), drain, and return partial results with
                        // `stalled: true`.
                        crate::log_warn!(
                            "async scheduler stalled: {} tasks in flight, none completed \
                             in {timeout:?} — abandoning them and returning partial results",
                            sched.in_flight()
                        );
                        if stable {
                            journal.append(&JournalEvent::AsyncEpoch { seq: epoch_seq })?;
                            epoch_seq += 1;
                            seq.clear();
                        }
                        let abandoned: Vec<(u64, PendingTask)> =
                            std::mem::take(&mut pending).into_iter().collect();
                        for (task_id, task) in abandoned {
                            let ev = JournalEvent::AsyncStalled { pid: task.pid, task: task_id };
                            journal.append(&ev)?;
                            if let Some(pc) = prune_coord {
                                pc.conclude(task_id);
                            }
                            lost += 1;
                            completion_log.push(CompletionRecord {
                                task_id,
                                queue_wait_ms: 0.0,
                                eval_ms: 0.0,
                                retries: task.retries,
                                outcome: CompletionOutcome::Lost,
                            });
                            let user_best = match sense {
                                Sense::Maximize => best_so_far,
                                Sense::Minimize => -best_so_far,
                            };
                            push_best_point(
                                sense,
                                &mut best_series,
                                user_best,
                                &mut since_improvement,
                            );
                            let record = IterationRecord {
                                iteration: iterations.len(),
                                proposed: proposed_since_record,
                                returned: 0,
                                best_so_far: user_best,
                                wall_ms: 0.0,
                            };
                            proposed_since_record = 0;
                            if let Some(cb) = &mut self.callback {
                                cb(&record);
                            }
                            iterations.push(record);
                        }
                        stalled = true;
                        break;
                    }
                }
                continue;
            }

            // ---- fold completions in (canonical ascending-id order under
            // `stable`; this poll's arrival order under `wallclock`) ----
            for comp in to_fold {
                let Some(mut task) = pending.remove(&comp.id) else { continue };
                // Stable mode: one journaled fold epoch per fold — the
                // replay audits both the marker contiguity and that every
                // fold between markers lands in ascending task-id order.
                if stable {
                    journal.append(&JournalEvent::AsyncEpoch { seq: epoch_seq })?;
                    epoch_seq += 1;
                }
                // A pruned trial's scheduler-level status (the early
                // return's Done/Failed) is superseded by the pruning
                // decision: conclude it as `Pruned` with a censored
                // history entry under the worst-seen policy.
                let pruned_at = prune_coord.and_then(|pc| pc.pruned_info(task.pid));
                if let Some(pc) = prune_coord {
                    pc.conclude(comp.id);
                }
                let (outcome, contributed) = if let Some((at_step, last_value)) = pruned_at {
                    journal.append(&JournalEvent::AsyncComplete {
                        pid: task.pid,
                        task: comp.id,
                        retries: task.retries,
                        outcome: EventOutcome::Pruned { at_step, last_value },
                        queue_ms: comp.queue_wait_ms,
                        eval_ms: comp.eval_ms,
                    })?;
                    let last_internal = match sense {
                        Sense::Maximize => last_value,
                        Sense::Minimize => -last_value,
                    };
                    let worst = worst_so_far.is_finite().then_some(worst_so_far);
                    let contributed =
                        if let Some(censored) = prune::censored_value(last_internal, worst) {
                            let user = match sense {
                                Sense::Maximize => censored,
                                Sense::Minimize => -censored,
                            };
                            best_so_far = best_so_far.max(censored);
                            worst_so_far = worst_so_far.min(censored);
                            history.push(task.config.clone(), censored);
                            user_history.push((task.config.clone(), user));
                            true
                        } else {
                            false
                        };
                    pruned_count += 1;
                    (CompletionOutcome::Pruned, contributed)
                } else {
                    match comp.status {
                        CompletionStatus::Done(v) => {
                            anyhow::ensure!(
                                v.is_finite(),
                                "objective returned a non-finite value"
                            );
                            journal.append(&JournalEvent::AsyncComplete {
                                pid: task.pid,
                                task: comp.id,
                                retries: task.retries,
                                outcome: EventOutcome::Done(v),
                                queue_ms: comp.queue_wait_ms,
                                eval_ms: comp.eval_ms,
                            })?;
                            let internal = match sense {
                                Sense::Maximize => v,
                                Sense::Minimize => -v,
                            };
                            best_so_far = best_so_far.max(internal);
                            worst_so_far = worst_so_far.min(internal);
                            history.push(task.config.clone(), internal);
                            user_history.push((task.config.clone(), v));
                            (CompletionOutcome::Done, true)
                        }
                        CompletionStatus::Failed => {
                            journal.append(&JournalEvent::AsyncComplete {
                                pid: task.pid,
                                task: comp.id,
                                retries: task.retries,
                                outcome: EventOutcome::Failed,
                                queue_ms: comp.queue_wait_ms,
                                eval_ms: comp.eval_ms,
                            })?;
                            (CompletionOutcome::Failed, false)
                        }
                        CompletionStatus::Lost(reason) => {
                            // After early stop, a retried result could no longer
                            // change anything — let the proposal die instead.
                            if !stopped_early && task.retries < cfg.max_retries {
                                task.retries += 1;
                                retried += 1;
                                crate::log_debug!(
                                    "task {} lost ({reason:?}); retry {}/{}",
                                    comp.id,
                                    task.retries,
                                    cfg.max_retries
                                );
                                journal.append(&JournalEvent::AsyncComplete {
                                    pid: task.pid,
                                    task: comp.id,
                                    retries: task.retries,
                                    outcome: EventOutcome::Resubmitted(reason),
                                    queue_ms: comp.queue_wait_ms,
                                    eval_ms: comp.eval_ms,
                                })?;
                                completion_log.push(CompletionRecord {
                                    task_id: comp.id,
                                    queue_wait_ms: comp.queue_wait_ms,
                                    eval_ms: comp.eval_ms,
                                    retries: task.retries,
                                    outcome: CompletionOutcome::Resubmitted,
                                });
                                // Deterministic retry backoff (0 when the
                                // knob is off) and a fresh admission
                                // cutoff — both journaled so a resume
                                // re-applies them verbatim.
                                let backoff_ms =
                                    retry_backoff_ms(cfg, task.pid, task.retries);
                                let cutoff = if stable { seq.fold_next } else { 0 };
                                if let Some(pc) = prune_coord {
                                    pc.register(
                                        next_task_id,
                                        task.pid,
                                        stable.then_some(cutoff),
                                    );
                                }
                                let meta = SubmitMeta {
                                    backoff: Duration::from_secs_f64(backoff_ms / 1e3),
                                    fate_key: stable
                                        .then(|| stable_fate_key(task.pid, task.retries)),
                                };
                                let ids =
                                    sched.submit_with(std::slice::from_ref(&task.config), &meta);
                                anyhow::ensure!(ids.len() == 1, "resubmit must assign one id");
                                anyhow::ensure!(
                                    prune_coord.is_none() || ids[0] == next_task_id,
                                    "scheduler assigned task id {} (expected {next_task_id}): \
                                     pruning requires sequential task ids",
                                    ids[0]
                                );
                                next_task_id = ids[0] + 1;
                                journal.append(&JournalEvent::AsyncSubmit {
                                    pid: task.pid,
                                    task: ids[0],
                                    retries: task.retries,
                                    cutoff,
                                    backoff_ms,
                                })?;
                                pending.insert(ids[0], task);
                                continue; // not concluded: no iteration record
                            }
                            journal.append(&JournalEvent::AsyncComplete {
                                pid: task.pid,
                                task: comp.id,
                                retries: task.retries,
                                outcome: EventOutcome::Lost(reason),
                                queue_ms: comp.queue_wait_ms,
                                eval_ms: comp.eval_ms,
                            })?;
                            lost += 1;
                            (CompletionOutcome::Lost, false)
                        }
                    }
                };

                // ---- one concluded proposal = one iteration record ----
                completion_log.push(CompletionRecord {
                    task_id: comp.id,
                    queue_wait_ms: comp.queue_wait_ms,
                    eval_ms: comp.eval_ms,
                    retries: task.retries,
                    outcome,
                });
                let user_best = match sense {
                    Sense::Maximize => best_so_far,
                    Sense::Minimize => -best_so_far,
                };
                push_best_point(sense, &mut best_series, user_best, &mut since_improvement);
                let record = IterationRecord {
                    iteration: iterations.len(),
                    proposed: proposed_since_record,
                    returned: usize::from(contributed),
                    best_so_far: user_best,
                    wall_ms: comp.queue_wait_ms + comp.eval_ms,
                };
                proposed_since_record = 0;
                if let Some(cb) = &mut self.callback {
                    cb(&record);
                }
                iterations.push(record);

                if let Some(stop) = early_stop_events {
                    if since_improvement >= stop && !stopped_early {
                        stopped_early = true;
                        let cancelled = sched.cancel_pending();
                        for id in &cancelled {
                            // Journal each withdrawal as a terminal event:
                            // without it a resume would classify these
                            // proposals as in-flight and re-run work the
                            // original run cancelled.
                            if let Some(t) = pending.remove(id) {
                                journal
                                    .append(&JournalEvent::AsyncCancel { pid: t.pid, task: *id })?;
                                if let Some(pc) = prune_coord {
                                    pc.conclude(*id);
                                }
                            }
                        }
                        crate::log_info!(
                            "async early stop after {} completions ({} queued cancelled)",
                            iterations.len(),
                            cancelled.len()
                        );
                    }
                }
            }
        }

        let (best_cfg, best_internal) = history
            .best()
            .ok_or_else(|| anyhow!("no evaluation ever succeeded"))?;
        let best_objective = match sense {
            Sense::Maximize => best_internal,
            Sense::Minimize => -best_internal,
        };
        Ok(TuningResult {
            best_params: best_cfg.clone(),
            best_objective,
            evaluations: user_history.len(),
            history: user_history,
            best_series,
            iterations,
            wall_ms: total.elapsed_ms(),
            completions: completion_log,
            scheduler_stats: Some(sched.stats()),
            retried,
            lost,
            pruned: pruned_count,
            reports: reports_count,
            stalled,
            journal_degraded: journal.degraded,
            dist_cache: optimizer.dist_cache_stats(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamValue;

    fn tuner(optimizer: OptimizerKind, iters: usize, batch: usize) -> Tuner {
        let space = crate::space::svm_space();
        Tuner::new(
            space,
            TunerConfig {
                optimizer,
                num_iterations: iters,
                batch_size: batch,
                backend: SurrogateBackend::Native,
                seed: 11,
                ..Default::default()
            },
        )
    }

    fn async_tuner(optimizer: OptimizerKind, iters: usize, batch: usize) -> Tuner {
        let space = crate::space::svm_space();
        Tuner::new(
            space,
            TunerConfig {
                optimizer,
                num_iterations: iters,
                batch_size: batch,
                backend: SurrogateBackend::Native,
                seed: 11,
                mode: ExecutionMode::Async,
                ..Default::default()
            },
        )
    }

    fn quad(cfg: &Config) -> Option<f64> {
        let c = cfg.get_f64("c")?;
        Some(-(c - 60.0) * (c - 60.0))
    }

    #[test]
    fn maximize_converges_and_reports() {
        let mut t = tuner(OptimizerKind::Hallucination, 20, 1);
        let r = t.maximize(quad).unwrap();
        assert_eq!(r.best_series.len(), 20);
        assert_eq!(r.evaluations, 20);
        assert!(r.best_objective > -100.0, "best {}", r.best_objective);
        // best_series is monotone non-decreasing for maximization
        for w in r.best_series.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert_eq!(r.best_objective, *r.best_series.last().unwrap());
    }

    #[test]
    fn minimize_flips_sense() {
        let mut t = tuner(OptimizerKind::Hallucination, 15, 1);
        let r = t.minimize(|cfg| {
            let c = cfg.get_f64("c")?;
            Some((c - 60.0) * (c - 60.0))
        }).unwrap();
        assert!(r.best_objective < 100.0);
        for w in r.best_series.windows(2) {
            assert!(w[1] <= w[0], "minimize series must not increase");
        }
    }

    #[test]
    fn batch_mode_with_partial_results() {
        let mut t = tuner(OptimizerKind::Random, 10, 4);
        let mut calls = 0usize;
        let r = t
            .maximize_batch(|batch| {
                calls += 1;
                let mut out = BatchResult::default();
                // Lose every other evaluation (straggler simulation).
                for (i, cfg) in batch.iter().enumerate() {
                    if i % 2 == 0 {
                        out.push(cfg.clone(), quad(cfg).unwrap());
                    }
                }
                out
            })
            .unwrap();
        assert_eq!(calls, 10);
        assert_eq!(r.evaluations, 20, "half of 40 proposals returned");
    }

    #[test]
    fn iteration_records_count_partial_returns() {
        // The per-iteration `returned` field must match each iteration's
        // arrivals (regression test for the O(n²) recomputation).
        let mut t = tuner(OptimizerKind::Random, 8, 3);
        let r = t
            .maximize_batch(|batch| {
                let mut out = BatchResult::default();
                for (i, cfg) in batch.iter().enumerate() {
                    if i != 0 {
                        out.push(cfg.clone(), 1.0);
                    }
                }
                out
            })
            .unwrap();
        assert_eq!(r.iterations.len(), 8);
        for rec in &r.iterations {
            assert_eq!(rec.proposed, 3);
            assert_eq!(rec.returned, 2, "iter {}: lost exactly one", rec.iteration);
        }
        assert_eq!(r.evaluations, 16);
    }

    #[test]
    fn early_stop_halts() {
        let space = crate::space::svm_space();
        let mut t = Tuner::new(
            space,
            TunerConfig {
                optimizer: OptimizerKind::Random,
                num_iterations: 50,
                early_stop: Some(3),
                backend: SurrogateBackend::Native,
                seed: 1,
                ..Default::default()
            },
        );
        // Constant objective: never improves after the first iteration.
        let r = t.maximize(|_| Some(1.0)).unwrap();
        assert!(r.best_series.len() <= 6, "ran {} iters", r.best_series.len());
    }

    #[test]
    fn callback_sees_every_iteration() {
        use std::cell::RefCell;
        use std::rc::Rc;
        let seen = Rc::new(RefCell::new(0usize));
        let seen2 = seen.clone();
        let space = crate::space::svm_space();
        let mut t = Tuner::new(
            space,
            TunerConfig {
                optimizer: OptimizerKind::Random,
                num_iterations: 7,
                backend: SurrogateBackend::Native,
                ..Default::default()
            },
        )
        .with_callback(move |rec| {
            assert!(rec.proposed >= 1);
            *seen2.borrow_mut() += 1;
        });
        t.maximize(|_| Some(0.0)).unwrap();
        assert_eq!(*seen.borrow(), 7);
    }

    #[test]
    fn all_failures_is_an_error() {
        let mut t = tuner(OptimizerKind::Random, 3, 2);
        let err = t.maximize(|_| None).unwrap_err();
        assert!(err.to_string().contains("no evaluation"));
    }

    #[test]
    fn non_finite_objective_rejected() {
        let mut t = tuner(OptimizerKind::Random, 2, 1);
        assert!(t.maximize(|_| Some(f64::NAN)).is_err());
    }

    #[test]
    fn tpe_and_clustering_run_end_to_end() {
        for kind in [OptimizerKind::Tpe, OptimizerKind::Clustering] {
            let mut t = tuner(kind, 10, 3);
            let r = t.maximize(quad).unwrap();
            assert_eq!(r.evaluations, 30);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut t = tuner(OptimizerKind::Hallucination, 8, 2);
            t.maximize(quad).unwrap().best_objective
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn from_run_config_maps() {
        let rc = RunConfig {
            optimizer: "clustering".into(),
            scheduler: "threaded".into(),
            backend: "native".into(),
            batch_size: 5,
            workers: 8,
            ..Default::default()
        };
        let tc = TunerConfig::from_run_config(&rc).unwrap();
        assert_eq!(tc.optimizer, OptimizerKind::Clustering);
        assert_eq!(tc.scheduler, SchedulerKind::Threaded);
        assert_eq!(tc.workers, 8);
        let _ = Config::new(vec![("x".into(), ParamValue::F64(0.0))]); // silence import
    }

    #[test]
    fn from_run_config_plumbs_early_stop_and_surrogate_cap() {
        let rc = RunConfig {
            early_stop: 7,
            max_surrogate_obs: 128,
            mode: "async".into(),
            async_window: 12,
            max_retries: 5,
            ..Default::default()
        };
        let tc = TunerConfig::from_run_config(&rc).unwrap();
        assert_eq!(tc.early_stop, Some(7));
        assert_eq!(tc.max_surrogate_obs, 128);
        assert_eq!(tc.mode, ExecutionMode::Async);
        assert_eq!(tc.async_window, 12);
        assert_eq!(tc.max_retries, 5);
        // early_stop = 0 means disabled
        let tc0 = TunerConfig::from_run_config(&RunConfig::default()).unwrap();
        assert_eq!(tc0.early_stop, None);
        assert_eq!(tc0.mode, ExecutionMode::Sync);
    }

    #[test]
    fn to_run_config_roundtrips_through_from_run_config() {
        let tc = TunerConfig {
            batch_size: 3,
            num_iterations: 17,
            initial_random: 4,
            optimizer: OptimizerKind::Thompson,
            scheduler: SchedulerKind::Celery,
            workers: 6,
            mc_samples: 512,
            seed: 99,
            backend: SurrogateBackend::Native,
            tune_lengthscale: true,
            early_stop: Some(5),
            max_surrogate_obs: 64,
            mode: ExecutionMode::Async,
            async_window: 9,
            max_retries: 1,
            proposal_threads: 4,
            proposal_shards: 3,
            kernel_profile: crate::gp::KernelProfile::Fast,
            fsync_every_n: 16,
            pruner: PrunerKind::Asha,
            pruner_warmup: 2,
            asha_reduction: 4.0,
            replay: ReplayMode::Stable,
            journal_on_error: JournalPolicy::Degrade,
            retry_backoff_ms: 12.5,
            stall_timeout_ms: 1234,
            journal_segment_events: 64,
            journal_keep_segments: 3,
            compact_on_resume: true,
            celery: None,
        };
        let rc = tc.to_run_config();
        rc.validate().unwrap();
        let back = TunerConfig::from_run_config(&rc).unwrap();
        assert_eq!(back.batch_size, tc.batch_size);
        assert_eq!(back.num_iterations, tc.num_iterations);
        assert_eq!(back.initial_random, tc.initial_random);
        assert_eq!(back.optimizer, tc.optimizer);
        assert_eq!(back.scheduler, tc.scheduler);
        assert_eq!(back.workers, tc.workers);
        assert_eq!(back.mc_samples, tc.mc_samples);
        assert_eq!(back.seed, tc.seed);
        assert_eq!(back.backend, tc.backend);
        assert_eq!(back.tune_lengthscale, tc.tune_lengthscale);
        assert_eq!(back.early_stop, tc.early_stop);
        assert_eq!(back.max_surrogate_obs, tc.max_surrogate_obs);
        assert_eq!(back.mode, tc.mode);
        assert_eq!(back.async_window, tc.async_window);
        assert_eq!(back.max_retries, tc.max_retries);
        assert_eq!(back.proposal_threads, tc.proposal_threads);
        assert_eq!(back.proposal_shards, tc.proposal_shards);
        assert_eq!(back.kernel_profile, tc.kernel_profile);
        assert_eq!(back.fsync_every_n, tc.fsync_every_n);
        assert_eq!(back.pruner, tc.pruner);
        assert_eq!(back.pruner_warmup, tc.pruner_warmup);
        assert_eq!(back.asha_reduction, tc.asha_reduction);
        assert_eq!(back.replay, tc.replay);
        assert_eq!(back.journal_on_error, tc.journal_on_error);
        assert_eq!(back.retry_backoff_ms, tc.retry_backoff_ms);
        assert_eq!(back.stall_timeout_ms, tc.stall_timeout_ms);
        assert_eq!(back.journal_segment_events, tc.journal_segment_events);
        assert_eq!(back.journal_keep_segments, tc.journal_keep_segments);
        assert_eq!(back.compact_on_resume, tc.compact_on_resume);
    }

    // ---------------- async event-loop tests ----------------

    #[test]
    fn async_serial_runs_full_budget_with_telemetry() {
        let mut t = async_tuner(OptimizerKind::Hallucination, 10, 2);
        let r = t.maximize(quad).unwrap();
        assert_eq!(r.evaluations, 20, "reliable serial async runs the full budget");
        assert_eq!(r.best_series.len(), 20, "one series point per completion");
        for w in r.best_series.windows(2) {
            assert!(w[1] >= w[0], "maximize series must not decrease");
        }
        assert_eq!(r.completions.len(), 20);
        for c in &r.completions {
            assert_eq!(c.outcome, crate::coordinator::CompletionOutcome::Done);
            assert!(c.queue_wait_ms >= 0.0 && c.eval_ms >= 0.0);
        }
        let stats = r.scheduler_stats.as_ref().unwrap();
        assert_eq!(stats.submitted, 20);
        assert_eq!(stats.completed, 20);
        assert!(stats.max_in_flight >= 2, "window must actually fill");
    }

    #[test]
    fn async_event_loop_deterministic_given_seed() {
        let run = || {
            let mut t = async_tuner(OptimizerKind::Hallucination, 8, 2);
            let r = t.maximize(quad).unwrap();
            (r.best_objective, r.best_series.clone())
        };
        let (a_best, a_series) = run();
        let (b_best, b_series) = run();
        assert_eq!(a_best, b_best, "same seed, same optimum");
        assert_eq!(a_series, b_series, "same seed, same trajectory");
    }

    #[test]
    fn async_minimize_flips_sense() {
        let mut t = async_tuner(OptimizerKind::Hallucination, 8, 2);
        let r = t
            .minimize(|cfg| {
                let c = cfg.get_f64("c")?;
                Some((c - 60.0) * (c - 60.0))
            })
            .unwrap();
        assert!(r.best_objective < 400.0);
        for w in r.best_series.windows(2) {
            assert!(w[1] <= w[0], "minimize series must not increase");
        }
    }

    #[test]
    fn async_all_failures_is_an_error_and_terminates() {
        let mut t = async_tuner(OptimizerKind::Random, 3, 2);
        let err = t.maximize(|_| None).unwrap_err();
        assert!(err.to_string().contains("no evaluation"));
    }

    #[test]
    fn async_early_stop_cancels_queue() {
        let space = crate::space::svm_space();
        let mut t = Tuner::new(
            space,
            TunerConfig {
                optimizer: OptimizerKind::Random,
                num_iterations: 50,
                batch_size: 1,
                early_stop: Some(3),
                backend: SurrogateBackend::Native,
                mode: ExecutionMode::Async,
                async_window: 4,
                seed: 1,
                ..Default::default()
            },
        );
        let r = t.maximize(|_| Some(1.0)).unwrap();
        // 1 improvement + 3 stagnant completions + <= window stragglers.
        assert!(
            r.best_series.len() <= 4 + 4,
            "ran {} completions",
            r.best_series.len()
        );
    }

    #[test]
    fn async_threaded_overlaps_evaluations() {
        let space = crate::space::svm_space();
        let mut t = Tuner::new(
            space,
            TunerConfig {
                optimizer: OptimizerKind::Random,
                num_iterations: 8,
                batch_size: 1,
                scheduler: SchedulerKind::Threaded,
                workers: 8,
                async_window: 8,
                backend: SurrogateBackend::Native,
                mode: ExecutionMode::Async,
                seed: 2,
                ..Default::default()
            },
        );
        let start = std::time::Instant::now();
        let r = t
            .maximize(|cfg| {
                std::thread::sleep(Duration::from_millis(30));
                quad(cfg)
            })
            .unwrap();
        let ms = start.elapsed().as_millis();
        assert_eq!(r.evaluations, 8);
        assert!(ms < 240, "8x30ms on 8 workers took {ms}ms — window not full");
    }

    // ---------------- journal smoke tests ----------------
    // (full crash-injection coverage lives in rust/tests/recovery.rs)

    fn tmp_journal(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("mango_tuner_{}_{name}.jsonl", std::process::id()))
    }

    #[test]
    fn journaled_run_resumes_to_identical_result_after_completion() {
        let path = tmp_journal("finished");
        let run_cfg = || TunerConfig {
            optimizer: OptimizerKind::Hallucination,
            num_iterations: 6,
            batch_size: 2,
            backend: SurrogateBackend::Native,
            seed: 7,
            ..Default::default()
        };
        let space = crate::space::svm_space();
        let baseline = Tuner::new(space.clone(), run_cfg()).maximize(quad).unwrap();
        let journaled = Tuner::new(space.clone(), run_cfg())
            .with_journal(&path)
            .maximize(quad)
            .unwrap();
        assert_eq!(journaled.best_params, baseline.best_params, "journaling is transparent");
        assert_eq!(journaled.best_objective, baseline.best_objective);
        assert_eq!(journaled.history, baseline.history);
        // Resuming a *finished* journal replays everything and runs nothing.
        let resumed = Tuner::resume_from(space, &path).unwrap().maximize(quad).unwrap();
        assert_eq!(resumed.best_params, baseline.best_params);
        assert_eq!(resumed.best_objective, baseline.best_objective);
        assert_eq!(resumed.history, baseline.history);
        assert_eq!(resumed.best_series, baseline.best_series);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn resume_with_wrong_space_or_sense_fails_loudly() {
        let path = tmp_journal("guards");
        let space = crate::space::svm_space();
        Tuner::new(
            space.clone(),
            TunerConfig {
                optimizer: OptimizerKind::Random,
                num_iterations: 2,
                backend: SurrogateBackend::Native,
                ..Default::default()
            },
        )
        .with_journal(&path)
        .maximize(|_| Some(1.0))
        .unwrap();
        // Wrong space: fingerprint mismatch.
        let err = Tuner::resume_from(crate::space::xgboost_space(), &path).unwrap_err();
        assert!(err.to_string().contains("different search space"), "got: {err:#}");
        // Wrong sense: header records maximize.
        let err = Tuner::resume_from(space, &path)
            .unwrap()
            .minimize(|_| Some(1.0))
            .unwrap_err();
        assert!(err.to_string().contains("maximize"), "got: {err:#}");
        std::fs::remove_file(&path).ok();
    }

    // ---------------- order-stable replay (`--replay stable`) ----------------

    fn completion(id: TaskId) -> Completion {
        Completion {
            id,
            config: Config::default(),
            status: CompletionStatus::Done(id as f64),
            queue_wait_ms: 0.0,
            eval_ms: 0.0,
            epoch: 1,
        }
    }

    #[test]
    fn sequencer_fold_order_invariant_to_adversarial_permutations() {
        // Whatever order (and grouping) completions arrive in, the
        // sequencer must release them in exactly ascending task id.
        for seed in 0..16u64 {
            let mut ids: Vec<u64> = (0..64).collect();
            let mut rng = Pcg64::new(seed);
            // Fisher–Yates: an adversarial arrival permutation per seed.
            for i in (1..ids.len()).rev() {
                let j = (rng.next_u64() % (i as u64 + 1)) as usize;
                ids.swap(i, j);
            }
            let chunk = 1 + (seed as usize % 7); // vary poll batch sizes too
            let mut seq = Sequencer::new(0);
            let mut folded = Vec::new();
            for arrival in ids.chunks(chunk) {
                seq.absorb(arrival.iter().map(|&id| completion(id)).collect());
                while let Some(c) = seq.pop_ready() {
                    folded.push(c.id);
                }
            }
            assert_eq!(folded, (0..64).collect::<Vec<u64>>(), "seed {seed} chunk {chunk}");
        }
    }

    #[test]
    fn sequencer_blocks_until_the_frontier_arrives() {
        let mut seq = Sequencer::new(5);
        seq.absorb(vec![completion(7), completion(6)]);
        assert!(!seq.has_ready(), "frontier (5) has not arrived");
        assert!(seq.pop_ready().is_none());
        seq.absorb(vec![completion(5)]);
        assert!(seq.has_ready());
        let order: Vec<u64> = std::iter::from_fn(|| seq.pop_ready().map(|c| c.id)).collect();
        assert_eq!(order, vec![5, 6, 7]);
        assert!(!seq.has_ready());
    }

    #[test]
    fn stable_replay_on_serial_matches_wallclock_exactly() {
        // The serial scheduler already completes in submission order, so
        // the reorder buffer must be a no-op there: both replay modes give
        // the identical trajectory.
        let run = |replay: ReplayMode| {
            let space = crate::space::svm_space();
            let mut t = Tuner::new(
                space,
                TunerConfig {
                    optimizer: OptimizerKind::Hallucination,
                    num_iterations: 8,
                    batch_size: 2,
                    backend: SurrogateBackend::Native,
                    seed: 11,
                    mode: ExecutionMode::Async,
                    replay,
                    ..Default::default()
                },
            );
            t.maximize(quad).unwrap()
        };
        let w = run(ReplayMode::Wallclock);
        let s = run(ReplayMode::Stable);
        assert_eq!(s.best_params, w.best_params);
        assert_eq!(s.best_objective, w.best_objective);
        assert_eq!(s.history, w.history);
        assert_eq!(s.best_series, w.best_series);
    }

    #[test]
    fn stable_fold_is_scheduler_invariant() {
        // The tentpole contract, cheapest form: under `--replay stable` a
        // threaded run with wall-clock-shuffled completions produces the
        // byte-identical trajectory to the serial reference — and to
        // itself, run to run.
        let run = |kind: SchedulerKind, workers: usize| {
            let space = crate::space::svm_space();
            let mut t = Tuner::new(
                space,
                TunerConfig {
                    optimizer: OptimizerKind::Hallucination,
                    num_iterations: 10,
                    batch_size: 1,
                    scheduler: kind,
                    workers,
                    async_window: 4,
                    backend: SurrogateBackend::Native,
                    seed: 5,
                    mode: ExecutionMode::Async,
                    replay: ReplayMode::Stable,
                    ..Default::default()
                },
            );
            // Per-config jitter shuffles threaded completion order without
            // touching the (deterministic) objective value.
            t.maximize(|cfg| {
                let c = cfg.get_f64("c")?;
                std::thread::sleep(Duration::from_millis((c as u64 % 5) * 4));
                quad(cfg)
            })
            .unwrap()
        };
        let serial = run(SchedulerKind::Serial, 1);
        let threaded_a = run(SchedulerKind::Threaded, 4);
        let threaded_b = run(SchedulerKind::Threaded, 4);
        assert_eq!(threaded_a.history, threaded_b.history, "run-to-run identity");
        assert_eq!(threaded_a.best_series, threaded_b.best_series);
        assert_eq!(threaded_a.history, serial.history, "cross-scheduler identity");
        assert_eq!(threaded_a.best_series, serial.best_series);
        assert_eq!(threaded_a.best_params, serial.best_params);
        assert_eq!(threaded_a.best_objective, serial.best_objective);
    }

    #[test]
    fn async_stall_degrades_to_partial_result() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let calls = AtomicUsize::new(0);
        let space = crate::space::svm_space();
        let mut t = Tuner::new(
            space,
            TunerConfig {
                optimizer: OptimizerKind::Random,
                num_iterations: 2,
                batch_size: 1,
                scheduler: SchedulerKind::Threaded,
                workers: 1,
                backend: SurrogateBackend::Native,
                mode: ExecutionMode::Async,
                stall_timeout_ms: 50,
                seed: 3,
                ..Default::default()
            },
        );
        let r = t
            .maximize(|_| {
                if calls.fetch_add(1, Ordering::SeqCst) > 0 {
                    // The second evaluation goes silent far past the stall
                    // patience; the run must abandon it, not hang or abort.
                    std::thread::sleep(Duration::from_millis(600));
                }
                Some(1.0)
            })
            .unwrap();
        assert!(r.stalled, "stall must be surfaced on the result");
        assert_eq!(r.evaluations, 1, "only the first evaluation landed");
        assert_eq!(r.lost, 1, "the abandoned task counts as lost");
        assert_eq!(r.best_objective, 1.0);
    }

    #[test]
    fn retry_backoff_schedule_is_deterministic_and_bounded() {
        let cfg = TunerConfig { retry_backoff_ms: 100.0, seed: 9, ..Default::default() };
        let mut prev = 0.0f64;
        for attempt in 1..=10usize {
            let d = retry_backoff_ms(&cfg, 3, attempt);
            let base = 100.0 * f64::powi(2.0, attempt.saturating_sub(1).min(6) as i32);
            let lo = base / 2.0;
            assert!(d >= lo && d < base, "attempt {attempt}: {d} not in [{lo}, {base})");
            assert_eq!(d, retry_backoff_ms(&cfg, 3, attempt), "same inputs, same delay");
            if attempt > 7 {
                // Exponent caps at 2^6: the envelope stops growing.
                assert!(d < 100.0 * 64.0, "attempt {attempt} exceeded the cap");
            }
            prev = d.max(prev);
        }
        assert!(prev >= 100.0, "later attempts back off further than the base");
        // Different (pid, attempt) draw from independent streams.
        assert_ne!(retry_backoff_ms(&cfg, 3, 1), retry_backoff_ms(&cfg, 4, 1));
        // Knob off: no delay, no RNG.
        let off = TunerConfig::default();
        assert_eq!(retry_backoff_ms(&off, 3, 1), 0.0);
    }
}
