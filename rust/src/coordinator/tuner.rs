//! The [`Tuner`]: MANGO's user-facing entry point.
//!
//! Two execution modes share the optimizer/scheduler/space plumbing:
//!
//! * **`mode = "sync"`** (default) — the paper's Fig. 1 workflow: propose a
//!   batch → schedule → absorb (possibly partial) results → repeat. One
//!   barrier per batch; Fig. 2/3 parity semantics.
//! * **`mode = "async"`** — an event-loop coordinator over the
//!   [`AsyncScheduler`](crate::scheduler::AsyncScheduler) submit/poll
//!   contract: a bounded in-flight window (`async_window`) is kept full;
//!   each completion immediately updates the history and triggers a
//!   replacement proposal conditioned on the configs still in flight
//!   ([`BatchOptimizer::propose_pending`]), so stragglers never idle the
//!   rest of the pool. Lost evaluations (worker crash / result timeout)
//!   are retried up to `max_retries` times; per-completion telemetry
//!   (queue wait, eval wall, retries) lands in
//!   [`TuningResult::completions`]. The total evaluation budget is
//!   `num_iterations * batch_size` — identical to sync mode.

use super::results::{CompletionOutcome, CompletionRecord, IterationRecord, TuningResult};
use crate::config::settings::RunConfig;
use crate::optimizer::{self, BatchOptimizer, GpOptions, History, OptimizerKind, SurrogateBackend};
use crate::scheduler::{
    self, AsyncScheduler, BatchResult, Completion, CompletionStatus, SchedulerKind,
};
use crate::space::{Config, SearchSpace};
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::time::Duration;

/// Per-config objective closure type (boxed form used by the CLI).
pub type ObjectiveFn = Box<dyn Fn(&Config) -> Option<f64> + Sync>;

/// How evaluations are coordinated: batch barriers or the event loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecutionMode {
    /// One barrier per batch (the paper's semantics).
    Sync,
    /// Submit/poll event loop with a bounded in-flight window.
    Async,
}

impl ExecutionMode {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "sync" => Some(Self::Sync),
            "async" => Some(Self::Async),
            _ => None,
        }
    }
}

/// How long one event-loop poll waits before re-checking the window.
const POLL_TIMEOUT: Duration = Duration::from_millis(25);
/// Abort an async run if nothing completes for this long (a worker died
/// without reporting — the in-repo schedulers themselves never go silent,
/// so this is a deadlock backstop, set far above any sane eval time).
const STALL_TIMEOUT: Duration = Duration::from_secs(3600);

/// Tuner configuration — the paper's user-controlled options (§2.4).
#[derive(Clone, Debug)]
pub struct TunerConfig {
    pub batch_size: usize,
    pub num_iterations: usize,
    pub initial_random: usize,
    pub optimizer: OptimizerKind,
    pub scheduler: SchedulerKind,
    pub workers: usize,
    /// 0 = the space's Monte-Carlo heuristic.
    pub mc_samples: usize,
    pub seed: u64,
    pub backend: SurrogateBackend,
    pub tune_lengthscale: bool,
    /// Stop after this many iterations without improvement (None = never).
    /// Async mode counts `early_stop * batch_size` concluded proposals.
    pub early_stop: Option<usize>,
    /// Largest history the surrogate sees (PJRT artifacts cap at 512).
    pub max_surrogate_obs: usize,
    /// Batch barriers (paper) or the submit/poll event loop.
    pub mode: ExecutionMode,
    /// Async mode: in-flight window size; 0 = max(batch_size, workers).
    pub async_window: usize,
    /// Async mode: resubmissions allowed per lost evaluation.
    pub max_retries: usize,
    /// Override the Celery simulator's fault/latency model.
    pub celery: Option<scheduler::celery::CelerySimConfig>,
}

impl Default for TunerConfig {
    fn default() -> Self {
        Self {
            batch_size: 1,
            num_iterations: 60,
            initial_random: 2,
            optimizer: OptimizerKind::Hallucination,
            scheduler: SchedulerKind::Serial,
            workers: 1,
            mc_samples: 0,
            seed: 0,
            backend: SurrogateBackend::Pjrt,
            tune_lengthscale: false,
            early_stop: None,
            max_surrogate_obs: 512,
            mode: ExecutionMode::Sync,
            async_window: 0,
            max_retries: 2,
            celery: None,
        }
    }
}

impl TunerConfig {
    /// Build from the JSON-level [`RunConfig`].
    pub fn from_run_config(rc: &RunConfig) -> Result<Self> {
        Ok(Self {
            batch_size: rc.batch_size,
            num_iterations: rc.num_iterations,
            initial_random: rc.initial_random,
            optimizer: OptimizerKind::from_str(&rc.optimizer)
                .ok_or_else(|| anyhow!("bad optimizer {}", rc.optimizer))?,
            scheduler: SchedulerKind::from_str(&rc.scheduler)
                .ok_or_else(|| anyhow!("bad scheduler {}", rc.scheduler))?,
            workers: rc.workers.max(1),
            mc_samples: rc.mc_samples,
            seed: rc.seed,
            backend: SurrogateBackend::from_str(&rc.backend)
                .ok_or_else(|| anyhow!("bad backend {}", rc.backend))?,
            tune_lengthscale: rc.tune_lengthscale,
            early_stop: match rc.early_stop {
                0 => None,
                n => Some(n),
            },
            max_surrogate_obs: rc.max_surrogate_obs,
            mode: ExecutionMode::from_str(&rc.mode)
                .ok_or_else(|| anyhow!("bad mode {}", rc.mode))?,
            async_window: rc.async_window,
            max_retries: rc.max_retries,
            celery: None,
        })
    }

    /// Effective in-flight window for async mode.
    fn window(&self) -> usize {
        let auto = self.batch_size.max(self.workers);
        let w = if self.async_window == 0 { auto } else { self.async_window };
        w.max(1)
    }
}

/// Objective sense.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Sense {
    Maximize,
    Minimize,
}

/// Coordinator-side record of one in-flight evaluation.
struct PendingTask {
    config: Config,
    retries: usize,
}

/// The paper's Fig. 1 coordinator.
pub struct Tuner {
    space: SearchSpace,
    config: TunerConfig,
    /// Optional per-iteration callback (progress bars, early inspection).
    callback: Option<Box<dyn FnMut(&IterationRecord)>>,
}

impl Tuner {
    pub fn new(space: SearchSpace, config: TunerConfig) -> Self {
        Self { space, config, callback: None }
    }

    /// Register a per-iteration callback.
    pub fn with_callback(mut self, cb: impl FnMut(&IterationRecord) + 'static) -> Self {
        self.callback = Some(Box::new(cb));
        self
    }

    pub fn config(&self) -> &TunerConfig {
        &self.config
    }

    /// Maximize a per-config objective using the configured scheduler
    /// (dispatches on [`TunerConfig::mode`]).
    pub fn maximize<F>(&mut self, objective: F) -> Result<TuningResult>
    where
        F: Fn(&Config) -> Option<f64> + Sync,
    {
        self.run_objective(Sense::Maximize, &objective)
    }

    /// Minimize a per-config objective.
    pub fn minimize<F>(&mut self, objective: F) -> Result<TuningResult>
    where
        F: Fn(&Config) -> Option<f64> + Sync,
    {
        self.run_objective(Sense::Minimize, &objective)
    }

    fn run_objective(
        &mut self,
        sense: Sense,
        objective: &(dyn Fn(&Config) -> Option<f64> + Sync),
    ) -> Result<TuningResult> {
        match self.config.mode {
            ExecutionMode::Sync => {
                let mut sched = scheduler::build_custom(
                    self.config.scheduler,
                    self.config.workers,
                    self.config.seed,
                    self.config.celery.clone(),
                );
                self.run(sense, &mut |batch| sched.evaluate(objective, batch))
            }
            ExecutionMode::Async => self.run_async(sense, objective),
        }
    }

    /// Maximize with a user-supplied *batch* objective — the paper's
    /// decoupling: bring any scheduling framework by consuming the whole
    /// batch yourself and returning (possibly partial) `(evals, params)`.
    /// Always batch-synchronous regardless of [`TunerConfig::mode`].
    pub fn maximize_batch<F>(&mut self, mut batch_objective: F) -> Result<TuningResult>
    where
        F: FnMut(&[Config]) -> BatchResult,
    {
        self.run(Sense::Maximize, &mut batch_objective)
    }

    /// Minimize with a user-supplied batch objective.
    pub fn minimize_batch<F>(&mut self, mut batch_objective: F) -> Result<TuningResult>
    where
        F: FnMut(&[Config]) -> BatchResult,
    {
        self.run(Sense::Minimize, &mut batch_objective)
    }

    fn gp_options(&self) -> GpOptions {
        GpOptions {
            backend: self.config.backend,
            mc_samples: self.config.mc_samples,
            initial_random: self.config.initial_random,
            tune_lengthscale: self.config.tune_lengthscale,
            ..Default::default()
        }
    }

    /// The batch-synchronous coordinator (one barrier per iteration).
    fn run(
        &mut self,
        sense: Sense,
        evaluate: &mut dyn FnMut(&[Config]) -> BatchResult,
    ) -> Result<TuningResult> {
        let cfg = &self.config;
        let opts = self.gp_options();
        let mut optimizer: Box<dyn BatchOptimizer> =
            optimizer::build(cfg.optimizer, &self.space, &opts)?;
        let mut rng = Pcg64::new(cfg.seed.wrapping_mul(0x9E37_79B9).wrapping_add(1));

        let total = Stopwatch::start();
        let mut history = History::new(); // maximization convention
        let mut user_history: Vec<(Config, f64)> = Vec::new();
        let mut best_series = Vec::with_capacity(cfg.num_iterations);
        let mut iterations = Vec::with_capacity(cfg.num_iterations);
        let mut since_improvement = 0usize;
        let mut best_so_far = f64::NEG_INFINITY; // internal sense
        let mut returned_total = 0usize; // running count: O(1) per iteration

        for iteration in 0..cfg.num_iterations {
            let it_timer = Stopwatch::start();
            // Surrogate history is capped to the smaller of the configured
            // window and the backend's actual capacity (the PJRT artifact
            // manifest, via Surrogate::max_obs): keep the most recent
            // window (the GP forgets the oldest points). Note the GP's
            // Cholesky cache stays incremental while this window grows
            // append-only; once it starts sliding, each round refits.
            let cap = cfg.max_surrogate_obs.min(optimizer.surrogate_capacity());
            let opt_view = history.recent(cap);
            let batch = optimizer.propose(&opt_view, cfg.batch_size, &mut rng)?;
            anyhow::ensure!(!batch.is_empty(), "optimizer proposed an empty batch");

            let result = evaluate(&batch);
            anyhow::ensure!(
                result.evals.len() == result.params.len(),
                "objective returned misaligned evals/params"
            );
            for (cfg_done, v) in result.params.into_iter().zip(result.evals) {
                anyhow::ensure!(v.is_finite(), "objective returned a non-finite value");
                let internal = match sense {
                    Sense::Maximize => v,
                    Sense::Minimize => -v,
                };
                best_so_far = best_so_far.max(internal);
                history.push(cfg_done.clone(), internal);
                user_history.push((cfg_done, v));
            }

            let user_best = match sense {
                Sense::Maximize => best_so_far,
                Sense::Minimize => -best_so_far,
            };
            best_series.push(user_best);
            let record = IterationRecord {
                iteration,
                proposed: batch.len(),
                returned: history.len() - returned_total,
                best_so_far: user_best,
                wall_ms: it_timer.elapsed_ms(),
            };
            returned_total = history.len();
            if let Some(cb) = &mut self.callback {
                cb(&record);
            }
            crate::log_debug!(
                "iter {iteration}: proposed {} returned {} best {:.6}",
                record.proposed,
                record.returned,
                user_best
            );
            // Early stopping on no improvement.
            let improved = best_series.len() < 2
                || match sense {
                    Sense::Maximize => {
                        best_series[best_series.len() - 1] > best_series[best_series.len() - 2]
                    }
                    Sense::Minimize => {
                        best_series[best_series.len() - 1] < best_series[best_series.len() - 2]
                    }
                };
            since_improvement = if improved { 0 } else { since_improvement + 1 };
            iterations.push(record);
            if let Some(stop) = cfg.early_stop {
                if since_improvement >= stop {
                    crate::log_info!("early stop after {iteration} iterations");
                    break;
                }
            }
        }

        let (best_cfg, best_internal) = history
            .best()
            .ok_or_else(|| anyhow!("no evaluation ever succeeded"))?;
        let best_objective = match sense {
            Sense::Maximize => best_internal,
            Sense::Minimize => -best_internal,
        };
        Ok(TuningResult {
            best_params: best_cfg.clone(),
            best_objective,
            evaluations: user_history.len(),
            history: user_history,
            best_series,
            iterations,
            wall_ms: total.elapsed_ms(),
            completions: Vec::new(),
            scheduler_stats: None,
            retried: 0,
            lost: 0,
        })
    }

    /// The asynchronous coordinator: spawn the scheduler's workers on a
    /// scope that lives exactly as long as the run, then drive the event
    /// loop against the submit/poll contract.
    fn run_async(
        &mut self,
        sense: Sense,
        objective: &(dyn Fn(&Config) -> Option<f64> + Sync),
    ) -> Result<TuningResult> {
        let cfg = self.config.clone();
        let opts = self.gp_options();
        let mut optimizer = optimizer::build(cfg.optimizer, &self.space, &opts)?;
        let space = self.space.clone();
        std::thread::scope(|scope| {
            let mut sched = scheduler::build_async(
                cfg.scheduler,
                cfg.workers,
                cfg.seed,
                cfg.celery.clone(),
                scope,
                objective,
            );
            self.event_loop(sense, &cfg, &space, optimizer.as_mut(), sched.as_mut())
        })
    }

    /// One replacement proposal, conditioned on the in-flight set. Each
    /// proposal draws from its own seed-derived RNG stream (keyed by its
    /// index), so the stream is independent of how completions happened to
    /// be grouped into polls. Returns `Ok(None)` when every candidate the
    /// optimizer and the space can produce is already in flight (tiny
    /// discrete spaces) — the caller then waits for a completion to free a
    /// point instead of double-submitting one.
    fn propose_one(
        cfg: &TunerConfig,
        space: &SearchSpace,
        optimizer: &mut dyn BatchOptimizer,
        history: &History,
        pending: &BTreeMap<u64, PendingTask>,
        proposal_idx: u64,
    ) -> Result<Option<Config>> {
        let pending_cfgs: Vec<Config> = pending.values().map(|p| p.config.clone()).collect();
        // Leave surrogate room for the hallucinated pending observations,
        // inside the backend's actual capacity (Surrogate::max_obs).
        let cap = cfg
            .max_surrogate_obs
            .min(optimizer.surrogate_capacity())
            .saturating_sub(pending_cfgs.len())
            .max(1);
        let opt_view = history.recent(cap);
        let mut rng = Pcg64::new(
            cfg.seed
                .wrapping_mul(0x9E37_79B9)
                .wrapping_add(0xA5F0_0000)
                .wrapping_add(proposal_idx),
        );
        let mut proposal = optimizer
            .propose_pending(&opt_view, &pending_cfgs, 1, &mut rng)?
            .into_iter()
            .next()
            .unwrap_or_else(|| space.sample(&mut rng));
        // Hard guarantee: never submit a config already in flight.
        let mut tries = 0;
        while pending_cfgs.contains(&proposal) {
            if tries >= 32 {
                return Ok(None); // space saturated by the in-flight window
            }
            proposal = space.sample(&mut rng);
            tries += 1;
        }
        Ok(Some(proposal))
    }

    /// The event loop: keep `window` evaluations in flight; fold each
    /// completion into the history the moment it arrives; retry lost work.
    fn event_loop(
        &mut self,
        sense: Sense,
        cfg: &TunerConfig,
        space: &SearchSpace,
        optimizer: &mut dyn BatchOptimizer,
        sched: &mut dyn AsyncScheduler,
    ) -> Result<TuningResult> {
        let budget = cfg.num_iterations * cfg.batch_size;
        let window = cfg.window().min(budget.max(1));
        let early_stop_events = cfg.early_stop.map(|n| (n * cfg.batch_size).max(1));

        let total = Stopwatch::start();
        let mut history = History::new(); // maximization convention
        let mut user_history: Vec<(Config, f64)> = Vec::new();
        let mut best_series = Vec::with_capacity(budget);
        let mut iterations = Vec::with_capacity(budget);
        let mut completion_log: Vec<CompletionRecord> = Vec::new();
        let mut pending: BTreeMap<u64, PendingTask> = BTreeMap::new();
        let mut proposals_made = 0usize;
        let mut proposed_since_record = 0usize;
        let mut best_so_far = f64::NEG_INFINITY; // internal sense
        let mut since_improvement = 0usize;
        let mut stopped_early = false;
        let mut retried = 0u64;
        let mut lost = 0u64;
        let mut last_progress = std::time::Instant::now();

        loop {
            // ---- refill: keep the in-flight window full ----
            while !stopped_early && pending.len() < window && proposals_made < budget {
                let Some(proposal) = Self::propose_one(
                    cfg,
                    space,
                    optimizer,
                    &history,
                    &pending,
                    proposals_made as u64,
                )?
                else {
                    // Every distinct config is in flight: wait for a
                    // completion to free a point before proposing again.
                    break;
                };
                let ids = sched.submit(std::slice::from_ref(&proposal));
                anyhow::ensure!(ids.len() == 1, "scheduler must assign one id per config");
                pending.insert(ids[0], PendingTask { config: proposal, retries: 0 });
                proposals_made += 1;
                proposed_since_record += 1;
            }

            if pending.is_empty() {
                break; // budget exhausted (or early-stopped), nothing in flight
            }

            // ---- wait for completions ----
            let completions: Vec<Completion> = sched.poll(POLL_TIMEOUT);
            if completions.is_empty() {
                if sched.in_flight() == 0 {
                    // Scheduler lost track of outstanding work.
                    lost += pending.len() as u64;
                    pending.clear();
                    break;
                }
                anyhow::ensure!(
                    last_progress.elapsed() < STALL_TIMEOUT,
                    "async scheduler stalled: {} tasks in flight, none completed in {:?}",
                    sched.in_flight(),
                    STALL_TIMEOUT
                );
                continue;
            }
            last_progress = std::time::Instant::now();

            // ---- fold completions in (poll returns them sorted by id) ----
            for comp in completions {
                let Some(mut task) = pending.remove(&comp.id) else { continue };
                let outcome = match comp.status {
                    CompletionStatus::Done(v) => {
                        anyhow::ensure!(
                            v.is_finite(),
                            "objective returned a non-finite value"
                        );
                        let internal = match sense {
                            Sense::Maximize => v,
                            Sense::Minimize => -v,
                        };
                        best_so_far = best_so_far.max(internal);
                        history.push(task.config.clone(), internal);
                        user_history.push((task.config.clone(), v));
                        CompletionOutcome::Done
                    }
                    CompletionStatus::Failed => CompletionOutcome::Failed,
                    CompletionStatus::Lost(reason) => {
                        // After early stop, a retried result could no longer
                        // change anything — let the proposal die instead.
                        if !stopped_early && task.retries < cfg.max_retries {
                            task.retries += 1;
                            retried += 1;
                            crate::log_debug!(
                                "task {} lost ({reason:?}); retry {}/{}",
                                comp.id,
                                task.retries,
                                cfg.max_retries
                            );
                            completion_log.push(CompletionRecord {
                                task_id: comp.id,
                                queue_wait_ms: comp.queue_wait_ms,
                                eval_ms: comp.eval_ms,
                                retries: task.retries,
                                outcome: CompletionOutcome::Resubmitted,
                            });
                            let ids = sched.submit(std::slice::from_ref(&task.config));
                            anyhow::ensure!(ids.len() == 1, "resubmit must assign one id");
                            pending.insert(ids[0], task);
                            continue; // not concluded: no iteration record
                        }
                        lost += 1;
                        CompletionOutcome::Lost
                    }
                };

                // ---- one concluded proposal = one iteration record ----
                completion_log.push(CompletionRecord {
                    task_id: comp.id,
                    queue_wait_ms: comp.queue_wait_ms,
                    eval_ms: comp.eval_ms,
                    retries: task.retries,
                    outcome,
                });
                let user_best = match sense {
                    Sense::Maximize => best_so_far,
                    Sense::Minimize => -best_so_far,
                };
                best_series.push(user_best);
                let improved = best_series.len() < 2
                    || match sense {
                        Sense::Maximize => {
                            best_series[best_series.len() - 1]
                                > best_series[best_series.len() - 2]
                        }
                        Sense::Minimize => {
                            best_series[best_series.len() - 1]
                                < best_series[best_series.len() - 2]
                        }
                    };
                since_improvement = if improved { 0 } else { since_improvement + 1 };
                let record = IterationRecord {
                    iteration: iterations.len(),
                    proposed: proposed_since_record,
                    returned: usize::from(outcome == CompletionOutcome::Done),
                    best_so_far: user_best,
                    wall_ms: comp.queue_wait_ms + comp.eval_ms,
                };
                proposed_since_record = 0;
                if let Some(cb) = &mut self.callback {
                    cb(&record);
                }
                iterations.push(record);

                if let Some(stop) = early_stop_events {
                    if since_improvement >= stop && !stopped_early {
                        stopped_early = true;
                        let cancelled = sched.cancel_pending();
                        for id in &cancelled {
                            pending.remove(id);
                        }
                        crate::log_info!(
                            "async early stop after {} completions ({} queued cancelled)",
                            iterations.len(),
                            cancelled.len()
                        );
                    }
                }
            }
        }

        let (best_cfg, best_internal) = history
            .best()
            .ok_or_else(|| anyhow!("no evaluation ever succeeded"))?;
        let best_objective = match sense {
            Sense::Maximize => best_internal,
            Sense::Minimize => -best_internal,
        };
        Ok(TuningResult {
            best_params: best_cfg.clone(),
            best_objective,
            evaluations: user_history.len(),
            history: user_history,
            best_series,
            iterations,
            wall_ms: total.elapsed_ms(),
            completions: completion_log,
            scheduler_stats: Some(sched.stats()),
            retried,
            lost,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamValue;

    fn tuner(optimizer: OptimizerKind, iters: usize, batch: usize) -> Tuner {
        let space = crate::space::svm_space();
        Tuner::new(
            space,
            TunerConfig {
                optimizer,
                num_iterations: iters,
                batch_size: batch,
                backend: SurrogateBackend::Native,
                seed: 11,
                ..Default::default()
            },
        )
    }

    fn async_tuner(optimizer: OptimizerKind, iters: usize, batch: usize) -> Tuner {
        let space = crate::space::svm_space();
        Tuner::new(
            space,
            TunerConfig {
                optimizer,
                num_iterations: iters,
                batch_size: batch,
                backend: SurrogateBackend::Native,
                seed: 11,
                mode: ExecutionMode::Async,
                ..Default::default()
            },
        )
    }

    fn quad(cfg: &Config) -> Option<f64> {
        let c = cfg.get_f64("c")?;
        Some(-(c - 60.0) * (c - 60.0))
    }

    #[test]
    fn maximize_converges_and_reports() {
        let mut t = tuner(OptimizerKind::Hallucination, 20, 1);
        let r = t.maximize(quad).unwrap();
        assert_eq!(r.best_series.len(), 20);
        assert_eq!(r.evaluations, 20);
        assert!(r.best_objective > -100.0, "best {}", r.best_objective);
        // best_series is monotone non-decreasing for maximization
        for w in r.best_series.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert_eq!(r.best_objective, *r.best_series.last().unwrap());
    }

    #[test]
    fn minimize_flips_sense() {
        let mut t = tuner(OptimizerKind::Hallucination, 15, 1);
        let r = t.minimize(|cfg| {
            let c = cfg.get_f64("c")?;
            Some((c - 60.0) * (c - 60.0))
        }).unwrap();
        assert!(r.best_objective < 100.0);
        for w in r.best_series.windows(2) {
            assert!(w[1] <= w[0], "minimize series must not increase");
        }
    }

    #[test]
    fn batch_mode_with_partial_results() {
        let mut t = tuner(OptimizerKind::Random, 10, 4);
        let mut calls = 0usize;
        let r = t
            .maximize_batch(|batch| {
                calls += 1;
                let mut out = BatchResult::default();
                // Lose every other evaluation (straggler simulation).
                for (i, cfg) in batch.iter().enumerate() {
                    if i % 2 == 0 {
                        out.push(cfg.clone(), quad(cfg).unwrap());
                    }
                }
                out
            })
            .unwrap();
        assert_eq!(calls, 10);
        assert_eq!(r.evaluations, 20, "half of 40 proposals returned");
    }

    #[test]
    fn iteration_records_count_partial_returns() {
        // The per-iteration `returned` field must match each iteration's
        // arrivals (regression test for the O(n²) recomputation).
        let mut t = tuner(OptimizerKind::Random, 8, 3);
        let r = t
            .maximize_batch(|batch| {
                let mut out = BatchResult::default();
                for (i, cfg) in batch.iter().enumerate() {
                    if i != 0 {
                        out.push(cfg.clone(), 1.0);
                    }
                }
                out
            })
            .unwrap();
        assert_eq!(r.iterations.len(), 8);
        for rec in &r.iterations {
            assert_eq!(rec.proposed, 3);
            assert_eq!(rec.returned, 2, "iter {}: lost exactly one", rec.iteration);
        }
        assert_eq!(r.evaluations, 16);
    }

    #[test]
    fn early_stop_halts() {
        let space = crate::space::svm_space();
        let mut t = Tuner::new(
            space,
            TunerConfig {
                optimizer: OptimizerKind::Random,
                num_iterations: 50,
                early_stop: Some(3),
                backend: SurrogateBackend::Native,
                seed: 1,
                ..Default::default()
            },
        );
        // Constant objective: never improves after the first iteration.
        let r = t.maximize(|_| Some(1.0)).unwrap();
        assert!(r.best_series.len() <= 6, "ran {} iters", r.best_series.len());
    }

    #[test]
    fn callback_sees_every_iteration() {
        use std::cell::RefCell;
        use std::rc::Rc;
        let seen = Rc::new(RefCell::new(0usize));
        let seen2 = seen.clone();
        let space = crate::space::svm_space();
        let mut t = Tuner::new(
            space,
            TunerConfig {
                optimizer: OptimizerKind::Random,
                num_iterations: 7,
                backend: SurrogateBackend::Native,
                ..Default::default()
            },
        )
        .with_callback(move |rec| {
            assert!(rec.proposed >= 1);
            *seen2.borrow_mut() += 1;
        });
        t.maximize(|_| Some(0.0)).unwrap();
        assert_eq!(*seen.borrow(), 7);
    }

    #[test]
    fn all_failures_is_an_error() {
        let mut t = tuner(OptimizerKind::Random, 3, 2);
        let err = t.maximize(|_| None).unwrap_err();
        assert!(err.to_string().contains("no evaluation"));
    }

    #[test]
    fn non_finite_objective_rejected() {
        let mut t = tuner(OptimizerKind::Random, 2, 1);
        assert!(t.maximize(|_| Some(f64::NAN)).is_err());
    }

    #[test]
    fn tpe_and_clustering_run_end_to_end() {
        for kind in [OptimizerKind::Tpe, OptimizerKind::Clustering] {
            let mut t = tuner(kind, 10, 3);
            let r = t.maximize(quad).unwrap();
            assert_eq!(r.evaluations, 30);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut t = tuner(OptimizerKind::Hallucination, 8, 2);
            t.maximize(quad).unwrap().best_objective
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn from_run_config_maps() {
        let rc = RunConfig {
            optimizer: "clustering".into(),
            scheduler: "threaded".into(),
            backend: "native".into(),
            batch_size: 5,
            workers: 8,
            ..Default::default()
        };
        let tc = TunerConfig::from_run_config(&rc).unwrap();
        assert_eq!(tc.optimizer, OptimizerKind::Clustering);
        assert_eq!(tc.scheduler, SchedulerKind::Threaded);
        assert_eq!(tc.workers, 8);
        let _ = Config::new(vec![("x".into(), ParamValue::F64(0.0))]); // silence import
    }

    #[test]
    fn from_run_config_plumbs_early_stop_and_surrogate_cap() {
        let rc = RunConfig {
            early_stop: 7,
            max_surrogate_obs: 128,
            mode: "async".into(),
            async_window: 12,
            max_retries: 5,
            ..Default::default()
        };
        let tc = TunerConfig::from_run_config(&rc).unwrap();
        assert_eq!(tc.early_stop, Some(7));
        assert_eq!(tc.max_surrogate_obs, 128);
        assert_eq!(tc.mode, ExecutionMode::Async);
        assert_eq!(tc.async_window, 12);
        assert_eq!(tc.max_retries, 5);
        // early_stop = 0 means disabled
        let tc0 = TunerConfig::from_run_config(&RunConfig::default()).unwrap();
        assert_eq!(tc0.early_stop, None);
        assert_eq!(tc0.mode, ExecutionMode::Sync);
    }

    // ---------------- async event-loop tests ----------------

    #[test]
    fn async_serial_runs_full_budget_with_telemetry() {
        let mut t = async_tuner(OptimizerKind::Hallucination, 10, 2);
        let r = t.maximize(quad).unwrap();
        assert_eq!(r.evaluations, 20, "reliable serial async runs the full budget");
        assert_eq!(r.best_series.len(), 20, "one series point per completion");
        for w in r.best_series.windows(2) {
            assert!(w[1] >= w[0], "maximize series must not decrease");
        }
        assert_eq!(r.completions.len(), 20);
        for c in &r.completions {
            assert_eq!(c.outcome, crate::coordinator::CompletionOutcome::Done);
            assert!(c.queue_wait_ms >= 0.0 && c.eval_ms >= 0.0);
        }
        let stats = r.scheduler_stats.as_ref().unwrap();
        assert_eq!(stats.submitted, 20);
        assert_eq!(stats.completed, 20);
        assert!(stats.max_in_flight >= 2, "window must actually fill");
    }

    #[test]
    fn async_event_loop_deterministic_given_seed() {
        let run = || {
            let mut t = async_tuner(OptimizerKind::Hallucination, 8, 2);
            let r = t.maximize(quad).unwrap();
            (r.best_objective, r.best_series.clone())
        };
        let (a_best, a_series) = run();
        let (b_best, b_series) = run();
        assert_eq!(a_best, b_best, "same seed, same optimum");
        assert_eq!(a_series, b_series, "same seed, same trajectory");
    }

    #[test]
    fn async_minimize_flips_sense() {
        let mut t = async_tuner(OptimizerKind::Hallucination, 8, 2);
        let r = t
            .minimize(|cfg| {
                let c = cfg.get_f64("c")?;
                Some((c - 60.0) * (c - 60.0))
            })
            .unwrap();
        assert!(r.best_objective < 400.0);
        for w in r.best_series.windows(2) {
            assert!(w[1] <= w[0], "minimize series must not increase");
        }
    }

    #[test]
    fn async_all_failures_is_an_error_and_terminates() {
        let mut t = async_tuner(OptimizerKind::Random, 3, 2);
        let err = t.maximize(|_| None).unwrap_err();
        assert!(err.to_string().contains("no evaluation"));
    }

    #[test]
    fn async_early_stop_cancels_queue() {
        let space = crate::space::svm_space();
        let mut t = Tuner::new(
            space,
            TunerConfig {
                optimizer: OptimizerKind::Random,
                num_iterations: 50,
                batch_size: 1,
                early_stop: Some(3),
                backend: SurrogateBackend::Native,
                mode: ExecutionMode::Async,
                async_window: 4,
                seed: 1,
                ..Default::default()
            },
        );
        let r = t.maximize(|_| Some(1.0)).unwrap();
        // 1 improvement + 3 stagnant completions + <= window stragglers.
        assert!(
            r.best_series.len() <= 4 + 4,
            "ran {} completions",
            r.best_series.len()
        );
    }

    #[test]
    fn async_threaded_overlaps_evaluations() {
        let space = crate::space::svm_space();
        let mut t = Tuner::new(
            space,
            TunerConfig {
                optimizer: OptimizerKind::Random,
                num_iterations: 8,
                batch_size: 1,
                scheduler: SchedulerKind::Threaded,
                workers: 8,
                async_window: 8,
                backend: SurrogateBackend::Native,
                mode: ExecutionMode::Async,
                seed: 2,
                ..Default::default()
            },
        );
        let start = std::time::Instant::now();
        let r = t
            .maximize(|cfg| {
                std::thread::sleep(Duration::from_millis(30));
                quad(cfg)
            })
            .unwrap();
        let ms = start.elapsed().as_millis();
        assert_eq!(r.evaluations, 8);
        assert!(ms < 240, "8x30ms on 8 workers took {ms}ms — window not full");
    }
}
