//! The [`Tuner`]: MANGO's user-facing entry point.

use super::results::{IterationRecord, TuningResult};
use crate::config::settings::RunConfig;
use crate::optimizer::{self, BatchOptimizer, GpOptions, History, OptimizerKind, SurrogateBackend};
use crate::scheduler::{self, BatchResult, SchedulerKind};
use crate::space::{Config, SearchSpace};
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;
use anyhow::{anyhow, Result};

/// Per-config objective closure type (boxed form used by the CLI).
pub type ObjectiveFn = Box<dyn Fn(&Config) -> Option<f64> + Sync>;

/// Tuner configuration — the paper's user-controlled options (§2.4).
#[derive(Clone, Debug)]
pub struct TunerConfig {
    pub batch_size: usize,
    pub num_iterations: usize,
    pub initial_random: usize,
    pub optimizer: OptimizerKind,
    pub scheduler: SchedulerKind,
    pub workers: usize,
    /// 0 = the space's Monte-Carlo heuristic.
    pub mc_samples: usize,
    pub seed: u64,
    pub backend: SurrogateBackend,
    pub tune_lengthscale: bool,
    /// Stop after this many iterations without improvement (None = never).
    pub early_stop: Option<usize>,
    /// Largest history the surrogate sees (PJRT artifacts cap at 512).
    pub max_surrogate_obs: usize,
}

impl Default for TunerConfig {
    fn default() -> Self {
        Self {
            batch_size: 1,
            num_iterations: 60,
            initial_random: 2,
            optimizer: OptimizerKind::Hallucination,
            scheduler: SchedulerKind::Serial,
            workers: 1,
            mc_samples: 0,
            seed: 0,
            backend: SurrogateBackend::Pjrt,
            tune_lengthscale: false,
            early_stop: None,
            max_surrogate_obs: 512,
        }
    }
}

impl TunerConfig {
    /// Build from the JSON-level [`RunConfig`].
    pub fn from_run_config(rc: &RunConfig) -> Result<Self> {
        Ok(Self {
            batch_size: rc.batch_size,
            num_iterations: rc.num_iterations,
            initial_random: rc.initial_random,
            optimizer: OptimizerKind::from_str(&rc.optimizer)
                .ok_or_else(|| anyhow!("bad optimizer {}", rc.optimizer))?,
            scheduler: SchedulerKind::from_str(&rc.scheduler)
                .ok_or_else(|| anyhow!("bad scheduler {}", rc.scheduler))?,
            workers: rc.workers.max(1),
            mc_samples: rc.mc_samples,
            seed: rc.seed,
            backend: SurrogateBackend::from_str(&rc.backend)
                .ok_or_else(|| anyhow!("bad backend {}", rc.backend))?,
            tune_lengthscale: rc.tune_lengthscale,
            early_stop: None,
            max_surrogate_obs: 512,
        })
    }
}

/// Objective sense.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Sense {
    Maximize,
    Minimize,
}

/// The paper's Fig. 1 coordinator.
pub struct Tuner {
    space: SearchSpace,
    config: TunerConfig,
    /// Optional per-iteration callback (progress bars, early inspection).
    callback: Option<Box<dyn FnMut(&IterationRecord)>>,
}

impl Tuner {
    pub fn new(space: SearchSpace, config: TunerConfig) -> Self {
        Self { space, config, callback: None }
    }

    /// Register a per-iteration callback.
    pub fn with_callback(mut self, cb: impl FnMut(&IterationRecord) + 'static) -> Self {
        self.callback = Some(Box::new(cb));
        self
    }

    pub fn config(&self) -> &TunerConfig {
        &self.config
    }

    /// Maximize a per-config objective using the configured scheduler.
    pub fn maximize<F>(&mut self, objective: F) -> Result<TuningResult>
    where
        F: Fn(&Config) -> Option<f64> + Sync,
    {
        let mut sched =
            scheduler::build(self.config.scheduler, self.config.workers, self.config.seed);
        self.run(Sense::Maximize, &mut |batch| sched.evaluate(&objective, batch))
    }

    /// Minimize a per-config objective.
    pub fn minimize<F>(&mut self, objective: F) -> Result<TuningResult>
    where
        F: Fn(&Config) -> Option<f64> + Sync,
    {
        let mut sched =
            scheduler::build(self.config.scheduler, self.config.workers, self.config.seed);
        self.run(Sense::Minimize, &mut |batch| sched.evaluate(&objective, batch))
    }

    /// Maximize with a user-supplied *batch* objective — the paper's
    /// decoupling: bring any scheduling framework by consuming the whole
    /// batch yourself and returning (possibly partial) `(evals, params)`.
    pub fn maximize_batch<F>(&mut self, mut batch_objective: F) -> Result<TuningResult>
    where
        F: FnMut(&[Config]) -> BatchResult,
    {
        self.run(Sense::Maximize, &mut batch_objective)
    }

    /// Minimize with a user-supplied batch objective.
    pub fn minimize_batch<F>(&mut self, mut batch_objective: F) -> Result<TuningResult>
    where
        F: FnMut(&[Config]) -> BatchResult,
    {
        self.run(Sense::Minimize, &mut batch_objective)
    }

    fn run(
        &mut self,
        sense: Sense,
        evaluate: &mut dyn FnMut(&[Config]) -> BatchResult,
    ) -> Result<TuningResult> {
        let cfg = &self.config;
        let opts = GpOptions {
            backend: cfg.backend,
            mc_samples: cfg.mc_samples,
            initial_random: cfg.initial_random,
            tune_lengthscale: cfg.tune_lengthscale,
            ..Default::default()
        };
        let mut optimizer: Box<dyn BatchOptimizer> =
            optimizer::build(cfg.optimizer, &self.space, &opts)?;
        let mut rng = Pcg64::new(cfg.seed.wrapping_mul(0x9E37_79B9).wrapping_add(1));

        let total = Stopwatch::start();
        let mut history = History::new(); // maximization convention
        let mut user_history: Vec<(Config, f64)> = Vec::new();
        let mut best_series = Vec::with_capacity(cfg.num_iterations);
        let mut iterations = Vec::with_capacity(cfg.num_iterations);
        let mut since_improvement = 0usize;
        let mut best_so_far = f64::NEG_INFINITY; // internal sense

        for iteration in 0..cfg.num_iterations {
            let it_timer = Stopwatch::start();
            // Surrogate history is capped to the artifact capacity: keep the
            // most recent window (the GP forgets the oldest points).
            let mut opt_view = history.clone();
            opt_view.truncate_to_recent(cfg.max_surrogate_obs);
            let batch = optimizer.propose(&opt_view, cfg.batch_size, &mut rng)?;
            anyhow::ensure!(!batch.is_empty(), "optimizer proposed an empty batch");

            let result = evaluate(&batch);
            anyhow::ensure!(
                result.evals.len() == result.params.len(),
                "objective returned misaligned evals/params"
            );
            for (cfg_done, v) in result.params.into_iter().zip(result.evals) {
                anyhow::ensure!(v.is_finite(), "objective returned a non-finite value");
                let internal = match sense {
                    Sense::Maximize => v,
                    Sense::Minimize => -v,
                };
                best_so_far = best_so_far.max(internal);
                history.push(cfg_done.clone(), internal);
                user_history.push((cfg_done, v));
            }

            let user_best = match sense {
                Sense::Maximize => best_so_far,
                Sense::Minimize => -best_so_far,
            };
            best_series.push(user_best);
            let record = IterationRecord {
                iteration,
                proposed: batch.len(),
                returned: history.len() - iterations.iter().map(|r: &IterationRecord| r.returned).sum::<usize>(),
                best_so_far: user_best,
                wall_ms: it_timer.elapsed_ms(),
            };
            if let Some(cb) = &mut self.callback {
                cb(&record);
            }
            crate::log_debug!(
                "iter {iteration}: proposed {} returned {} best {:.6}",
                record.proposed,
                record.returned,
                user_best
            );
            // Early stopping on no improvement.
            let improved = best_series.len() < 2
                || match sense {
                    Sense::Maximize => {
                        best_series[best_series.len() - 1] > best_series[best_series.len() - 2]
                    }
                    Sense::Minimize => {
                        best_series[best_series.len() - 1] < best_series[best_series.len() - 2]
                    }
                };
            since_improvement = if improved { 0 } else { since_improvement + 1 };
            iterations.push(record);
            if let Some(stop) = cfg.early_stop {
                if since_improvement >= stop {
                    crate::log_info!("early stop after {iteration} iterations");
                    break;
                }
            }
        }

        let (best_cfg, best_internal) = history
            .best()
            .ok_or_else(|| anyhow!("no evaluation ever succeeded"))?;
        let best_objective = match sense {
            Sense::Maximize => best_internal,
            Sense::Minimize => -best_internal,
        };
        Ok(TuningResult {
            best_params: best_cfg.clone(),
            best_objective,
            evaluations: user_history.len(),
            history: user_history,
            best_series,
            iterations,
            wall_ms: total.elapsed_ms(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamValue;

    fn tuner(optimizer: OptimizerKind, iters: usize, batch: usize) -> Tuner {
        let space = crate::space::svm_space();
        Tuner::new(
            space,
            TunerConfig {
                optimizer,
                num_iterations: iters,
                batch_size: batch,
                backend: SurrogateBackend::Native,
                seed: 11,
                ..Default::default()
            },
        )
    }

    fn quad(cfg: &Config) -> Option<f64> {
        let c = cfg.get_f64("c")?;
        Some(-(c - 60.0) * (c - 60.0))
    }

    #[test]
    fn maximize_converges_and_reports() {
        let mut t = tuner(OptimizerKind::Hallucination, 20, 1);
        let r = t.maximize(quad).unwrap();
        assert_eq!(r.best_series.len(), 20);
        assert_eq!(r.evaluations, 20);
        assert!(r.best_objective > -100.0, "best {}", r.best_objective);
        // best_series is monotone non-decreasing for maximization
        for w in r.best_series.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert_eq!(r.best_objective, *r.best_series.last().unwrap());
    }

    #[test]
    fn minimize_flips_sense() {
        let mut t = tuner(OptimizerKind::Hallucination, 15, 1);
        let r = t.minimize(|cfg| {
            let c = cfg.get_f64("c")?;
            Some((c - 60.0) * (c - 60.0))
        }).unwrap();
        assert!(r.best_objective < 100.0);
        for w in r.best_series.windows(2) {
            assert!(w[1] <= w[0], "minimize series must not increase");
        }
    }

    #[test]
    fn batch_mode_with_partial_results() {
        let mut t = tuner(OptimizerKind::Random, 10, 4);
        let mut calls = 0usize;
        let r = t
            .maximize_batch(|batch| {
                calls += 1;
                let mut out = BatchResult::default();
                // Lose every other evaluation (straggler simulation).
                for (i, cfg) in batch.iter().enumerate() {
                    if i % 2 == 0 {
                        out.push(cfg.clone(), quad(cfg).unwrap());
                    }
                }
                out
            })
            .unwrap();
        assert_eq!(calls, 10);
        assert_eq!(r.evaluations, 20, "half of 40 proposals returned");
    }

    #[test]
    fn early_stop_halts() {
        let space = crate::space::svm_space();
        let mut t = Tuner::new(
            space,
            TunerConfig {
                optimizer: OptimizerKind::Random,
                num_iterations: 50,
                early_stop: Some(3),
                backend: SurrogateBackend::Native,
                seed: 1,
                ..Default::default()
            },
        );
        // Constant objective: never improves after the first iteration.
        let r = t.maximize(|_| Some(1.0)).unwrap();
        assert!(r.best_series.len() <= 6, "ran {} iters", r.best_series.len());
    }

    #[test]
    fn callback_sees_every_iteration() {
        use std::cell::RefCell;
        use std::rc::Rc;
        let seen = Rc::new(RefCell::new(0usize));
        let seen2 = seen.clone();
        let space = crate::space::svm_space();
        let mut t = Tuner::new(
            space,
            TunerConfig {
                optimizer: OptimizerKind::Random,
                num_iterations: 7,
                backend: SurrogateBackend::Native,
                ..Default::default()
            },
        )
        .with_callback(move |rec| {
            assert!(rec.proposed >= 1);
            *seen2.borrow_mut() += 1;
        });
        t.maximize(|_| Some(0.0)).unwrap();
        assert_eq!(*seen.borrow(), 7);
    }

    #[test]
    fn all_failures_is_an_error() {
        let mut t = tuner(OptimizerKind::Random, 3, 2);
        let err = t.maximize(|_| None).unwrap_err();
        assert!(err.to_string().contains("no evaluation"));
    }

    #[test]
    fn non_finite_objective_rejected() {
        let mut t = tuner(OptimizerKind::Random, 2, 1);
        assert!(t.maximize(|_| Some(f64::NAN)).is_err());
    }

    #[test]
    fn tpe_and_clustering_run_end_to_end() {
        for kind in [OptimizerKind::Tpe, OptimizerKind::Clustering] {
            let mut t = tuner(kind, 10, 3);
            let r = t.maximize(quad).unwrap();
            assert_eq!(r.evaluations, 30);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut t = tuner(OptimizerKind::Hallucination, 8, 2);
            t.maximize(quad).unwrap().best_objective
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn from_run_config_maps() {
        let rc = RunConfig {
            optimizer: "clustering".into(),
            scheduler: "threaded".into(),
            backend: "native".into(),
            batch_size: 5,
            workers: 8,
            ..Default::default()
        };
        let tc = TunerConfig::from_run_config(&rc).unwrap();
        assert_eq!(tc.optimizer, OptimizerKind::Clustering);
        assert_eq!(tc.scheduler, SchedulerKind::Threaded);
        assert_eq!(tc.workers, 8);
        let _ = Config::new(vec![("x".into(), ParamValue::F64(0.0))]); // silence import
    }
}
