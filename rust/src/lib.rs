//! # mango — parallel hyperparameter tuning in Rust + JAX + Pallas
//!
//! A full reproduction of *MANGO: A Python Library for Parallel
//! Hyperparameter Tuning* (Sandha et al., 2020) as a three-layer system:
//!
//! * **Layer 3 (this crate)** — the MANGO coordinator: search-space DSL
//!   ([`space`]), batch Bayesian optimizers ([`optimizer`]), decoupled
//!   schedulers with fault tolerance ([`scheduler`]), and the [`coordinator`]
//!   tying them together.
//! * **Layer 2** — the GP-UCB surrogate authored in JAX
//!   (`python/compile/model.py`), AOT-lowered to HLO text and executed from
//!   Rust through PJRT ([`runtime`]).
//! * **Layer 1** — the Pallas ARD-RBF kernel-matrix kernel
//!   (`python/compile/kernels/rbf.py`) embedded in the L2 program.
//!
//! Python never runs on the request path: `make artifacts` lowers the L2/L1
//! programs once; the Rust binary is self-contained afterwards.
//!
//! ## Quickstart
//!
//! ```no_run
//! use mango::prelude::*;
//!
//! let space = SearchSpace::builder()
//!     .uniform("c", 0.01, 100.0)
//!     .loguniform("gamma", 1e-4, 1e3)
//!     .build();
//! let mut tuner = Tuner::new(space, TunerConfig::default());
//! let result = tuner
//!     .maximize(|cfg: &Config| {
//!         let c = cfg.get_f64("c")?;
//!         let g = cfg.get_f64("gamma")?;
//!         Some(-(c - 10.0).powi(2) - (g.log10() + 1.0).powi(2))
//!     })
//!     .unwrap();
//! println!("best = {} @ {}", result.best_params, result.best_objective);
//! ```

pub mod util;
pub mod config;
pub mod linalg;
pub mod space;
pub mod gp;
pub mod acq;
pub mod runtime;
pub mod optimizer;
pub mod scheduler;
pub mod persist;
pub mod coordinator;
pub mod ml;
pub mod benchfn;
pub mod exp;
pub mod cli;
pub mod lint;

/// Convenience re-exports covering the common tuning workflow.
pub mod prelude {
    pub use crate::coordinator::{
        ExecutionMode, ObjectiveFn, Tuner, TunerConfig, TuningResult,
    };
    pub use crate::optimizer::{OptimizerKind, SurrogateBackend};
    pub use crate::scheduler::{
        AsyncScheduler, BatchResult, Completion, CompletionStatus, Scheduler, SchedulerKind,
    };
    pub use crate::space::{Config, ParamValue, SearchSpace};
    pub use crate::util::rng::Pcg64;
}
