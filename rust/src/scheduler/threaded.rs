//! Local thread-pool scheduler ("to use all cores in local machine,
//! threading can be used to evaluate a set of values" — paper §2.2).
//!
//! The engine is [`ThreadedAsyncScheduler`]: a persistent worker pool fed
//! through a broker queue and drained over a channel ([`super::pool`]) —
//! workers are spawned once per run, not per batch. The batch-synchronous
//! [`ThreadedScheduler`] is now a thin special case: spawn the pool,
//! submit the whole batch, drain to completion.

use super::pool::{Fate, Task, WorkerPool};
use super::{
    AsyncScheduler, AsyncStats, BatchResult, Completion, CompletionStatus, Objective, Scheduler,
    SubmitMeta, TaskId, TaskObjective,
};
use crate::space::Config;
use std::time::{Duration, Instant};

pub struct ThreadedScheduler {
    workers: usize,
}

impl ThreadedScheduler {
    pub fn new(workers: usize) -> Self {
        Self { workers: workers.max(1) }
    }
}

impl Scheduler for ThreadedScheduler {
    fn evaluate(&mut self, objective: Objective<'_>, batch: &[Config]) -> BatchResult {
        // The paper: "maximum level of parallelism per job is decided by the
        // size of the batch".
        let workers = self.workers.min(batch.len()).max(1);
        // Sync mode has no report channel: adapt the plain objective.
        let exec = |_: TaskId, cfg: &Config| objective(cfg);
        std::thread::scope(|scope| {
            let mut engine = ThreadedAsyncScheduler::spawn(scope, &exec, workers);
            engine.submit(batch);
            let completions = engine.drain(Duration::from_secs(24 * 3600));
            // Results arrive out of order; keep arrival order (the optimizer
            // matches on params, not position — the paper's contract).
            let mut out = BatchResult::default();
            for c in completions {
                if let CompletionStatus::Done(v) = c.status {
                    out.push(c.config, v);
                }
            }
            out
        })
    }

    fn name(&self) -> &'static str {
        "threaded"
    }
}

/// Submit/poll engine over a persistent local worker pool. Tasks are never
/// lost here (no fault injection): every submission completes as
/// `Done`/`Failed`.
pub struct ThreadedAsyncScheduler {
    pool: WorkerPool,
    next_id: TaskId,
}

impl ThreadedAsyncScheduler {
    /// Spawn `workers` pool threads on `scope`; they borrow `objective`
    /// until the scope ends.
    pub fn spawn<'scope, 'env>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        objective: TaskObjective<'env>,
        workers: usize,
    ) -> Self {
        Self::spawn_from(scope, objective, workers, 0)
    }

    /// [`spawn`](Self::spawn) with the task-id counter starting at
    /// `first_id` (resumed runs continue the crashed run's id sequence).
    pub fn spawn_from<'scope, 'env>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        objective: TaskObjective<'env>,
        workers: usize,
        first_id: TaskId,
    ) -> Self {
        Self { pool: WorkerPool::spawn(scope, objective, workers), next_id: first_id }
    }
}

impl AsyncScheduler for ThreadedAsyncScheduler {
    fn submit(&mut self, configs: &[Config]) -> Vec<TaskId> {
        self.submit_with(configs, &SubmitMeta::default())
    }

    fn submit_with(&mut self, configs: &[Config], meta: &SubmitMeta) -> Vec<TaskId> {
        configs
            .iter()
            .map(|cfg| {
                let id = self.next_id;
                self.next_id += 1;
                // Retry backoff rides the pool's simulated-latency slot:
                // the worker sleeps it out before executing. No fault
                // model here, so the fate key is irrelevant.
                self.pool.submit_task(Task {
                    id,
                    config: cfg.clone(),
                    submitted_at: Instant::now(),
                    fate: Fate::Deliver { delay: meta.backoff },
                });
                id
            })
            .collect()
    }

    fn poll(&mut self, timeout: Duration) -> Vec<Completion> {
        self.pool.poll(timeout)
    }

    fn in_flight(&self) -> usize {
        self.pool.in_flight()
    }

    fn cancel_pending(&mut self) -> Vec<TaskId> {
        self.pool.cancel_pending()
    }

    fn stats(&self) -> AsyncStats {
        self.pool.stats()
    }

    fn name(&self) -> &'static str {
        "threaded-async"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamValue;

    fn batch_of(n: usize) -> Vec<Config> {
        (0..n)
            .map(|i| Config::new(vec![("i".into(), ParamValue::Int(i as i64))]))
            .collect()
    }

    #[test]
    fn evaluates_all_and_matches_params() {
        let batch = batch_of(16);
        let mut s = ThreadedScheduler::new(4);
        let res = s.evaluate(&|cfg| Some(cfg.get_i64("i").unwrap() as f64 * 2.0), &batch);
        assert_eq!(res.len(), 16);
        for (cfg, v) in res.params.iter().zip(&res.evals) {
            assert_eq!(*v, cfg.get_i64("i").unwrap() as f64 * 2.0);
        }
    }

    #[test]
    fn really_parallel() {
        // 8 tasks of ~30ms on 8 workers must finish well under 8*30ms.
        let batch = batch_of(8);
        let mut s = ThreadedScheduler::new(8);
        let t = std::time::Instant::now();
        let res = s.evaluate(
            &|_| {
                std::thread::sleep(std::time::Duration::from_millis(30));
                Some(1.0)
            },
            &batch,
        );
        let ms = t.elapsed().as_millis();
        assert_eq!(res.len(), 8);
        assert!(ms < 160, "took {ms}ms — not parallel");
    }

    #[test]
    fn failures_are_partial() {
        let batch = batch_of(10);
        let mut s = ThreadedScheduler::new(3);
        let res = s.evaluate(
            &|cfg| {
                let i = cfg.get_i64("i").unwrap();
                (i % 2 == 0).then_some(i as f64)
            },
            &batch,
        );
        assert_eq!(res.len(), 5);
        for cfg in &res.params {
            assert_eq!(cfg.get_i64("i").unwrap() % 2, 0);
        }
    }

    #[test]
    fn single_worker_degenerates_to_serial() {
        let batch = batch_of(5);
        let mut s = ThreadedScheduler::new(1);
        let res = s.evaluate(&|cfg| Some(cfg.get_i64("i").unwrap() as f64), &batch);
        assert_eq!(res.len(), 5);
    }

    #[test]
    fn async_engine_overlaps_submissions() {
        // Submit in two waves without waiting for the first: 8 sleepy tasks
        // across 8 workers still finish in ~1 task's wall time.
        let objective = |_: TaskId, _: &Config| {
            std::thread::sleep(Duration::from_millis(30));
            Some(1.0)
        };
        std::thread::scope(|scope| {
            let mut s = ThreadedAsyncScheduler::spawn(scope, &objective, 8);
            let t = Instant::now();
            s.submit(&batch_of(4));
            s.submit(&batch_of(4));
            assert_eq!(s.in_flight(), 8);
            let comps = s.drain(Duration::from_secs(10));
            let ms = t.elapsed().as_millis();
            assert_eq!(comps.len(), 8);
            assert!(ms < 160, "took {ms}ms — waves must overlap");
            assert_eq!(s.stats().completed, 8);
            assert_eq!(s.stats().max_in_flight, 8);
        });
    }

    #[test]
    fn poll_reports_queue_wait_and_eval_time() {
        let objective = |_: TaskId, _: &Config| {
            std::thread::sleep(Duration::from_millis(10));
            Some(1.0)
        };
        std::thread::scope(|scope| {
            let mut s = ThreadedAsyncScheduler::spawn(scope, &objective, 1);
            s.submit(&batch_of(2));
            let comps = s.drain(Duration::from_secs(10));
            assert_eq!(comps.len(), 2);
            for c in &comps {
                assert!(c.eval_ms >= 5.0, "eval took {}ms", c.eval_ms);
            }
            // The second task waited behind the first on the single worker.
            let waited = comps.iter().map(|c| c.queue_wait_ms).fold(0f64, f64::max);
            assert!(waited >= 5.0, "max queue wait {waited}ms");
        });
    }
}
