//! Local thread-pool scheduler ("to use all cores in local machine,
//! threading can be used to evaluate a set of values" — paper §2.2).

use super::{BatchResult, Objective, Scheduler};
use crate::space::Config;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

pub struct ThreadedScheduler {
    workers: usize,
}

impl ThreadedScheduler {
    pub fn new(workers: usize) -> Self {
        Self { workers: workers.max(1) }
    }
}

impl Scheduler for ThreadedScheduler {
    fn evaluate(&mut self, objective: Objective<'_>, batch: &[Config]) -> BatchResult {
        // The paper: "maximum level of parallelism per job is decided by the
        // size of the batch".
        let workers = self.workers.min(batch.len()).max(1);
        let next = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<(usize, Option<f64>)>();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let tx = tx.clone();
                let next = &next;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= batch.len() {
                        break;
                    }
                    let v = objective(&batch[i]);
                    if tx.send((i, v)).is_err() {
                        break;
                    }
                });
            }
        });
        drop(tx);
        // Results arrive out of order; keep arrival order (the optimizer
        // matches on params, not position — the paper's contract).
        let mut out = BatchResult::default();
        for (i, v) in rx {
            if let Some(v) = v {
                out.push(batch[i].clone(), v);
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "threaded"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamValue;

    fn batch_of(n: usize) -> Vec<Config> {
        (0..n)
            .map(|i| Config::new(vec![("i".into(), ParamValue::Int(i as i64))]))
            .collect()
    }

    #[test]
    fn evaluates_all_and_matches_params() {
        let batch = batch_of(16);
        let mut s = ThreadedScheduler::new(4);
        let res = s.evaluate(&|cfg| Some(cfg.get_i64("i").unwrap() as f64 * 2.0), &batch);
        assert_eq!(res.len(), 16);
        for (cfg, v) in res.params.iter().zip(&res.evals) {
            assert_eq!(*v, cfg.get_i64("i").unwrap() as f64 * 2.0);
        }
    }

    #[test]
    fn really_parallel() {
        // 8 tasks of ~30ms on 8 workers must finish well under 8*30ms.
        let batch = batch_of(8);
        let mut s = ThreadedScheduler::new(8);
        let t = std::time::Instant::now();
        let res = s.evaluate(
            &|_| {
                std::thread::sleep(std::time::Duration::from_millis(30));
                Some(1.0)
            },
            &batch,
        );
        let ms = t.elapsed().as_millis();
        assert_eq!(res.len(), 8);
        assert!(ms < 160, "took {ms}ms — not parallel");
    }

    #[test]
    fn failures_are_partial() {
        let batch = batch_of(10);
        let mut s = ThreadedScheduler::new(3);
        let res = s.evaluate(
            &|cfg| {
                let i = cfg.get_i64("i").unwrap();
                (i % 2 == 0).then_some(i as f64)
            },
            &batch,
        );
        assert_eq!(res.len(), 5);
        for cfg in &res.params {
            assert_eq!(cfg.get_i64("i").unwrap() % 2, 0);
        }
    }

    #[test]
    fn single_worker_degenerates_to_serial() {
        let batch = batch_of(5);
        let mut s = ThreadedScheduler::new(1);
        let res = s.evaluate(&|cfg| Some(cfg.get_i64("i").unwrap() as f64), &batch);
        assert_eq!(res.len(), 5);
    }
}
