//! Serial scheduler — the paper's Listing 3 skeleton.

use super::{BatchResult, Objective, Scheduler};
use crate::space::Config;

pub struct SerialScheduler;

impl Scheduler for SerialScheduler {
    fn evaluate(&mut self, objective: Objective<'_>, batch: &[Config]) -> BatchResult {
        let mut out = BatchResult::default();
        for cfg in batch {
            if let Some(v) = objective(cfg) {
                out.push(cfg.clone(), v);
            }
            // failed evaluations are simply omitted — partial results
        }
        out
    }

    fn name(&self) -> &'static str {
        "serial"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{svm_space, ParamValue};
    use crate::util::rng::Pcg64;

    #[test]
    fn evaluates_in_order() {
        let space = svm_space();
        let mut rng = Pcg64::new(1);
        let batch = space.sample_n(&mut rng, 4);
        let mut s = SerialScheduler;
        let res = s.evaluate(&|cfg| cfg.get_f64("c"), &batch);
        assert_eq!(res.len(), 4);
        for (i, cfg) in batch.iter().enumerate() {
            assert_eq!(&res.params[i], cfg);
            assert_eq!(res.evals[i], cfg.get_f64("c").unwrap());
        }
    }

    #[test]
    fn partial_results_on_failure() {
        let batch = vec![
            Config::new(vec![("x".into(), ParamValue::F64(1.0))]),
            Config::new(vec![("x".into(), ParamValue::F64(-1.0))]),
            Config::new(vec![("x".into(), ParamValue::F64(2.0))]),
        ];
        let mut s = SerialScheduler;
        // negative x "crashes"
        let res = s.evaluate(
            &|cfg| {
                let x = cfg.get_f64("x").unwrap();
                (x > 0.0).then_some(x)
            },
            &batch,
        );
        assert_eq!(res.len(), 2);
        assert_eq!(res.evals, vec![1.0, 2.0]);
    }
}
