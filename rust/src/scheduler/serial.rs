//! Serial scheduler — the paper's Listing 3 skeleton, in both contracts:
//! the batch-synchronous [`SerialScheduler`] and the submit/poll adapter
//! [`SerialAsyncScheduler`] (one queued evaluation per poll, fully
//! deterministic — the reference implementation for event-loop tests).

use super::{
    AsyncScheduler, AsyncStats, BatchResult, Completion, CompletionStatus, Objective, Scheduler,
    SubmitMeta, TaskId, TaskObjective,
};
use crate::space::Config;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

pub struct SerialScheduler;

impl Scheduler for SerialScheduler {
    fn evaluate(&mut self, objective: Objective<'_>, batch: &[Config]) -> BatchResult {
        let mut out = BatchResult::default();
        for cfg in batch {
            if let Some(v) = objective(cfg) {
                out.push(cfg.clone(), v);
            }
            // failed evaluations are simply omitted — partial results
        }
        out
    }

    fn name(&self) -> &'static str {
        "serial"
    }
}

/// Submit/poll adapter over in-line evaluation: `submit` only queues;
/// each `poll` runs exactly one task to completion. Nothing is ever lost,
/// so every completion is `Done`/`Failed` and runs are deterministic.
pub struct SerialAsyncScheduler<'a> {
    objective: TaskObjective<'a>,
    /// `(id, config, submitted_at, backoff)` — backoff is an
    /// execution-side delay slept out when the task is polled.
    queue: VecDeque<(TaskId, Config, Instant, Duration)>,
    next_id: TaskId,
    /// 1-based drain counter stamped on each [`Completion`] (telemetry).
    epoch: u64,
    stats: AsyncStats,
}

impl<'a> SerialAsyncScheduler<'a> {
    pub fn new(objective: TaskObjective<'a>) -> Self {
        Self {
            objective,
            queue: VecDeque::new(),
            next_id: 0,
            epoch: 0,
            stats: AsyncStats::default(),
        }
    }

    /// Start the task-id counter at `first_id` — a resumed run continues
    /// the crashed run's id sequence so journaled telemetry stays unique
    /// across restarts.
    pub fn with_first_id(mut self, first_id: TaskId) -> Self {
        self.next_id = first_id;
        self
    }
}

impl AsyncScheduler for SerialAsyncScheduler<'_> {
    fn submit(&mut self, configs: &[Config]) -> Vec<TaskId> {
        self.submit_with(configs, &SubmitMeta::default())
    }

    fn submit_with(&mut self, configs: &[Config], meta: &SubmitMeta) -> Vec<TaskId> {
        configs
            .iter()
            .map(|cfg| {
                let id = self.next_id;
                self.next_id += 1;
                self.queue.push_back((id, cfg.clone(), Instant::now(), meta.backoff));
                self.stats.submitted += 1;
                self.stats.max_in_flight = self.stats.max_in_flight.max(self.queue.len());
                id
            })
            .collect()
    }

    fn poll(&mut self, _timeout: Duration) -> Vec<Completion> {
        let Some((id, config, submitted_at, backoff)) = self.queue.pop_front() else {
            return Vec::new();
        };
        // Retry backoff models the worker holding the task before running
        // it, so it lands in queue wait, not eval time.
        if !backoff.is_zero() {
            std::thread::sleep(backoff);
        }
        let queue_wait_ms = submitted_at.elapsed().as_secs_f64() * 1e3;
        let t0 = Instant::now();
        let value = (self.objective)(id, &config);
        let eval_ms = t0.elapsed().as_secs_f64() * 1e3;
        let status = match value {
            Some(v) => {
                self.stats.completed += 1;
                CompletionStatus::Done(v)
            }
            None => {
                self.stats.failed += 1;
                CompletionStatus::Failed
            }
        };
        self.epoch += 1;
        vec![Completion { id, config, status, queue_wait_ms, eval_ms, epoch: self.epoch }]
    }

    fn in_flight(&self) -> usize {
        self.queue.len()
    }

    fn cancel_pending(&mut self) -> Vec<TaskId> {
        let cancelled: Vec<TaskId> = self.queue.drain(..).map(|(id, _, _, _)| id).collect();
        self.stats.cancelled += cancelled.len() as u64;
        cancelled
    }

    fn stats(&self) -> AsyncStats {
        self.stats.clone()
    }

    fn name(&self) -> &'static str {
        "serial-async"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{svm_space, ParamValue};
    use crate::util::rng::Pcg64;

    #[test]
    fn evaluates_in_order() {
        let space = svm_space();
        let mut rng = Pcg64::new(1);
        let batch = space.sample_n(&mut rng, 4);
        let mut s = SerialScheduler;
        let res = s.evaluate(&|cfg| cfg.get_f64("c"), &batch);
        assert_eq!(res.len(), 4);
        for (i, cfg) in batch.iter().enumerate() {
            assert_eq!(&res.params[i], cfg);
            assert_eq!(res.evals[i], cfg.get_f64("c").unwrap());
        }
    }

    #[test]
    fn partial_results_on_failure() {
        let batch = vec![
            Config::new(vec![("x".into(), ParamValue::F64(1.0))]),
            Config::new(vec![("x".into(), ParamValue::F64(-1.0))]),
            Config::new(vec![("x".into(), ParamValue::F64(2.0))]),
        ];
        let mut s = SerialScheduler;
        // negative x "crashes"
        let res = s.evaluate(
            &|cfg| {
                let x = cfg.get_f64("x").unwrap();
                (x > 0.0).then_some(x)
            },
            &batch,
        );
        assert_eq!(res.len(), 2);
        assert_eq!(res.evals, vec![1.0, 2.0]);
    }

    #[test]
    fn async_adapter_polls_one_at_a_time_in_order() {
        let objective = |_: TaskId, cfg: &Config| cfg.get_f64("x");
        let batch: Vec<Config> = (0..3)
            .map(|i| Config::new(vec![("x".into(), ParamValue::F64(i as f64))]))
            .collect();
        let mut s = SerialAsyncScheduler::new(&objective);
        let ids = s.submit(&batch);
        assert_eq!(ids, vec![0, 1, 2]);
        assert_eq!(s.in_flight(), 3);
        for want in 0..3 {
            let comps = s.poll(Duration::ZERO);
            assert_eq!(comps.len(), 1);
            assert_eq!(comps[0].id, want as TaskId);
            assert_eq!(comps[0].status, CompletionStatus::Done(want as f64));
        }
        assert_eq!(s.in_flight(), 0);
        assert!(s.poll(Duration::ZERO).is_empty());
        assert_eq!(s.stats().completed, 3);
    }

    #[test]
    fn async_adapter_cancels_queue() {
        let objective = |_: TaskId, _: &Config| Some(1.0);
        let mut s = SerialAsyncScheduler::new(&objective);
        s.submit(&[Config::default(), Config::default()]);
        let cancelled = s.cancel_pending();
        assert_eq!(cancelled, vec![0, 1]);
        assert_eq!(s.in_flight(), 0);
        assert_eq!(s.stats().cancelled, 2);
    }
}
