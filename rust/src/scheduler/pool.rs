//! Persistent worker pool: the shared broker/worker/collector machinery
//! behind [`super::threaded::ThreadedAsyncScheduler`] and
//! [`super::celery::CeleryAsyncScheduler`].
//!
//! Architecture (mirrors a Celery deployment, DESIGN.md §2):
//! * a **broker** — a mutex-guarded task queue workers block on via a
//!   condvar (supports mid-run cancellation, which an mpsc queue can't),
//! * N **worker** threads pulling tasks for the lifetime of the pool
//!   (spawned once on a [`std::thread::Scope`], *not* per batch),
//! * a **collector** — an mpsc channel the pool drains in
//!   [`WorkerPool::poll`].
//!
//! Each task carries a pre-rolled [`Fate`]: real evaluation (optionally
//! after a simulated latency) or an explicit loss. Lost tasks still report
//! — as [`CompletionStatus::Lost`] — so the coordinator can retry them
//! instead of inferring losses from silence.

use super::{AsyncStats, Completion, CompletionStatus, LossReason, Objective, TaskId};
use crate::space::Config;
use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// What will happen to a task once a worker picks it up.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Fate {
    /// Wait out `delay` (simulated queue/network latency), then evaluate.
    Deliver { delay: Duration },
    /// The worker dies with the task after `delay`: reports `Lost(Crashed)`.
    Crash { delay: Duration },
    /// Straggles past the collector's patience: `Lost(TimedOut)` after
    /// `delay` (the result-timeout, not the full straggler latency).
    TimeOut { delay: Duration },
}

/// A unit of work on the broker queue.
pub(crate) struct Task {
    pub id: TaskId,
    pub config: Config,
    pub submitted_at: Instant,
    pub fate: Fate,
}

struct BrokerState {
    queue: VecDeque<Task>,
    shutdown: bool,
}

type Broker = Arc<(Mutex<BrokerState>, Condvar)>;

/// The pool: broker + workers + collector. Workers are spawned on a
/// caller-provided scope and exit when the pool drops (shutdown flag) or
/// the collector disappears.
pub(crate) struct WorkerPool {
    broker: Broker,
    results: mpsc::Receiver<Completion>,
    in_flight: usize,
    stats: AsyncStats,
}

impl WorkerPool {
    pub(crate) fn spawn<'scope, 'env>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        objective: Objective<'env>,
        workers: usize,
    ) -> Self {
        let broker: Broker = Arc::new((
            Mutex::new(BrokerState { queue: VecDeque::new(), shutdown: false }),
            Condvar::new(),
        ));
        let (tx, rx) = mpsc::channel::<Completion>();
        for _ in 0..workers.max(1) {
            let broker = broker.clone();
            let tx = tx.clone();
            scope.spawn(move || worker_loop(&broker, objective, &tx));
        }
        Self { broker, results: rx, in_flight: 0, stats: AsyncStats::default() }
    }

    pub(crate) fn submit_task(&mut self, task: Task) {
        let (lock, cv) = &*self.broker;
        lock.lock().unwrap().queue.push_back(task);
        cv.notify_one();
        self.in_flight += 1;
        self.stats.submitted += 1;
        self.stats.max_in_flight = self.stats.max_in_flight.max(self.in_flight);
    }

    pub(crate) fn poll(&mut self, timeout: Duration) -> Vec<Completion> {
        let mut out = Vec::new();
        if self.in_flight == 0 {
            return out;
        }
        match self.results.recv_timeout(timeout) {
            Ok(c) => out.push(c),
            Err(mpsc::RecvTimeoutError::Timeout) => return out,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Every worker is gone (the objective panicked): nothing
                // will ever arrive. Zero the in-flight count so callers
                // stop waiting — the scope join propagates the panic.
                self.in_flight = 0;
                return out;
            }
        }
        // Drain everything else that's already ready.
        while let Ok(c) = self.results.try_recv() {
            out.push(c);
        }
        self.in_flight -= out.len();
        for c in &out {
            match c.status {
                CompletionStatus::Done(_) => self.stats.completed += 1,
                CompletionStatus::Failed => self.stats.failed += 1,
                CompletionStatus::Lost(_) => self.stats.lost += 1,
            }
        }
        out.sort_by_key(|c| c.id);
        out
    }

    pub(crate) fn in_flight(&self) -> usize {
        self.in_flight
    }

    pub(crate) fn cancel_pending(&mut self) -> Vec<TaskId> {
        let (lock, _) = &*self.broker;
        let cancelled: Vec<TaskId> =
            lock.lock().unwrap().queue.drain(..).map(|t| t.id).collect();
        self.in_flight -= cancelled.len();
        self.stats.cancelled += cancelled.len() as u64;
        cancelled
    }

    pub(crate) fn stats(&self) -> AsyncStats {
        self.stats.clone()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        let (lock, cv) = &*self.broker;
        let mut st = lock.lock().unwrap();
        st.shutdown = true;
        // Nobody will collect queued work now — don't make the scope join
        // wait for evaluations whose results would be thrown away.
        st.queue.clear();
        cv.notify_all();
    }
}

fn worker_loop(broker: &Broker, objective: Objective<'_>, tx: &mpsc::Sender<Completion>) {
    loop {
        let task = {
            let (lock, cv) = &**broker;
            let mut st = lock.lock().unwrap();
            loop {
                if let Some(t) = st.queue.pop_front() {
                    break Some(t);
                }
                if st.shutdown {
                    break None;
                }
                st = cv.wait(st).unwrap();
            }
        };
        let Some(task) = task else { return };
        let completion = match task.fate {
            Fate::Deliver { delay } => {
                if !delay.is_zero() {
                    std::thread::sleep(delay);
                }
                let queue_wait_ms = task.submitted_at.elapsed().as_secs_f64() * 1e3;
                let t0 = Instant::now();
                let value = objective(&task.config);
                let eval_ms = t0.elapsed().as_secs_f64() * 1e3;
                Completion {
                    id: task.id,
                    config: task.config,
                    status: match value {
                        Some(v) => CompletionStatus::Done(v),
                        None => CompletionStatus::Failed,
                    },
                    queue_wait_ms,
                    eval_ms,
                }
            }
            Fate::Crash { delay } => {
                std::thread::sleep(delay);
                Completion {
                    id: task.id,
                    config: task.config,
                    status: CompletionStatus::Lost(LossReason::Crashed),
                    queue_wait_ms: task.submitted_at.elapsed().as_secs_f64() * 1e3,
                    eval_ms: 0.0,
                }
            }
            Fate::TimeOut { delay } => {
                std::thread::sleep(delay);
                Completion {
                    id: task.id,
                    config: task.config,
                    status: CompletionStatus::Lost(LossReason::TimedOut),
                    queue_wait_ms: task.submitted_at.elapsed().as_secs_f64() * 1e3,
                    eval_ms: 0.0,
                }
            }
        };
        if tx.send(completion).is_err() {
            return; // collector gone: the run is over
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamValue;

    fn cfg_i(i: i64) -> Config {
        Config::new(vec![("i".into(), ParamValue::Int(i))])
    }

    fn deliver(id: TaskId, i: i64) -> Task {
        Task {
            id,
            config: cfg_i(i),
            submitted_at: Instant::now(),
            fate: Fate::Deliver { delay: Duration::ZERO },
        }
    }

    #[test]
    fn pool_runs_tasks_and_counts() {
        let objective = |c: &Config| Some(c.get_i64("i").unwrap() as f64 * 2.0);
        std::thread::scope(|scope| {
            let mut pool = WorkerPool::spawn(scope, &objective, 3);
            for i in 0..10 {
                pool.submit_task(deliver(i, i as i64));
            }
            assert_eq!(pool.in_flight(), 10);
            let mut got = Vec::new();
            while pool.in_flight() > 0 {
                got.extend(pool.poll(Duration::from_secs(10)));
            }
            assert_eq!(got.len(), 10);
            // poll sorts each drain by id; a full drain is checkable per batch
            for c in &got {
                match c.status {
                    CompletionStatus::Done(v) => {
                        assert_eq!(v, c.config.get_i64("i").unwrap() as f64 * 2.0)
                    }
                    other => panic!("unexpected status {other:?}"),
                }
            }
            let stats = pool.stats();
            assert_eq!(stats.submitted, 10);
            assert_eq!(stats.completed, 10);
            assert_eq!(stats.max_in_flight, 10);
        });
    }

    #[test]
    fn lost_fates_report_explicitly() {
        let objective = |_: &Config| Some(1.0);
        std::thread::scope(|scope| {
            let mut pool = WorkerPool::spawn(scope, &objective, 2);
            pool.submit_task(Task {
                id: 0,
                config: cfg_i(0),
                submitted_at: Instant::now(),
                fate: Fate::Crash { delay: Duration::from_millis(1) },
            });
            pool.submit_task(Task {
                id: 1,
                config: cfg_i(1),
                submitted_at: Instant::now(),
                fate: Fate::TimeOut { delay: Duration::from_millis(1) },
            });
            let mut got = Vec::new();
            while pool.in_flight() > 0 {
                got.extend(pool.poll(Duration::from_secs(10)));
            }
            got.sort_by_key(|c| c.id);
            assert_eq!(got[0].status, CompletionStatus::Lost(LossReason::Crashed));
            assert_eq!(got[1].status, CompletionStatus::Lost(LossReason::TimedOut));
            assert_eq!(pool.stats().lost, 2);
        });
    }

    #[test]
    fn cancel_pending_withdraws_queued_work() {
        // A single worker stuck on a slow task leaves the rest queued.
        use std::sync::atomic::{AtomicBool, Ordering};
        let started = AtomicBool::new(false);
        let objective = |c: &Config| {
            if c.get_i64("i").unwrap() == 0 {
                started.store(true, Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(80));
            }
            Some(1.0)
        };
        std::thread::scope(|scope| {
            let mut pool = WorkerPool::spawn(scope, &objective, 1);
            for i in 0..5 {
                pool.submit_task(deliver(i, i as i64));
            }
            // Wait until the worker has claimed task 0, then cancel the rest.
            let deadline = Instant::now() + Duration::from_secs(5);
            while !started.load(Ordering::SeqCst) && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(1));
            }
            let cancelled = pool.cancel_pending();
            assert!(!cancelled.is_empty(), "queued tasks must be cancellable");
            assert!(!cancelled.contains(&0), "running task is not cancellable");
            let mut got = Vec::new();
            while pool.in_flight() > 0 {
                got.extend(pool.poll(Duration::from_secs(10)));
            }
            assert_eq!(got.len() + cancelled.len(), 5);
            assert_eq!(pool.stats().cancelled, cancelled.len() as u64);
        });
    }
}
