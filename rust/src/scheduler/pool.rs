//! Persistent worker pool: the shared broker/worker/collector machinery
//! behind [`super::threaded::ThreadedAsyncScheduler`],
//! [`super::celery::CeleryAsyncScheduler`], and the propose-time scoring
//! shards ([`crate::gp::acquire_sharded`]).
//!
//! Architecture (mirrors a Celery deployment, DESIGN.md §2):
//! * a **broker** — a mutex-guarded task queue workers block on via a
//!   condvar (supports mid-run cancellation, which an mpsc queue can't),
//! * N **worker** threads pulling jobs for the lifetime of the pool
//!   (spawned once on a [`std::thread::Scope`], *not* per batch),
//! * a **collector** — an mpsc channel the pool drains in
//!   [`JobPool::poll`].
//!
//! The core is **generic over the work item**: [`JobPool<P, R>`] carries
//! any `Send` payload `P` to an executor — the plain `Fn(&P) -> Option<R>`
//! form or the task-id-tagged `Fn(TaskId, &P) -> Option<R>` form — and
//! drains typed [`JobDone<P, R>`] results. Objective evaluations
//! (`P = Config, R = f64`, via the [`WorkerPool`] adapter the schedulers
//! use; the tagged form, so each evaluation can key a
//! [`super::TrialReporter`] intermediate-report channel by its task id)
//! and candidate-scoring shards (`P = range, R = AcquireOut`; the plain
//! form) ride the identical machinery, so propose-time work scales through
//! the same scheduler abstraction as trial evaluations.
//!
//! Each job carries a pre-rolled [`Fate`]: real execution (optionally
//! after a simulated latency) or an explicit loss. Lost jobs still report
//! — as [`JobStatus::Lost`] — so the caller can retry them instead of
//! inferring losses from silence. Fated-to-be-lost jobs never execute the
//! objective at all, which is exactly the report-channel fault semantics:
//! a crashed or timed-out trial's intermediate reports are dropped, and a
//! delivered trial's reports are delayed by its simulated latency.

use super::{AsyncStats, Completion, CompletionStatus, LossReason, TaskId, TaskObjective};
use crate::space::Config;
use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// What will happen to a job once a worker picks it up.
#[derive(Clone, Copy, Debug)]
pub(crate) enum Fate {
    /// Wait out `delay` (simulated queue/network latency), then execute.
    Deliver { delay: Duration },
    /// The worker dies with the job after `delay`: reports `Lost(Crashed)`.
    Crash { delay: Duration },
    /// Straggles past the collector's patience: `Lost(TimedOut)` after
    /// `delay` (the result-timeout, not the full straggler latency).
    TimeOut { delay: Duration },
}

/// A unit of work on the broker queue.
pub(crate) struct Job<P> {
    pub id: TaskId,
    pub payload: P,
    pub submitted_at: Instant,
    pub fate: Fate,
}

/// Terminal state of one executed job.
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum JobStatus<R> {
    /// The executor returned a value.
    Done(R),
    /// The executor ran and declined (`None`) — deterministic, not retried.
    Failed,
    /// The job was lost in flight — the retriable fault class.
    Lost(LossReason),
}

/// One completed (or lost) job, as drained by [`JobPool::poll`].
pub(crate) struct JobDone<P, R> {
    pub id: TaskId,
    pub payload: P,
    pub status: JobStatus<R>,
    /// Submit → execution start (broker queue + simulated network latency).
    pub queue_wait_ms: f64,
    /// Time spent inside the executor itself.
    pub eval_ms: f64,
}

struct BrokerState<P> {
    queue: VecDeque<Job<P>>,
    shutdown: bool,
}

type Broker<P> = Arc<(Mutex<BrokerState<P>>, Condvar)>;

/// The generic pool: broker + workers + collector. Workers are spawned on
/// a caller-provided scope and exit when the pool drops (shutdown flag) or
/// the collector disappears.
pub(crate) struct JobPool<P, R> {
    broker: Broker<P>,
    results: mpsc::Receiver<JobDone<P, R>>,
    in_flight: usize,
    stats: AsyncStats,
}

/// How a worker invokes the executor: the plain per-payload form (scoring
/// shards) or the task-id-tagged form (objective evaluations, where the id
/// keys the [`super::TrialReporter`] report channel).
enum Exec<'a, P, R> {
    Plain(&'a (dyn Fn(&P) -> Option<R> + Sync)),
    Tagged(&'a (dyn Fn(TaskId, &P) -> Option<R> + Sync)),
}

// Manual impls: derive would demand `P: Copy, R: Copy`, but the enum only
// holds references.
impl<P, R> Clone for Exec<'_, P, R> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<P, R> Copy for Exec<'_, P, R> {}

impl<P, R> Exec<'_, P, R> {
    fn run(&self, id: TaskId, payload: &P) -> Option<R> {
        match self {
            Exec::Plain(f) => f(payload),
            Exec::Tagged(f) => f(id, payload),
        }
    }
}

impl<P: Send, R: Send> JobPool<P, R> {
    pub(crate) fn spawn<'scope, 'env>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        exec: &'env (dyn Fn(&P) -> Option<R> + Sync),
        workers: usize,
    ) -> Self
    where
        P: 'env,
        R: 'env,
    {
        Self::spawn_exec(scope, Exec::Plain(exec), workers)
    }

    /// [`spawn`](Self::spawn) with the task-id-tagged executor form.
    pub(crate) fn spawn_tagged<'scope, 'env>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        exec: &'env (dyn Fn(TaskId, &P) -> Option<R> + Sync),
        workers: usize,
    ) -> Self
    where
        P: 'env,
        R: 'env,
    {
        Self::spawn_exec(scope, Exec::Tagged(exec), workers)
    }

    fn spawn_exec<'scope, 'env>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        exec: Exec<'env, P, R>,
        workers: usize,
    ) -> Self
    where
        P: 'env,
        R: 'env,
    {
        let broker: Broker<P> = Arc::new((
            Mutex::new(BrokerState { queue: VecDeque::new(), shutdown: false }),
            Condvar::new(),
        ));
        let (tx, rx) = mpsc::channel::<JobDone<P, R>>();
        for _ in 0..workers.max(1) {
            let broker = broker.clone();
            let tx = tx.clone();
            scope.spawn(move || worker_loop(&broker, exec, &tx));
        }
        Self { broker, results: rx, in_flight: 0, stats: AsyncStats::default() }
    }

    pub(crate) fn submit_job(&mut self, job: Job<P>) {
        let (lock, cv) = &*self.broker;
        // pallas-lint: allow(R6, "broker poisoning means a worker panicked mid-pop; propagating the panic to the submitter is the contract")
        lock.lock().unwrap().queue.push_back(job);
        cv.notify_one();
        self.in_flight += 1;
        self.stats.submitted += 1;
        self.stats.max_in_flight = self.stats.max_in_flight.max(self.in_flight);
    }

    pub(crate) fn poll(&mut self, timeout: Duration) -> Vec<JobDone<P, R>> {
        let mut out = Vec::new();
        if self.in_flight == 0 {
            return out;
        }
        match self.results.recv_timeout(timeout) {
            Ok(c) => out.push(c),
            Err(mpsc::RecvTimeoutError::Timeout) => return out,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Every worker is gone (the executor panicked): nothing
                // will ever arrive. Zero the in-flight count so callers
                // stop waiting — the scope join propagates the panic.
                self.in_flight = 0;
                return out;
            }
        }
        // Drain everything else that's already ready.
        while let Ok(c) = self.results.try_recv() {
            out.push(c);
        }
        self.in_flight -= out.len();
        for c in &out {
            match c.status {
                JobStatus::Done(_) => self.stats.completed += 1,
                JobStatus::Failed => self.stats.failed += 1,
                JobStatus::Lost(_) => self.stats.lost += 1,
            }
        }
        out.sort_by_key(|c| c.id);
        out
    }

    pub(crate) fn in_flight(&self) -> usize {
        self.in_flight
    }

    pub(crate) fn cancel_pending(&mut self) -> Vec<TaskId> {
        let (lock, _) = &*self.broker;
        let cancelled: Vec<TaskId> =
            // pallas-lint: allow(R6, "broker poisoning means a worker panicked mid-pop; propagating the panic to the canceller is the contract")
            lock.lock().unwrap().queue.drain(..).map(|t| t.id).collect();
        self.in_flight -= cancelled.len();
        self.stats.cancelled += cancelled.len() as u64;
        cancelled
    }

    pub(crate) fn stats(&self) -> AsyncStats {
        self.stats.clone()
    }
}

impl<P, R> Drop for JobPool<P, R> {
    fn drop(&mut self) {
        let (lock, cv) = &*self.broker;
        // pallas-lint: allow(R6, "poison on drop: the panicking worker already doomed the scope join; a double panic here would abort, but only during unwind of a dead run")
        let mut st = lock.lock().unwrap();
        st.shutdown = true;
        // Nobody will collect queued work now — don't make the scope join
        // wait for executions whose results would be thrown away.
        st.queue.clear();
        cv.notify_all();
    }
}

fn worker_loop<P: Send, R: Send>(
    broker: &Broker<P>,
    exec: Exec<'_, P, R>,
    tx: &mpsc::Sender<JobDone<P, R>>,
) {
    loop {
        let job = {
            let (lock, cv) = &**broker;
            // pallas-lint: allow(R6, "a poisoned broker means a sibling worker panicked holding the queue; this worker re-panics and the scope join reports it")
            let mut st = lock.lock().unwrap();
            loop {
                if let Some(t) = st.queue.pop_front() {
                    break Some(t);
                }
                if st.shutdown {
                    break None;
                }
                // pallas-lint: allow(R5, "condvar poison, same as the lock above: re-panic so the scope join surfaces the original worker panic")
                st = cv.wait(st).unwrap();
            }
        };
        let Some(job) = job else { return };
        let done = match job.fate {
            Fate::Deliver { delay } => {
                if !delay.is_zero() {
                    std::thread::sleep(delay);
                }
                let queue_wait_ms = job.submitted_at.elapsed().as_secs_f64() * 1e3;
                let t0 = Instant::now();
                let value = exec.run(job.id, &job.payload);
                let eval_ms = t0.elapsed().as_secs_f64() * 1e3;
                JobDone {
                    id: job.id,
                    payload: job.payload,
                    status: match value {
                        Some(v) => JobStatus::Done(v),
                        None => JobStatus::Failed,
                    },
                    queue_wait_ms,
                    eval_ms,
                }
            }
            Fate::Crash { delay } => {
                std::thread::sleep(delay);
                JobDone {
                    id: job.id,
                    payload: job.payload,
                    status: JobStatus::Lost(LossReason::Crashed),
                    queue_wait_ms: job.submitted_at.elapsed().as_secs_f64() * 1e3,
                    eval_ms: 0.0,
                }
            }
            Fate::TimeOut { delay } => {
                std::thread::sleep(delay);
                JobDone {
                    id: job.id,
                    payload: job.payload,
                    status: JobStatus::Lost(LossReason::TimedOut),
                    queue_wait_ms: job.submitted_at.elapsed().as_secs_f64() * 1e3,
                    eval_ms: 0.0,
                }
            }
        };
        if tx.send(done).is_err() {
            return; // collector gone: the run is over
        }
    }
}

/// A unit of objective-evaluation work (the [`WorkerPool`] adapter's form).
pub(crate) struct Task {
    pub id: TaskId,
    pub config: Config,
    pub submitted_at: Instant,
    pub fate: Fate,
}

/// The objective-evaluation pool the async schedulers are built on: a thin
/// `Config → f64` instantiation of [`JobPool`] translating results into
/// the scheduler-level [`Completion`] vocabulary.
pub(crate) struct WorkerPool {
    inner: JobPool<Config, f64>,
    /// 1-based non-empty-drain counter stamped on each [`Completion`]
    /// (telemetry: which poll drain carried the result).
    epoch: u64,
}

impl WorkerPool {
    pub(crate) fn spawn<'scope, 'env>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        objective: TaskObjective<'env>,
        workers: usize,
    ) -> Self {
        Self { inner: JobPool::spawn_tagged(scope, objective, workers), epoch: 0 }
    }

    pub(crate) fn submit_task(&mut self, task: Task) {
        self.inner.submit_job(Job {
            id: task.id,
            payload: task.config,
            submitted_at: task.submitted_at,
            fate: task.fate,
        });
    }

    pub(crate) fn poll(&mut self, timeout: Duration) -> Vec<Completion> {
        let drained = self.inner.poll(timeout);
        if drained.is_empty() {
            return Vec::new();
        }
        self.epoch += 1;
        let epoch = self.epoch;
        drained
            .into_iter()
            .map(|d| Completion {
                id: d.id,
                config: d.payload,
                status: match d.status {
                    JobStatus::Done(v) => CompletionStatus::Done(v),
                    JobStatus::Failed => CompletionStatus::Failed,
                    JobStatus::Lost(r) => CompletionStatus::Lost(r),
                },
                queue_wait_ms: d.queue_wait_ms,
                eval_ms: d.eval_ms,
                epoch,
            })
            .collect()
    }

    pub(crate) fn in_flight(&self) -> usize {
        self.inner.in_flight()
    }

    pub(crate) fn cancel_pending(&mut self) -> Vec<TaskId> {
        self.inner.cancel_pending()
    }

    pub(crate) fn stats(&self) -> AsyncStats {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamValue;

    fn cfg_i(i: i64) -> Config {
        Config::new(vec![("i".into(), ParamValue::Int(i))])
    }

    fn deliver(id: TaskId, i: i64) -> Task {
        Task {
            id,
            config: cfg_i(i),
            submitted_at: Instant::now(),
            fate: Fate::Deliver { delay: Duration::ZERO },
        }
    }

    #[test]
    fn pool_runs_tasks_and_counts() {
        let objective = |_: TaskId, c: &Config| Some(c.get_i64("i").unwrap() as f64 * 2.0);
        std::thread::scope(|scope| {
            let mut pool = WorkerPool::spawn(scope, &objective, 3);
            for i in 0..10 {
                pool.submit_task(deliver(i, i as i64));
            }
            assert_eq!(pool.in_flight(), 10);
            let mut got = Vec::new();
            while pool.in_flight() > 0 {
                got.extend(pool.poll(Duration::from_secs(10)));
            }
            assert_eq!(got.len(), 10);
            // poll sorts each drain by id; a full drain is checkable per batch
            for c in &got {
                match c.status {
                    CompletionStatus::Done(v) => {
                        assert_eq!(v, c.config.get_i64("i").unwrap() as f64 * 2.0)
                    }
                    other => panic!("unexpected status {other:?}"),
                }
            }
            let stats = pool.stats();
            assert_eq!(stats.submitted, 10);
            assert_eq!(stats.completed, 10);
            assert_eq!(stats.max_in_flight, 10);
        });
    }

    /// The tagged executor sees each job's task id — the substrate the
    /// [`super::super::TrialReporter`] channel keys reports on.
    #[test]
    fn tagged_exec_receives_task_ids() {
        let seen = Mutex::new(Vec::new());
        let objective = |id: TaskId, c: &Config| {
            seen.lock().unwrap().push((id, c.get_i64("i").unwrap()));
            Some(0.0)
        };
        std::thread::scope(|scope| {
            let mut pool = WorkerPool::spawn(scope, &objective, 1);
            for i in 0..4 {
                pool.submit_task(deliver(100 + i, i as i64));
            }
            while pool.in_flight() > 0 {
                pool.poll(Duration::from_secs(10));
            }
        });
        let mut got = seen.into_inner().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![(100, 0), (101, 1), (102, 2), (103, 3)]);
    }

    #[test]
    fn lost_fates_report_explicitly() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let ran = AtomicUsize::new(0);
        let objective = |_: TaskId, _: &Config| {
            ran.fetch_add(1, Ordering::SeqCst);
            Some(1.0)
        };
        std::thread::scope(|scope| {
            let mut pool = WorkerPool::spawn(scope, &objective, 2);
            pool.submit_task(Task {
                id: 0,
                config: cfg_i(0),
                submitted_at: Instant::now(),
                fate: Fate::Crash { delay: Duration::from_millis(1) },
            });
            pool.submit_task(Task {
                id: 1,
                config: cfg_i(1),
                submitted_at: Instant::now(),
                fate: Fate::TimeOut { delay: Duration::from_millis(1) },
            });
            let mut got = Vec::new();
            while pool.in_flight() > 0 {
                got.extend(pool.poll(Duration::from_secs(10)));
            }
            got.sort_by_key(|c| c.id);
            assert_eq!(got[0].status, CompletionStatus::Lost(LossReason::Crashed));
            assert_eq!(got[1].status, CompletionStatus::Lost(LossReason::TimedOut));
            assert_eq!(pool.stats().lost, 2);
        });
        // A fated-to-be-lost job never executes — its reports are dropped
        // at the source, not filtered downstream.
        assert_eq!(ran.load(Ordering::SeqCst), 0, "lost fates must not run the objective");
    }

    #[test]
    fn cancel_pending_withdraws_queued_work() {
        // A single worker stuck on a slow task leaves the rest queued.
        let started = (Mutex::new(false), Condvar::new());
        let objective = |_: TaskId, c: &Config| {
            if c.get_i64("i").unwrap() == 0 {
                *started.0.lock().unwrap() = true;
                started.1.notify_all();
                std::thread::sleep(Duration::from_millis(80));
            }
            Some(1.0)
        };
        std::thread::scope(|scope| {
            let mut pool = WorkerPool::spawn(scope, &objective, 1);
            for i in 0..5 {
                pool.submit_task(deliver(i, i as i64));
            }
            // Block until the worker has claimed task 0 (condvar handshake —
            // no sleep-poll spin), then cancel the rest while it sleeps.
            let (claimed, timeout) = started
                .1
                .wait_timeout_while(started.0.lock().unwrap(), Duration::from_secs(5), |s| !*s)
                .unwrap();
            assert!(!timeout.timed_out(), "worker never claimed task 0");
            drop(claimed);
            let cancelled = pool.cancel_pending();
            assert!(!cancelled.is_empty(), "queued tasks must be cancellable");
            assert!(!cancelled.contains(&0), "running task is not cancellable");
            let mut got = Vec::new();
            while pool.in_flight() > 0 {
                got.extend(pool.poll(Duration::from_secs(10)));
            }
            assert_eq!(got.len() + cancelled.len(), 5);
            assert_eq!(pool.stats().cancelled, cancelled.len() as u64);
        });
    }

    /// The generic core carries non-Config payloads: a range-payload job
    /// (what scoring shards ship) executes and reports through the same
    /// broker/worker/collector path.
    #[test]
    fn generic_pool_carries_arbitrary_payloads() {
        let exec = |r: &(usize, usize)| -> Option<Vec<usize>> { Some((r.0..r.1).collect()) };
        std::thread::scope(|scope| {
            let mut pool: JobPool<(usize, usize), Vec<usize>> = JobPool::spawn(scope, &exec, 2);
            for (id, range) in [(0u64, (0usize, 3usize)), (1, (3, 5)), (2, (5, 5))] {
                pool.submit_job(Job {
                    id,
                    payload: range,
                    submitted_at: Instant::now(),
                    fate: Fate::Deliver { delay: Duration::ZERO },
                });
            }
            let mut got = Vec::new();
            while pool.in_flight() > 0 {
                got.extend(pool.poll(Duration::from_secs(10)));
            }
            got.sort_by_key(|d| d.id);
            assert_eq!(got.len(), 3);
            for d in &got {
                let JobStatus::Done(v) = &d.status else { panic!("job {} not done", d.id) };
                assert_eq!(*v, (d.payload.0..d.payload.1).collect::<Vec<_>>());
            }
            assert_eq!(pool.stats().completed, 3);
        });
    }
}
