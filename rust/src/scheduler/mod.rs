//! Schedulers (paper §2.4): evaluation of configurations, decoupled from
//! the optimizer. Two execution contracts live here:
//!
//! **Batch-synchronous** ([`Scheduler`]) — the paper's original contract:
//! the objective consumes a *batch* and returns `(evals, params)` —
//! out-of-order and **possibly partial** (stragglers and crashed workers
//! simply don't report). [`BatchResult`] encodes exactly that. This is the
//! `mode = "sync"` path and preserves the Fig. 2/3 barrier semantics.
//!
//! **Asynchronous submit/poll** ([`AsyncScheduler`]) — the event-loop
//! contract (Tune/Sherpa-style): `submit` enqueues configurations without
//! blocking, `poll` drains whatever completed, and lost work surfaces as
//! explicit [`CompletionStatus::Lost`] events instead of silent drops. The
//! coordinator keeps a bounded in-flight window full so stragglers never
//! idle the rest of the cluster (`mode = "async"`).
//!
//! Implementations, matching the paper's deployment options:
//!
//! * [`serial::SerialScheduler`] / [`serial::SerialAsyncScheduler`] —
//!   Listing 3: sequential evaluation (the async form is a trivial adapter
//!   that evaluates one queued task per poll).
//! * [`threaded::ThreadedScheduler`] / [`threaded::ThreadedAsyncScheduler`]
//!   — local parallelism ("to use all cores in local machine, threading can
//!   be used"); a persistent worker pool fed through a broker queue +
//!   channels (the sync form is now a submit-then-drain special case).
//! * [`celery::CelerySimScheduler`] / [`celery::CeleryAsyncScheduler`] —
//!   Listing 4's Celery-on-Kubernetes deployment as an in-repo distributed
//!   task-queue simulator: broker queue, worker pool, latency
//!   distributions, stragglers, crashes and result timeouts (DESIGN.md §2).

// Clock-permitted modules (lint rule R1): scheduler telemetry — queue
// waits, eval wall time, result timeouts — reads the clock by design;
// these attributes lift the clippy.toml disallowed-methods backstop that
// enforces R1 everywhere else.
#[allow(clippy::disallowed_methods)]
pub mod celery;
#[allow(clippy::disallowed_methods)]
pub mod pool;
#[allow(clippy::disallowed_methods)]
pub mod serial;
#[allow(clippy::disallowed_methods)]
pub mod threaded;

use crate::space::Config;
use std::sync::Arc;
use std::time::Duration;

/// Per-config objective: `None` = evaluation failed (worker crash, NaN, …).
pub type Objective<'a> = &'a (dyn Fn(&Config) -> Option<f64> + Sync);

/// Identifier the scheduler assigns to each submitted evaluation.
pub type TaskId = u64;

/// Task-id-aware objective — the form the async engines execute. The id
/// tags the evaluation so worker-side machinery (the [`TrialReporter`]
/// channel) can attribute intermediate reports to trials; the coordinator
/// builds this wrapper around the user objective, and plain objectives
/// adapt via `|_, c| f(c)`.
pub type TaskObjective<'a> = &'a (dyn Fn(TaskId, &Config) -> Option<f64> + Sync);

/// Objective with an intermediate-report channel — the form user code
/// writes when it wants trial-level early stopping: call
/// `reporter.report(step, value)` between epochs, and treat a `false`
/// return as "you've been pruned — stop wasting cycles".
pub type TrialObjective<'a> = &'a (dyn Fn(&Config, &TrialReporter) -> Option<f64> + Sync);

/// Receiver side of the intermediate-report channel. The coordinator's
/// pruning state machine implements this; `on_report` returns `false`
/// once the trial has been pruned so cooperative objectives can bail out
/// early instead of training to completion.
pub trait ReportSink: Send + Sync {
    fn on_report(&self, task: TaskId, step: u64, value: f64) -> bool;
}

/// Worker-side handle for streaming intermediate metrics out of a running
/// evaluation. Constructed per task by the coordinator's objective wrapper
/// (async mode) or as [`detached`](Self::detached) (sync mode, `--pruner
/// none`) where reports are accepted and discarded. Fault simulation
/// composes for free: a task whose pre-rolled fate is a crash or timeout
/// never executes the objective, so its reports are dropped; a delivered
/// task's simulated latency delays its reports along with its result.
pub struct TrialReporter {
    task: TaskId,
    sink: Option<Arc<dyn ReportSink>>,
}

impl TrialReporter {
    pub fn new(task: TaskId, sink: Option<Arc<dyn ReportSink>>) -> Self {
        Self { task, sink }
    }

    /// A reporter with no sink: every report is swallowed and answered
    /// `true` (keep going). The `--pruner none` and sync-mode form.
    pub fn detached() -> Self {
        Self { task: 0, sink: None }
    }

    /// Stream one intermediate metric. Returns `true` to continue, `false`
    /// once this trial has been pruned — the objective should then return
    /// promptly (its return value is recorded as the trial's last word
    /// either way; the coordinator journals the completion as `Pruned`).
    pub fn report(&self, step: u64, value: f64) -> bool {
        match &self.sink {
            Some(sink) => sink.on_report(self.task, step, value),
            None => true,
        }
    }
}

/// What a batch evaluation returned — the paper's `(evals, params)` pair.
/// `params[i]` produced `evals[i]`; configs missing from `params` were lost
/// (fault tolerance: the optimizer proceeds with what arrived).
#[derive(Clone, Debug, Default)]
pub struct BatchResult {
    pub evals: Vec<f64>,
    pub params: Vec<Config>,
}

impl BatchResult {
    pub fn len(&self) -> usize {
        self.evals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.evals.is_empty()
    }

    pub fn push(&mut self, cfg: Config, value: f64) {
        self.params.push(cfg);
        self.evals.push(value);
    }
}

/// A batch evaluation engine (the synchronous, barrier-per-batch contract).
pub trait Scheduler {
    /// Evaluate a batch; may return fewer results than configs.
    fn evaluate(&mut self, objective: Objective<'_>, batch: &[Config]) -> BatchResult;

    fn name(&self) -> &'static str;
}

/// Why an evaluation vanished without producing a value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LossReason {
    /// The worker died with the task (OOM-kill, crash).
    Crashed,
    /// The result never arrived before the collector's timeout.
    TimedOut,
}

/// Terminal state of one submitted evaluation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CompletionStatus {
    /// The objective returned a value.
    Done(f64),
    /// The objective ran and declined (`None`) — deterministic, not retried.
    Failed,
    /// The evaluation was lost in flight — the retriable fault class.
    Lost(LossReason),
}

/// One completed (or lost) evaluation, as drained by [`AsyncScheduler::poll`].
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: TaskId,
    pub config: Config,
    pub status: CompletionStatus,
    /// Submit → evaluation start (broker queue + simulated network latency).
    pub queue_wait_ms: f64,
    /// Time spent inside the objective itself.
    pub eval_ms: f64,
    /// Scheduler-side drain counter (1-based): which `poll` drain carried
    /// this completion. Telemetry only — the coordinator's fold order is
    /// governed by its own journaled epoch markers, never by this stamp.
    pub epoch: u64,
}

/// Per-submission metadata for [`AsyncScheduler::submit_with`].
/// `SubmitMeta::default()` is equivalent to plain
/// [`submit`](AsyncScheduler::submit) on every implementation.
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitMeta {
    /// Execution-side delay applied before the task runs (retry backoff).
    /// The worker holds the task for this long first, so task-id
    /// assignment stays submission-ordered regardless of backoff.
    pub backoff: Duration,
    /// Stable-replay fate key: when `Some`, fault-injecting schedulers
    /// (the Celery sim) derive this submission's fate from a fresh RNG
    /// keyed by `seed ^ key` instead of the sequential submission-order
    /// stream, so a resumed run re-rolls the same fate for the same
    /// logical attempt no matter how many submissions the crashed run made
    /// before it. `None` keeps the legacy sequential draw (the
    /// `--replay wallclock` path, byte-identical to plain `submit`).
    pub fate_key: Option<u64>,
}

/// Counters every async scheduler keeps (telemetry + tests).
#[derive(Clone, Debug, Default)]
pub struct AsyncStats {
    pub submitted: u64,
    /// Completions that delivered a value.
    pub completed: u64,
    /// Objective-level failures (`None`).
    pub failed: u64,
    /// Crash/timeout losses surfaced as [`CompletionStatus::Lost`].
    pub lost: u64,
    /// Queued tasks removed by [`AsyncScheduler::cancel_pending`].
    pub cancelled: u64,
    /// High-water mark of concurrently in-flight tasks.
    pub max_in_flight: usize,
}

/// The asynchronous submit/poll evaluation engine.
///
/// Contract:
/// * [`submit`](Self::submit) never blocks on evaluation; it assigns one
///   [`TaskId`] per config (monotonically increasing in submission order).
/// * [`poll`](Self::poll) blocks up to `timeout` for at least one
///   completion, then drains everything ready. Completions are sorted by
///   id; an empty vec means the timeout elapsed (or nothing is in flight).
///   Every submitted task eventually yields exactly one completion —
///   losses arrive as [`CompletionStatus::Lost`], never as silence.
/// * [`in_flight`](Self::in_flight) counts submitted-but-not-yet-polled
///   tasks; [`cancel_pending`](Self::cancel_pending) withdraws work still
///   queued on the broker (already-running tasks are not interrupted).
pub trait AsyncScheduler {
    /// Enqueue configs for evaluation; returns their ids (submission order).
    fn submit(&mut self, configs: &[Config]) -> Vec<TaskId>;

    /// [`submit`](Self::submit) with per-submission metadata (retry
    /// backoff, stable fate keys). The default implementation ignores the
    /// metadata — schedulers with latency or fault models override it.
    fn submit_with(&mut self, configs: &[Config], meta: &SubmitMeta) -> Vec<TaskId> {
        let _ = meta;
        self.submit(configs)
    }

    /// Wait up to `timeout` for completions; drain and return all ready.
    fn poll(&mut self, timeout: Duration) -> Vec<Completion>;

    /// Tasks submitted but not yet returned by `poll`.
    fn in_flight(&self) -> usize;

    /// Withdraw queued (not yet started) tasks; returns the cancelled ids.
    fn cancel_pending(&mut self) -> Vec<TaskId>;

    /// Scheduler-side counters.
    fn stats(&self) -> AsyncStats;

    fn name(&self) -> &'static str;

    /// Block until everything in flight completes (bounded by `timeout`).
    // Clock-permitted (lint rule R1): drain deadline bookkeeping.
    #[allow(clippy::disallowed_methods)]
    fn drain(&mut self, timeout: Duration) -> Vec<Completion> {
        let deadline = std::time::Instant::now() + timeout;
        let mut out = Vec::new();
        while self.in_flight() > 0 {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            out.extend(self.poll(deadline - now));
        }
        out
    }
}

/// Scheduler selection (CLI / config string form).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    Serial,
    Threaded,
    Celery,
}

impl SchedulerKind {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "serial" => Some(Self::Serial),
            "threaded" => Some(Self::Threaded),
            "celery" => Some(Self::Celery),
            _ => None,
        }
    }

    /// Inverse of [`from_str`](Self::from_str) (journal header round trip).
    pub fn as_str(self) -> &'static str {
        match self {
            Self::Serial => "serial",
            Self::Threaded => "threaded",
            Self::Celery => "celery",
        }
    }
}

/// Build a synchronous scheduler by kind with `workers` parallelism.
pub fn build(kind: SchedulerKind, workers: usize, seed: u64) -> Box<dyn Scheduler> {
    build_custom(kind, workers, seed, None)
}

/// [`build`] with an optional Celery fault-model override.
pub fn build_custom(
    kind: SchedulerKind,
    workers: usize,
    seed: u64,
    celery_config: Option<celery::CelerySimConfig>,
) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::Serial => Box::new(serial::SerialScheduler),
        SchedulerKind::Threaded => Box::new(threaded::ThreadedScheduler::new(workers)),
        SchedulerKind::Celery => Box::new(celery::CelerySimScheduler::new(
            celery_config
                .unwrap_or(celery::CelerySimConfig { workers, ..Default::default() }),
            seed,
        )),
    }
}

/// Build an asynchronous scheduler by kind. Pool-backed schedulers spawn
/// their workers on `scope`, borrowing `objective` for the scope's
/// lifetime — the coordinator wraps its event loop in
/// [`std::thread::scope`] so the pool lives exactly as long as the run.
/// The objective is the task-id-aware form ([`TaskObjective`]) so the
/// coordinator can hand each evaluation a [`TrialReporter`] keyed to its
/// task id.
pub fn build_async<'scope, 'env>(
    kind: SchedulerKind,
    workers: usize,
    seed: u64,
    celery_config: Option<celery::CelerySimConfig>,
    scope: &'scope std::thread::Scope<'scope, 'env>,
    objective: TaskObjective<'env>,
) -> Box<dyn AsyncScheduler + 'scope> {
    build_async_from(kind, workers, seed, celery_config, scope, objective, 0)
}

/// [`build_async`] with the scheduler's task-id counter starting at
/// `first_id`: a resumed run passes the crashed run's high-water mark + 1,
/// so task ids stay unique across restarts and journaled telemetry never
/// aliases two distinct evaluations under one id.
pub fn build_async_from<'scope, 'env>(
    kind: SchedulerKind,
    workers: usize,
    seed: u64,
    celery_config: Option<celery::CelerySimConfig>,
    scope: &'scope std::thread::Scope<'scope, 'env>,
    objective: TaskObjective<'env>,
    first_id: TaskId,
) -> Box<dyn AsyncScheduler + 'scope> {
    match kind {
        SchedulerKind::Serial => {
            Box::new(serial::SerialAsyncScheduler::new(objective).with_first_id(first_id))
        }
        SchedulerKind::Threaded => Box::new(threaded::ThreadedAsyncScheduler::spawn_from(
            scope, objective, workers, first_id,
        )),
        SchedulerKind::Celery => {
            let cfg = celery_config
                .unwrap_or(celery::CelerySimConfig { workers, ..Default::default() });
            Box::new(celery::CeleryAsyncScheduler::spawn_from(
                scope, objective, cfg, seed, first_id,
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parsing() {
        assert_eq!(SchedulerKind::from_str("serial"), Some(SchedulerKind::Serial));
        assert_eq!(SchedulerKind::from_str("threaded"), Some(SchedulerKind::Threaded));
        assert_eq!(SchedulerKind::from_str("celery"), Some(SchedulerKind::Celery));
        assert_eq!(SchedulerKind::from_str("slurm"), None);
    }

    #[test]
    fn trial_reporter_routes_to_sink_and_detached_swallows() {
        struct Recorder(std::sync::Mutex<Vec<(TaskId, u64, f64)>>);
        impl ReportSink for Recorder {
            fn on_report(&self, task: TaskId, step: u64, value: f64) -> bool {
                self.0.lock().unwrap().push((task, step, value));
                step < 2 // "pruned" from step 2 on
            }
        }
        let sink = Arc::new(Recorder(std::sync::Mutex::new(Vec::new())));
        let rep = TrialReporter::new(7, Some(sink.clone()));
        assert!(rep.report(1, 0.5));
        assert!(!rep.report(2, 0.25), "sink's false must reach the caller");
        assert_eq!(*sink.0.lock().unwrap(), vec![(7, 1, 0.5), (7, 2, 0.25)]);
        // Detached reporters accept everything and record nothing.
        let det = TrialReporter::detached();
        assert!(det.report(1, 1.0));
        assert!(det.report(999, f64::NAN));
    }

    #[test]
    fn batch_result_push() {
        let mut r = BatchResult::default();
        assert!(r.is_empty());
        r.push(Config::default(), 1.5);
        assert_eq!(r.len(), 1);
        assert_eq!(r.evals[0], 1.5);
    }

    #[test]
    fn build_async_all_kinds_submit_poll() {
        let objective = |_: TaskId, c: &Config| c.get_f64("x");
        let batch = vec![
            Config::new(vec![("x".into(), crate::space::ParamValue::F64(2.0))]),
            Config::new(vec![("x".into(), crate::space::ParamValue::F64(3.0))]),
        ];
        // A fault-free cluster so the Celery run is loss-free by construction.
        let reliable = celery::CelerySimConfig {
            workers: 2,
            base_latency_ms: 0.5,
            straggler_prob: 0.0,
            straggler_factor: 1.0,
            crash_prob: 0.0,
            result_timeout: Duration::from_secs(10),
        };
        for kind in [SchedulerKind::Serial, SchedulerKind::Threaded, SchedulerKind::Celery] {
            std::thread::scope(|scope| {
                let mut s = build_async(kind, 2, 1, Some(reliable.clone()), scope, &objective);
                let ids = s.submit(&batch);
                assert_eq!(ids, vec![0, 1], "{kind:?} ids");
                assert_eq!(s.in_flight(), 2);
                let comps = s.drain(Duration::from_secs(30));
                assert_eq!(comps.len(), 2, "{kind:?} must complete everything");
                assert_eq!(s.in_flight(), 0);
                let mut values: Vec<f64> = comps
                    .iter()
                    .filter_map(|c| match c.status {
                        CompletionStatus::Done(v) => Some(v),
                        _ => None,
                    })
                    .collect();
                values.sort_by(|a, b| a.total_cmp(b));
                assert_eq!(values, vec![2.0, 3.0], "{kind:?} values");
                assert_eq!(s.stats().submitted, 2);
                assert!(
                    comps.iter().all(|c| c.epoch >= 1),
                    "{kind:?} must stamp a 1-based drain epoch"
                );
            });
        }
    }

    #[test]
    fn submit_with_backoff_delays_execution_on_every_kind() {
        let objective = |_: TaskId, _: &Config| Some(1.0);
        let batch = vec![Config::default()];
        let reliable = celery::CelerySimConfig {
            workers: 1,
            base_latency_ms: 0.0,
            straggler_prob: 0.0,
            straggler_factor: 1.0,
            crash_prob: 0.0,
            result_timeout: Duration::from_secs(10),
        };
        for kind in [SchedulerKind::Serial, SchedulerKind::Threaded, SchedulerKind::Celery] {
            std::thread::scope(|scope| {
                let mut s = build_async(kind, 1, 1, Some(reliable.clone()), scope, &objective);
                let meta =
                    SubmitMeta { backoff: Duration::from_millis(40), ..SubmitMeta::default() };
                let t = std::time::Instant::now();
                s.submit_with(&batch, &meta);
                let comps = s.drain(Duration::from_secs(10));
                assert_eq!(comps.len(), 1, "{kind:?}");
                assert!(
                    t.elapsed() >= Duration::from_millis(35),
                    "{kind:?} completed in {:?} — backoff not applied",
                    t.elapsed()
                );
            });
        }
    }
}
