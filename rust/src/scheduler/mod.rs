//! Schedulers (paper §2.4): evaluation of configuration batches, decoupled
//! from the optimizer.
//!
//! The paper's contract: the objective consumes a *batch* and returns
//! `(evals, params)` — out-of-order and **possibly partial** (stragglers and
//! crashed workers simply don't report). [`BatchResult`] encodes exactly
//! that; every scheduler and the coordinator honour it.
//!
//! * [`serial::SerialScheduler`] — Listing 3: sequential evaluation.
//! * [`threaded::ThreadedScheduler`] — local parallelism ("to use all cores
//!   in local machine, threading can be used").
//! * [`celery::CelerySimScheduler`] — Listing 4's Celery-on-Kubernetes
//!   deployment as an in-repo distributed task-queue simulator: broker
//!   queue, worker pool, latency distributions, stragglers, crashes and
//!   result timeouts (DESIGN.md §2).

pub mod celery;
pub mod serial;
pub mod threaded;

use crate::space::Config;

/// Per-config objective: `None` = evaluation failed (worker crash, NaN, …).
pub type Objective<'a> = &'a (dyn Fn(&Config) -> Option<f64> + Sync);

/// What a batch evaluation returned — the paper's `(evals, params)` pair.
/// `params[i]` produced `evals[i]`; configs missing from `params` were lost
/// (fault tolerance: the optimizer proceeds with what arrived).
#[derive(Clone, Debug, Default)]
pub struct BatchResult {
    pub evals: Vec<f64>,
    pub params: Vec<Config>,
}

impl BatchResult {
    pub fn len(&self) -> usize {
        self.evals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.evals.is_empty()
    }

    pub fn push(&mut self, cfg: Config, value: f64) {
        self.params.push(cfg);
        self.evals.push(value);
    }
}

/// A batch evaluation engine.
pub trait Scheduler {
    /// Evaluate a batch; may return fewer results than configs.
    fn evaluate(&mut self, objective: Objective<'_>, batch: &[Config]) -> BatchResult;

    fn name(&self) -> &'static str;
}

/// Scheduler selection (CLI / config string form).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulerKind {
    Serial,
    Threaded,
    Celery,
}

impl SchedulerKind {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "serial" => Some(Self::Serial),
            "threaded" => Some(Self::Threaded),
            "celery" => Some(Self::Celery),
            _ => None,
        }
    }
}

/// Build a scheduler by kind with `workers` parallelism.
pub fn build(kind: SchedulerKind, workers: usize, seed: u64) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::Serial => Box::new(serial::SerialScheduler),
        SchedulerKind::Threaded => Box::new(threaded::ThreadedScheduler::new(workers)),
        SchedulerKind::Celery => Box::new(celery::CelerySimScheduler::new(
            celery::CelerySimConfig { workers, ..Default::default() },
            seed,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parsing() {
        assert_eq!(SchedulerKind::from_str("serial"), Some(SchedulerKind::Serial));
        assert_eq!(SchedulerKind::from_str("threaded"), Some(SchedulerKind::Threaded));
        assert_eq!(SchedulerKind::from_str("celery"), Some(SchedulerKind::Celery));
        assert_eq!(SchedulerKind::from_str("slurm"), None);
    }

    #[test]
    fn batch_result_push() {
        let mut r = BatchResult::default();
        assert!(r.is_empty());
        r.push(Config::default(), 1.5);
        assert_eq!(r.len(), 1);
        assert_eq!(r.evals[0], 1.5);
    }
}
