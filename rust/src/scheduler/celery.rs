//! Celery-like distributed task-queue simulator (paper Listing 4 +
//! DESIGN.md §2 substitution for the Celery/Kubernetes deployment).
//!
//! Architecture mirrors a Celery deployment:
//! * a **broker** queue of tasks (`delay(par)` in Listing 4),
//! * N **worker** threads pulling tasks, each with simulated network/queue
//!   latency, straggler slowdowns, and crash probability,
//! * a **collector** (`process.get()`) that gathers results until all
//!   surviving tasks report or the result timeout expires.
//!
//! Crashed and timed-out tasks never report — the scheduler returns the
//! partial `(evals, params)` the paper's fault-tolerance contract expects.

use super::{BatchResult, Objective, Scheduler};
use crate::space::Config;
use crate::util::rng::Pcg64;
use std::collections::VecDeque;
use std::sync::{mpsc, Mutex};
use std::time::Duration;

/// Fault/latency model for the simulated cluster.
#[derive(Clone, Debug)]
pub struct CelerySimConfig {
    pub workers: usize,
    /// Mean queue+network latency added to each task (ms).
    pub base_latency_ms: f64,
    /// Probability a task lands on a straggler worker…
    pub straggler_prob: f64,
    /// …which multiplies its latency by this factor.
    pub straggler_factor: f64,
    /// Probability a task is lost (worker crash / OOM-kill): never reports.
    pub crash_prob: f64,
    /// Collector gives up on missing results after this long.
    pub result_timeout: Duration,
}

impl Default for CelerySimConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            base_latency_ms: 2.0,
            straggler_prob: 0.05,
            straggler_factor: 8.0,
            crash_prob: 0.02,
            result_timeout: Duration::from_secs(5),
        }
    }
}

/// Counters exposed for tests and the metrics report.
#[derive(Clone, Debug, Default)]
pub struct CeleryStats {
    pub submitted: u64,
    pub completed: u64,
    pub crashed: u64,
    pub straggled: u64,
    pub timed_out: u64,
}

pub struct CelerySimScheduler {
    config: CelerySimConfig,
    rng: Pcg64,
    pub stats: CeleryStats,
}

impl CelerySimScheduler {
    pub fn new(config: CelerySimConfig, seed: u64) -> Self {
        Self { config, rng: Pcg64::new(seed ^ 0xCE1E_27), stats: CeleryStats::default() }
    }
}

/// A task on the broker: index + pre-rolled fate (determinism: fates are
/// drawn from the scheduler RNG at submit time, like task routing).
struct Task {
    index: usize,
    crash: bool,
    latency: Duration,
}

impl Scheduler for CelerySimScheduler {
    fn evaluate(&mut self, objective: Objective<'_>, batch: &[Config]) -> BatchResult {
        let cfg = self.config.clone();
        let workers = cfg.workers.min(batch.len()).max(1);

        // Submit: roll each task's fate, enqueue on the broker.
        let mut queue = VecDeque::with_capacity(batch.len());
        for (index, _) in batch.iter().enumerate() {
            let crash = self.rng.next_f64() < cfg.crash_prob;
            let straggle = self.rng.next_f64() < cfg.straggler_prob;
            let mult = if straggle { cfg.straggler_factor } else { 1.0 };
            // exponential-ish latency: -ln(u) * mean
            let lat_ms = -self.rng.next_f64().max(1e-12).ln() * cfg.base_latency_ms * mult;
            self.stats.submitted += 1;
            if crash {
                self.stats.crashed += 1;
            }
            if straggle {
                self.stats.straggled += 1;
            }
            queue.push_back(Task { index, crash, latency: Duration::from_secs_f64(lat_ms / 1e3) });
        }
        let expected = batch.len() - queue.iter().filter(|t| t.crash).count();
        let broker = Mutex::new(queue);
        let (tx, rx) = mpsc::channel::<(usize, Option<f64>)>();

        let mut out = BatchResult::default();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let tx = tx.clone();
                let broker = &broker;
                scope.spawn(move || loop {
                    let task = { broker.lock().unwrap().pop_front() };
                    let Some(task) = task else { break };
                    std::thread::sleep(task.latency);
                    if task.crash {
                        continue; // worker dies with the task: no report
                    }
                    let v = objective(&batch[task.index]);
                    if tx.send((task.index, v)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            // Collector: gather until all surviving tasks report or timeout.
            let deadline = std::time::Instant::now() + cfg.result_timeout;
            let mut received = 0;
            while received < expected {
                let now = std::time::Instant::now();
                if now >= deadline {
                    self.stats.timed_out += (expected - received) as u64;
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok((i, Some(v))) => {
                        received += 1;
                        self.stats.completed += 1;
                        out.push(batch[i].clone(), v);
                    }
                    Ok((_, None)) => received += 1, // objective-level failure
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        self.stats.timed_out += (expected - received) as u64;
                        break;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        });
        out
    }

    fn name(&self) -> &'static str {
        "celery"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamValue;

    fn batch_of(n: usize) -> Vec<Config> {
        (0..n)
            .map(|i| Config::new(vec![("i".into(), ParamValue::Int(i as i64))]))
            .collect()
    }

    fn reliable_config(workers: usize) -> CelerySimConfig {
        CelerySimConfig {
            workers,
            base_latency_ms: 0.5,
            straggler_prob: 0.0,
            straggler_factor: 1.0,
            crash_prob: 0.0,
            result_timeout: Duration::from_secs(10),
        }
    }

    #[test]
    fn reliable_cluster_returns_everything() {
        let mut s = CelerySimScheduler::new(reliable_config(4), 1);
        let res = s.evaluate(&|c| Some(c.get_i64("i").unwrap() as f64), &batch_of(20));
        assert_eq!(res.len(), 20);
        assert_eq!(s.stats.completed, 20);
        assert_eq!(s.stats.crashed, 0);
        // params/evals stay aligned even out-of-order
        for (cfg, v) in res.params.iter().zip(&res.evals) {
            assert_eq!(*v, cfg.get_i64("i").unwrap() as f64);
        }
    }

    #[test]
    fn crashes_produce_partial_results() {
        let mut cfg = reliable_config(4);
        cfg.crash_prob = 0.5;
        let mut s = CelerySimScheduler::new(cfg, 7);
        let res = s.evaluate(&|c| Some(c.get_i64("i").unwrap() as f64), &batch_of(40));
        assert!(res.len() < 40, "some tasks must be lost");
        assert!(!res.is_empty(), "but not all");
        assert_eq!(res.len() as u64, s.stats.completed);
        assert_eq!(s.stats.crashed, 40 - res.len() as u64);
    }

    #[test]
    fn stragglers_hit_the_timeout() {
        let cfg = CelerySimConfig {
            workers: 2,
            base_latency_ms: 1.0,
            straggler_prob: 1.0, // every task straggles…
            straggler_factor: 400.0,
            crash_prob: 0.0,
            result_timeout: Duration::from_millis(60),
        };
        let mut s = CelerySimScheduler::new(cfg, 3);
        let res = s.evaluate(&|c| Some(c.get_i64("i").unwrap() as f64), &batch_of(12));
        assert!(res.len() < 12, "timeout must cut off stragglers, got {}", res.len());
        assert!(s.stats.timed_out > 0);
    }

    #[test]
    fn deterministic_fates_per_seed() {
        let mut cfg = reliable_config(3);
        cfg.crash_prob = 0.3;
        let run = |seed: u64| {
            let mut s = CelerySimScheduler::new(cfg.clone(), seed);
            let r = s.evaluate(&|c| Some(c.get_i64("i").unwrap() as f64), &batch_of(30));
            let mut ids: Vec<i64> =
                r.params.iter().map(|c| c.get_i64("i").unwrap()).collect();
            ids.sort_unstable();
            ids
        };
        assert_eq!(run(5), run(5), "same seed, same surviving set");
    }
}
