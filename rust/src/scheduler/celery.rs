//! Celery-like distributed task-queue simulator (paper Listing 4 +
//! DESIGN.md §2 substitution for the Celery/Kubernetes deployment).
//!
//! Architecture mirrors a Celery deployment:
//! * a **broker** queue of tasks (`delay(par)` in Listing 4),
//! * N **worker** threads pulling tasks, each with simulated network/queue
//!   latency, straggler slowdowns, and crash probability,
//! * a **collector** (`process.get()`) that gathers results until all
//!   surviving tasks report or the result timeout expires.
//!
//! Two frontends share the fault model:
//! * [`CelerySimScheduler`] — the batch-synchronous form: crashed and
//!   timed-out tasks never report, the scheduler returns the partial
//!   `(evals, params)` the paper's fault-tolerance contract expects.
//! * [`CeleryAsyncScheduler`] — the submit/poll form over the persistent
//!   pool ([`super::pool`]): the same pre-rolled fates, but losses surface
//!   as explicit [`super::CompletionStatus::Lost`] events (crash vs
//!   timeout), so the coordinator's event loop can retry them.

use super::pool::{Fate, Task as PoolTask, WorkerPool};
use super::{
    AsyncScheduler, AsyncStats, BatchResult, Completion, Objective, Scheduler, SubmitMeta,
    TaskId, TaskObjective,
};
use crate::config::json::Json;
use crate::space::{f64_from_json, f64_to_json, Config};
use crate::util::rng::Pcg64;
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

/// Fault/latency model for the simulated cluster.
#[derive(Clone, Debug, PartialEq)]
pub struct CelerySimConfig {
    pub workers: usize,
    /// Mean queue+network latency added to each task (ms).
    pub base_latency_ms: f64,
    /// Probability a task lands on a straggler worker…
    pub straggler_prob: f64,
    /// …which multiplies its latency by this factor.
    pub straggler_factor: f64,
    /// Probability a task is lost (worker crash / OOM-kill): never reports.
    pub crash_prob: f64,
    /// Collector gives up on missing results after this long.
    pub result_timeout: Duration,
}

impl Default for CelerySimConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            base_latency_ms: 2.0,
            straggler_prob: 0.05,
            straggler_factor: 8.0,
            crash_prob: 0.02,
            result_timeout: Duration::from_secs(5),
        }
    }
}

/// One pre-rolled fate plus the straggle flag (the flag is a stats-only
/// detail [`Fate`] cannot carry: a straggler that also crashes still
/// counts as straggled).
pub(crate) struct RolledFate {
    pub fate: Fate,
    pub straggled: bool,
}

/// One raw fault-model draw: the crash/straggle outcomes and the task's
/// full simulated latency, before any mapping onto pool [`Fate`]s. The
/// sync collector consumes the raw form — its workers sleep the full
/// straggler latency and the *collector* enforces the timeout.
pub(crate) struct RawDraw {
    pub crash: bool,
    pub straggled: bool,
    pub latency: Duration,
}

impl CelerySimConfig {
    /// Journal-header encoding of the fault model, so a resumed run
    /// re-applies the exact simulator the crashed run used instead of
    /// silently reverting to defaults. Float fields ride the canonical
    /// bit-exact codec ([`f64_to_json`]); the timeout splits into exact
    /// integer seconds + subsecond nanos (both exactly representable).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workers", Json::Num(self.workers as f64)),
            ("base_latency_ms", f64_to_json(self.base_latency_ms)),
            ("straggler_prob", f64_to_json(self.straggler_prob)),
            ("straggler_factor", f64_to_json(self.straggler_factor)),
            ("crash_prob", f64_to_json(self.crash_prob)),
            ("result_timeout_s", Json::Num(self.result_timeout.as_secs() as f64)),
            (
                "result_timeout_subsec_ns",
                Json::Num(self.result_timeout.subsec_nanos() as f64),
            ),
        ])
    }

    /// Decode [`to_json`](Self::to_json)'s encoding. Corrupted counter
    /// fields fail loudly (the journal reader's posture) instead of
    /// truncating into a silently different fault model.
    pub fn from_json(j: &Json) -> Result<Self> {
        let f = |k: &str| -> Result<f64> {
            f64_from_json(j.get(k).ok_or_else(|| anyhow!("celery config missing '{k}'"))?)
        };
        let int = |k: &str| -> Result<u64> {
            let n = j
                .get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("celery config missing number '{k}'"))?;
            anyhow::ensure!(
                n.fract() == 0.0 && (0.0..=9_007_199_254_740_992.0).contains(&n),
                "celery config field '{k}' is not a valid non-negative integer: {n}"
            );
            Ok(n as u64)
        };
        let subsec = int("result_timeout_subsec_ns")?;
        anyhow::ensure!(subsec < 1_000_000_000, "subsecond nanos out of range: {subsec}");
        Ok(Self {
            workers: int("workers")? as usize,
            base_latency_ms: f("base_latency_ms")?,
            straggler_prob: f("straggler_prob")?,
            straggler_factor: f("straggler_factor")?,
            crash_prob: f("crash_prob")?,
            result_timeout: Duration::new(int("result_timeout_s")?, subsec as u32),
        })
    }

    /// The **single copy** of the fault-model draw (crash, straggle,
    /// latency, in that order) — shared by the sync collector, the async
    /// evaluation scheduler, and the propose-time scoring shards
    /// ([`crate::gp::acquire_sharded`]), so one seed yields one fault
    /// sequence per consumer stream and the model can never drift apart
    /// between the paths.
    pub(crate) fn roll_raw(&self, rng: &mut Pcg64) -> RawDraw {
        let crash = rng.next_f64() < self.crash_prob;
        let straggled = rng.next_f64() < self.straggler_prob;
        let mult = if straggled { self.straggler_factor } else { 1.0 };
        // exponential-ish latency: -ln(u) * mean
        let lat_ms = -rng.next_f64().max(1e-12).ln() * self.base_latency_ms * mult;
        RawDraw { crash, straggled, latency: Duration::from_secs_f64(lat_ms / 1e3) }
    }

    /// [`roll_raw`](Self::roll_raw) mapped onto a pool [`Fate`] — the
    /// async and scoring-shard form: delays are clamped to the result
    /// timeout because the pool worker itself plays the collector's
    /// patience.
    pub(crate) fn roll_fate(&self, rng: &mut Pcg64) -> RolledFate {
        let raw = self.roll_raw(rng);
        let fate = if raw.crash {
            // A crash is noticed at the collector's timeout at the latest.
            Fate::Crash { delay: raw.latency.min(self.result_timeout) }
        } else if raw.latency > self.result_timeout {
            Fate::TimeOut { delay: self.result_timeout }
        } else {
            Fate::Deliver { delay: raw.latency }
        };
        RolledFate { fate, straggled: raw.straggled }
    }
}

/// Counters exposed for tests and the metrics report.
#[derive(Clone, Debug, Default)]
pub struct CeleryStats {
    pub submitted: u64,
    pub completed: u64,
    pub crashed: u64,
    pub straggled: u64,
    pub timed_out: u64,
}

pub struct CelerySimScheduler {
    config: CelerySimConfig,
    rng: Pcg64,
    pub stats: CeleryStats,
}

impl CelerySimScheduler {
    pub fn new(config: CelerySimConfig, seed: u64) -> Self {
        Self { config, rng: Pcg64::new(seed ^ 0xCE1E_27), stats: CeleryStats::default() }
    }
}

/// A task on the broker: index + pre-rolled fate (determinism: fates are
/// drawn from the scheduler RNG at submit time, like task routing).
struct Task {
    index: usize,
    crash: bool,
    latency: Duration,
}

impl Scheduler for CelerySimScheduler {
    fn evaluate(&mut self, objective: Objective<'_>, batch: &[Config]) -> BatchResult {
        let cfg = self.config.clone();
        let workers = cfg.workers.min(batch.len()).max(1);

        // Submit: roll each task's fate (the shared fault-model draw),
        // enqueue on the broker.
        let mut queue = VecDeque::with_capacity(batch.len());
        for (index, _) in batch.iter().enumerate() {
            let raw = cfg.roll_raw(&mut self.rng);
            self.stats.submitted += 1;
            if raw.crash {
                self.stats.crashed += 1;
            }
            if raw.straggled {
                self.stats.straggled += 1;
            }
            queue.push_back(Task { index, crash: raw.crash, latency: raw.latency });
        }
        let expected = batch.len() - queue.iter().filter(|t| t.crash).count();
        let broker = Mutex::new(queue);
        let (tx, rx) = mpsc::channel::<(usize, Option<f64>)>();

        let mut out = BatchResult::default();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let tx = tx.clone();
                let broker = &broker;
                scope.spawn(move || loop {
                    // pallas-lint: allow(R6, "broker poisoning means a sibling sim-worker panicked; re-panicking lets the scope join report it")
                    let task = { broker.lock().unwrap().pop_front() };
                    let Some(task) = task else { break };
                    std::thread::sleep(task.latency);
                    if task.crash {
                        continue; // worker dies with the task: no report
                    }
                    let v = objective(&batch[task.index]);
                    if tx.send((task.index, v)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            // Collector: gather until all surviving tasks report or timeout.
            let deadline = std::time::Instant::now() + cfg.result_timeout;
            let mut received = 0;
            while received < expected {
                let now = std::time::Instant::now();
                if now >= deadline {
                    self.stats.timed_out += (expected - received) as u64;
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok((i, Some(v))) => {
                        received += 1;
                        self.stats.completed += 1;
                        out.push(batch[i].clone(), v);
                    }
                    Ok((_, None)) => received += 1, // objective-level failure
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        self.stats.timed_out += (expected - received) as u64;
                        break;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        });
        out
    }

    fn name(&self) -> &'static str {
        "celery"
    }
}

/// Submit/poll frontend over the simulated cluster: a persistent worker
/// pool with per-task fates pre-rolled at submit time (determinism: fates
/// are drawn from the scheduler RNG in submission order, like task
/// routing). Crashes report `Lost(Crashed)` after their latency; tasks
/// whose latency exceeds the result timeout report `Lost(TimedOut)` at the
/// timeout — nothing is silently dropped.
pub struct CeleryAsyncScheduler {
    pool: WorkerPool,
    config: CelerySimConfig,
    rng: Pcg64,
    /// The raw user seed, kept alongside the sequential `rng` so keyed
    /// fate draws ([`SubmitMeta::fate_key`]) can spin up a fresh
    /// per-attempt stream from it.
    seed: u64,
    next_id: TaskId,
    /// Celery-specific fault counters (submit-side: fates are pre-rolled).
    pub sim_stats: CeleryStats,
}

impl CeleryAsyncScheduler {
    pub fn spawn<'scope, 'env>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        objective: TaskObjective<'env>,
        config: CelerySimConfig,
        seed: u64,
    ) -> Self {
        Self::spawn_from(scope, objective, config, seed, 0)
    }

    /// [`spawn`](Self::spawn) with the task-id counter starting at
    /// `first_id` (resumed runs continue the crashed run's id sequence).
    /// Fates are still re-rolled from `seed` in submission order — the
    /// simulator models a fresh cluster after the coordinator restart, not
    /// a replay of the old cluster's fault schedule.
    pub fn spawn_from<'scope, 'env>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        objective: TaskObjective<'env>,
        config: CelerySimConfig,
        seed: u64,
        first_id: TaskId,
    ) -> Self {
        let workers = config.workers.max(1);
        Self {
            pool: WorkerPool::spawn(scope, objective, workers),
            config,
            rng: Pcg64::new(seed ^ 0xCE1E_27),
            seed,
            next_id: first_id,
            sim_stats: CeleryStats::default(),
        }
    }

    /// Record one rolled fate in the submit-side fault counters.
    fn count_fate(&mut self, rolled: &RolledFate) {
        self.sim_stats.submitted += 1;
        if rolled.straggled {
            self.sim_stats.straggled += 1;
        }
        match rolled.fate {
            Fate::Crash { .. } => self.sim_stats.crashed += 1,
            Fate::TimeOut { .. } => self.sim_stats.timed_out += 1,
            Fate::Deliver { .. } => {}
        }
    }

    /// Roll one task's fate — same draw order as the sync collector
    /// (crash, straggle, latency; the shared
    /// [`CelerySimConfig::roll_fate`]) so a given seed yields the same
    /// fault sequence in both modes.
    fn roll_fate(&mut self) -> Fate {
        let rolled = self.config.roll_fate(&mut self.rng);
        self.count_fate(&rolled);
        rolled.fate
    }

    /// Keyed fate draw for `--replay stable`: a fresh RNG per logical
    /// attempt (`seed ^ key`), so a resumed run re-rolls the same fate
    /// for the same (proposal, attempt) no matter how many submissions
    /// the crashed run made before it. The draw order inside the stream
    /// is the shared fault model's (crash, straggle, latency).
    fn roll_fate_keyed(&mut self, key: u64) -> Fate {
        let mut rng =
            Pcg64::new(self.seed ^ 0xCE1E_27 ^ key.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let rolled = self.config.roll_fate(&mut rng);
        self.count_fate(&rolled);
        rolled.fate
    }
}

impl AsyncScheduler for CeleryAsyncScheduler {
    fn submit(&mut self, configs: &[Config]) -> Vec<TaskId> {
        self.submit_with(configs, &SubmitMeta::default())
    }

    fn submit_with(&mut self, configs: &[Config], meta: &SubmitMeta) -> Vec<TaskId> {
        configs
            .iter()
            .enumerate()
            .map(|(i, cfg)| {
                let fate = match meta.fate_key {
                    Some(key) => self.roll_fate_keyed(key.wrapping_add(i as u64)),
                    None => self.roll_fate(),
                };
                // Retry backoff delays the fate's own latency: a delivered
                // or crashing task is noticed that much later. A timeout
                // already reports at the collector's full patience.
                let fate = match fate {
                    Fate::Deliver { delay } => Fate::Deliver { delay: delay + meta.backoff },
                    Fate::Crash { delay } => Fate::Crash { delay: delay + meta.backoff },
                    Fate::TimeOut { delay } => Fate::TimeOut { delay },
                };
                let id = self.next_id;
                self.next_id += 1;
                self.pool.submit_task(PoolTask {
                    id,
                    config: cfg.clone(),
                    submitted_at: Instant::now(),
                    fate,
                });
                id
            })
            .collect()
    }

    fn poll(&mut self, timeout: Duration) -> Vec<Completion> {
        let out = self.pool.poll(timeout);
        self.sim_stats.completed = self.pool.stats().completed;
        out
    }

    fn in_flight(&self) -> usize {
        self.pool.in_flight()
    }

    fn cancel_pending(&mut self) -> Vec<TaskId> {
        self.pool.cancel_pending()
    }

    fn stats(&self) -> AsyncStats {
        self.pool.stats()
    }

    fn name(&self) -> &'static str {
        "celery-async"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamValue;

    fn batch_of(n: usize) -> Vec<Config> {
        (0..n)
            .map(|i| Config::new(vec![("i".into(), ParamValue::Int(i as i64))]))
            .collect()
    }

    fn reliable_config(workers: usize) -> CelerySimConfig {
        CelerySimConfig {
            workers,
            base_latency_ms: 0.5,
            straggler_prob: 0.0,
            straggler_factor: 1.0,
            crash_prob: 0.0,
            result_timeout: Duration::from_secs(10),
        }
    }

    #[test]
    fn sim_config_json_roundtrip_is_exact() {
        let cfg = CelerySimConfig {
            workers: 7,
            base_latency_ms: 0.125,
            straggler_prob: 0.05,
            straggler_factor: 8.5,
            crash_prob: 0.02,
            result_timeout: Duration::new(3, 250_000_001),
        };
        let text = cfg.to_json().to_string();
        let back =
            CelerySimConfig::from_json(&crate::config::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, cfg, "via {text}");
        assert_eq!(back.to_json().to_string(), text, "re-serialization differs");
        // Defaults round-trip too (the header records them verbatim).
        let d = CelerySimConfig::default();
        let back = CelerySimConfig::from_json(
            &crate::config::json::parse(&d.to_json().to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(back, d);
        // Corrupted counters fail loudly.
        for bad in [
            r#"{"workers":-1,"base_latency_ms":1,"straggler_prob":0,"straggler_factor":1,"crash_prob":0,"result_timeout_s":1,"result_timeout_subsec_ns":0}"#,
            r#"{"workers":2,"base_latency_ms":1,"straggler_prob":0,"straggler_factor":1,"crash_prob":0,"result_timeout_s":1.5,"result_timeout_subsec_ns":0}"#,
            r#"{"workers":2,"base_latency_ms":1,"straggler_prob":0,"straggler_factor":1,"crash_prob":0,"result_timeout_s":1,"result_timeout_subsec_ns":2000000000}"#,
            r#"{"workers":2}"#,
        ] {
            let j = crate::config::json::parse(bad).unwrap();
            assert!(CelerySimConfig::from_json(&j).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn reliable_cluster_returns_everything() {
        let mut s = CelerySimScheduler::new(reliable_config(4), 1);
        let res = s.evaluate(&|c| Some(c.get_i64("i").unwrap() as f64), &batch_of(20));
        assert_eq!(res.len(), 20);
        assert_eq!(s.stats.completed, 20);
        assert_eq!(s.stats.crashed, 0);
        // params/evals stay aligned even out-of-order
        for (cfg, v) in res.params.iter().zip(&res.evals) {
            assert_eq!(*v, cfg.get_i64("i").unwrap() as f64);
        }
    }

    #[test]
    fn crashes_produce_partial_results() {
        let mut cfg = reliable_config(4);
        cfg.crash_prob = 0.5;
        let mut s = CelerySimScheduler::new(cfg, 7);
        let res = s.evaluate(&|c| Some(c.get_i64("i").unwrap() as f64), &batch_of(40));
        assert!(res.len() < 40, "some tasks must be lost");
        assert!(!res.is_empty(), "but not all");
        assert_eq!(res.len() as u64, s.stats.completed);
        assert_eq!(s.stats.crashed, 40 - res.len() as u64);
    }

    #[test]
    fn stragglers_hit_the_timeout() {
        let cfg = CelerySimConfig {
            workers: 2,
            base_latency_ms: 1.0,
            straggler_prob: 1.0, // every task straggles…
            straggler_factor: 400.0,
            crash_prob: 0.0,
            result_timeout: Duration::from_millis(60),
        };
        let mut s = CelerySimScheduler::new(cfg, 3);
        let res = s.evaluate(&|c| Some(c.get_i64("i").unwrap() as f64), &batch_of(12));
        assert!(res.len() < 12, "timeout must cut off stragglers, got {}", res.len());
        assert!(s.stats.timed_out > 0);
    }

    #[test]
    fn deterministic_fates_per_seed() {
        let mut cfg = reliable_config(3);
        cfg.crash_prob = 0.3;
        let run = |seed: u64| {
            let mut s = CelerySimScheduler::new(cfg.clone(), seed);
            let r = s.evaluate(&|c| Some(c.get_i64("i").unwrap() as f64), &batch_of(30));
            let mut ids: Vec<i64> =
                r.params.iter().map(|c| c.get_i64("i").unwrap()).collect();
            ids.sort_unstable();
            ids
        };
        assert_eq!(run(5), run(5), "same seed, same surviving set");
    }

    #[test]
    fn async_losses_are_explicit_events() {
        use crate::scheduler::{CompletionStatus, LossReason};
        let mut cfg = reliable_config(4);
        cfg.crash_prob = 0.5;
        let objective = |_: TaskId, c: &Config| Some(c.get_i64("i").unwrap() as f64);
        std::thread::scope(|scope| {
            let mut s = CeleryAsyncScheduler::spawn(scope, &objective, cfg, 7);
            s.submit(&batch_of(40));
            let comps = s.drain(Duration::from_secs(30));
            // Every submission reports — losses as events, not silence.
            assert_eq!(comps.len(), 40);
            let lost = comps
                .iter()
                .filter(|c| matches!(c.status, CompletionStatus::Lost(LossReason::Crashed)))
                .count();
            assert!(lost > 0, "fault injection must fire");
            assert!(lost < 40, "but not everything");
            assert_eq!(s.sim_stats.crashed, lost as u64);
            assert_eq!(s.stats().lost, lost as u64);
            assert_eq!(s.stats().completed, 40 - lost as u64);
        });
    }

    #[test]
    fn async_stragglers_time_out_without_blocking() {
        use crate::scheduler::{CompletionStatus, LossReason};
        let cfg = CelerySimConfig {
            workers: 4,
            base_latency_ms: 1.0,
            straggler_prob: 0.5,
            straggler_factor: 400.0,
            crash_prob: 0.0,
            result_timeout: Duration::from_millis(50),
        };
        let objective = |_: TaskId, c: &Config| Some(c.get_i64("i").unwrap() as f64);
        std::thread::scope(|scope| {
            let mut s = CeleryAsyncScheduler::spawn(scope, &objective, cfg, 3);
            let t = Instant::now();
            s.submit(&batch_of(12));
            let comps = s.drain(Duration::from_secs(30));
            assert_eq!(comps.len(), 12);
            let timed_out = comps
                .iter()
                .filter(|c| matches!(c.status, CompletionStatus::Lost(LossReason::TimedOut)))
                .count();
            assert!(timed_out > 0, "with p=0.5 over 12 tasks some must straggle");
            assert_eq!(s.sim_stats.timed_out, timed_out as u64);
            // Timed-out tasks report at the timeout, not at their 400x latency.
            assert!(t.elapsed() < Duration::from_secs(5), "took {:?}", t.elapsed());
        });
    }

    #[test]
    fn keyed_fates_ignore_submission_history() {
        // The stable-replay contract: the same fate key re-rolls the same
        // fate regardless of how many sequential draws preceded it.
        let mut cfg = reliable_config(2);
        cfg.crash_prob = 0.5;
        let objective = |_: TaskId, c: &Config| Some(c.get_i64("i").unwrap() as f64);
        let fates = |burn: usize| {
            std::thread::scope(|scope| {
                let mut s = CeleryAsyncScheduler::spawn(scope, &objective, cfg.clone(), 11);
                for _ in 0..burn {
                    s.roll_fate(); // consume the sequential stream
                }
                (0..16u64)
                    .map(|k| matches!(s.roll_fate_keyed(k), Fate::Crash { .. }))
                    .collect::<Vec<_>>()
            })
        };
        let baseline = fates(0);
        assert_eq!(baseline, fates(5), "keyed draws must not depend on prior submissions");
        assert!(baseline.iter().any(|c| *c), "p=0.5 over 16 keys must crash at least once");
        assert!(!baseline.iter().all(|c| *c), "…but not every one");
    }

    #[test]
    fn async_fates_deterministic_per_seed() {
        let mut cfg = reliable_config(3);
        cfg.crash_prob = 0.3;
        let objective = |_: TaskId, c: &Config| Some(c.get_i64("i").unwrap() as f64);
        let run = |seed: u64| {
            std::thread::scope(|scope| {
                let mut s = CeleryAsyncScheduler::spawn(scope, &objective, cfg.clone(), seed);
                s.submit(&batch_of(30));
                let comps = s.drain(Duration::from_secs(30));
                let mut done: Vec<i64> = comps
                    .iter()
                    .filter(|c| matches!(c.status, crate::scheduler::CompletionStatus::Done(_)))
                    .map(|c| c.config.get_i64("i").unwrap())
                    .collect();
                done.sort_unstable();
                done
            })
        };
        assert_eq!(run(5), run(5), "same seed, same surviving set");
    }
}
