//! The R1–R6 contract rules: what is scanned for, and where.
//!
//! Scopes are path prefixes relative to the source root (`rust/src`), so
//! rules track module boundaries, not syntax. The ROADMAP contracts these
//! encode:
//!
//! * **R1 wall-clock purity** — propose/persist/replay arithmetic must be
//!   a pure function of (history, seed). Clock reads live only in
//!   scheduler/coordinator telemetry and `util/timer.rs`.
//! * **R2 NaN-safe ordering** — `partial_cmp().unwrap()` panics on NaN,
//!   which is reachable from user objectives; f64 sorts go through
//!   `total_cmp` / `stats::nan_as_worst` (the PR 2 sweep).
//! * **R3 deterministic iteration** — hash-order iteration in a decision
//!   path silently breaks seed-replay bit-identity. Decision-path modules
//!   use `BTreeMap`/`Vec`, or prove a hash container lookup-only with a
//!   pragma.
//! * **R4 seeded randomness only** — every draw flows from
//!   `util::rng::Pcg64` so journals replay; ambient entropy is forbidden.
//! * **R5 no-panic recovery paths** — a panic in `persist/recover.rs` or
//!   inside a scheduler worker closure turns a recoverable event into a
//!   silent `Lost`; these paths return `Result` instead.
//! * **R6 atomics/ordering hygiene** — `Ordering::Relaxed` and bare
//!   `.lock().unwrap()` in `scheduler/` need a written justification
//!   (poison propagation is usually the right call — say so).

use super::lexer::Line;

/// Identifier of one contract rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum RuleId {
    R1,
    R2,
    R3,
    R4,
    R5,
    R6,
    /// Malformed suppression pragma (not a contract rule; never
    /// baselineable or suppressible).
    P0,
}

impl RuleId {
    pub fn as_str(&self) -> &'static str {
        match self {
            RuleId::R1 => "R1",
            RuleId::R2 => "R2",
            RuleId::R3 => "R3",
            RuleId::R4 => "R4",
            RuleId::R5 => "R5",
            RuleId::R6 => "R6",
            RuleId::P0 => "P0",
        }
    }

    /// Parse a rule name as written in pragmas and baselines. `P0` is
    /// intentionally not parseable: malformed pragmas must be fixed, not
    /// suppressed or grandfathered.
    pub fn parse(s: &str) -> Option<RuleId> {
        match s {
            "R1" => Some(RuleId::R1),
            "R2" => Some(RuleId::R2),
            "R3" => Some(RuleId::R3),
            "R4" => Some(RuleId::R4),
            "R5" => Some(RuleId::R5),
            "R6" => Some(RuleId::R6),
            _ => None,
        }
    }

    pub fn title(&self) -> &'static str {
        match self {
            RuleId::R1 => "wall-clock purity",
            RuleId::R2 => "NaN-safe ordering",
            RuleId::R3 => "deterministic iteration",
            RuleId::R4 => "seeded randomness only",
            RuleId::R5 => "no-panic recovery path",
            RuleId::R6 => "atomics/locking hygiene",
            RuleId::P0 => "malformed pragma",
        }
    }
}

/// One rule violation at a source location. `file` is relative to the
/// scanned source root, forward slashes; `line` is 1-indexed.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    pub rule: RuleId,
    pub file: String,
    pub line: usize,
    pub excerpt: String,
    pub message: String,
}

/// Modules whose arithmetic must be a pure function of (history, seed):
/// no wall-clock reads (R1). Everything else — scheduler, coordinator,
/// util/timer, exp, cli — may read the clock for telemetry.
const R1_PURE_MODULES: &[&str] =
    &["gp/", "optimizer/", "space/", "acq/", "persist/", "linalg/"];

/// Decision-path modules for R3: anything whose iteration order can reach
/// proposal numerics, journal bytes, or replayed state.
const R3_DECISION_PATH: &[&str] =
    &["gp/", "optimizer/", "space/", "acq/", "persist/", "linalg/", "runtime/"];

/// R4 exemption: the one module that owns seed expansion.
const R4_EXEMPT: &[&str] = &["util/rng.rs"];

/// R5 scope: the replay path and the scheduler files whose closures run on
/// worker threads (where a panic degrades to a silent `Lost`).
const R5_FILES: &[&str] = &[
    "persist/recover.rs",
    "persist/segment.rs",
    "persist/compact.rs",
    "persist/corpus.rs",
    "scheduler/pool.rs",
    "scheduler/threaded.rs",
    "scheduler/celery.rs",
];

const R6_SCOPE: &[&str] = &["scheduler/"];

fn in_scope(file: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| {
        if p.ends_with('/') {
            file.starts_with(p)
        } else {
            file == *p
        }
    })
}

/// True if `needle` occurs at `idx` delimited by non-identifier chars.
fn word_at(code: &str, idx: usize, needle: &str) -> bool {
    let ident = |c: char| c.is_alphanumeric() || c == '_';
    let before_ok = idx == 0 || !code[..idx].chars().next_back().is_some_and(ident);
    let after = idx + needle.len();
    let after_ok = after >= code.len() || !code[after..].chars().next().is_some_and(ident);
    before_ok && after_ok
}

fn word_occurrences(code: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut start = 0;
    while let Some(pos) = code[start..].find(needle) {
        let idx = start + pos;
        if word_at(code, idx, needle) {
            out.push(idx);
        }
        start = idx + needle.len();
    }
    out
}

/// Run every rule over one lexed file. `raw_lines` provides the excerpts;
/// `lines` is the lexed code/comment split (same length).
pub fn scan_file(file: &str, raw_lines: &[&str], lines: &[Line]) -> Vec<Finding> {
    let mut out = Vec::new();
    let finding = |rule: RuleId, line_no: usize, message: String, raw: &str| Finding {
        rule,
        file: file.to_string(),
        line: line_no,
        excerpt: excerpt_of(raw),
        message,
    };

    for (i, line) in lines.iter().enumerate() {
        let code = line.code.as_str();
        let raw = raw_lines.get(i).copied().unwrap_or("");
        let line_no = i + 1;

        // R1 — wall-clock purity in pure modules (tests included: a test
        // that needs a clock belongs next to the scheduler, not the math).
        if in_scope(file, R1_PURE_MODULES) {
            for pat in ["Instant::now", "SystemTime"] {
                for _ in word_occurrences(code, pat) {
                    out.push(finding(
                        RuleId::R1,
                        line_no,
                        format!(
                            "`{pat}` in a pure module — propose/persist/replay \
                             arithmetic must not read the clock (telemetry lives in \
                             scheduler/, coordinator/, util/timer.rs)"
                        ),
                        raw,
                    ));
                }
            }
        }

        // R2 — NaN-unsafe float ordering, everywhere. The unwrap may sit
        // on the next line; search the rest of the statement (up to `;`).
        for idx in word_occurrences(code, "partial_cmp") {
            let mut tail = code[idx + "partial_cmp".len()..].to_string();
            if !tail.contains(';') {
                if let Some(next) = lines.get(i + 1) {
                    tail.push(' ');
                    tail.push_str(next.code.trim());
                }
            }
            let stmt = tail.split(';').next().unwrap_or("");
            if stmt.contains(".unwrap()") || stmt.contains(".expect(") {
                out.push(finding(
                    RuleId::R2,
                    line_no,
                    "`partial_cmp(..).unwrap()` panics on NaN (reachable from user \
                     objectives) — use `total_cmp`, or `stats::nan_as_worst` for \
                     objective ranks"
                        .to_string(),
                    raw,
                ));
            }
        }

        // R3 — hash containers in decision-path modules (tests included:
        // assertions that iterate a hash container flake the same way).
        if in_scope(file, R3_DECISION_PATH) {
            for pat in ["HashMap", "HashSet"] {
                for _ in word_occurrences(code, pat) {
                    out.push(finding(
                        RuleId::R3,
                        line_no,
                        format!(
                            "`{pat}` in a decision-path module — iteration order is \
                             nondeterministic; use BTreeMap/BTreeSet/Vec, or prove it \
                             lookup-only with `// pallas-lint: allow(R3, \"…\")`"
                        ),
                        raw,
                    ));
                }
            }
        }

        // R4 — ambient entropy, everywhere but util/rng.rs.
        if !in_scope(file, R4_EXEMPT) {
            for pat in ["thread_rng", "from_entropy", "OsRng", "getrandom"] {
                for _ in word_occurrences(code, pat) {
                    out.push(finding(
                        RuleId::R4,
                        line_no,
                        format!(
                            "`{pat}` — all randomness must flow from a journaled \
                             `util::rng::Pcg64` seed so runs replay bit-exactly"
                        ),
                        raw,
                    ));
                }
            }
            if code.contains("rand::random") {
                out.push(finding(
                    RuleId::R4,
                    line_no,
                    "`rand::random` — all randomness must flow from a journaled \
                     `util::rng::Pcg64` seed so runs replay bit-exactly"
                        .to_string(),
                    raw,
                ));
            }
        }

        // R5 — panics on recovery/worker paths (non-test code only; tests
        // panic by design). `.lock().unwrap()` is R6's finding, not R5's.
        if !line.in_test && in_scope(file, R5_FILES) {
            for pat in [".unwrap()", ".expect("] {
                for idx in occurrences(code, pat) {
                    if pat == ".unwrap()" && code[..idx].ends_with(".lock()") {
                        continue;
                    }
                    out.push(finding(
                        RuleId::R5,
                        line_no,
                        format!(
                            "`{pat}` on a recovery/worker path — a panic here becomes \
                             a silent `Lost`; bubble a Result (or justify with an R5 \
                             pragma)"
                        ),
                        raw,
                    ));
                }
            }
            for pat in ["panic!", "unreachable!", "todo!", "unimplemented!"] {
                for _ in word_occurrences(code, pat) {
                    out.push(finding(
                        RuleId::R5,
                        line_no,
                        format!(
                            "`{pat}` on a recovery/worker path — a panic here becomes \
                             a silent `Lost`; bubble a Result (or justify with an R5 \
                             pragma)"
                        ),
                        raw,
                    ));
                }
            }
        }

        // R6 — locking/atomics hygiene in scheduler/ (non-test code).
        if !line.in_test && in_scope(file, R6_SCOPE) {
            for _ in occurrences(code, ".lock().unwrap()") {
                out.push(finding(
                    RuleId::R6,
                    line_no,
                    "bare `.lock().unwrap()` in scheduler code — justify the poison \
                     policy with `// pallas-lint: allow(R6, \"…\")` or handle the \
                     PoisonError"
                        .to_string(),
                    raw,
                ));
            }
            for _ in occurrences(code, "Ordering::Relaxed") {
                out.push(finding(
                    RuleId::R6,
                    line_no,
                    "`Ordering::Relaxed` in scheduler code — justify why relaxed \
                     ordering is safe with `// pallas-lint: allow(R6, \"…\")` or use \
                     SeqCst/Acquire-Release"
                        .to_string(),
                    raw,
                ));
            }
        }
    }
    out
}

fn occurrences(code: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut start = 0;
    while let Some(pos) = code[start..].find(needle) {
        out.push(start + pos);
        start = start + pos + needle.len();
    }
    out
}

/// A finding's excerpt: the trimmed raw source line, truncated on a char
/// boundary. Baseline entries match on this, so edits that move a line
/// without changing it keep matching.
pub fn excerpt_of(raw: &str) -> String {
    const MAX: usize = 160;
    let t = raw.trim();
    if t.chars().count() <= MAX {
        t.to_string()
    } else {
        let cut: String = t.chars().take(MAX).collect();
        format!("{cut}…")
    }
}
