//! The grandfather file: `lint-baseline.json`.
//!
//! A baseline entry matches a finding on `(rule, file, excerpt)` — the
//! line number is recorded for humans but ignored for matching, so
//! unrelated edits that shift a grandfathered line don't break the build.
//! Matching is multiset-style: each entry absolves at most one finding.
//!
//! Entries that match nothing are reported as **stale** — the tree got
//! cleaner; regenerate with `--write-baseline` (the committed test suite
//! asserts the exact count, so the baseline can only shrink).

use super::rules::{Finding, RuleId};
use crate::config::json::{self, Json};
use std::fs;
use std::path::Path;

pub const BASELINE_VERSION: f64 = 1.0;

#[derive(Debug, Clone, PartialEq)]
pub struct BaselineEntry {
    pub rule: RuleId,
    pub file: String,
    pub line: usize,
    pub excerpt: String,
    pub reason: String,
}

#[derive(Debug, Clone, Default)]
pub struct Baseline {
    pub entries: Vec<BaselineEntry>,
}

impl Baseline {
    /// Build a baseline that grandfathers exactly the given findings.
    pub fn from_findings(findings: &[Finding], reason: &str) -> Self {
        Self {
            entries: findings
                .iter()
                .map(|f| BaselineEntry {
                    rule: f.rule,
                    file: f.file.clone(),
                    line: f.line,
                    excerpt: f.excerpt.clone(),
                    reason: reason.to_string(),
                })
                .collect(),
        }
    }

    pub fn load(path: &Path) -> Result<Self, String> {
        let text = fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    pub fn parse(text: &str) -> Result<Self, String> {
        let root = json::parse(text).map_err(|e| e.to_string())?;
        let version = root
            .get("version")
            .and_then(Json::as_f64)
            .ok_or("baseline missing `version`")?;
        if version != BASELINE_VERSION {
            return Err(format!(
                "baseline version {version} unsupported (expected {BASELINE_VERSION})"
            ));
        }
        let raw = root
            .get("findings")
            .and_then(Json::as_arr)
            .ok_or("baseline missing `findings` array")?;
        let mut entries = Vec::with_capacity(raw.len());
        for (i, e) in raw.iter().enumerate() {
            let field = |k: &str| -> Result<String, String> {
                e.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or(format!("baseline entry {i}: missing string `{k}`"))
            };
            let rule_txt = field("rule")?;
            let rule = RuleId::parse(&rule_txt)
                .ok_or(format!("baseline entry {i}: unknown rule `{rule_txt}`"))?;
            let reason = field("reason")?;
            if reason.trim().is_empty() {
                return Err(format!("baseline entry {i}: empty reason"));
            }
            entries.push(BaselineEntry {
                rule,
                file: field("file")?,
                line: e.get("line").and_then(Json::as_usize).unwrap_or(0),
                excerpt: field("excerpt")?,
                reason,
            });
        }
        Ok(Self { entries })
    }

    /// Serialize: one entry per line, keys in fixed order, stable output
    /// for reviewable diffs.
    pub fn to_json_string(&self) -> String {
        let mut s = String::from("{\n  \"version\": 1,\n  \"findings\": [");
        for (i, e) in self.entries.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str("\n    ");
            let obj = [
                ("rule", Json::Str(e.rule.as_str().to_string())),
                ("file", Json::Str(e.file.clone())),
                ("line", Json::Num(e.line as f64)),
                ("excerpt", Json::Str(e.excerpt.clone())),
                ("reason", Json::Str(e.reason.clone())),
            ];
            s.push('{');
            for (j, (k, v)) in obj.iter().enumerate() {
                if j > 0 {
                    s.push_str(", ");
                }
                s.push_str(&format!("{}: {v}", Json::Str(k.to_string())));
            }
            s.push('}');
        }
        s.push_str("\n  ]\n}\n");
        s
    }

    pub fn save(&self, path: &Path) -> Result<(), String> {
        fs::write(path, self.to_json_string())
            .map_err(|e| format!("writing {}: {e}", path.display()))
    }

    /// Split findings into (new, baselined-count); returns the stale
    /// (unmatched) entries as the third element.
    pub fn apply(&self, findings: Vec<Finding>) -> (Vec<Finding>, usize, Vec<BaselineEntry>) {
        let mut used = vec![false; self.entries.len()];
        let mut new = Vec::new();
        let mut absolved = 0usize;
        for f in findings {
            let hit = self.entries.iter().enumerate().find(|(i, e)| {
                !used[*i] && e.rule == f.rule && e.file == f.file && e.excerpt == f.excerpt
            });
            match hit {
                Some((i, _)) => {
                    used[i] = true;
                    absolved += 1;
                }
                None => new.push(f),
            }
        }
        let stale = self
            .entries
            .iter()
            .zip(&used)
            .filter(|(_, u)| !**u)
            .map(|(e, _)| e.clone())
            .collect();
        (new, absolved, stale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(rule: RuleId, file: &str, line: usize, excerpt: &str) -> Finding {
        Finding {
            rule,
            file: file.into(),
            line,
            excerpt: excerpt.into(),
            message: String::new(),
        }
    }

    #[test]
    fn round_trips_through_json() {
        let b = Baseline::from_findings(
            &[f(RuleId::R3, "runtime/pjrt.rs", 31, "use std::collections::HashMap;")],
            "lookup-only cache",
        );
        let text = b.to_json_string();
        let b2 = Baseline::parse(&text).expect("parse");
        assert_eq!(b2.entries, b.entries);
    }

    #[test]
    fn matching_ignores_line_numbers() {
        let b = Baseline::from_findings(&[f(RuleId::R1, "gp/mod.rs", 10, "x()")], "ok");
        let (new, absolved, stale) = b.apply(vec![f(RuleId::R1, "gp/mod.rs", 99, "x()")]);
        assert!(new.is_empty());
        assert_eq!(absolved, 1);
        assert!(stale.is_empty());
    }

    #[test]
    fn multiset_semantics_one_entry_one_finding() {
        let b = Baseline::from_findings(&[f(RuleId::R1, "gp/mod.rs", 10, "x()")], "ok");
        let (new, absolved, _) = b.apply(vec![
            f(RuleId::R1, "gp/mod.rs", 10, "x()"),
            f(RuleId::R1, "gp/mod.rs", 11, "x()"),
        ]);
        assert_eq!(absolved, 1, "one entry absolves exactly one finding");
        assert_eq!(new.len(), 1);
    }

    #[test]
    fn stale_entries_are_surfaced() {
        let b = Baseline::from_findings(&[f(RuleId::R2, "a.rs", 1, "gone()")], "fixed since");
        let (new, absolved, stale) = b.apply(vec![]);
        assert!(new.is_empty());
        assert_eq!(absolved, 0);
        assert_eq!(stale.len(), 1);
    }

    #[test]
    fn rejects_unknown_rule_and_empty_reason() {
        let bad_rule = r#"{"version": 1, "findings": [{"rule": "P0", "file": "a", "line": 1, "excerpt": "x", "reason": "r"}]}"#;
        assert!(Baseline::parse(bad_rule).is_err(), "P0 must not be baselineable");
        let bad_reason = r#"{"version": 1, "findings": [{"rule": "R1", "file": "a", "line": 1, "excerpt": "x", "reason": "  "}]}"#;
        assert!(Baseline::parse(bad_reason).is_err());
    }
}
