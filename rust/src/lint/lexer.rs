//! A minimal Rust source lexer for `pallas-lint`: splits every line into
//! *code text* and *comment text* so the rule scanners never match inside
//! comments, string/char literals, or doc text, and marks the line ranges
//! belonging to `#[cfg(test)] mod … { … }` blocks so panic/lock rules can
//! exempt test code.
//!
//! This is deliberately not a full Rust lexer — it only has to be exact
//! about the four things that would make substring rules lie:
//!
//! * line comments (`//`) and *nested* block comments (`/* /* */ */`),
//! * string literals with escapes (`"a\"b"`), including byte strings,
//! * raw strings with hash fences (`r#"…"#`, `br##"…"##`),
//! * char literals vs lifetimes (`'x'` / `'\n'` vs `'a` and `'static`).
//!
//! Stripped regions are replaced by spaces, so column positions and line
//! counts in findings match the original file.

/// One source line, split into its code and comment parts.
#[derive(Debug, Clone)]
pub struct Line {
    /// Source text with comments, string contents, and char-literal
    /// contents blanked to spaces (delimiters too). Same length as the
    /// original line.
    pub code: String,
    /// The concatenated comment text of this line (line + block comments,
    /// without the `//` / `/*` markers). Pragmas are parsed from this.
    pub comment: String,
    /// True if this line sits inside a `#[cfg(test)] mod … { … }` region.
    pub in_test: bool,
}

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Code,
    /// Inside `/* … */`; payload = nesting depth.
    Block(u32),
    /// Inside `"…"`; `raw_hashes = None` for escaped strings, `Some(n)`
    /// for raw strings fenced by `n` hashes.
    Str { raw_hashes: Option<u32> },
    /// Inside a char literal `'…'`.
    Char,
}

/// Lex a whole file into per-line code/comment splits.
pub fn lex(src: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut mode = Mode::Code;
    for raw in src.lines() {
        let (line, next) = lex_line(raw, mode);
        mode = match next {
            // Strings and chars do not continue across a newline except
            // raw strings and escaped multi-line strings — both of which
            // we keep open. A char literal never spans lines; reset.
            Mode::Char => Mode::Code,
            m => m,
        };
        out.push(line);
    }
    mark_test_regions(&mut out);
    out
}

fn lex_line(raw: &str, mut mode: Mode) -> (Line, Mode) {
    let bytes: Vec<char> = raw.chars().collect();
    let n = bytes.len();
    let mut code = String::with_capacity(n);
    let mut comment = String::new();
    let mut i = 0usize;
    while i < n {
        let c = bytes[i];
        match mode {
            Mode::Block(depth) => {
                if c == '/' && i + 1 < n && bytes[i + 1] == '*' {
                    mode = Mode::Block(depth + 1);
                    code.push_str("  ");
                    i += 2;
                } else if c == '*' && i + 1 < n && bytes[i + 1] == '/' {
                    mode = if depth == 1 { Mode::Code } else { Mode::Block(depth - 1) };
                    code.push_str("  ");
                    i += 2;
                } else {
                    comment.push(c);
                    code.push(' ');
                    i += 1;
                }
            }
            Mode::Str { raw_hashes } => {
                match raw_hashes {
                    None => {
                        if c == '\\' && i + 1 < n {
                            code.push_str("  ");
                            i += 2;
                        } else if c == '"' {
                            code.push(' ');
                            mode = Mode::Code;
                            i += 1;
                        } else {
                            code.push(' ');
                            i += 1;
                        }
                    }
                    Some(h) => {
                        if c == '"' && closes_raw(&bytes, i, h) {
                            for _ in 0..(1 + h as usize) {
                                code.push(' ');
                            }
                            i += 1 + h as usize;
                            mode = Mode::Code;
                        } else {
                            code.push(' ');
                            i += 1;
                        }
                    }
                }
            }
            Mode::Char => {
                if c == '\\' && i + 1 < n {
                    code.push_str("  ");
                    i += 2;
                } else if c == '\'' {
                    code.push(' ');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            Mode::Code => {
                if c == '/' && i + 1 < n && bytes[i + 1] == '/' {
                    // Line comment: the rest of the line is comment text.
                    comment.push_str(&raw[byte_pos(raw, i + 2)..]);
                    for _ in i..n {
                        code.push(' ');
                    }
                    i = n;
                } else if c == '/' && i + 1 < n && bytes[i + 1] == '*' {
                    mode = Mode::Block(1);
                    code.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    code.push(' ');
                    mode = Mode::Str { raw_hashes: None };
                    i += 1;
                } else if is_raw_string_start(&bytes, i) {
                    let (consumed, hashes) = raw_string_open(&bytes, i);
                    for _ in 0..consumed {
                        code.push(' ');
                    }
                    i += consumed;
                    mode = Mode::Str { raw_hashes: Some(hashes) };
                } else if c == 'b' && i + 1 < n && bytes[i + 1] == '"' {
                    code.push_str("  ");
                    i += 2;
                    mode = Mode::Str { raw_hashes: None };
                } else if c == '\'' {
                    // Lifetime (`'a`, `'static`) or char literal (`'x'`,
                    // `'\n'`)? A lifetime is `'` + ident NOT followed by a
                    // closing `'`.
                    let is_lifetime = i + 1 < n
                        && (bytes[i + 1].is_alphabetic() || bytes[i + 1] == '_')
                        && !(i + 2 < n && bytes[i + 2] == '\'');
                    if is_lifetime {
                        code.push(c);
                        i += 1;
                    } else {
                        code.push(' ');
                        mode = Mode::Char;
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
        }
    }
    // `b` prefix before a raw string is consumed by is_raw_string_start;
    // pad code to the original char length if a 2-char consume ran past.
    while code.chars().count() < n {
        code.push(' ');
    }
    (Line { code, comment, in_test: false }, mode)
}

/// `raw` is char-indexed by the lexer; translate a char index into a byte
/// offset for slicing the original line.
fn byte_pos(raw: &str, char_idx: usize) -> usize {
    raw.char_indices().nth(char_idx).map(|(b, _)| b).unwrap_or(raw.len())
}

fn is_raw_string_start(b: &[char], i: usize) -> bool {
    let start = if b[i] == 'b' { i + 1 } else { i };
    if b.get(start) != Some(&'r') {
        return false;
    }
    // Don't treat identifiers ending in r/br (e.g. `var"`) as raw strings.
    if i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_') {
        return false;
    }
    let mut j = start + 1;
    while b.get(j) == Some(&'#') {
        j += 1;
    }
    b.get(j) == Some(&'"')
}

/// Returns (chars consumed by the opener, hash count).
fn raw_string_open(b: &[char], i: usize) -> (usize, u32) {
    let start = if b[i] == 'b' { i + 1 } else { i };
    let mut j = start + 1;
    let mut hashes = 0u32;
    while b.get(j) == Some(&'#') {
        j += 1;
        hashes += 1;
    }
    // consume: optional b, r, hashes, opening quote
    (j + 1 - i, hashes)
}

fn closes_raw(b: &[char], i: usize, hashes: u32) -> bool {
    for k in 0..hashes as usize {
        if b.get(i + 1 + k) != Some(&'#') {
            return false;
        }
    }
    true
}

/// Mark every line inside `#[cfg(test)] mod … { … }` regions. Tracks brace
/// depth over the *code* text (strings/comments already blanked), arms on a
/// line containing the literal attribute `#[cfg(test)]`, and opens a region
/// at the next `{`, closing when depth returns to the opening level.
fn mark_test_regions(lines: &mut [Line]) {
    let mut depth: i64 = 0;
    let mut armed = false;
    let mut armed_line = 0usize;
    // (depth the region opened at)
    let mut region_open: Option<i64> = None;
    for idx in 0..lines.len() {
        let code = lines[idx].code.clone();
        if region_open.is_none() && code.contains("#[cfg(test)]") {
            armed = true;
            armed_line = idx;
        }
        let was_inside = region_open.is_some() || armed;
        for ch in code.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if armed && region_open.is_none() {
                        region_open = Some(depth - 1);
                        armed = false;
                        // The attribute and `mod` header lines count too.
                        for l in lines.iter_mut().take(idx).skip(armed_line) {
                            l.in_test = true;
                        }
                    }
                }
                '}' => {
                    depth -= 1;
                    if let Some(open) = region_open {
                        if depth <= open {
                            region_open = None;
                        }
                    }
                }
                _ => {}
            }
        }
        if was_inside || region_open.is_some() || armed {
            lines[idx].in_test = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(src: &str) -> Vec<String> {
        lex(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn line_comments_are_stripped() {
        let c = codes("let x = 1; // Instant::now()\nlet y = 2;");
        assert!(!c[0].contains("Instant::now"));
        assert!(c[0].contains("let x = 1;"));
        assert!(c[1].contains("let y = 2;"));
    }

    #[test]
    fn nested_block_comments_are_stripped() {
        let c = codes("a /* x /* HashMap */ y */ b\nplain");
        assert!(c[0].contains('a') && c[0].contains('b'));
        assert!(!c[0].contains("HashMap"));
        assert!(c[1].contains("plain"));
    }

    #[test]
    fn multiline_block_comment_spans_lines() {
        let c = codes("pre /* one\n SystemTime \n*/ post");
        assert!(!c[1].contains("SystemTime"));
        assert!(c[2].contains("post"));
    }

    #[test]
    fn string_contents_are_blanked() {
        let c = codes(r#"let s = "Instant::now()"; let t = s;"#);
        assert!(!c[0].contains("Instant::now"));
        assert!(c[0].contains("let t = s;"));
    }

    #[test]
    fn escaped_quote_does_not_end_string() {
        let c = codes(r#"let s = "a\"HashMap"; keep"#);
        assert!(!c[0].contains("HashMap"));
        assert!(c[0].contains("keep"));
    }

    #[test]
    fn raw_strings_with_hashes() {
        let c = codes(r##"let s = r#"thread_rng " still"#; after"##);
        assert!(!c[0].contains("thread_rng"));
        assert!(c[0].contains("after"));
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let c = codes("let a: &'static str = x; let q = '\"'; let b = 1;");
        // The lifetime must not open a char literal that swallows the line.
        assert!(c[0].contains("let b = 1;"));
        // The quote char's content is blanked.
        assert!(!c[0].contains('"'));
    }

    #[test]
    fn comment_text_is_captured_for_pragmas() {
        let l = lex("x(); // pallas-lint: allow(R1, \"why\")");
        assert!(l[0].comment.contains("pallas-lint: allow(R1"));
    }

    #[test]
    fn cfg_test_region_marking() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}\n";
        let l = lex(src);
        assert!(!l[0].in_test, "code before the region");
        assert!(l[1].in_test, "attribute line");
        assert!(l[2].in_test, "mod header");
        assert!(l[3].in_test, "body");
        assert!(l[4].in_test, "closing brace");
        assert!(!l[5].in_test, "code after the region");
    }

    #[test]
    fn nested_braces_keep_region_open() {
        let src = "#[cfg(test)]\nmod t {\n    fn b() { if x { y(); } }\n    fn d() {}\n}\nfn after() {}\n";
        let l = lex(src);
        assert!(l[3].in_test, "second fn still inside");
        assert!(!l[5].in_test);
    }
}
