//! Rendering a [`LintReport`](super::LintReport) as human text or JSON
//! (`--json`, for tooling/CI annotations).

use super::LintReport;
use crate::config::json::Json;
use std::fmt::Write as _;

pub fn human(report: &LintReport) -> String {
    let mut s = String::new();
    for f in &report.findings {
        let _ = writeln!(
            s,
            "{}:{} [{}] {}\n    {}\n    {}",
            f.file,
            f.line,
            f.rule.as_str(),
            f.rule.title(),
            f.excerpt,
            f.message
        );
    }
    for e in &report.stale_baseline {
        let _ = writeln!(
            s,
            "stale baseline entry: {} [{}] `{}` no longer matches — regenerate with \
             --write-baseline (the baseline only shrinks)",
            e.file,
            e.rule.as_str(),
            e.excerpt
        );
    }
    let _ = writeln!(
        s,
        "pallas-lint: {} new finding(s), {} suppressed by pragma, {} baselined, \
         {} stale baseline entr{}, {} file(s) scanned",
        report.findings.len(),
        report.suppressed,
        report.baselined,
        report.stale_baseline.len(),
        if report.stale_baseline.len() == 1 { "y" } else { "ies" },
        report.files_scanned,
    );
    s
}

pub fn json(report: &LintReport) -> String {
    let findings = report
        .findings
        .iter()
        .map(|f| {
            Json::obj(vec![
                ("rule", Json::Str(f.rule.as_str().to_string())),
                ("file", Json::Str(f.file.clone())),
                ("line", Json::Num(f.line as f64)),
                ("excerpt", Json::Str(f.excerpt.clone())),
                ("message", Json::Str(f.message.clone())),
            ])
        })
        .collect();
    let stale = report
        .stale_baseline
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("rule", Json::Str(e.rule.as_str().to_string())),
                ("file", Json::Str(e.file.clone())),
                ("excerpt", Json::Str(e.excerpt.clone())),
            ])
        })
        .collect();
    let root = Json::obj(vec![
        ("findings", Json::Arr(findings)),
        ("stale_baseline", Json::Arr(stale)),
        (
            "counts",
            Json::obj(vec![
                ("new", Json::Num(report.findings.len() as f64)),
                ("suppressed", Json::Num(report.suppressed as f64)),
                ("baselined", Json::Num(report.baselined as f64)),
                ("files_scanned", Json::Num(report.files_scanned as f64)),
            ]),
        ),
    ]);
    format!("{root}\n")
}
