//! # `pallas-lint` — static contract enforcement for the deterministic core
//!
//! The crash+resume story (persist/), the bit-exactness contracts (gp/,
//! linalg/), and seed-replay determinism (space/, optimizer/) all rest on
//! source-level invariants that no test can fully police: no wall-clock
//! reads in pure modules, no NaN-unsafe float sorts, no hash-order
//! iteration on decision paths, no ambient entropy, no panics on recovery
//! paths. This module checks them *statically* — it lexes every file under
//! `rust/src`, strips comments and string literals, and pattern-scans the
//! remaining code per [`rules`] (R1–R6), before any toolchain ever runs a
//! test.
//!
//! Run it via the dedicated binary:
//!
//! ```text
//! cargo run --bin pallas-lint -- --deny        # CI gate: fail on new findings
//! cargo run --bin pallas-lint -- --json        # machine-readable findings
//! cargo run --bin pallas-lint -- --write-baseline
//! ```
//!
//! Justified violations are suppressed inline:
//!
//! ```text
//! let cache = HashMap::new(); // pallas-lint: allow(R3, "lookup-only, never iterated")
//! ```
//!
//! and pre-existing ones are grandfathered in `rust/lint-baseline.json`
//! ([`baseline`]), which the test suite pins to an exact count so it can
//! only shrink.

pub mod baseline;
pub mod lexer;
pub mod pragma;
pub mod report;
pub mod rules;

pub use baseline::{Baseline, BaselineEntry};
pub use rules::{Finding, RuleId};

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// The outcome of linting a tree: `findings` are *new* (neither suppressed
/// by a pragma nor absolved by the baseline).
#[derive(Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub suppressed: usize,
    pub baselined: usize,
    pub stale_baseline: Vec<BaselineEntry>,
    pub files_scanned: usize,
}

impl LintReport {
    /// What `--deny` gates on: any new finding (malformed pragmas are
    /// findings too, rule `P0`).
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }
}

/// Lint one file's source text. Returns (findings, suppressed-count).
/// `rel_path` is the path relative to the source root, forward slashes —
/// it decides which rule scopes apply.
pub fn lint_source(rel_path: &str, source: &str) -> (Vec<Finding>, usize) {
    let raw_lines: Vec<&str> = source.lines().collect();
    let lines = lexer::lex(source);

    // Per-line effective pragmas: a pragma applies to its own line, or —
    // on a comment-only line — to the next code-bearing line.
    let mut effective: Vec<Vec<pragma::Pragma>> = vec![Vec::new(); lines.len()];
    let mut findings: Vec<Finding> = Vec::new();
    let mut carried: Vec<pragma::Pragma> = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        let (pragmas, errors) = pragma::parse_line(&line.comment);
        for e in errors {
            findings.push(Finding {
                rule: RuleId::P0,
                file: rel_path.to_string(),
                line: i + 1,
                excerpt: rules::excerpt_of(raw_lines.get(i).copied().unwrap_or("")),
                message: e.message,
            });
        }
        let code_bearing = !line.code.trim().is_empty();
        if code_bearing {
            effective[i].append(&mut carried);
        }
        if pragmas.is_empty() {
            continue;
        }
        if code_bearing {
            effective[i].extend(pragmas);
        } else {
            carried.extend(pragmas);
        }
    }

    let mut suppressed = 0usize;
    for f in rules::scan_file(rel_path, &raw_lines, &lines) {
        let allowed = effective
            .get(f.line - 1)
            .is_some_and(|ps| ps.iter().any(|p| p.rule == f.rule));
        if allowed {
            suppressed += 1;
        } else {
            findings.push(f);
        }
    }
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    (findings, suppressed)
}

/// Recursively collect `.rs` files under `root`, sorted for deterministic
/// finding order (the linter holds itself to R3).
fn collect_rs_files(root: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<PathBuf> =
            fs::read_dir(&dir)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
        entries.sort();
        for path in entries {
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Lint every `.rs` file under `src_root`, applying `baseline` if given.
pub fn lint_tree(src_root: &Path, baseline: Option<&Baseline>) -> io::Result<LintReport> {
    let files = collect_rs_files(src_root)?;
    let mut all = Vec::new();
    let mut suppressed = 0usize;
    for path in &files {
        let source = fs::read_to_string(path)?;
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let (f, s) = lint_source(&rel, &source);
        all.extend(f);
        suppressed += s;
    }
    let (findings, baselined, stale_baseline) = match baseline {
        Some(b) => b.apply(all),
        None => (all, 0, Vec::new()),
    };
    Ok(LintReport {
        findings,
        suppressed,
        baselined,
        stale_baseline,
        files_scanned: files.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_line_pragma_suppresses() {
        let src = "use std::collections::HashMap; // pallas-lint: allow(R3, \"lookup-only\")\n";
        let (f, s) = lint_source("gp/x.rs", src);
        assert!(f.is_empty(), "{f:?}");
        assert_eq!(s, 1);
    }

    #[test]
    fn standalone_pragma_covers_next_code_line() {
        let src = "// pallas-lint: allow(R3, \"lookup-only\")\nuse std::collections::HashMap;\n";
        let (f, s) = lint_source("gp/x.rs", src);
        assert!(f.is_empty(), "{f:?}");
        assert_eq!(s, 1);
    }

    #[test]
    fn pragma_does_not_leak_past_next_code_line() {
        let src = "// pallas-lint: allow(R3, \"only the first\")\n\
                   use std::collections::HashMap;\n\
                   use std::collections::HashSet;\n";
        let (f, s) = lint_source("gp/x.rs", src);
        assert_eq!(s, 1);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn wrong_rule_pragma_does_not_suppress() {
        let src = "use std::collections::HashMap; // pallas-lint: allow(R1, \"wrong rule\")\n";
        let (f, s) = lint_source("gp/x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, RuleId::R3);
        assert_eq!(s, 0);
    }

    #[test]
    fn malformed_pragma_is_a_p0_finding() {
        let src = "let x = 1; // pallas-lint: allow(R3)\n";
        let (f, _) = lint_source("util/x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, RuleId::P0);
    }

    #[test]
    fn out_of_scope_files_are_clean() {
        // HashMap in a non-decision-path module is fine.
        let (f, _) = lint_source("cli/mod.rs", "use std::collections::HashMap;\n");
        assert!(f.is_empty());
        // Clock reads in scheduler are fine (R1 scope excludes it).
        let (f, _) = lint_source("scheduler/pool.rs", "let t = Instant::now();\n");
        assert!(f.is_empty());
    }

    #[test]
    fn findings_in_comments_and_strings_do_not_fire() {
        let src = "// Instant::now() is forbidden here\nlet s = \"SystemTime\";\n";
        let (f, _) = lint_source("gp/mod.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }
}
