//! Inline suppression pragmas.
//!
//! Syntax (inside any comment):
//!
//! ```text
//! // pallas-lint: allow(R3, "membership-only: set order never observed")
//! ```
//!
//! A pragma suppresses findings of the named rule on **its own line**, or
//! — when the pragma's line carries no code — on the **next code-bearing
//! line**. The reason string is mandatory: an allow without a justification
//! is itself reported (`P0`), so suppressions can't rot silently.

use super::rules::RuleId;

/// One parsed `allow` pragma.
#[derive(Debug, Clone, PartialEq)]
pub struct Pragma {
    pub rule: RuleId,
    pub reason: String,
}

/// A pragma that failed to parse — reported as a finding so it fails
/// `--deny` instead of silently not suppressing.
#[derive(Debug, Clone, PartialEq)]
pub struct PragmaError {
    pub message: String,
}

const MARKER: &str = "pallas-lint:";

/// Parse every pragma in one line's comment text.
pub fn parse_line(comment: &str) -> (Vec<Pragma>, Vec<PragmaError>) {
    let mut pragmas = Vec::new();
    let mut errors = Vec::new();
    let mut rest = comment;
    while let Some(pos) = rest.find(MARKER) {
        rest = &rest[pos + MARKER.len()..];
        match parse_one(rest) {
            Ok(p) => pragmas.push(p),
            Err(msg) => errors.push(PragmaError { message: msg }),
        }
    }
    (pragmas, errors)
}

fn parse_one(after_marker: &str) -> Result<Pragma, String> {
    let s = after_marker.trim_start();
    let Some(body) = s.strip_prefix("allow") else {
        return Err(format!(
            "expected `allow(<rule>, \"<reason>\")` after `{MARKER}`, got `{}`",
            s.chars().take(40).collect::<String>()
        ));
    };
    let body = body.trim_start();
    let Some(body) = body.strip_prefix('(') else {
        return Err("expected `(` after `allow`".into());
    };
    let Some(close) = body.find(')') else {
        return Err("unterminated `allow(` pragma".into());
    };
    let inner = &body[..close];
    let Some(comma) = inner.find(',') else {
        return Err("allow pragma needs a justification: `allow(R_, \"why\")`".into());
    };
    let rule_txt = inner[..comma].trim();
    let Some(rule) = RuleId::parse(rule_txt) else {
        return Err(format!("unknown rule `{rule_txt}` in allow pragma"));
    };
    let reason_txt = inner[comma + 1..].trim();
    let reason = reason_txt
        .strip_prefix('"')
        .and_then(|r| r.strip_suffix('"'))
        .map(str::trim)
        .filter(|r| !r.is_empty());
    let Some(reason) = reason else {
        return Err("allow pragma reason must be a non-empty quoted string".into());
    };
    Ok(Pragma { rule, reason: reason.to_string() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_well_formed_pragma() {
        let (p, e) = parse_line(r#" pallas-lint: allow(R3, "lookup-only cache") "#);
        assert!(e.is_empty());
        assert_eq!(p, vec![Pragma { rule: RuleId::R3, reason: "lookup-only cache".into() }]);
    }

    #[test]
    fn reason_is_mandatory() {
        let (p, e) = parse_line("pallas-lint: allow(R1)");
        assert!(p.is_empty());
        assert_eq!(e.len(), 1);
        assert!(e[0].message.contains("justification"));
    }

    #[test]
    fn empty_reason_is_rejected() {
        let (p, e) = parse_line(r#"pallas-lint: allow(R1, "")"#);
        assert!(p.is_empty());
        assert_eq!(e.len(), 1);
    }

    #[test]
    fn unknown_rule_is_reported() {
        let (_, e) = parse_line(r#"pallas-lint: allow(R9, "nope")"#);
        assert_eq!(e.len(), 1);
        assert!(e[0].message.contains("unknown rule"));
    }

    #[test]
    fn multiple_pragmas_on_one_line() {
        let (p, e) = parse_line(
            r#"pallas-lint: allow(R5, "a") pallas-lint: allow(R6, "b")"#,
        );
        assert!(e.is_empty());
        assert_eq!(p.len(), 2);
        assert_eq!(p[0].rule, RuleId::R5);
        assert_eq!(p[1].rule, RuleId::R6);
    }

    #[test]
    fn ordinary_comments_are_ignored() {
        let (p, e) = parse_line("just a normal comment mentioning lint");
        assert!(p.is_empty() && e.is_empty());
    }
}
