//! Typed configuration loaded from JSON files or CLI flags.

use super::json::Json;
use anyhow::{anyhow, Result};

/// How one tuning run is configured — mirrors MANGO's user-controlled
/// options (§2.4: batch size, algorithm, max iterations, initial random
/// evaluations, acquisition sample-size override).
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    /// Batch size k: configurations proposed per iteration.
    pub batch_size: usize,
    /// Number of optimizer iterations (batches), the paper's x-axis.
    pub num_iterations: usize,
    /// Random configurations evaluated before the surrogate takes over.
    pub initial_random: usize,
    /// "hallucination" | "clustering" | "random" | "tpe".
    pub optimizer: String,
    /// "serial" | "threaded" | "celery".
    pub scheduler: String,
    /// Worker count for parallel schedulers.
    pub workers: usize,
    /// Override for the Monte-Carlo acquisition sample count (0 = heuristic).
    pub mc_samples: usize,
    /// Base RNG seed.
    pub seed: u64,
    /// GP surrogate backend: "pjrt" (artifacts) or "native".
    pub backend: String,
    /// Optimize GP lengthscale by marginal likelihood grid search.
    pub tune_lengthscale: bool,
    /// Stop after this many iterations without improvement (0 = never).
    pub early_stop: usize,
    /// Largest history the surrogate sees (PJRT artifacts cap at 512).
    pub max_surrogate_obs: usize,
    /// "sync" (batch barriers, the paper) or "async" (event loop).
    pub mode: String,
    /// Async mode: in-flight window size (0 = max(batch_size, workers)).
    pub async_window: usize,
    /// Async mode: resubmissions allowed per lost evaluation.
    pub max_retries: usize,
    /// Worker threads for Monte-Carlo candidate scoring (native backend;
    /// 0 = one per core). The chunked scoring pipeline is deterministic:
    /// output is byte-identical for every setting.
    pub proposal_threads: usize,
    /// Scoring shards shipped through the scheduler's worker-pool
    /// machinery per propose round (native backend). 0 = local-only
    /// scoring (today's behavior byte-for-byte); n ≥ 1 executes n fixed
    /// candidate chunks as pool jobs under the run's scheduler kind.
    /// Byte-identical output for every setting.
    pub proposal_shards: usize,
    /// Propose-hot-path arithmetic profile: "exact" (default — every
    /// bit-exactness contract holds) or "fast" (SIMD-friendly chunked
    /// kernels + tiled mixed-precision distance cache; run-to-run
    /// deterministic and threads/shards-invariant, ≤1e-10 relative of the
    /// scalar oracles, not bit-equal to exact).
    pub kernel_profile: String,
    /// Journal durability: fsync after every n appends (0 = flush-only —
    /// survives a process kill; a machine crash can lose recent events).
    pub fsync_every_n: usize,
    /// Trial-level early stopping rule consulted on each intermediate
    /// report (async mode only): "none" | "median" | "asha".
    pub pruner: String,
    /// Reports a trial must make before the pruner may cancel it
    /// ("median"), or the first-rung budget r0 ("asha").
    pub pruner_warmup: usize,
    /// ASHA reduction factor eta (> 1): rung budgets grow as r0 * eta^k
    /// and the top 1/eta of each rung survives.
    pub asha_reduction: f64,
    /// Crash-safe run journal path ("" = no persistence). The run appends
    /// one JSONL event per proposal/submission/completion so it can be
    /// resumed after a coordinator crash.
    pub journal: String,
    /// Resume from `journal` instead of starting fresh (requires an
    /// existing journal written by a crashed or finished run).
    pub resume: bool,
    /// Async completion-folding order: "wallclock" (default — fold in
    /// arrival order, today's path byte-for-byte) or "stable" (reorder
    /// buffer folds in ascending task id, making the trajectory
    /// byte-identical run-to-run and across schedulers; requires async
    /// mode).
    pub replay: String,
    /// What a journal write error does: "fail-stop" (default — the run
    /// aborts with the cause) or "degrade" (log once, drop the journal,
    /// finish the run with `journal_degraded` set on the result).
    pub journal_on_error: String,
    /// Base retry backoff in ms (0 = resubmit immediately, today's path).
    /// Retries wait `base * 2^(attempt-1)` capped at 64x, jittered
    /// deterministically from the run seed; journaled so a resumed run
    /// keeps the crashed run's schedule.
    pub retry_backoff_ms: f64,
    /// Async mode: abandon in-flight work and return partial results
    /// (`stalled: true`) after this many ms without any completion
    /// (0 = wait forever).
    pub stall_timeout_ms: u64,
    /// Journal segment rotation: seal + rotate to a new segment file
    /// every n events (0 = single-file layout, byte-identical to the
    /// pre-segmentation journal apart from the schema version).
    pub journal_segment_events: usize,
    /// Sealed segments compaction leaves uncompacted behind the active
    /// one — the warm tail a resume replays event-by-event.
    pub journal_keep_segments: usize,
    /// Run a compaction pass over the sealed prefix before resuming
    /// (bounds the replay cost of a long-crashed run up front).
    pub compact_on_resume: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            batch_size: 1,
            num_iterations: 60,
            initial_random: 2,
            optimizer: "hallucination".into(),
            scheduler: "serial".into(),
            workers: 1,
            mc_samples: 0,
            seed: 0,
            backend: "pjrt".into(),
            tune_lengthscale: false,
            early_stop: 0,
            max_surrogate_obs: 512,
            mode: "sync".into(),
            async_window: 0,
            max_retries: 2,
            proposal_threads: 1,
            proposal_shards: 0,
            kernel_profile: "exact".into(),
            fsync_every_n: 0,
            pruner: "none".into(),
            pruner_warmup: 1,
            asha_reduction: 3.0,
            journal: String::new(),
            resume: false,
            replay: "wallclock".into(),
            journal_on_error: "fail-stop".into(),
            retry_backoff_ms: 0.0,
            stall_timeout_ms: 3_600_000,
            journal_segment_events: 0,
            journal_keep_segments: 2,
            compact_on_resume: false,
        }
    }
}

impl RunConfig {
    /// Parse from a JSON object, falling back to defaults per field.
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut c = Self::default();
        let obj = j.as_obj().ok_or_else(|| anyhow!("run config must be an object"))?;
        for (k, v) in obj {
            match k.as_str() {
                "batch_size" => c.batch_size = num(v, k)? as usize,
                "num_iterations" => c.num_iterations = num(v, k)? as usize,
                "initial_random" => c.initial_random = num(v, k)? as usize,
                "workers" => c.workers = num(v, k)? as usize,
                "mc_samples" => c.mc_samples = num(v, k)? as usize,
                "seed" => c.seed = num(v, k)? as u64,
                "early_stop" => c.early_stop = num(v, k)? as usize,
                "max_surrogate_obs" => c.max_surrogate_obs = num(v, k)? as usize,
                "async_window" => c.async_window = num(v, k)? as usize,
                "max_retries" => c.max_retries = num(v, k)? as usize,
                "proposal_threads" => c.proposal_threads = num(v, k)? as usize,
                "proposal_shards" => c.proposal_shards = num(v, k)? as usize,
                "fsync_every_n" => c.fsync_every_n = num(v, k)? as usize,
                "pruner_warmup" => c.pruner_warmup = num(v, k)? as usize,
                "asha_reduction" => c.asha_reduction = num(v, k)?,
                "pruner" => c.pruner = str_(v, k)?,
                "optimizer" => c.optimizer = str_(v, k)?,
                "scheduler" => c.scheduler = str_(v, k)?,
                "backend" => c.backend = str_(v, k)?,
                "mode" => c.mode = str_(v, k)?,
                "kernel_profile" => c.kernel_profile = str_(v, k)?,
                "journal" => c.journal = str_(v, k)?,
                "replay" => c.replay = str_(v, k)?,
                "journal_on_error" => c.journal_on_error = str_(v, k)?,
                "retry_backoff_ms" => c.retry_backoff_ms = num(v, k)?,
                "stall_timeout_ms" => c.stall_timeout_ms = num(v, k)? as u64,
                "journal_segment_events" => c.journal_segment_events = num(v, k)? as usize,
                "journal_keep_segments" => c.journal_keep_segments = num(v, k)? as usize,
                "compact_on_resume" => {
                    c.compact_on_resume = v.as_bool().ok_or_else(|| anyhow!("{k}: bool"))?
                }
                "tune_lengthscale" => {
                    c.tune_lengthscale = v.as_bool().ok_or_else(|| anyhow!("{k}: bool"))?
                }
                "resume" => c.resume = v.as_bool().ok_or_else(|| anyhow!("{k}: bool"))?,
                _ => return Err(anyhow!("unknown run config key '{k}'")),
            }
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if self.batch_size == 0 {
            return Err(anyhow!("batch_size must be >= 1"));
        }
        if self.num_iterations == 0 {
            return Err(anyhow!("num_iterations must be >= 1"));
        }
        const OPTS: [&str; 5] = ["hallucination", "clustering", "random", "tpe", "thompson"];
        if !OPTS.contains(&self.optimizer.as_str()) {
            return Err(anyhow!("unknown optimizer '{}' (one of {OPTS:?})", self.optimizer));
        }
        const SCHEDS: [&str; 3] = ["serial", "threaded", "celery"];
        if !SCHEDS.contains(&self.scheduler.as_str()) {
            return Err(anyhow!("unknown scheduler '{}' (one of {SCHEDS:?})", self.scheduler));
        }
        const BACKENDS: [&str; 2] = ["pjrt", "native"];
        if !BACKENDS.contains(&self.backend.as_str()) {
            return Err(anyhow!("unknown backend '{}' (one of {BACKENDS:?})", self.backend));
        }
        const MODES: [&str; 2] = ["sync", "async"];
        if !MODES.contains(&self.mode.as_str()) {
            return Err(anyhow!("unknown mode '{}' (one of {MODES:?})", self.mode));
        }
        const PROFILES: [&str; 2] = ["exact", "fast"];
        if !PROFILES.contains(&self.kernel_profile.as_str()) {
            return Err(anyhow!(
                "unknown kernel_profile '{}' (one of {PROFILES:?})",
                self.kernel_profile
            ));
        }
        if self.max_surrogate_obs == 0 {
            return Err(anyhow!("max_surrogate_obs must be >= 1"));
        }
        const PRUNERS: [&str; 3] = ["none", "median", "asha"];
        if !PRUNERS.contains(&self.pruner.as_str()) {
            return Err(anyhow!("unknown pruner '{}' (one of {PRUNERS:?})", self.pruner));
        }
        if self.pruner != "none" && self.mode != "async" {
            return Err(anyhow!(
                "pruner '{}' requires mode \"async\" (sync batches have no report channel)",
                self.pruner
            ));
        }
        if !self.asha_reduction.is_finite() || self.asha_reduction <= 1.0 {
            return Err(anyhow!(
                "asha_reduction must be a finite factor > 1 (got {})",
                self.asha_reduction
            ));
        }
        if self.resume && self.journal.is_empty() {
            return Err(anyhow!("resume requires a journal path"));
        }
        const REPLAYS: [&str; 2] = ["wallclock", "stable"];
        if !REPLAYS.contains(&self.replay.as_str()) {
            return Err(anyhow!("unknown replay '{}' (one of {REPLAYS:?})", self.replay));
        }
        if self.replay == "stable" && self.mode != "async" {
            return Err(anyhow!(
                "replay \"stable\" requires mode \"async\" (sync batches already fold \
                 deterministically)"
            ));
        }
        const JOURNAL_POLICIES: [&str; 2] = ["fail-stop", "degrade"];
        if !JOURNAL_POLICIES.contains(&self.journal_on_error.as_str()) {
            return Err(anyhow!(
                "unknown journal_on_error '{}' (one of {JOURNAL_POLICIES:?})",
                self.journal_on_error
            ));
        }
        if !self.retry_backoff_ms.is_finite() || self.retry_backoff_ms < 0.0 {
            return Err(anyhow!(
                "retry_backoff_ms must be a finite delay >= 0 (got {})",
                self.retry_backoff_ms
            ));
        }
        // journal_segment_events / journal_keep_segments / compact_on_resume
        // carry no standalone invariants: the journal-path coupling is a
        // CLI-level concern (the journaled header config deliberately
        // blanks the path, so validating it here would reject every
        // segmented journal on replay).
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("batch_size", Json::Num(self.batch_size as f64)),
            ("num_iterations", Json::Num(self.num_iterations as f64)),
            ("initial_random", Json::Num(self.initial_random as f64)),
            ("optimizer", Json::Str(self.optimizer.clone())),
            ("scheduler", Json::Str(self.scheduler.clone())),
            ("workers", Json::Num(self.workers as f64)),
            ("mc_samples", Json::Num(self.mc_samples as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("backend", Json::Str(self.backend.clone())),
            ("tune_lengthscale", Json::Bool(self.tune_lengthscale)),
            ("early_stop", Json::Num(self.early_stop as f64)),
            ("max_surrogate_obs", Json::Num(self.max_surrogate_obs as f64)),
            ("mode", Json::Str(self.mode.clone())),
            ("async_window", Json::Num(self.async_window as f64)),
            ("max_retries", Json::Num(self.max_retries as f64)),
            ("proposal_threads", Json::Num(self.proposal_threads as f64)),
            ("proposal_shards", Json::Num(self.proposal_shards as f64)),
            ("kernel_profile", Json::Str(self.kernel_profile.clone())),
            ("fsync_every_n", Json::Num(self.fsync_every_n as f64)),
            ("pruner", Json::Str(self.pruner.clone())),
            ("pruner_warmup", Json::Num(self.pruner_warmup as f64)),
            ("asha_reduction", Json::Num(self.asha_reduction)),
            ("journal", Json::Str(self.journal.clone())),
            ("resume", Json::Bool(self.resume)),
            ("replay", Json::Str(self.replay.clone())),
            ("journal_on_error", Json::Str(self.journal_on_error.clone())),
            ("retry_backoff_ms", Json::Num(self.retry_backoff_ms)),
            ("stall_timeout_ms", Json::Num(self.stall_timeout_ms as f64)),
            (
                "journal_segment_events",
                Json::Num(self.journal_segment_events as f64),
            ),
            (
                "journal_keep_segments",
                Json::Num(self.journal_keep_segments as f64),
            ),
            ("compact_on_resume", Json::Bool(self.compact_on_resume)),
        ])
    }
}

/// A whole experiment: a run config repeated `repeats` times on a named
/// workload (what the figure harnesses consume).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub workload: String,
    pub repeats: usize,
    pub run: RunConfig,
}

impl ExperimentConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let obj = j.as_obj().ok_or_else(|| anyhow!("experiment must be an object"))?;
        let name = obj
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("experiment needs 'name'"))?
            .to_string();
        let workload = obj
            .get("workload")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("experiment needs 'workload'"))?
            .to_string();
        let repeats = obj.get("repeats").and_then(|v| v.as_usize()).unwrap_or(1);
        let run = match obj.get("run") {
            Some(r) => RunConfig::from_json(r)?,
            None => RunConfig::default(),
        };
        Ok(Self { name, workload, repeats, run })
    }
}

fn num(v: &Json, k: &str) -> Result<f64> {
    v.as_f64().ok_or_else(|| anyhow!("{k}: expected number"))
}

fn str_(v: &Json, k: &str) -> Result<String> {
    Ok(v.as_str().ok_or_else(|| anyhow!("{k}: expected string"))?.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json::parse;

    #[test]
    fn defaults_are_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn from_json_overrides() {
        let j = parse(r#"{"batch_size": 5, "optimizer": "clustering", "seed": 7}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.batch_size, 5);
        assert_eq!(c.optimizer, "clustering");
        assert_eq!(c.seed, 7);
        assert_eq!(c.num_iterations, 60); // default preserved
    }

    #[test]
    fn rejects_unknown_key_and_bad_values() {
        assert!(RunConfig::from_json(&parse(r#"{"bogus": 1}"#).unwrap()).is_err());
        assert!(RunConfig::from_json(&parse(r#"{"batch_size": 0}"#).unwrap()).is_err());
        assert!(RunConfig::from_json(&parse(r#"{"optimizer": "sgd"}"#).unwrap()).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let c = RunConfig {
            batch_size: 5,
            seed: 42,
            early_stop: 4,
            max_surrogate_obs: 256,
            mode: "async".into(),
            async_window: 9,
            max_retries: 3,
            ..Default::default()
        };
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn async_fields_parse_and_validate() {
        let j = parse(
            r#"{"mode": "async", "async_window": 6, "max_retries": 1,
                "early_stop": 5, "max_surrogate_obs": 64}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.mode, "async");
        assert_eq!(c.async_window, 6);
        assert_eq!(c.max_retries, 1);
        assert_eq!(c.early_stop, 5);
        assert_eq!(c.max_surrogate_obs, 64);
        assert!(RunConfig::from_json(&parse(r#"{"mode": "batch"}"#).unwrap()).is_err());
        assert!(
            RunConfig::from_json(&parse(r#"{"max_surrogate_obs": 0}"#).unwrap()).is_err()
        );
    }

    #[test]
    fn perf_knobs_parse_default_and_roundtrip() {
        // Absent keys keep the defaults: single-threaded scoring,
        // flush-only journal durability.
        let c = RunConfig::from_json(&parse("{}").unwrap()).unwrap();
        assert_eq!(c.proposal_threads, 1);
        assert_eq!(c.proposal_shards, 0, "local-only scoring by default");
        assert_eq!(c.fsync_every_n, 0);
        let j = parse(r#"{"proposal_threads": 8, "proposal_shards": 4, "fsync_every_n": 32}"#)
            .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.proposal_threads, 8);
        assert_eq!(c.proposal_shards, 4);
        assert_eq!(c.fsync_every_n, 32);
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2, "perf knobs survive the json round trip");
    }

    #[test]
    fn journal_fields_parse_and_validate() {
        let j = parse(r#"{"journal": "/tmp/run.jsonl", "resume": true}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.journal, "/tmp/run.jsonl");
        assert!(c.resume);
        // resume without a journal path is rejected loudly.
        assert!(RunConfig::from_json(&parse(r#"{"resume": true}"#).unwrap()).is_err());
        // journal alone (fresh journaled run) is fine.
        let c = RunConfig::from_json(&parse(r#"{"journal": "j.jsonl"}"#).unwrap()).unwrap();
        assert!(!c.resume);
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2, "journal fields survive the json round trip");
    }

    #[test]
    fn kernel_profile_parses_validates_and_roundtrips() {
        let c = RunConfig::from_json(&parse("{}").unwrap()).unwrap();
        assert_eq!(c.kernel_profile, "exact", "exact is the default profile");
        let c =
            RunConfig::from_json(&parse(r#"{"kernel_profile": "fast"}"#).unwrap()).unwrap();
        assert_eq!(c.kernel_profile, "fast");
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2, "kernel_profile survives the json round trip");
        assert!(
            RunConfig::from_json(&parse(r#"{"kernel_profile": "simd"}"#).unwrap()).is_err(),
            "unknown profiles are rejected loudly"
        );
    }

    #[test]
    fn pruner_fields_parse_validate_and_roundtrip() {
        let c = RunConfig::from_json(&parse("{}").unwrap()).unwrap();
        assert_eq!(c.pruner, "none", "pruning is off by default");
        assert_eq!(c.pruner_warmup, 1);
        assert_eq!(c.asha_reduction, 3.0);
        let j = parse(
            r#"{"mode": "async", "pruner": "asha", "pruner_warmup": 2,
                "asha_reduction": 4.0}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.pruner, "asha");
        assert_eq!(c.pruner_warmup, 2);
        assert_eq!(c.asha_reduction, 4.0);
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2, "pruner knobs survive the json round trip");
        // Unknown rules, sync-mode pruning, and degenerate eta are loud.
        assert!(RunConfig::from_json(&parse(r#"{"pruner": "hyperband"}"#).unwrap()).is_err());
        assert!(
            RunConfig::from_json(&parse(r#"{"pruner": "median"}"#).unwrap()).is_err(),
            "pruning requires async mode"
        );
        assert!(RunConfig::from_json(
            &parse(r#"{"mode": "async", "pruner": "asha", "asha_reduction": 1.0}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn segment_fields_parse_validate_and_roundtrip() {
        let c = RunConfig::from_json(&parse("{}").unwrap()).unwrap();
        assert_eq!(c.journal_segment_events, 0, "single-file layout by default");
        assert_eq!(c.journal_keep_segments, 2);
        assert!(!c.compact_on_resume);
        let j = parse(
            r#"{"journal": "run.jsonl", "journal_segment_events": 64,
                "journal_keep_segments": 3, "compact_on_resume": true,
                "resume": true}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.journal_segment_events, 64);
        assert_eq!(c.journal_keep_segments, 3);
        assert!(c.compact_on_resume);
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2, "segment knobs survive the json round trip");
        // A journaled header blanks the journal path, so segment knobs must
        // stay valid without one (the CLI enforces the flag coupling).
        let c3 = RunConfig::from_json(
            &parse(r#"{"journal_segment_events": 4, "journal_keep_segments": 0}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(c3.journal_segment_events, 4);
        assert_eq!(c3.journal_keep_segments, 0);
        assert!(RunConfig::from_json(&parse(r#"{"compact_on_resume": 1}"#).unwrap()).is_err());
    }

    #[test]
    fn replay_and_robustness_fields_parse_validate_and_roundtrip() {
        let c = RunConfig::from_json(&parse("{}").unwrap()).unwrap();
        assert_eq!(c.replay, "wallclock", "arrival-order folding is the default");
        assert_eq!(c.journal_on_error, "fail-stop");
        assert_eq!(c.retry_backoff_ms, 0.0);
        assert_eq!(c.stall_timeout_ms, 3_600_000);
        let j = parse(
            r#"{"mode": "async", "replay": "stable", "journal_on_error": "degrade",
                "retry_backoff_ms": 250.5, "stall_timeout_ms": 60000}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.replay, "stable");
        assert_eq!(c.journal_on_error, "degrade");
        assert_eq!(c.retry_backoff_ms, 250.5);
        assert_eq!(c.stall_timeout_ms, 60_000);
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, c2, "replay knobs survive the json round trip");
        // Unknown modes/policies and stable-on-sync are rejected loudly.
        assert!(RunConfig::from_json(&parse(r#"{"replay": "sorted"}"#).unwrap()).is_err());
        assert!(
            RunConfig::from_json(&parse(r#"{"replay": "stable"}"#).unwrap()).is_err(),
            "stable replay requires async mode"
        );
        assert!(
            RunConfig::from_json(&parse(r#"{"journal_on_error": "retry"}"#).unwrap()).is_err()
        );
        assert!(
            RunConfig::from_json(&parse(r#"{"retry_backoff_ms": -1.0}"#).unwrap()).is_err()
        );
    }

    #[test]
    fn experiment_parse() {
        let j = parse(
            r#"{"name": "fig2", "workload": "wine_gbt", "repeats": 20,
                "run": {"batch_size": 5}}"#,
        )
        .unwrap();
        let e = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(e.repeats, 20);
        assert_eq!(e.run.batch_size, 5);
    }
}
