//! Minimal JSON: parse + serialize, sufficient for the artifact manifest,
//! experiment configs, and results dumps. RFC 8259 subset: no surrogate
//! escapes beyond \uXXXX pass-through, numbers as f64.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError { pos: self.pos, msg: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err(format!("expected '{lit}'"))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or(ParseError {
                                pos: self.pos,
                                msg: "eof in \\u escape".into(),
                            })?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or(ParseError {
                                    pos: self.pos,
                                    msg: "bad hex digit".into(),
                                })?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x20 => return self.err("control char in string"),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences verbatim.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.b.len());
                        if let Ok(chunk) = std::str::from_utf8(&self.b[start..end]) {
                            s.push_str(chunk);
                            self.pos = end;
                        } else {
                            return self.err("invalid utf-8");
                        }
                    }
                }
                None => return self.err("unterminated string"),
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| ParseError { pos: start, msg: format!("bad number: {e}") })
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m_cand":512,"n_variants":[64,128],"programs":{"64":{"fit":"gp_fit_n64.hlo.txt"}}}"#;
        let j = parse(src).unwrap();
        let again = parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn display_escapes() {
        let j = Json::Str("a\"b\\c\n".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\n""#);
    }
}
