//! Configuration: a hand-rolled JSON value type + parser/serializer (the
//! offline registry has no serde) and the typed experiment/tuner config
//! loaded by the CLI.

pub mod json;
pub mod settings;

pub use json::{parse as parse_json, Json};
pub use settings::{ExperimentConfig, RunConfig};
