//! Crash-safe run persistence: the append-only JSONL run journal and the
//! resume path that replays it.
//!
//! The Mango paper names fault tolerance as a gap blocking practical
//! large-scale tuning; production tuning services (Tune, Auptimizer) treat
//! experiment checkpointing/resume as a core primitive. This subsystem
//! makes a run survive coordinator death:
//!
//! * [`journal`] — the event log: a header (schema version, search-space
//!   fingerprint, full `RunConfig` + seed, objective sense) and one line
//!   per proposal, submission, intermediate report, completion (including
//!   `Lost` fates and `Pruned` cancellations), and optimizer round. Writes
//!   are line-atomic-on-kill: at most one torn trailing line, which the
//!   reader detects and drops.
//! * [`recover`] — pure replay: reconstructs the history (including
//!   censored entries of pruned trials), report streams, pending set
//!   (with retry counters), telemetry, and RNG/rounds state without
//!   calling the objective or fitting anything.
//! * [`segment`] — bounded-footprint layout: with
//!   `--journal-segment-events N` the writer rotates through sealed,
//!   checksummed segment files instead of one unbounded log; recovery
//!   becomes segment-aware (one torn trailing line tolerated only in the
//!   newest active segment — a damaged *sealed* segment is corruption).
//! * [`compact`] — folds a sealed segment prefix into one `checkpoint`
//!   record (the complete replay-fold state, round-trip exact), so resume
//!   cost and disk footprint are O(active window), not O(run length).
//! * [`corpus`] — a fingerprint-keyed JSONL manifest over accumulated
//!   journals: runs → segments/checkpoints → final best, the queryable
//!   substrate the warm-start direction builds on.
//!
//! `Tuner::with_journal` turns journaling on; `Tuner::resume_from` builds
//! a tuner from a journal and continues the run where it died. With a
//! fixed seed and a deterministic scheduler, crash-at-any-point + resume
//! reproduces the uninterrupted run's best config and `History` exactly —
//! the property `rust/tests/recovery.rs` enforces for every event-boundary
//! crash point (including mid-rotation and mid-compaction kills) in both
//! execution modes.

pub mod compact;
pub mod corpus;
pub mod journal;
pub mod recover;
pub mod segment;

pub use compact::compact;
pub use corpus::RunRecord;
pub use journal::{
    read_journal, EventOutcome, JournalError, JournalEvent, JournalFault, JournalPolicy,
    JournalWriter, RunHeader, SenseTag, JOURNAL_MAGIC, JOURNAL_VERSION,
};
pub use recover::{
    recover, AsyncReplay, CompletionLogEntry, PartialRound, PendingReplay, RecoveredRun,
    Replay, RoundRecord, SyncReplay, TerminalReplay,
};
pub use segment::{
    read_run, CheckpointRecord, JournalLayout, RunStream, SegmentOpts, SegmentedWriter,
};
