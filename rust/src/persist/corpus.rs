//! The run corpus: a fingerprint-keyed JSONL manifest over accumulated
//! run journals.
//!
//! Every tuning run leaves a journal (single-file or segmented); the
//! corpus index makes that accumulation queryable: one manifest line per
//! run, keyed by `SearchSpace::fingerprint()`, recording the layout
//! (segments / checkpoints), the event and evaluation counts, and the
//! final best value. Grouping by fingerprint is what makes the corpus a
//! warm-start substrate: runs that share a fingerprint explored the *same*
//! space, so their histories are directly transferable.
//!
//! Deliberately timestamp-free (pallas-lint R1): records are derived
//! purely from journal content, so re-indexing the same directory yields
//! byte-identical manifests — the corpus is reproducible evidence, not a
//! log.

use super::journal::split_jsonl;
use super::recover::{recover, Replay};
use super::segment::{self, JournalLayout};
use crate::config::json::{parse, Json};
use crate::persist::journal::RunHeader;
use crate::space::{f64_from_json, f64_to_json};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::{Path, PathBuf};

/// One manifest line: a single run journal, summarized.
#[derive(Clone, Debug, PartialEq)]
pub struct RunRecord {
    /// `SearchSpace::fingerprint()` of the space the run explored.
    pub space_fp: u64,
    /// Journal base path, as indexed (manifest-relative or absolute,
    /// whatever the caller handed `scan_journal`).
    pub journal: String,
    /// `"sync"` / `"async"`.
    pub mode: String,
    /// `"maximize"` / `"minimize"`.
    pub sense: String,
    pub seed: u64,
    /// Live segment files (1 for a single-file journal).
    pub segments: u64,
    /// Checkpoint records present (0 or 1 today).
    pub checkpoints: u64,
    /// Events in the replayable stream (post-checkpoint tail for a
    /// compacted journal).
    pub events: u64,
    /// History entries the run accumulated (successful + censored).
    pub evaluations: u64,
    /// Best objective value over the run's history, user sense
    /// (`None`: no finite evaluation landed).
    pub best: Option<f64>,
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("space_fp", Json::Str(format!("{:016x}", self.space_fp))),
            ("journal", Json::Str(self.journal.clone())),
            ("mode", Json::Str(self.mode.clone())),
            ("sense", Json::Str(self.sense.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("segments", Json::Num(self.segments as f64)),
            ("checkpoints", Json::Num(self.checkpoints as f64)),
            ("events", Json::Num(self.events as f64)),
            ("evaluations", Json::Num(self.evaluations as f64)),
            (
                "best",
                match self.best {
                    Some(v) => f64_to_json(v),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        use super::journal::{req_str, req_u64};
        let fp_hex = req_str(j, "space_fp")?;
        let space_fp = u64::from_str_radix(fp_hex, 16)
            .map_err(|e| anyhow!("bad space fingerprint '{fp_hex}': {e}"))?;
        let best = match j.get("best") {
            None | Some(Json::Null) => None,
            Some(v) => Some(f64_from_json(v)?),
        };
        Ok(Self {
            space_fp,
            journal: req_str(j, "journal")?.to_string(),
            mode: req_str(j, "mode")?.to_string(),
            sense: req_str(j, "sense")?.to_string(),
            seed: req_u64(j, "seed")?,
            segments: req_u64(j, "segments")?,
            checkpoints: req_u64(j, "checkpoints")?,
            events: req_u64(j, "events")?,
            evaluations: req_u64(j, "evaluations")?,
            best,
        })
    }
}

/// Summarize the run journal at `path` into a manifest record. Works on
/// both layouts; a compacted journal's evaluation counts and best come
/// from the checkpointed replay, identical to what a full-stream replay
/// would report.
pub fn scan_journal(path: &Path) -> Result<RunRecord> {
    let stream = segment::read_run(path)?;
    let rec = recover(path)?;
    let segments = match &stream.layout {
        JournalLayout::Single => 1,
        JournalLayout::Segmented { sealed, .. } => sealed.len() as u64 + 1,
    };
    let history: &[(crate::space::Config, f64)] = match &rec.replay {
        Replay::Sync(s) => &s.history,
        Replay::Async(a) => &a.history,
    };
    let sense = stream.header.sense;
    let mut best: Option<f64> = None;
    for &(_, v) in history {
        if v.is_nan() {
            continue;
        }
        best = Some(match best {
            None => v,
            Some(b) => {
                let better = match sense {
                    super::journal::SenseTag::Maximize => v > b,
                    super::journal::SenseTag::Minimize => v < b,
                };
                if better {
                    v
                } else {
                    b
                }
            }
        });
    }
    Ok(RunRecord {
        space_fp: stream.header.space_fp,
        journal: path.to_string_lossy().into_owned(),
        mode: stream.header.run.mode.clone(),
        sense: sense.as_str().to_string(),
        seed: stream.header.run.seed,
        segments,
        checkpoints: u64::from(stream.checkpoint.is_some()),
        events: stream.events.len() as u64,
        evaluations: history.len() as u64,
        best,
    })
}

/// Append one record to the manifest (creating it if needed). The
/// manifest is itself JSONL with the journal's torn-tail contract, so a
/// crash mid-append costs at most the line being written.
pub fn append_record(manifest: &Path, rec: &RunRecord) -> Result<()> {
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(manifest)
        .with_context(|| format!("opening corpus manifest {}", manifest.display()))?;
    let mut line = rec.to_json().to_string();
    line.push('\n');
    f.write_all(line.as_bytes())
        .with_context(|| format!("appending to corpus manifest {}", manifest.display()))?;
    f.flush().with_context(|| format!("flushing corpus manifest {}", manifest.display()))?;
    Ok(())
}

/// Load the manifest, grouped by space fingerprint (the warm-start
/// lookup key). A missing manifest is an empty corpus; one unterminated
/// trailing line is dropped (torn append); a newline-terminated malformed
/// line is corruption and fails loudly.
pub fn load(manifest: &Path) -> Result<BTreeMap<u64, Vec<RunRecord>>> {
    let bytes = match std::fs::read(manifest) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(BTreeMap::new()),
        Err(e) => {
            return Err(anyhow!(e))
                .with_context(|| format!("reading corpus manifest {}", manifest.display()))
        }
    };
    let mut out: BTreeMap<u64, Vec<RunRecord>> = BTreeMap::new();
    for (idx, (_, raw, terminated)) in split_jsonl(&bytes).iter().enumerate() {
        if !terminated {
            crate::log_debug!(
                "corpus manifest {}: dropping unterminated trailing line (torn append)",
                manifest.display()
            );
            break;
        }
        if raw.is_empty() {
            continue;
        }
        let text = std::str::from_utf8(raw)
            .map_err(|e| anyhow!("corpus manifest line {}: non-utf8: {e}", idx + 1))?;
        let j = parse(text).with_context(|| {
            format!(
                "corpus manifest {} corrupted at line {} (newline-terminated, so not \
                 a torn append)",
                manifest.display(),
                idx + 1
            )
        })?;
        let rec = RunRecord::from_json(&j)
            .with_context(|| format!("corpus manifest line {}", idx + 1))?;
        out.entry(rec.space_fp).or_default().push(rec);
    }
    Ok(out)
}

/// Discover the run journals under `dir` (non-recursive): segmented runs
/// by their `.seg000000` file, single-file runs by a header probe on the
/// first line. Derived files (`.seg*`, `.tmp`, `.quarantined`) and the
/// manifest itself are skipped.
fn discover_journals(dir: &Path, manifest: &Path) -> Result<Vec<PathBuf>> {
    let mut bases: BTreeMap<PathBuf, ()> = BTreeMap::new();
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("listing corpus directory {}", dir.display()))?;
    for entry in entries {
        let entry = entry
            .with_context(|| format!("listing corpus directory {}", dir.display()))?;
        let path = entry.path();
        if path == manifest {
            continue;
        }
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.ends_with(".tmp") || name.ends_with(".quarantined") {
            continue;
        }
        if let Some(pos) = name.rfind(".seg") {
            let suffix = &name[pos + 4..];
            if suffix.len() == 6 && suffix.bytes().all(|b| b.is_ascii_digit()) {
                if suffix == "000000" {
                    let mut base = path.clone().into_os_string().to_string_lossy().into_owned();
                    base.truncate(base.len() - ".seg000000".len());
                    bases.insert(PathBuf::from(base), ());
                }
                continue; // higher segments never name a run by themselves
            }
        }
        // Single-file candidate: probe the first terminated line for a
        // valid run header; anything else is not a journal, skip quietly.
        let Ok(bytes) = std::fs::read(&path) else { continue };
        let Some((_, raw, true)) = split_jsonl(&bytes).first().copied() else { continue };
        let Ok(text) = std::str::from_utf8(raw) else { continue };
        let Ok(j) = parse(text) else { continue };
        if RunHeader::from_json(&j).is_ok() {
            bases.insert(path, ());
        }
    }
    Ok(bases.into_keys().collect())
}

/// Rebuild the manifest from the journals under `dir` (deterministic
/// path order) and return the records. A journal that fails to scan is
/// skipped with a warning — one corrupt run must not hide the rest of
/// the corpus.
pub fn index_dir(dir: &Path, manifest: &Path) -> Result<Vec<RunRecord>> {
    let mut records = Vec::new();
    for base in discover_journals(dir, manifest)? {
        match scan_journal(&base) {
            Ok(rec) => records.push(rec),
            Err(e) => {
                crate::log_warn!(
                    "corpus index: skipping unreadable journal {}: {e:#}",
                    base.display()
                );
            }
        }
    }
    // Rebuild wholesale: same directory in, same manifest bytes out.
    let mut body = String::new();
    for rec in &records {
        body.push_str(&rec.to_json().to_string());
        body.push('\n');
    }
    std::fs::write(manifest, body.as_bytes())
        .with_context(|| format!("writing corpus manifest {}", manifest.display()))?;
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::settings::RunConfig;
    use crate::persist::journal::{EventOutcome, JournalEvent, JournalWriter, SenseTag};
    use crate::persist::segment::{SegmentOpts, SegmentedWriter};
    use crate::space::{Config, ParamValue};

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("mango_corpus_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn cfg(i: i64) -> Config {
        Config::new(vec![("i".into(), ParamValue::Int(i))])
    }

    fn header(fp: u64, seed: u64, segment_events: usize) -> RunHeader {
        RunHeader {
            space_fp: fp,
            sense: SenseTag::Maximize,
            run: RunConfig {
                mode: "async".into(),
                seed,
                journal_segment_events: segment_events,
                ..Default::default()
            },
            celery: None,
        }
    }

    fn run_events(n: u64) -> Vec<JournalEvent> {
        let mut ev = Vec::new();
        for i in 0..n {
            ev.push(JournalEvent::AsyncPropose { pid: i, rounds: 0, config: cfg(i as i64) });
            ev.push(JournalEvent::AsyncSubmit {
                pid: i,
                task: i,
                retries: 0,
                cutoff: 0,
                backoff_ms: 0.0,
            });
            ev.push(JournalEvent::AsyncComplete {
                pid: i,
                task: i,
                retries: 0,
                outcome: EventOutcome::Done(i as f64),
                queue_ms: 0.0,
                eval_ms: 0.0,
            });
        }
        ev
    }

    #[test]
    fn record_roundtrips_through_json_including_non_finite_best() {
        for best in [None, Some(1.5), Some(f64::NEG_INFINITY)] {
            let rec = RunRecord {
                space_fp: 0xabcd_ef01_2345_6789,
                journal: "runs/a.jsonl".into(),
                mode: "async".into(),
                sense: "maximize".into(),
                seed: 42,
                segments: 3,
                checkpoints: 1,
                events: 17,
                evaluations: 5,
                best,
            };
            let j = parse(&rec.to_json().to_string()).unwrap();
            assert_eq!(RunRecord::from_json(&j).unwrap(), rec);
        }
    }

    #[test]
    fn scan_summarizes_single_and_segmented_runs() {
        let d = tmpdir("scan");
        let single = d.join("single.jsonl");
        {
            let mut w = JournalWriter::create(&single, &header(11, 1, 0)).unwrap();
            for ev in &run_events(3) {
                w.append(ev).unwrap();
            }
        }
        let rec = scan_journal(&single).unwrap();
        assert_eq!(rec.space_fp, 11);
        assert_eq!(rec.segments, 1);
        assert_eq!(rec.checkpoints, 0);
        assert_eq!(rec.events, 9);
        assert_eq!(rec.evaluations, 3);
        assert_eq!(rec.best, Some(2.0), "maximize: best of 0,1,2");

        let seg = d.join("seg.jsonl");
        {
            let o = SegmentOpts { segment_events: 4, keep_segments: 0, fsync_every_n: 0 };
            let mut w = SegmentedWriter::create(&seg, &header(11, 2, 4), o).unwrap();
            for ev in &run_events(4) {
                w.append(ev).unwrap();
            }
        }
        let rec = scan_journal(&seg).unwrap();
        assert_eq!(rec.checkpoints, 1, "live compaction checkpointed the prefix");
        assert_eq!(rec.evaluations, 4, "evaluations count through the checkpoint");
        assert_eq!(rec.best, Some(3.0));
        assert!(rec.events < 12, "a compacted journal replays only the tail");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn manifest_appends_load_grouped_by_fingerprint_and_tolerate_torn_tail() {
        let d = tmpdir("manifest");
        let manifest = d.join("corpus.jsonl");
        let rec = |fp: u64, seed: u64| RunRecord {
            space_fp: fp,
            journal: format!("run{seed}.jsonl"),
            mode: "async".into(),
            sense: "maximize".into(),
            seed,
            segments: 1,
            checkpoints: 0,
            events: 0,
            evaluations: 0,
            best: None,
        };
        append_record(&manifest, &rec(1, 10)).unwrap();
        append_record(&manifest, &rec(2, 20)).unwrap();
        append_record(&manifest, &rec(1, 11)).unwrap();
        // Torn append: dropped, everything before it survives.
        {
            let mut f =
                std::fs::OpenOptions::new().append(true).open(&manifest).unwrap();
            f.write_all(b"{\"space_fp\":\"00").unwrap();
        }
        let by_fp = load(&manifest).unwrap();
        assert_eq!(by_fp.len(), 2);
        assert_eq!(by_fp[&1].len(), 2);
        assert_eq!(by_fp[&1][1].seed, 11);
        assert_eq!(by_fp[&2].len(), 1);
        // A terminated malformed line is corruption, not a torn append.
        {
            let mut f =
                std::fs::OpenOptions::new().write(true).truncate(true).open(&manifest).unwrap();
            f.write_all(b"{\"space_fp\":\"zz\"}\n").unwrap();
        }
        assert!(load(&manifest).is_err());
        // Missing manifest = empty corpus.
        assert!(load(&d.join("absent.jsonl")).unwrap().is_empty());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn index_dir_discovers_both_layouts_and_is_deterministic() {
        let d = tmpdir("index");
        let manifest = d.join("corpus.jsonl");
        {
            let mut w = JournalWriter::create(&d.join("a.jsonl"), &header(5, 1, 0)).unwrap();
            for ev in &run_events(2) {
                w.append(ev).unwrap();
            }
        }
        {
            let o = SegmentOpts { segment_events: 3, keep_segments: 100, fsync_every_n: 0 };
            let mut w =
                SegmentedWriter::create(&d.join("b.jsonl"), &header(5, 2, 3), o).unwrap();
            for ev in &run_events(3) {
                w.append(ev).unwrap();
            }
        }
        // Noise the index must ignore.
        std::fs::write(d.join("notes.txt"), b"not a journal\n").unwrap();
        std::fs::write(d.join("b.jsonl.seg000000.tmp"), b"staging").unwrap();

        let records = index_dir(&d, &manifest).unwrap();
        assert_eq!(records.len(), 2, "got: {records:?}");
        let names: Vec<&str> = records
            .iter()
            .map(|r| r.journal.rsplit('/').next().unwrap_or(&r.journal))
            .collect();
        assert_eq!(names, vec!["a.jsonl", "b.jsonl"], "deterministic path order");
        assert!(records.iter().all(|r| r.space_fp == 5));
        // The manifest round-trips through load()...
        let by_fp = load(&manifest).unwrap();
        assert_eq!(by_fp[&5].len(), 2);
        // ...and re-indexing is byte-identical (no timestamps, no drift).
        let bytes = std::fs::read(&manifest).unwrap();
        index_dir(&d, &manifest).unwrap();
        assert_eq!(std::fs::read(&manifest).unwrap(), bytes);
        std::fs::remove_dir_all(&d).ok();
    }
}
