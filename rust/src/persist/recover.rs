//! Journal replay: turn a (possibly crash-truncated) run journal back
//! into coordinator state.
//!
//! Replay is *pure data reconstruction* — no objective is called, no GP is
//! fit, no RNG is advanced. The coordinator then:
//!
//! * restores `History` (arrival order, bit-exact values via the canonical
//!   codec), the per-completion telemetry log, retry/lost counters, and —
//!   in sync mode — the shared RNG stream state journaled after the last
//!   propose;
//! * re-enqueues configurations that were in flight at the crash (async) or
//!   re-evaluates the un-absorbed remainder of a partially completed batch
//!   (sync);
//! * rehydrates the optimizer ([`crate::optimizer::BatchOptimizer::
//!   rehydrate`]): the adaptive-beta rounds clock is restored from the
//!   journal and the GP's `CholeskyState` is rebuilt from the replayed
//!   rows through the incremental append path — O(n²) per replayed
//!   observation (one factorization pass in total), never an O(n³) refit
//!   per replayed event — and bit-identical to the factor the crashed
//!   process held.
//!
//! With a fixed seed and a deterministic scheduler, the resumed run's
//! proposals, history, and best config are exactly those of an
//! uninterrupted run: everything behavior-affecting is either journaled
//! (RNG state, rounds, in-flight set and order) or recomputed from
//! journaled data by the same arithmetic.
//!
//! Replay is implemented as *streaming folds* ([`SyncFold`] /
//! [`AsyncFold`]): one event at a time into an explicit state struct,
//! finished into the public [`SyncReplay`] / [`AsyncReplay`] views only at
//! the end. The mid-scan fold state is exactly what journal compaction
//! ([`crate::persist::compact`]) snapshots into a `checkpoint` record —
//! recovery of a compacted journal deserializes the checkpoint back into
//! a fold and keeps folding the tail segments, which is why
//! `recover(checkpoint + tail)` is bit-identical to `recover(full
//! stream)`.

use super::journal::{EventOutcome, JournalEvent, RunHeader, SenseTag};
use super::segment::{self, JournalLayout};
use crate::optimizer::prune;
use crate::space::{Config, SearchSpace};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One completed sync iteration, as journaled.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundRecord {
    pub iter: usize,
    pub proposed: usize,
    pub returned: usize,
    pub best: f64,
    pub wall_ms: f64,
}

/// The partially evaluated batch at crash time (sync mode): the proposed
/// configs plus whichever evaluations were journaled before the kill.
#[derive(Clone, Debug, PartialEq)]
pub struct PartialRound {
    pub iter: usize,
    pub batch: Vec<Config>,
    /// Journaled evaluations, in arrival order (`None` = objective failed).
    pub evals: Vec<(Config, Option<f64>)>,
}

/// Replay state for a sync-mode journal.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SyncReplay {
    /// Completed iterations, in order.
    pub rounds_done: Vec<RoundRecord>,
    /// Successful evaluations of completed iterations, arrival order,
    /// user objective sense.
    pub history: Vec<(Config, f64)>,
    /// The iteration interrupted mid-batch, if the crash split one.
    pub partial: Option<PartialRound>,
    /// Shared coordinator RNG state after the last journaled propose
    /// (`None`: nothing was proposed before the crash).
    pub rng_state: Option<u128>,
    /// Optimizer rounds counter after the last journaled propose.
    pub rounds: usize,
}

/// One completion event, replayed for the telemetry log.
#[derive(Clone, Debug, PartialEq)]
pub struct CompletionLogEntry {
    pub task: u64,
    pub retries: usize,
    pub outcome: EventOutcome,
    pub queue_ms: f64,
    pub eval_ms: f64,
}

/// One concluded proposal (terminal completion), in conclusion order.
#[derive(Clone, Debug, PartialEq)]
pub struct TerminalReplay {
    pub task: u64,
    pub retries: usize,
    pub outcome: EventOutcome,
    /// queue + eval wall of the concluding completion (IterationRecord).
    pub wall_ms: f64,
    /// Proposals journaled since the previous terminal conclusion — the
    /// event loop's `proposed_since_record` bookkeeping.
    pub proposed_before: usize,
    /// Did this conclusion push a history entry? True for `Done` and for
    /// `Pruned` whose censored value (recomputed here under the same
    /// worst-seen policy as the live loop) was `Some`.
    pub contributed: bool,
}

/// A proposal in flight at the crash, to be re-enqueued on resume.
#[derive(Clone, Debug, PartialEq)]
pub struct PendingReplay {
    pub pid: u64,
    pub config: Config,
    /// Retries already consumed — the retry budget is honored *across*
    /// restarts, not per process lifetime.
    pub retries: usize,
    /// The stable-mode fold frontier the task was admitted under (its
    /// last journaled submit's `cutoff`). A resume re-registers the
    /// re-enqueued task with this original cutoff, so its pruning
    /// comparisons match the seed-matched uninterrupted run instead of
    /// widening to everything folded before the crash.
    pub cutoff: u64,
    /// Deterministic retry backoff the last submission carried; a resume
    /// re-applies it so the replayed execution schedule matches.
    pub backoff_ms: f64,
}

/// Replay state for an async-mode journal.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AsyncReplay {
    /// Done completions in arrival order, user objective sense.
    pub history: Vec<(Config, f64)>,
    /// Terminal conclusions in order (drives best-series/records rebuild).
    pub terminals: Vec<TerminalReplay>,
    /// Every completion event (incl. `resubmitted` intermediates).
    pub completion_log: Vec<CompletionLogEntry>,
    /// In-flight at crash, ordered by their last submission — the same
    /// order the crashed coordinator's pending map iterated in, so
    /// constant-liar fits see identical pending rows after resume.
    pub pending: Vec<PendingReplay>,
    /// Stable proposal ids handed out so far (resume continues from here).
    pub proposals_made: u64,
    /// Optimizer rounds counter after the last journaled propose.
    pub rounds: usize,
    /// Task-id high-water mark + 1 (scheduler ids stay unique across
    /// restarts).
    pub next_task_id: u64,
    /// Losses that were resubmitted / proposals abandoned, replayed.
    pub retried: u64,
    pub lost: u64,
    /// Proposals journaled after the last terminal conclusion (carried
    /// into the resumed loop's `proposed_since_record`).
    pub trailing_proposed: usize,
    /// Intermediate reports of *concluded* proposals, journal order:
    /// `(pid, step, user-sense value, pruned decision)`. Reports of
    /// in-flight-at-crash proposals are dropped — those trials re-execute
    /// and re-report from scratch on resume.
    pub reports: Vec<(u64, u64, f64, bool)>,
    /// Trials the crashed run's pruner cancelled, replayed.
    pub pruned: u64,
    /// Fold-epoch markers seen (`--replay stable`); the resumed loop
    /// continues its epoch counter from here.
    pub epochs: u64,
    /// Final task id of every *concluded* proposal, ascending by pid —
    /// seeds the stable-mode pruning filter, whose cutoff comparisons
    /// need each concluded proposal's last task id.
    pub pid_last_task: Vec<(u64, u64)>,
    /// The run gave up on in-flight work via the stall backstop. (Its
    /// `async_stalled` terminals are already folded into `terminals` /
    /// `lost`; the flag is telemetry.)
    pub stalled: bool,
}

/// Mode-specific replay payload.
#[derive(Clone, Debug, PartialEq)]
pub enum Replay {
    Sync(SyncReplay),
    Async(AsyncReplay),
}

/// A parsed + replayed journal, ready to hand to `Tuner::resume_from`.
#[derive(Debug)]
pub struct RecoveredRun {
    pub header: RunHeader,
    /// Valid byte prefix of the *active* file — the single journal file,
    /// or the newest live segment (a torn trailing line is excluded; the
    /// resumed writer truncates to this before appending).
    pub valid_len: u64,
    /// On-disk layout the journal was recovered from; the resumed writer
    /// reopens the matching file(s).
    pub layout: JournalLayout,
    pub replay: Replay,
}

impl RecoveredRun {
    /// Refuse to replay against a space that doesn't match the journal's
    /// fingerprint — a changed space would silently re-encode replayed
    /// configs into different GP features.
    pub fn validate_space(&self, space: &SearchSpace) -> Result<()> {
        let fp = space.fingerprint();
        anyhow::ensure!(
            fp == self.header.space_fp,
            "journal was recorded for a different search space \
             (journal fingerprint {:016x}, this space {:016x})",
            self.header.space_fp,
            fp
        );
        Ok(())
    }
}

/// Read, validate, and replay the journal at `path` — a single file or a
/// set of `<path>.segNNNNNN` segment files (discovered automatically).
/// Segmented journals resume from their newest `checkpoint` record, if
/// any: segments it covers are skipped entirely, so replay cost is
/// O(events since the checkpoint), not O(run length).
pub fn recover(path: &Path) -> Result<RecoveredRun> {
    let stream = segment::read_run(path)?;
    let stable = stream.header.run.replay == "stable";
    let replay = match stream.header.run.mode.as_str() {
        "sync" => {
            let mut fold = match &stream.checkpoint {
                Some(cp) => super::compact::sync_fold_from_checkpoint(cp)?,
                None => SyncFold::new(),
            };
            for ev in &stream.events {
                fold.fold(ev)?;
            }
            Replay::Sync(fold.finish())
        }
        "async" => {
            let sense = stream.header.sense;
            let mut fold = match &stream.checkpoint {
                Some(cp) => super::compact::async_fold_from_checkpoint(cp, sense, stable)?,
                None => AsyncFold::new(sense, stable),
            };
            for ev in &stream.events {
                fold.fold(ev)?;
            }
            Replay::Async(fold.finish())
        }
        other => return Err(anyhow!("journal header has unknown mode '{other}'")),
    };
    Ok(RecoveredRun {
        header: stream.header,
        valid_len: stream.valid_len,
        layout: stream.layout,
        replay,
    })
}

/// Streaming fold for a sync-mode journal: feed events one at a time,
/// [`finish`](Self::finish) into the [`SyncReplay`] view. The mid-scan
/// state (accumulators + the open partial round) is what a `checkpoint`
/// record snapshots.
#[derive(Clone, Debug)]
pub(crate) struct SyncFold {
    pub(crate) r: SyncReplay,
    /// The currently open (un-committed) iteration, if any.
    pub(crate) current: Option<PartialRound>,
}

impl SyncFold {
    pub(crate) fn new() -> Self {
        Self { r: SyncReplay::default(), current: None }
    }

    pub(crate) fn fold(&mut self, ev: &JournalEvent) -> Result<()> {
        match ev {
            JournalEvent::SyncPropose { iter, rounds, rng, configs } => {
                anyhow::ensure!(
                    self.current.is_none(),
                    "sync_propose for iter {iter} before iter {} closed",
                    self.current.as_ref().map(|p| p.iter).unwrap_or(0)
                );
                anyhow::ensure!(
                    *iter == self.r.rounds_done.len(),
                    "sync_propose iter {iter} out of order (expected {})",
                    self.r.rounds_done.len()
                );
                self.r.rng_state = Some(*rng);
                self.r.rounds = *rounds;
                self.current =
                    Some(PartialRound { iter: *iter, batch: configs.clone(), evals: Vec::new() });
            }
            JournalEvent::SyncEval { iter, config, value } => {
                let cur = self
                    .current
                    .as_mut()
                    .ok_or_else(|| anyhow!("sync_eval for iter {iter} without a propose"))?;
                anyhow::ensure!(cur.iter == *iter, "sync_eval iter {iter} != open {}", cur.iter);
                anyhow::ensure!(
                    cur.evals.len() < cur.batch.len(),
                    "iter {iter}: more evals than proposed configs"
                );
                cur.evals.push((config.clone(), *value));
            }
            JournalEvent::SyncRound { iter, proposed, returned, best, wall_ms } => {
                let cur = self
                    .current
                    .take()
                    .ok_or_else(|| anyhow!("sync_round for iter {iter} without a propose"))?;
                anyhow::ensure!(cur.iter == *iter, "sync_round iter {iter} != open {}", cur.iter);
                for (cfg, v) in cur.evals {
                    if let Some(v) = v {
                        self.r.history.push((cfg, v));
                    }
                }
                self.r.rounds_done.push(RoundRecord {
                    iter: *iter,
                    proposed: *proposed,
                    returned: *returned,
                    best: *best,
                    wall_ms: *wall_ms,
                });
            }
            other => {
                return Err(anyhow!("async event {other:?} in a sync-mode journal"));
            }
        }
        Ok(())
    }

    pub(crate) fn finish(mut self) -> SyncReplay {
        self.r.partial = self.current;
        self.r
    }
}

/// Per-proposal bookkeeping while scanning an async journal.
#[derive(Clone, Debug)]
pub(crate) struct PidState {
    pub(crate) config: Config,
    pub(crate) retries: usize,
    /// Sequence number of the proposal's latest submit (or its propose,
    /// if the crash landed between propose and submit).
    pub(crate) order: u64,
    pub(crate) concluded: bool,
    /// Intermediate reports of the proposal's *current* attempt:
    /// `(step, user-sense value, pruned decision)`. Cleared on every
    /// submit — a re-enqueued trial re-reports from scratch, so only the
    /// final attempt's stream may reach `AsyncReplay::reports`.
    pub(crate) reports: Vec<(u64, f64, bool)>,
    /// Task id of the proposal's latest submit.
    pub(crate) last_task: Option<u64>,
    /// Fold cutoff / retry backoff of the latest submit (v4 fields).
    pub(crate) cutoff: u64,
    pub(crate) backoff_ms: f64,
}

/// Streaming fold for an async-mode journal. Every field — including the
/// open-proposal map, the global sequence counter, and the running
/// worst-seen censoring state — is part of the checkpoint snapshot;
/// omitting any of them would make `recover(checkpoint + tail)` diverge
/// from `recover(full stream)`.
#[derive(Clone, Debug)]
pub(crate) struct AsyncFold {
    pub(crate) sense: SenseTag,
    pub(crate) stable: bool,
    pub(crate) r: AsyncReplay,
    pub(crate) pids: BTreeMap<u64, PidState>,
    /// Global event order for pending-order reconstruction.
    pub(crate) seq: u64,
    /// Proposals journaled since the last terminal conclusion.
    pub(crate) proposed_counter: usize,
    /// Running worst internal-sense history value — the same state the
    /// live loop's censoring policy reads, rebuilt in the same push order.
    pub(crate) worst_internal: f64,
    /// Stable-mode canonical-order audit: the last folded/abandoned task
    /// id. Under `--replay stable` the journal's terminal order *is* the
    /// fold order, so it must be globally ascending — a violation means
    /// the journal was not produced by a stable run and replaying it as
    /// one would rebuild different state than the crashed process held.
    pub(crate) last_fold: Option<u64>,
}

impl AsyncFold {
    pub(crate) fn new(sense: SenseTag, stable: bool) -> Self {
        Self {
            sense,
            stable,
            r: AsyncReplay::default(),
            pids: BTreeMap::new(),
            seq: 0,
            proposed_counter: 0,
            worst_internal: f64::INFINITY,
            last_fold: None,
        }
    }

    fn to_internal(&self, v: f64) -> f64 {
        match self.sense {
            SenseTag::Maximize => v,
            SenseTag::Minimize => -v,
        }
    }

    fn audit_fold(&mut self, task: u64) -> Result<()> {
        if self.stable {
            anyhow::ensure!(
                self.r.epochs > 0,
                "stable journal concludes task {task} before any async_epoch marker"
            );
            anyhow::ensure!(
                self.last_fold.map_or(true, |t| task > t),
                "stable journal folds task {task} after task {:?} — canonical \
                 ascending-task-id order violated",
                self.last_fold
            );
        }
        self.last_fold = Some(task);
        Ok(())
    }

    pub(crate) fn fold(&mut self, ev: &JournalEvent) -> Result<()> {
        self.seq += 1;
        match ev {
            JournalEvent::AsyncPropose { pid, rounds, config } => {
                anyhow::ensure!(
                    !self.pids.contains_key(pid),
                    "duplicate async_propose for proposal {pid}"
                );
                self.pids.insert(
                    *pid,
                    PidState {
                        config: config.clone(),
                        retries: 0,
                        order: self.seq,
                        concluded: false,
                        reports: Vec::new(),
                        last_task: None,
                        cutoff: 0,
                        backoff_ms: 0.0,
                    },
                );
                self.r.proposals_made = self.r.proposals_made.max(pid + 1);
                self.r.rounds = *rounds;
                self.proposed_counter += 1;
            }
            JournalEvent::AsyncSubmit { pid, task, retries, cutoff, backoff_ms } => {
                let st = self
                    .pids
                    .get_mut(pid)
                    .ok_or_else(|| anyhow!("async_submit for unknown proposal {pid}"))?;
                anyhow::ensure!(!st.concluded, "async_submit for concluded proposal {pid}");
                st.retries = *retries;
                st.order = self.seq;
                st.reports.clear(); // fresh attempt: any prior stream is stale
                st.last_task = Some(*task);
                st.cutoff = *cutoff;
                st.backoff_ms = *backoff_ms;
                self.r.next_task_id = self.r.next_task_id.max(task + 1);
            }
            JournalEvent::AsyncEpoch { seq: epoch_seq } => {
                anyhow::ensure!(
                    self.stable,
                    "async_epoch marker in a journal whose header says --replay wallclock"
                );
                anyhow::ensure!(
                    *epoch_seq == self.r.epochs,
                    "async_epoch out of order: seq {epoch_seq}, expected {}",
                    self.r.epochs
                );
                self.r.epochs += 1;
            }
            JournalEvent::AsyncStalled { pid, task } => {
                let epochs = self.r.epochs;
                let st = self
                    .pids
                    .get_mut(pid)
                    .ok_or_else(|| anyhow!("async_stalled for unknown proposal {pid}"))?;
                anyhow::ensure!(!st.concluded, "async_stalled for concluded proposal {pid}");
                let _ = epochs;
                let retries = st.retries;
                let reports = st.reports.clone();
                st.concluded = true;
                self.audit_fold(*task)?;
                self.r.lost += 1;
                self.r.stalled = true;
                // Mirrors the live stall path: a recordless value, a lost
                // conclusion, zero wall — the trial's reports (already
                // journaled) replay like any concluded trial's.
                let outcome = EventOutcome::Lost(crate::scheduler::LossReason::TimedOut);
                self.r.completion_log.push(CompletionLogEntry {
                    task: *task,
                    retries,
                    outcome,
                    queue_ms: 0.0,
                    eval_ms: 0.0,
                });
                for &(step, value, pruned) in &reports {
                    self.r.reports.push((*pid, step, value, pruned));
                }
                self.r.terminals.push(TerminalReplay {
                    task: *task,
                    retries,
                    outcome,
                    wall_ms: 0.0,
                    proposed_before: std::mem::take(&mut self.proposed_counter),
                    contributed: false,
                });
            }
            JournalEvent::AsyncReport { pid, step, value, pruned, .. } => {
                let st = self
                    .pids
                    .get_mut(pid)
                    .ok_or_else(|| anyhow!("async_report for unknown proposal {pid}"))?;
                anyhow::ensure!(!st.concluded, "async_report for concluded proposal {pid}");
                st.reports.push((*step, *value, *pruned));
            }
            JournalEvent::AsyncCancel { pid, .. } => {
                let st = self
                    .pids
                    .get_mut(pid)
                    .ok_or_else(|| anyhow!("async_cancel for unknown proposal {pid}"))?;
                anyhow::ensure!(!st.concluded, "async_cancel for concluded proposal {pid}");
                // Terminal, but recordless: the live loop produces no
                // iteration record, history entry, or counter for work the
                // early stop withdrew — replay must not re-enqueue it.
                st.concluded = true;
            }
            JournalEvent::AsyncComplete { pid, task, retries, outcome, queue_ms, eval_ms } => {
                let st = self
                    .pids
                    .get_mut(pid)
                    .ok_or_else(|| anyhow!("async_complete for unknown proposal {pid}"))?;
                anyhow::ensure!(!st.concluded, "async_complete for concluded proposal {pid}");
                let seq = self.seq;
                let config = st.config.clone();
                let reports = st.reports.clone();
                match outcome {
                    EventOutcome::Resubmitted(_) => {
                        st.retries = *retries;
                        st.order = seq;
                        // Not terminal: the proposal stays pending. `order`
                        // moves to this event (and again at the follow-up
                        // async_submit, if it was journaled before the
                        // crash): the resubmission would have received a
                        // fresh, highest task id, so the proposal belongs
                        // at the back of the pending order either way.
                    }
                    _ => st.concluded = true,
                }
                // Every async_complete (terminals *and* resubmitted
                // intermediates) is one fold of its task.
                self.audit_fold(*task)?;
                self.r.completion_log.push(CompletionLogEntry {
                    task: *task,
                    retries: *retries,
                    outcome: *outcome,
                    queue_ms: *queue_ms,
                    eval_ms: *eval_ms,
                });
                match outcome {
                    EventOutcome::Resubmitted(_) => {
                        self.r.retried += 1;
                    }
                    terminal => {
                        let contributed = match terminal {
                            EventOutcome::Done(v) => {
                                let internal = self.to_internal(*v);
                                self.worst_internal = self.worst_internal.min(internal);
                                self.r.history.push((config, *v));
                                true
                            }
                            EventOutcome::Pruned { last_value, .. } => {
                                // Recompute the censored entry with the
                                // exact policy (and running state) the live
                                // loop applied, instead of journaling a
                                // second derived value that could drift.
                                self.r.pruned += 1;
                                let worst = self
                                    .worst_internal
                                    .is_finite()
                                    .then_some(self.worst_internal);
                                let internal = self.to_internal(*last_value);
                                match prune::censored_value(internal, worst) {
                                    Some(censored) => {
                                        self.worst_internal =
                                            self.worst_internal.min(censored);
                                        let user = match self.sense {
                                            SenseTag::Maximize => censored,
                                            SenseTag::Minimize => -censored,
                                        };
                                        self.r.history.push((config, user));
                                        true
                                    }
                                    None => false,
                                }
                            }
                            EventOutcome::Lost(_) => {
                                self.r.lost += 1;
                                false
                            }
                            _ => false,
                        };
                        for &(step, value, pruned) in &reports {
                            self.r.reports.push((*pid, step, value, pruned));
                        }
                        self.r.terminals.push(TerminalReplay {
                            task: *task,
                            retries: *retries,
                            outcome: *outcome,
                            wall_ms: *queue_ms + *eval_ms,
                            proposed_before: std::mem::take(&mut self.proposed_counter),
                            contributed,
                        });
                    }
                }
            }
            other => {
                return Err(anyhow!("sync event {other:?} in an async-mode journal"));
            }
        }
        Ok(())
    }

    pub(crate) fn finish(mut self) -> AsyncReplay {
        self.r.pid_last_task = self
            .pids
            .iter()
            .filter(|(_, st)| st.concluded)
            .filter_map(|(pid, st)| st.last_task.map(|t| (*pid, t)))
            .collect();
        let mut pending: Vec<(u64, PendingReplay)> = self
            .pids
            .into_iter()
            .filter(|(_, st)| !st.concluded)
            .map(|(pid, st)| {
                (
                    st.order,
                    PendingReplay {
                        pid,
                        config: st.config,
                        retries: st.retries,
                        cutoff: st.cutoff,
                        backoff_ms: st.backoff_ms,
                    },
                )
            })
            .collect();
        pending.sort_by_key(|(order, _)| *order);
        self.r.pending = pending.into_iter().map(|(_, p)| p).collect();
        self.r.trailing_proposed = self.proposed_counter;
        self.r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::settings::RunConfig;
    use crate::persist::journal::{JournalWriter, SenseTag};
    use crate::scheduler::LossReason;
    use crate::space::ParamValue;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("mango_recover_{}_{name}.jsonl", std::process::id()))
    }

    fn cfg(i: i64) -> Config {
        Config::new(vec![("i".into(), ParamValue::Int(i))])
    }

    /// A fresh (retries 0, cutoff 0, no backoff) submit event.
    fn submit(pid: u64, task: u64) -> JournalEvent {
        JournalEvent::AsyncSubmit { pid, task, retries: 0, cutoff: 0, backoff_ms: 0.0 }
    }

    fn write_journal(path: &Path, mode: &str, events: &[JournalEvent]) {
        let header = RunHeader {
            space_fp: 42,
            sense: SenseTag::Maximize,
            run: RunConfig { mode: mode.into(), ..Default::default() },
            celery: None,
        };
        let mut w = JournalWriter::create(path, &header).unwrap();
        for ev in events {
            w.append(ev).unwrap();
        }
    }

    #[test]
    fn sync_replay_reconstructs_rounds_and_partial() {
        let path = tmp("sync");
        write_journal(
            &path,
            "sync",
            &[
                JournalEvent::SyncPropose {
                    iter: 0,
                    rounds: 0,
                    rng: 11,
                    configs: vec![cfg(0), cfg(1)],
                },
                JournalEvent::SyncEval { iter: 0, config: cfg(0), value: Some(1.0) },
                JournalEvent::SyncEval { iter: 0, config: cfg(1), value: None },
                JournalEvent::SyncRound {
                    iter: 0,
                    proposed: 2,
                    returned: 1,
                    best: 1.0,
                    wall_ms: 3.0,
                },
                JournalEvent::SyncPropose {
                    iter: 1,
                    rounds: 1,
                    rng: 22,
                    configs: vec![cfg(2), cfg(3)],
                },
                JournalEvent::SyncEval { iter: 1, config: cfg(2), value: Some(2.0) },
                // crash: no eval for cfg(3), no round marker
            ],
        );
        let rec = recover(&path).unwrap();
        assert_eq!(rec.layout, JournalLayout::Single);
        let Replay::Sync(s) = rec.replay else { panic!("expected sync replay") };
        assert_eq!(s.rounds_done.len(), 1);
        assert_eq!(s.rounds_done[0].returned, 1);
        assert_eq!(s.history, vec![(cfg(0), 1.0)], "failed evals stay out of history");
        assert_eq!(s.rng_state, Some(22), "rng from the LAST propose");
        assert_eq!(s.rounds, 1);
        let p = s.partial.unwrap();
        assert_eq!(p.iter, 1);
        assert_eq!(p.batch, vec![cfg(2), cfg(3)]);
        assert_eq!(p.evals, vec![(cfg(2), Some(2.0))]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sync_replay_rejects_out_of_order_events() {
        let path = tmp("sync_bad");
        write_journal(
            &path,
            "sync",
            &[JournalEvent::SyncEval { iter: 0, config: cfg(0), value: Some(1.0) }],
        );
        assert!(recover(&path).unwrap_err().to_string().contains("without a propose"));
        write_journal(
            &path,
            "sync",
            &[JournalEvent::AsyncPropose { pid: 0, rounds: 0, config: cfg(0) }],
        );
        assert!(recover(&path).unwrap_err().to_string().contains("sync-mode journal"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn async_replay_reconstructs_pending_in_submit_order_with_retries() {
        let path = tmp("async");
        write_journal(
            &path,
            "async",
            &[
                JournalEvent::AsyncPropose { pid: 0, rounds: 0, config: cfg(0) },
                submit(0, 0),
                JournalEvent::AsyncPropose { pid: 1, rounds: 0, config: cfg(1) },
                submit(1, 1),
                JournalEvent::AsyncPropose { pid: 2, rounds: 0, config: cfg(2) },
                submit(2, 2),
                // pid 0 is lost once and resubmitted as task 3 → goes to
                // the back of the pending order.
                JournalEvent::AsyncComplete {
                    pid: 0,
                    task: 0,
                    retries: 1,
                    outcome: EventOutcome::Resubmitted(LossReason::Crashed),
                    queue_ms: 0.0,
                    eval_ms: 0.0,
                },
                JournalEvent::AsyncSubmit {
                    pid: 0,
                    task: 3,
                    retries: 1,
                    cutoff: 2,
                    backoff_ms: 40.0,
                },
                // pid 1 completes.
                JournalEvent::AsyncComplete {
                    pid: 1,
                    task: 1,
                    retries: 0,
                    outcome: EventOutcome::Done(5.0),
                    queue_ms: 1.0,
                    eval_ms: 2.0,
                },
                // refill proposal after the completion; crash before submit.
                JournalEvent::AsyncPropose { pid: 3, rounds: 2, config: cfg(3) },
            ],
        );
        let rec = recover(&path).unwrap();
        let Replay::Async(a) = rec.replay else { panic!("expected async replay") };
        assert_eq!(a.history, vec![(cfg(1), 5.0)]);
        assert_eq!(a.proposals_made, 4);
        assert_eq!(a.rounds, 2);
        assert_eq!(a.next_task_id, 4);
        assert_eq!(a.retried, 1);
        assert_eq!(a.lost, 0);
        assert_eq!(a.terminals.len(), 1);
        assert_eq!(a.terminals[0].proposed_before, 3, "3 proposes before the terminal");
        assert_eq!(a.trailing_proposed, 1, "pid 3 proposed after the last terminal");
        assert_eq!(a.completion_log.len(), 2);
        // Pending order: pid 2 (submit seq 6) < pid 0 (resubmit seq 8) <
        // pid 3 (propose only, seq 10).
        let pids: Vec<u64> = a.pending.iter().map(|p| p.pid).collect();
        assert_eq!(pids, vec![2, 0, 3]);
        assert_eq!(a.pending[1].retries, 1, "retry count survives the crash");
        // The v4 submit metadata survives too: pid 0's resubmit carried a
        // cutoff and a backoff, and the concluded pid 1 lands in the
        // last-task map for the stable-mode pruning filter.
        assert_eq!(a.pending[1].cutoff, 2);
        assert_eq!(a.pending[1].backoff_ms, 40.0);
        assert_eq!(a.pending[0].cutoff, 0);
        assert_eq!(a.pid_last_task, vec![(1, 1)]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn async_replay_replays_reports_and_pruned_terminals() {
        let path = tmp("async_prune");
        write_journal(
            &path,
            "async",
            &[
                JournalEvent::AsyncPropose { pid: 0, rounds: 0, config: cfg(0) },
                submit(0, 0),
                JournalEvent::AsyncPropose { pid: 1, rounds: 0, config: cfg(1) },
                submit(1, 1),
                JournalEvent::AsyncReport { pid: 0, task: 0, step: 0, value: 1.0, pruned: false },
                JournalEvent::AsyncReport { pid: 0, task: 0, step: 1, value: 2.0, pruned: false },
                JournalEvent::AsyncComplete {
                    pid: 0,
                    task: 0,
                    retries: 0,
                    outcome: EventOutcome::Done(2.0),
                    queue_ms: 1.0,
                    eval_ms: 2.0,
                },
                JournalEvent::AsyncReport { pid: 1, task: 1, step: 0, value: 0.5, pruned: true },
                JournalEvent::AsyncComplete {
                    pid: 1,
                    task: 1,
                    retries: 0,
                    outcome: EventOutcome::Pruned { at_step: 0, last_value: 0.5 },
                    queue_ms: 1.0,
                    eval_ms: 1.0,
                },
                JournalEvent::AsyncPropose { pid: 2, rounds: 2, config: cfg(2) },
                submit(2, 2),
                JournalEvent::AsyncReport { pid: 2, task: 2, step: 0, value: 9.0, pruned: false },
                // crash: pid 2 in flight with a half-journaled report stream
            ],
        );
        let rec = recover(&path).unwrap();
        let Replay::Async(a) = rec.replay else { panic!("expected async replay") };
        // Pruned pid 1's censored value: min(last=0.5, worst-seen=2.0) = 0.5.
        assert_eq!(a.history, vec![(cfg(0), 2.0), (cfg(1), 0.5)]);
        assert_eq!(a.pruned, 1);
        assert_eq!(a.terminals.len(), 2);
        assert!(a.terminals[0].contributed);
        assert!(a.terminals[1].contributed, "censored entry counts as contributed");
        assert!(matches!(a.terminals[1].outcome, EventOutcome::Pruned { at_step: 0, .. }));
        // Only concluded pids' streams replay; pid 2 re-reports on resume.
        assert_eq!(
            a.reports,
            vec![(0, 0, 1.0, false), (0, 1, 2.0, false), (1, 0, 0.5, true)]
        );
        assert_eq!(a.pending.iter().map(|p| p.pid).collect::<Vec<_>>(), vec![2]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn async_replay_censors_to_none_with_empty_history() {
        // A trial pruned on a NaN report before any history exists has no
        // finite censored value: it must not contribute an entry.
        let path = tmp("async_prune_nan");
        write_journal(
            &path,
            "async",
            &[
                JournalEvent::AsyncPropose { pid: 0, rounds: 0, config: cfg(0) },
                submit(0, 0),
                JournalEvent::AsyncReport {
                    pid: 0,
                    task: 0,
                    step: 0,
                    value: f64::NAN,
                    pruned: true,
                },
                JournalEvent::AsyncComplete {
                    pid: 0,
                    task: 0,
                    retries: 0,
                    outcome: EventOutcome::Pruned { at_step: 0, last_value: f64::NAN },
                    queue_ms: 0.0,
                    eval_ms: 0.0,
                },
            ],
        );
        let rec = recover(&path).unwrap();
        let Replay::Async(a) = rec.replay else { panic!("expected async replay") };
        assert!(a.history.is_empty());
        assert_eq!(a.pruned, 1);
        assert!(!a.terminals[0].contributed);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn async_replay_rejects_orphan_reports() {
        let path = tmp("async_orphan_report");
        write_journal(
            &path,
            "async",
            &[JournalEvent::AsyncReport { pid: 7, task: 0, step: 0, value: 1.0, pruned: false }],
        );
        let err = recover(&path).unwrap_err();
        assert!(err.to_string().contains("unknown proposal 7"), "got: {err:#}");
        std::fs::remove_file(&path).ok();
    }

    fn write_stable_journal(path: &Path, events: &[JournalEvent]) {
        let header = RunHeader {
            space_fp: 42,
            sense: SenseTag::Maximize,
            run: RunConfig {
                mode: "async".into(),
                replay: "stable".into(),
                ..Default::default()
            },
            celery: None,
        };
        let mut w = JournalWriter::create(path, &header).unwrap();
        for ev in events {
            w.append(ev).unwrap();
        }
    }

    fn propose_and_submit(pid: u64, task: u64, cutoff: u64) -> Vec<JournalEvent> {
        vec![
            JournalEvent::AsyncPropose { pid, rounds: 0, config: cfg(pid as i64) },
            JournalEvent::AsyncSubmit { pid, task, retries: 0, cutoff, backoff_ms: 0.0 },
        ]
    }

    fn done(pid: u64, task: u64, v: f64) -> JournalEvent {
        JournalEvent::AsyncComplete {
            pid,
            task,
            retries: 0,
            outcome: EventOutcome::Done(v),
            queue_ms: 0.0,
            eval_ms: 0.0,
        }
    }

    #[test]
    fn stable_journal_replays_epochs_and_validates_canonical_order() {
        let path = tmp("stable_ok");
        let mut events = Vec::new();
        events.extend(propose_and_submit(0, 0, 0));
        events.extend(propose_and_submit(1, 1, 0));
        events.push(JournalEvent::AsyncEpoch { seq: 0 });
        events.push(done(0, 0, 1.0));
        events.push(JournalEvent::AsyncEpoch { seq: 1 });
        events.push(done(1, 1, 2.0));
        write_stable_journal(&path, &events);
        let rec = recover(&path).unwrap();
        let Replay::Async(a) = rec.replay else { panic!("expected async replay") };
        assert_eq!(a.epochs, 2, "resume continues the epoch counter from here");
        assert_eq!(a.history, vec![(cfg(0), 1.0), (cfg(1), 2.0)]);
        assert_eq!(a.pid_last_task, vec![(0, 0), (1, 1)]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stable_journal_refuses_non_ascending_folds() {
        let path = tmp("stable_order");
        let mut events = Vec::new();
        events.extend(propose_and_submit(0, 0, 0));
        events.extend(propose_and_submit(1, 1, 0));
        events.push(JournalEvent::AsyncEpoch { seq: 0 });
        events.push(done(1, 1, 2.0));
        events.push(JournalEvent::AsyncEpoch { seq: 1 });
        events.push(done(0, 0, 1.0)); // task 0 folded after task 1
        write_stable_journal(&path, &events);
        let err = recover(&path).unwrap_err();
        assert!(err.to_string().contains("canonical"), "got: {err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn stable_journal_requires_epoch_markers_with_contiguous_seqs() {
        // A fold before any epoch marker is refused...
        let path = tmp("stable_noepoch");
        let mut events = Vec::new();
        events.extend(propose_and_submit(0, 0, 0));
        events.push(done(0, 0, 1.0));
        write_stable_journal(&path, &events);
        let err = recover(&path).unwrap_err();
        assert!(err.to_string().contains("before any async_epoch"), "got: {err:#}");
        // ...as is a gap in the epoch sequence.
        let mut events = Vec::new();
        events.extend(propose_and_submit(0, 0, 0));
        events.push(JournalEvent::AsyncEpoch { seq: 1 });
        write_stable_journal(&path, &events);
        let err = recover(&path).unwrap_err();
        assert!(err.to_string().contains("out of order"), "got: {err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn epoch_marker_in_a_wallclock_journal_is_refused() {
        let path = tmp("wallclock_epoch");
        write_journal(&path, "async", &[JournalEvent::AsyncEpoch { seq: 0 }]);
        let err = recover(&path).unwrap_err();
        assert!(err.to_string().contains("wallclock"), "got: {err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn async_stalled_replays_as_a_lost_terminal() {
        let path = tmp("stalled");
        let mut events = Vec::new();
        events.extend(propose_and_submit(0, 0, 0));
        events.extend(propose_and_submit(1, 1, 0));
        events.push(done(0, 0, 3.0));
        events.push(JournalEvent::AsyncReport {
            pid: 1,
            task: 1,
            step: 0,
            value: 0.25,
            pruned: false,
        });
        events.push(JournalEvent::AsyncStalled { pid: 1, task: 1 });
        write_journal(&path, "async", &events);
        let rec = recover(&path).unwrap();
        let Replay::Async(a) = rec.replay else { panic!("expected async replay") };
        assert!(a.stalled);
        assert_eq!(a.lost, 1, "a stalled trial counts as lost work");
        assert_eq!(a.history, vec![(cfg(0), 3.0)], "no value from the stalled trial");
        assert_eq!(a.terminals.len(), 2, "async_stalled is terminal for its proposal");
        assert!(!a.terminals[1].contributed);
        assert!(a.pending.is_empty(), "a resume must not re-enqueue stalled work");
        assert_eq!(a.reports, vec![(1, 0, 0.25, false)], "its reports still replay");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn space_fingerprint_mismatch_is_loud() {
        let path = tmp("fp");
        write_journal(&path, "sync", &[]);
        let rec = recover(&path).unwrap();
        let space = crate::space::svm_space(); // fingerprint != 42
        let err = rec.validate_space(&space).unwrap_err();
        assert!(err.to_string().contains("different search space"), "got: {err:#}");
        std::fs::remove_file(&path).ok();
    }

    /// Folding a prefix, snapshotting nothing, and continuing must equal a
    /// single uninterrupted fold — the in-crate statement of the
    /// checkpoint-equivalence property (the cross-codec version lives in
    /// `persist::compact`). Split at *every* prefix length.
    #[test]
    fn async_fold_is_splittable_at_every_event_boundary() {
        let mut events = Vec::new();
        events.extend(propose_and_submit(0, 0, 0));
        events.extend(propose_and_submit(1, 1, 0));
        events.push(JournalEvent::AsyncReport { pid: 0, task: 0, step: 0, value: 1.0, pruned: false });
        events.push(JournalEvent::AsyncComplete {
            pid: 0,
            task: 0,
            retries: 1,
            outcome: EventOutcome::Resubmitted(LossReason::Crashed),
            queue_ms: 0.5,
            eval_ms: 0.0,
        });
        events.push(JournalEvent::AsyncSubmit { pid: 0, task: 2, retries: 1, cutoff: 1, backoff_ms: 8.0 });
        events.push(done(1, 1, 4.0));
        events.push(JournalEvent::AsyncReport { pid: 0, task: 2, step: 0, value: 0.5, pruned: true });
        events.push(JournalEvent::AsyncComplete {
            pid: 0,
            task: 2,
            retries: 1,
            outcome: EventOutcome::Pruned { at_step: 0, last_value: 0.5 },
            queue_ms: 0.25,
            eval_ms: 0.75,
        });
        events.push(JournalEvent::AsyncPropose { pid: 2, rounds: 3, config: cfg(2) });
        let full = {
            let mut f = AsyncFold::new(SenseTag::Maximize, false);
            for ev in &events {
                f.fold(ev).unwrap();
            }
            f.finish()
        };
        for cut in 0..=events.len() {
            let mut f = AsyncFold::new(SenseTag::Maximize, false);
            for ev in &events[..cut] {
                f.fold(ev).unwrap();
            }
            // A clone at the cut stands in for snapshot+restore.
            let mut g = f.clone();
            for ev in &events[cut..] {
                g.fold(ev).unwrap();
            }
            assert_eq!(g.finish(), full, "split at {cut} diverged");
        }
    }
}
