//! The append-only run journal: one JSONL line per run event.
//!
//! The journal is the run's *only* persistent state. Line 1 is the header
//! (schema magic + version, search-space fingerprint, sense, and the full
//! `RunConfig` including the seed); every following line is one event:
//!
//! * sync mode — `sync_propose` (batch configs + the shared RNG state and
//!   optimizer rounds counter *after* the propose), one `sync_eval` per
//!   result absorbed at the barrier, and a `sync_round` commit marker per
//!   iteration;
//! * async mode — `async_propose` (stable proposal id + config + rounds),
//!   `async_submit` (proposal → scheduler task id, including resubmissions
//!   after a loss, plus the fold cutoff and retry-backoff the task was
//!   admitted under), `async_report` (one intermediate metric report plus
//!   the pruner's decision on it), `async_epoch` (a fold-epoch boundary
//!   under `--replay stable` — every terminal between one epoch marker
//!   and the next was folded in canonical ascending-task-id order),
//!   `async_stalled` (a terminal marker for work abandoned by the stall
//!   backstop), and `async_complete` (terminal
//!   `done`/`failed`/`lost`/`pruned` outcomes plus `resubmitted`
//!   intermediates, with retry counters and queue/eval telemetry).
//!
//! Every `append` writes one complete `\n`-terminated line in a single
//! `write_all` and flushes, so a process kill leaves at worst one
//! *unterminated* trailing fragment. [`read_journal`] drops exactly that
//! torn tail (and reports the byte length of the valid prefix so a resume
//! truncates it before appending); any `\n`-terminated line that fails to
//! parse — final or not — was fully committed and is treated as
//! corruption, failing loudly, as does a header whose magic or version
//! doesn't match — mirroring the artifact manifest's `posterior: "chol"`
//! schema guard.
//!
//! All `Config`s and objective values are encoded with the canonical
//! journal codec ([`crate::space::f64_to_json`] /
//! [`Config::to_journal_json`]), which round-trips every f64 bit pattern —
//! NaN payloads, `±inf`, `-0.0` — exactly, so a replayed history is
//! bit-identical to the one the crashed process held.

use crate::config::json::{parse, Json};
use crate::config::settings::RunConfig;
use crate::scheduler::{LossReason, TaskId};
use crate::space::{f64_from_json, f64_to_json, Config};
use anyhow::{anyhow, Context, Result};
use std::fs::{File, OpenOptions};
use std::io::{Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// Schema magic: refuses to replay files that merely look like JSONL.
pub const JOURNAL_MAGIC: &str = "mango-run-journal";
/// Bump on any incompatible event-schema change; the reader fails loudly
/// on mismatch instead of mis-replaying a stale journal.
///
/// v2: the header carries the Celery fault-simulator override
/// ([`RunHeader::celery`]), so a resumed run re-applies the exact fault
/// model instead of silently reverting to defaults.
///
/// v3: trial-level early stopping — intermediate-metric reports are
/// journaled as `async_report` events and a pruned trial concludes with
/// the `pruned` completion outcome (`at_step` + `last_v`); the header's
/// `RunConfig` grew the `pruner`/`pruner_warmup`/`asha_reduction` knobs.
/// v1 and v2 journals fail loudly, as every version mismatch does — a v2
/// replay under v3 rules could silently resume a pruning run without its
/// rung state.
///
/// v4: order-stable completion folding — `async_submit` grew the `cutoff`
/// (the stable-mode fold frontier the task was admitted under, which
/// scopes its pruning comparisons) and `backoff_ms` (the deterministic
/// retry backoff applied to the submission) fields, and two events were
/// added: `async_epoch` (a stable-mode fold-epoch boundary) and
/// `async_stalled` (a terminal marker for in-flight work abandoned when
/// the stall backstop degrades instead of aborting). v1–v3 journals fail
/// loudly: a v3 journal replayed under v4 rules would resume a stable
/// run without its fold frontier and re-derive different pruning
/// decisions.
///
/// v5: segmented, checkpointed journals — `--journal-segment-events N`
/// rotates the writer to a numbered segment file every N events, sealing
/// each finished segment with a `seal` footer (event count + FNV-1a-64
/// checksum), and compaction replays a sealed prefix into one
/// `checkpoint` record (the full mid-replay fold state, round-trip exact)
/// so resume cost and disk footprint stay O(active window). `seal` and
/// `checkpoint` are *segment-layer* records handled by
/// [`crate::persist::segment`] / [`crate::persist::compact`] — they never
/// appear in a single-file journal, whose byte layout is unchanged from
/// v4 apart from this version number. v1–v4 journals fail loudly: a v4
/// journal replayed under v5 rules (or vice versa) would mix
/// segment-layer records into the event stream.
pub const JOURNAL_VERSION: u64 = 5;

/// Objective sense recorded in the header; `Tuner::maximize`/`minimize`
/// on a resumed run must match it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SenseTag {
    Maximize,
    Minimize,
}

impl SenseTag {
    pub fn as_str(self) -> &'static str {
        match self {
            SenseTag::Maximize => "maximize",
            SenseTag::Minimize => "minimize",
        }
    }

    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "maximize" => Some(Self::Maximize),
            "minimize" => Some(Self::Minimize),
            _ => None,
        }
    }
}

/// The journal's first line.
#[derive(Clone, Debug)]
pub struct RunHeader {
    /// [`crate::space::SearchSpace::fingerprint`] of the run's space.
    pub space_fp: u64,
    pub sense: SenseTag,
    /// The full run configuration (seed included), so `Tuner::resume_from`
    /// can rebuild the tuner without the caller re-specifying it.
    pub run: RunConfig,
    /// The Celery fault-simulator override the run was started with
    /// (`TunerConfig::celery`), if any — serialized so `Tuner::resume_from`
    /// re-applies the exact fault model without the caller re-supplying it
    /// via `with_celery`.
    pub celery: Option<crate::scheduler::celery::CelerySimConfig>,
}

impl RunHeader {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("e", Json::Str("header".into())),
            ("journal", Json::Str(JOURNAL_MAGIC.into())),
            ("version", Json::Num(JOURNAL_VERSION as f64)),
            ("space_fp", Json::Str(format!("{:016x}", self.space_fp))),
            ("sense", Json::Str(self.sense.as_str().into())),
            ("config", self.run.to_json()),
            (
                "celery",
                match &self.celery {
                    Some(c) => c.to_json(),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let magic = j.get("journal").and_then(Json::as_str);
        anyhow::ensure!(
            magic == Some(JOURNAL_MAGIC),
            "not a mango run journal (magic {magic:?})"
        );
        let version = j.get("version").and_then(Json::as_f64).map(|v| v as u64);
        anyhow::ensure!(
            version == Some(JOURNAL_VERSION),
            "journal schema version mismatch: this build reads v{JOURNAL_VERSION}, \
             found {version:?} — re-run from scratch or use a matching build"
        );
        let fp_hex = j
            .get("space_fp")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("journal header missing space_fp"))?;
        let space_fp = u64::from_str_radix(fp_hex, 16)
            .map_err(|e| anyhow!("bad space_fp '{fp_hex}': {e}"))?;
        let sense = j
            .get("sense")
            .and_then(Json::as_str)
            .and_then(SenseTag::from_str)
            .ok_or_else(|| anyhow!("journal header missing/bad sense"))?;
        let run = RunConfig::from_json(
            j.get("config").ok_or_else(|| anyhow!("journal header missing config"))?,
        )
        .context("journal header config")?;
        let celery = match j.get("celery") {
            None | Some(Json::Null) => None,
            Some(c) => Some(
                crate::scheduler::celery::CelerySimConfig::from_json(c)
                    .context("journal header celery config")?,
            ),
        };
        Ok(Self { space_fp, sense, run, celery })
    }
}

/// Terminal or intermediate outcome of one async completion event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EventOutcome {
    /// Delivered a value (user objective sense).
    Done(f64),
    /// The objective declined (`None`); terminal, never retried.
    Failed,
    /// Lost with retries exhausted; terminal.
    Lost(LossReason),
    /// Lost but re-enqueued; a later event concludes the same proposal.
    Resubmitted(LossReason),
    /// Cancelled mid-flight by the pruner at intermediate step `at_step`;
    /// terminal. `last_value` is the trial's final reported value (user
    /// objective sense) — the censored history contribution is recomputed
    /// from it (and the worst history value) by
    /// [`crate::optimizer::prune::censored_value`], identically in the
    /// live loop and the replay.
    Pruned { at_step: u64, last_value: f64 },
}

fn reason_str(r: LossReason) -> &'static str {
    match r {
        LossReason::Crashed => "crashed",
        LossReason::TimedOut => "timed_out",
    }
}

fn reason_from(s: &str) -> Result<LossReason> {
    match s {
        "crashed" => Ok(LossReason::Crashed),
        "timed_out" => Ok(LossReason::TimedOut),
        other => Err(anyhow!("unknown loss reason '{other}'")),
    }
}

/// Push the outcome's `"o"` tag + payload fields. Shared between the
/// `async_complete` event codec and the checkpoint codec
/// ([`crate::persist::compact`]), so a checkpointed terminal round-trips
/// through the exact same encoding as the event it replaced.
pub(crate) fn outcome_fields(outcome: &EventOutcome, fields: &mut Vec<(&'static str, Json)>) {
    match outcome {
        EventOutcome::Done(v) => {
            fields.push(("o", Json::Str("done".into())));
            fields.push(("v", f64_to_json(*v)));
        }
        EventOutcome::Failed => fields.push(("o", Json::Str("failed".into()))),
        EventOutcome::Lost(r) => {
            fields.push(("o", Json::Str("lost".into())));
            fields.push(("reason", Json::Str(reason_str(*r).into())));
        }
        EventOutcome::Resubmitted(r) => {
            fields.push(("o", Json::Str("resubmitted".into())));
            fields.push(("reason", Json::Str(reason_str(*r).into())));
        }
        EventOutcome::Pruned { at_step, last_value } => {
            fields.push(("o", Json::Str("pruned".into())));
            fields.push(("at_step", Json::Num(*at_step as f64)));
            fields.push(("last_v", f64_to_json(*last_value)));
        }
    }
}

/// Parse an outcome from an object carrying the `"o"` tag + payload
/// fields written by [`outcome_fields`].
pub(crate) fn outcome_from_json(j: &Json) -> Result<EventOutcome> {
    Ok(match req_str(j, "o")? {
        "done" => EventOutcome::Done(f64_from_json(
            j.get("v").ok_or_else(|| anyhow!("done completion missing v"))?,
        )?),
        "failed" => EventOutcome::Failed,
        "lost" => EventOutcome::Lost(reason_from(req_str(j, "reason")?)?),
        "resubmitted" => EventOutcome::Resubmitted(reason_from(req_str(j, "reason")?)?),
        "pruned" => EventOutcome::Pruned {
            at_step: req_u64(j, "at_step")?,
            last_value: f64_from_json(
                j.get("last_v").ok_or_else(|| anyhow!("pruned completion missing last_v"))?,
            )?,
        },
        other => return Err(anyhow!("unknown completion outcome '{other}'")),
    })
}

/// One journal line after the header.
#[derive(Clone, Debug, PartialEq)]
pub enum JournalEvent {
    /// Sync mode: one proposed batch. `rng` and `rounds` are the shared
    /// coordinator RNG state and the optimizer's rounds counter *after*
    /// the propose call — exactly what the next iteration needs.
    SyncPropose { iter: usize, rounds: usize, rng: u128, configs: Vec<Config> },
    /// Sync mode: one evaluation result absorbed at the barrier
    /// (`value: None` = the objective declined).
    SyncEval { iter: usize, config: Config, value: Option<f64> },
    /// Sync mode: iteration commit marker — every eval of `iter` is in.
    SyncRound { iter: usize, proposed: usize, returned: usize, best: f64, wall_ms: f64 },
    /// Async mode: one proposal, with its stable proposal id.
    AsyncPropose { pid: u64, rounds: usize, config: Config },
    /// Async mode: proposal handed to the scheduler as task `task`
    /// (`retries > 0` = a resubmission after a loss, including the
    /// re-enqueue of in-flight-at-crash work on resume). `cutoff` is the
    /// stable-mode fold frontier at admission — the task's pruning
    /// decisions compare only against proposals whose final task id is
    /// below it (0 and ignored under `--replay wallclock`). `backoff_ms`
    /// is the deterministic retry backoff the submission was delayed by
    /// (0 for first submissions and when the knob is off); a resume
    /// re-applies both so the replayed trajectory matches.
    AsyncSubmit { pid: u64, task: TaskId, retries: usize, cutoff: TaskId, backoff_ms: f64 },
    /// Async mode, `--replay stable` only: a fold-epoch boundary. Every
    /// terminal journaled between this marker and the next one was folded
    /// in canonical ascending-task-id order; the replay validates that
    /// instead of trusting raw arrival order.
    AsyncEpoch { seq: u64 },
    /// Async mode: terminal marker for a task that was still in flight
    /// when the stall backstop fired (no completion arrived within
    /// `stall_timeout_ms`). Terminal for its proposal — a resume does not
    /// re-enqueue stalled work, mirroring the degraded run that gave up
    /// on it.
    AsyncStalled { pid: u64, task: TaskId },
    /// Async mode: a queued (never started) task withdrawn by the early
    /// stop. Terminal for its proposal — without this event a resume would
    /// re-enqueue and evaluate work the original run cancelled.
    AsyncCancel { pid: u64, task: TaskId },
    /// Async mode: one intermediate metric report from the worker
    /// evaluating proposal `pid` as task `task` (`value` in user objective
    /// sense). `pruned` records the pruner's decision *on this report* —
    /// journaling the decision, not just the observation, lets the replay
    /// cross-check that re-deriving decisions from the report book agrees
    /// with what the crashed process actually did.
    AsyncReport { pid: u64, task: TaskId, step: u64, value: f64, pruned: bool },
    /// Async mode: one completion event for proposal `pid`.
    AsyncComplete {
        pid: u64,
        task: TaskId,
        retries: usize,
        outcome: EventOutcome,
        queue_ms: f64,
        eval_ms: f64,
    },
}

impl JournalEvent {
    pub fn to_json(&self) -> Json {
        match self {
            JournalEvent::SyncPropose { iter, rounds, rng, configs } => Json::obj(vec![
                ("e", Json::Str("sync_propose".into())),
                ("iter", Json::Num(*iter as f64)),
                ("rounds", Json::Num(*rounds as f64)),
                ("rng", Json::Str(format!("{rng:032x}"))),
                (
                    "configs",
                    Json::Arr(configs.iter().map(Config::to_journal_json).collect()),
                ),
            ]),
            JournalEvent::SyncEval { iter, config, value } => {
                let mut fields = vec![
                    ("e", Json::Str("sync_eval".into())),
                    ("iter", Json::Num(*iter as f64)),
                    ("config", config.to_journal_json()),
                ];
                match value {
                    Some(v) => fields.push(("v", f64_to_json(*v))),
                    None => fields.push(("failed", Json::Bool(true))),
                }
                Json::obj(fields)
            }
            JournalEvent::SyncRound { iter, proposed, returned, best, wall_ms } => {
                Json::obj(vec![
                    ("e", Json::Str("sync_round".into())),
                    ("iter", Json::Num(*iter as f64)),
                    ("proposed", Json::Num(*proposed as f64)),
                    ("returned", Json::Num(*returned as f64)),
                    ("best", f64_to_json(*best)),
                    ("wall_ms", Json::Num(*wall_ms)),
                ])
            }
            JournalEvent::AsyncPropose { pid, rounds, config } => Json::obj(vec![
                ("e", Json::Str("async_propose".into())),
                ("pid", Json::Num(*pid as f64)),
                ("rounds", Json::Num(*rounds as f64)),
                ("config", config.to_journal_json()),
            ]),
            JournalEvent::AsyncSubmit { pid, task, retries, cutoff, backoff_ms } => {
                Json::obj(vec![
                    ("e", Json::Str("async_submit".into())),
                    ("pid", Json::Num(*pid as f64)),
                    ("task", Json::Num(*task as f64)),
                    ("retries", Json::Num(*retries as f64)),
                    ("cutoff", Json::Num(*cutoff as f64)),
                    ("backoff_ms", Json::Num(*backoff_ms)),
                ])
            }
            JournalEvent::AsyncEpoch { seq } => Json::obj(vec![
                ("e", Json::Str("async_epoch".into())),
                ("seq", Json::Num(*seq as f64)),
            ]),
            JournalEvent::AsyncStalled { pid, task } => Json::obj(vec![
                ("e", Json::Str("async_stalled".into())),
                ("pid", Json::Num(*pid as f64)),
                ("task", Json::Num(*task as f64)),
            ]),
            JournalEvent::AsyncCancel { pid, task } => Json::obj(vec![
                ("e", Json::Str("async_cancel".into())),
                ("pid", Json::Num(*pid as f64)),
                ("task", Json::Num(*task as f64)),
            ]),
            JournalEvent::AsyncReport { pid, task, step, value, pruned } => Json::obj(vec![
                ("e", Json::Str("async_report".into())),
                ("pid", Json::Num(*pid as f64)),
                ("task", Json::Num(*task as f64)),
                ("step", Json::Num(*step as f64)),
                ("v", f64_to_json(*value)),
                ("pruned", Json::Bool(*pruned)),
            ]),
            JournalEvent::AsyncComplete { pid, task, retries, outcome, queue_ms, eval_ms } => {
                let mut fields = vec![
                    ("e", Json::Str("async_complete".into())),
                    ("pid", Json::Num(*pid as f64)),
                    ("task", Json::Num(*task as f64)),
                    ("retries", Json::Num(*retries as f64)),
                ];
                outcome_fields(outcome, &mut fields);
                fields.push(("queue_ms", Json::Num(*queue_ms)));
                fields.push(("eval_ms", Json::Num(*eval_ms)));
                Json::obj(fields)
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let tag = j
            .get("e")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("event missing 'e' tag"))?;
        match tag {
            "sync_propose" => {
                let rng_hex = req_str(j, "rng")?;
                let rng = u128::from_str_radix(rng_hex, 16)
                    .map_err(|e| anyhow!("bad rng state '{rng_hex}': {e}"))?;
                let configs = j
                    .get("configs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("sync_propose missing configs"))?
                    .iter()
                    .map(Config::from_journal_json)
                    .collect::<Result<Vec<_>>>()?;
                anyhow::ensure!(!configs.is_empty(), "sync_propose with empty batch");
                Ok(JournalEvent::SyncPropose {
                    iter: req_usize(j, "iter")?,
                    rounds: req_usize(j, "rounds")?,
                    rng,
                    configs,
                })
            }
            "sync_eval" => {
                let config = Config::from_journal_json(
                    j.get("config").ok_or_else(|| anyhow!("sync_eval missing config"))?,
                )?;
                let value = match j.get("v") {
                    Some(v) => Some(f64_from_json(v)?),
                    None => {
                        anyhow::ensure!(
                            j.get("failed").and_then(Json::as_bool) == Some(true),
                            "sync_eval needs 'v' or 'failed'"
                        );
                        None
                    }
                };
                Ok(JournalEvent::SyncEval { iter: req_usize(j, "iter")?, config, value })
            }
            "sync_round" => Ok(JournalEvent::SyncRound {
                iter: req_usize(j, "iter")?,
                proposed: req_usize(j, "proposed")?,
                returned: req_usize(j, "returned")?,
                best: f64_from_json(
                    j.get("best").ok_or_else(|| anyhow!("sync_round missing best"))?,
                )?,
                wall_ms: req_f64(j, "wall_ms")?,
            }),
            "async_propose" => Ok(JournalEvent::AsyncPropose {
                pid: req_u64(j, "pid")?,
                rounds: req_usize(j, "rounds")?,
                config: Config::from_journal_json(
                    j.get("config").ok_or_else(|| anyhow!("async_propose missing config"))?,
                )?,
            }),
            "async_submit" => Ok(JournalEvent::AsyncSubmit {
                pid: req_u64(j, "pid")?,
                task: req_u64(j, "task")?,
                retries: req_usize(j, "retries")?,
                cutoff: req_u64(j, "cutoff")?,
                backoff_ms: req_f64(j, "backoff_ms")?,
            }),
            "async_epoch" => Ok(JournalEvent::AsyncEpoch { seq: req_u64(j, "seq")? }),
            "async_stalled" => Ok(JournalEvent::AsyncStalled {
                pid: req_u64(j, "pid")?,
                task: req_u64(j, "task")?,
            }),
            "async_cancel" => Ok(JournalEvent::AsyncCancel {
                pid: req_u64(j, "pid")?,
                task: req_u64(j, "task")?,
            }),
            "async_report" => Ok(JournalEvent::AsyncReport {
                pid: req_u64(j, "pid")?,
                task: req_u64(j, "task")?,
                step: req_u64(j, "step")?,
                value: f64_from_json(
                    j.get("v").ok_or_else(|| anyhow!("async_report missing v"))?,
                )?,
                pruned: j
                    .get("pruned")
                    .and_then(Json::as_bool)
                    .ok_or_else(|| anyhow!("async_report missing bool 'pruned'"))?,
            }),
            "async_complete" => {
                let outcome = outcome_from_json(j)?;
                Ok(JournalEvent::AsyncComplete {
                    pid: req_u64(j, "pid")?,
                    task: req_u64(j, "task")?,
                    retries: req_usize(j, "retries")?,
                    outcome,
                    queue_ms: req_f64(j, "queue_ms")?,
                    eval_ms: req_f64(j, "eval_ms")?,
                })
            }
            "header" => Err(anyhow!("duplicate header mid-journal")),
            other => Err(anyhow!("unknown journal event '{other}'")),
        }
    }
}

pub(crate) fn req_f64(j: &Json, k: &str) -> Result<f64> {
    j.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow!("event missing number '{k}'"))
}

/// Counter fields must be exact non-negative integers: a saturating `as`
/// cast would let a corrupted-but-parseable value (negative, huge, or
/// fractional) replay as silently wrong state — e.g. `retries: -1`
/// saturating to 0 resets a retry budget, `1e300` saturating to
/// `usize::MAX` exhausts it — instead of failing loudly.
pub(crate) fn req_u64(j: &Json, k: &str) -> Result<u64> {
    let n = req_f64(j, k)?;
    anyhow::ensure!(
        n.fract() == 0.0 && (0.0..=9_007_199_254_740_992.0).contains(&n),
        "event field '{k}' is not a valid non-negative integer: {n}"
    );
    Ok(n as u64)
}

pub(crate) fn req_usize(j: &Json, k: &str) -> Result<usize> {
    Ok(req_u64(j, k)? as usize)
}

pub(crate) fn req_str<'a>(j: &'a Json, k: &str) -> Result<&'a str> {
    j.get(k).and_then(Json::as_str).ok_or_else(|| anyhow!("event missing string '{k}'"))
}

/// Structured journal-append failure: every I/O error on the append path
/// (write, flush, fsync, a short write with no error) surfaces as one of
/// these instead of an opaque context chain, so the coordinator's
/// `--journal-on-error` policy can decide between aborting the run
/// (fail-stop) and continuing without a journal (degrade). Whatever the
/// policy, the bytes already on disk remain a valid committed prefix —
/// at worst with one torn, newline-less tail that [`read_journal`] drops.
#[derive(Debug)]
pub enum JournalError {
    /// The OS returned an error from `op` (`"write"`, `"flush"`,
    /// `"fsync"`) — e.g. ENOSPC mid-run.
    Io { op: &'static str, path: PathBuf, source: std::io::Error },
    /// A write made no progress (`Ok(0)`) before the line was fully
    /// committed: `wrote` of `len` bytes landed, the rest never will.
    ShortWrite { path: PathBuf, wrote: usize, len: usize },
}

impl std::fmt::Display for JournalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JournalError::Io { op, path, source } => {
                write!(f, "journal {op} failed on {}: {source}", path.display())
            }
            JournalError::ShortWrite { path, wrote, len } => write!(
                f,
                "journal short write on {}: {wrote} of {len} bytes committed",
                path.display()
            ),
        }
    }
}

impl std::error::Error for JournalError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            JournalError::Io { source, .. } => Some(source),
            JournalError::ShortWrite { .. } => None,
        }
    }
}

/// What the coordinator does when an append fails mid-run
/// (`--journal-on-error`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JournalPolicy {
    /// Abort the run with the [`JournalError`] (the default): the journal
    /// is the only persistent state, so losing it loses resumability.
    FailStop,
    /// Keep tuning without a journal: log the error once, stop appending,
    /// and mark the result non-resumable (`journal_degraded`). The file's
    /// committed prefix stays replayable up to the failure point.
    Degrade,
}

impl JournalPolicy {
    pub fn as_str(self) -> &'static str {
        match self {
            JournalPolicy::FailStop => "fail-stop",
            JournalPolicy::Degrade => "degrade",
        }
    }

    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "fail-stop" => Some(Self::FailStop),
            "degrade" => Some(Self::Degrade),
            _ => None,
        }
    }
}

/// Failing-writer test double: which I/O failure to inject.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JournalFault {
    /// The write fails outright with ENOSPC; no bytes of the line land.
    Enospc,
    /// Half the line's bytes land (a real torn, newline-less tail on
    /// disk), then the write errors — the committed prefix stays valid.
    ShortWrite,
}

/// Append-only writer. Each [`append`](Self::append) writes exactly one
/// `\n`-terminated line and flushes it to the OS, so a killed process
/// loses at most the event it was mid-write on (the torn tail the reader
/// drops) — never a previously appended one.
///
/// Flush-only durability survives a *process* kill but not a machine
/// crash (the OS page cache holds unsynced appends). The opt-in
/// [`with_fsync_every`](Self::with_fsync_every) knob adds an
/// `fsync`/`fdatasync` barrier every n appends, bounding machine-crash
/// loss to the last n events at a measured per-append latency cost.
pub struct JournalWriter {
    file: File,
    path: PathBuf,
    /// fsync after every n appends; 0 = never (flush-only, the default).
    fsync_every_n: usize,
    /// Appends since the last fsync barrier.
    unsynced: usize,
    /// Failing-writer test double: fail the append once `.0` more event
    /// appends have succeeded, and keep failing (a full disk stays full).
    fault: Option<(usize, JournalFault)>,
}

impl JournalWriter {
    /// Start a fresh journal at `path` (truncating any previous file) and
    /// write the header line.
    pub fn create(path: &Path, header: &RunHeader) -> Result<Self> {
        let file = File::create(path)
            .with_context(|| format!("creating run journal {}", path.display()))?;
        let mut w = Self {
            file,
            path: path.to_path_buf(),
            fsync_every_n: 0,
            unsynced: 0,
            fault: None,
        };
        w.append_json_raw(&header.to_json())?;
        Ok(w)
    }

    /// Reopen an existing journal for a resumed run: truncate to
    /// `valid_len` (dropping a torn trailing line, if any) and position at
    /// the end so new events append after the replayed ones.
    pub fn resume(path: &Path, valid_len: u64) -> Result<Self> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("reopening run journal {}", path.display()))?;
        file.set_len(valid_len)
            .with_context(|| format!("truncating torn tail of {}", path.display()))?;
        let mut w = Self {
            file,
            path: path.to_path_buf(),
            fsync_every_n: 0,
            unsynced: 0,
            fault: None,
        };
        w.file.seek(SeekFrom::End(0))?;
        Ok(w)
    }

    /// Opt into machine-crash durability: fsync after every `n` appends
    /// (`0` keeps the default flush-only behavior — byte-identical output,
    /// no sync syscalls).
    pub fn with_fsync_every(mut self, n: usize) -> Self {
        self.fsync_every_n = n;
        self
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Failing-writer test double: let `appends` more event appends
    /// succeed, then fail every later one with `kind`. Exercises the
    /// `--journal-on-error` policy at every append site without a real
    /// full disk.
    #[doc(hidden)]
    pub fn inject_fault_after(&mut self, appends: usize, kind: JournalFault) {
        self.fault = Some((appends, kind));
    }

    pub fn append(&mut self, event: &JournalEvent) -> std::result::Result<(), JournalError> {
        self.append_json(&event.to_json())
    }

    /// Append one arbitrary JSONL record, subject to the fault countdown.
    /// The segment layer ([`crate::persist::segment`]) routes its *event*
    /// appends through here so injected faults hit the same append sites
    /// in both layouts; its header/seal/checkpoint records bypass the
    /// countdown via [`Self::append_json_raw`] (the rotation seam has its
    /// own injection hook).
    pub(crate) fn append_json(&mut self, j: &Json) -> std::result::Result<(), JournalError> {
        let triggered = match &mut self.fault {
            Some((0, kind)) => Some(*kind),
            Some((remaining, _)) => {
                *remaining -= 1;
                None
            }
            None => None,
        };
        let mut line = j.to_string();
        line.push('\n');
        if let Some(kind) = triggered {
            return Err(self.inject_failure_line(&line, kind));
        }
        self.write_bytes(line.as_bytes())
    }

    /// Append one JSONL record, bypassing the fault countdown.
    pub(crate) fn append_json_raw(&mut self, j: &Json) -> std::result::Result<(), JournalError> {
        let mut line = j.to_string();
        line.push('\n');
        self.write_bytes(line.as_bytes())
    }

    /// Append a pre-serialized record line (no trailing newline), bypassing
    /// the fault countdown — the segment layer re-writes the stored header
    /// line byte-for-byte at the start of every segment.
    pub(crate) fn append_line_raw(&mut self, line: &str) -> std::result::Result<(), JournalError> {
        let mut full = String::with_capacity(line.len() + 1);
        full.push_str(line);
        full.push('\n');
        self.write_bytes(full.as_bytes())
    }

    /// Simulate the failure mode on the real file so the bytes on disk
    /// match what the error claims: ENOSPC lands nothing, a short write
    /// lands a torn newline-less prefix the reader will drop. `line` is
    /// the full record line including its trailing newline.
    pub(crate) fn inject_failure_line(&mut self, line: &str, kind: JournalFault) -> JournalError {
        match kind {
            JournalFault::Enospc => JournalError::Io {
                op: "write",
                path: self.path.clone(),
                source: std::io::Error::from_raw_os_error(28), // ENOSPC
            },
            JournalFault::ShortWrite => {
                let body = line.len().saturating_sub(1); // bytes before the newline
                let torn = &line.as_bytes()[..body / 2];
                // Best-effort: if even the torn prefix fails to land the
                // journal is still a committed prefix, just a shorter one.
                let _ = self.file.write(torn);
                let _ = self.file.flush();
                JournalError::ShortWrite {
                    path: self.path.clone(),
                    wrote: torn.len(),
                    len: line.len(),
                }
            }
        }
    }

    /// Take the remaining fault countdown (the segment layer carries it
    /// across a rotation into the successor segment's writer).
    pub(crate) fn remaining_fault(&self) -> Option<(usize, JournalFault)> {
        self.fault
    }

    /// Force an fsync barrier now (the rotation seam syncs a sealed
    /// segment before activating its successor).
    pub(crate) fn sync_data_now(&mut self) -> std::result::Result<(), JournalError> {
        self.file.sync_data().map_err(|e| JournalError::Io {
            op: "fsync",
            path: self.path.clone(),
            source: e,
        })?;
        self.unsynced = 0;
        Ok(())
    }

    /// Wrap an already-open file (the segment layer opens successor
    /// segments itself so creation failures map to [`JournalError`]).
    pub(crate) fn from_file(file: File, path: PathBuf) -> Self {
        Self { file, path, fsync_every_n: 0, unsynced: 0, fault: None }
    }

    fn write_bytes(&mut self, bytes: &[u8]) -> std::result::Result<(), JournalError> {
        let mut wrote = 0usize;
        // Manual write loop instead of write_all: an Ok(0) from the OS is
        // a short write with no errno and must surface as a structured
        // error, not an unreachable-disk panic or a silent truncation.
        while wrote < bytes.len() {
            match self.file.write(&bytes[wrote..]) {
                Ok(0) => {
                    return Err(JournalError::ShortWrite {
                        path: self.path.clone(),
                        wrote,
                        len: bytes.len(),
                    })
                }
                Ok(n) => wrote += n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    return Err(JournalError::Io {
                        op: "write",
                        path: self.path.clone(),
                        source: e,
                    })
                }
            }
        }
        self.file.flush().map_err(|e| JournalError::Io {
            op: "flush",
            path: self.path.clone(),
            source: e,
        })?;
        if self.fsync_every_n > 0 {
            self.unsynced += 1;
            if self.unsynced >= self.fsync_every_n {
                self.file.sync_data().map_err(|e| JournalError::Io {
                    op: "fsync",
                    path: self.path.clone(),
                    source: e,
                })?;
                self.unsynced = 0;
            }
        }
        Ok(())
    }
}

/// A fully parsed journal.
#[derive(Debug)]
pub struct JournalContents {
    pub header: RunHeader,
    pub events: Vec<JournalEvent>,
    /// Byte length of the valid prefix — everything after this (at most
    /// one torn trailing line) is dropped, and
    /// [`JournalWriter::resume`] truncates to it before appending.
    pub valid_len: u64,
}

/// Split raw journal bytes into `(offset, line, newline-terminated)`
/// triples, keeping byte offsets so callers can compute valid prefixes.
/// Shared with the segment-aware reader ([`crate::persist::segment`]).
pub(crate) fn split_jsonl(bytes: &[u8]) -> Vec<(usize, &[u8], bool)> {
    let mut lines: Vec<(usize, &[u8], bool)> = Vec::new();
    let mut start = 0usize;
    for (i, &b) in bytes.iter().enumerate() {
        if b == b'\n' {
            lines.push((start, &bytes[start..i], true));
            start = i + 1;
        }
    }
    if start < bytes.len() {
        lines.push((start, &bytes[start..], false)); // unterminated tail
    }
    lines
}

/// Read and validate a journal. An *unterminated* final line is a torn
/// write from the crash and is safely dropped (its bytes are excluded
/// from `valid_len`); a malformed `\n`-terminated line anywhere, a bad
/// header, or a magic/version mismatch is corruption and fails loudly.
pub fn read_journal(path: &Path) -> Result<JournalContents> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading run journal {}", path.display()))?;
    let lines = split_jsonl(&bytes);
    anyhow::ensure!(!lines.is_empty(), "journal {} is empty", path.display());

    let parse_line = |raw: &[u8]| -> Result<Json> {
        let text = std::str::from_utf8(raw).map_err(|e| anyhow!("non-utf8 line: {e}"))?;
        Ok(parse(text)?)
    };

    // A line is committed only once its newline landed: an unterminated
    // tail is a torn write even if the bytes happen to parse — counting it
    // into valid_len would make a resume append the next event onto the
    // same line, merging two events into one corrupt record.
    anyhow::ensure!(
        lines[0].2,
        "journal {} ends mid-header (torn first write) — nothing to resume",
        path.display()
    );
    let header = RunHeader::from_json(
        &parse_line(lines[0].1).with_context(|| "journal line 1 (header)".to_string())?,
    )?;
    let mut valid_len = (lines[0].0 + lines[0].1.len() + 1) as u64;

    let mut events = Vec::with_capacity(lines.len().saturating_sub(1));
    for (idx, (offset, raw, terminated)) in lines.iter().enumerate().skip(1) {
        if !terminated {
            crate::log_debug!(
                "journal {}: dropping unterminated trailing line (torn write)",
                path.display()
            );
            break; // the unterminated tail is always the last line
        }
        if raw.is_empty() {
            // Blank line (e.g. double newline): zero information, but its
            // newline is committed — keep valid_len moving past it.
            valid_len = (*offset + 1) as u64;
            continue;
        }
        // A '\n'-terminated line was fully committed (append() writes the
        // line and its newline in one write_all, so a kill can only ever
        // produce an unterminated prefix) — if it doesn't parse, that is
        // real corruption, even on the final line, and replaying around it
        // would silently re-execute a committed event.
        match parse_line(raw).and_then(|j| JournalEvent::from_json(&j)) {
            Ok(ev) => {
                events.push(ev);
                valid_len = (*offset + raw.len() + 1) as u64;
            }
            Err(e) => {
                return Err(e.context(format!(
                    "journal {} corrupted at line {} (newline-terminated, so not a torn \
                     write — refusing to replay)",
                    path.display(),
                    idx + 1
                )));
            }
        }
    }
    Ok(JournalContents { header, events, valid_len })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamValue;
    use crate::util::proptest::check;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("mango_journal_{}_{name}.jsonl", std::process::id()))
    }

    fn header() -> RunHeader {
        RunHeader {
            space_fp: 0xDEAD_BEEF_0123_4567,
            sense: SenseTag::Maximize,
            run: RunConfig { seed: 9, batch_size: 2, ..Default::default() },
            celery: None,
        }
    }

    fn cfg(bits: u64) -> Config {
        Config::new(vec![
            ("x".into(), ParamValue::F64(f64::from_bits(bits))),
            ("k".into(), ParamValue::Str("a".into())),
        ])
    }

    fn sample_events() -> Vec<JournalEvent> {
        vec![
            // NB: fixture configs stay NaN-free — these tests compare
            // events with derived PartialEq (NaN != NaN); NaN/±inf/-0.0
            // bit-exactness is property-tested at the codec level in
            // `space::value`.
            JournalEvent::SyncPropose {
                iter: 0,
                rounds: 1,
                rng: 0xABCD_EF01_2345_6789_ABCD_EF01_2345_6789,
                configs: vec![cfg(0x3FF0_0000_0000_0000), cfg(0xC008_0000_0000_0000)],
            },
            JournalEvent::SyncEval { iter: 0, config: cfg(1), value: Some(-2.5) },
            JournalEvent::SyncEval { iter: 0, config: cfg(2), value: None },
            JournalEvent::SyncRound {
                iter: 0,
                proposed: 2,
                returned: 1,
                best: -2.5,
                wall_ms: 1.25,
            },
            JournalEvent::AsyncPropose { pid: 3, rounds: 2, config: cfg(4) },
            JournalEvent::AsyncSubmit { pid: 3, task: 7, retries: 1, cutoff: 5, backoff_ms: 12.5 },
            JournalEvent::AsyncEpoch { seq: 2 },
            JournalEvent::AsyncStalled { pid: 8, task: 14 },
            JournalEvent::AsyncCancel { pid: 6, task: 12 },
            JournalEvent::AsyncComplete {
                pid: 3,
                task: 7,
                retries: 1,
                outcome: EventOutcome::Resubmitted(LossReason::Crashed),
                queue_ms: 0.5,
                eval_ms: 0.0,
            },
            JournalEvent::AsyncComplete {
                pid: 3,
                task: 9,
                retries: 2,
                outcome: EventOutcome::Lost(LossReason::TimedOut),
                queue_ms: 0.5,
                eval_ms: 0.0,
            },
            JournalEvent::AsyncComplete {
                pid: 4,
                task: 10,
                retries: 0,
                outcome: EventOutcome::Done(3.75),
                queue_ms: 0.1,
                eval_ms: 0.2,
            },
            JournalEvent::AsyncComplete {
                pid: 5,
                task: 11,
                retries: 0,
                outcome: EventOutcome::Failed,
                queue_ms: 0.1,
                eval_ms: 0.2,
            },
            JournalEvent::AsyncReport { pid: 7, task: 13, step: 2, value: -1.5, pruned: false },
            JournalEvent::AsyncReport { pid: 7, task: 13, step: 3, value: -8.25, pruned: true },
            JournalEvent::AsyncComplete {
                pid: 7,
                task: 13,
                retries: 0,
                outcome: EventOutcome::Pruned { at_step: 3, last_value: -8.25 },
                queue_ms: 0.1,
                eval_ms: 0.3,
            },
        ]
    }

    #[test]
    fn events_roundtrip_through_json() {
        for ev in sample_events() {
            let text = ev.to_json().to_string();
            let back = JournalEvent::from_json(&parse(&text).unwrap()).unwrap();
            assert_eq!(back, ev, "via {text}");
            assert_eq!(back.to_json().to_string(), text, "re-serialization differs");
        }
    }

    #[test]
    fn write_read_roundtrip_and_resume_append() {
        let path = tmp("roundtrip");
        let events = sample_events();
        {
            let mut w = JournalWriter::create(&path, &header()).unwrap();
            for ev in &events[..6] {
                w.append(ev).unwrap();
            }
        }
        let c = read_journal(&path).unwrap();
        assert_eq!(c.header.space_fp, 0xDEAD_BEEF_0123_4567);
        assert_eq!(c.header.sense, SenseTag::Maximize);
        assert_eq!(c.header.run.seed, 9);
        assert_eq!(c.events, &events[..6]);
        assert_eq!(c.valid_len, std::fs::metadata(&path).unwrap().len());
        // Resume: append the rest, read everything back.
        {
            let mut w = JournalWriter::resume(&path, c.valid_len).unwrap();
            for ev in &events[6..] {
                w.append(ev).unwrap();
            }
        }
        let c2 = read_journal(&path).unwrap();
        assert_eq!(c2.events, events);
        std::fs::remove_file(&path).ok();
    }

    /// The fsync knob must not change what reaches the file: `0`/absent
    /// preserves flush-only behavior byte-for-byte, and any `n` produces
    /// the identical journal (fsync is a durability barrier, not a format
    /// change) that replays identically.
    #[test]
    fn fsync_knob_is_byte_transparent_and_zero_means_flush_only() {
        let events = sample_events();
        let write_with = |name: &str, n: usize| -> Vec<u8> {
            let path = tmp(name);
            {
                let mut w = JournalWriter::create(&path, &header()).unwrap().with_fsync_every(n);
                assert_eq!(w.fsync_every_n, n);
                for ev in &events {
                    w.append(ev).unwrap();
                }
                if n == 0 {
                    assert_eq!(w.unsynced, 0, "flush-only writer must never count appends");
                }
            }
            let bytes = std::fs::read(&path).unwrap();
            let c = read_journal(&path).unwrap();
            assert_eq!(c.events, events, "fsync={n}: journal must replay identically");
            std::fs::remove_file(&path).ok();
            bytes
        };
        let flush_only = write_with("fsync0", 0);
        for n in [1usize, 3, 1000] {
            assert_eq!(
                write_with(&format!("fsync{n}"), n),
                flush_only,
                "fsync_every_n={n} must not change journal bytes"
            );
        }
        // The resume path accepts the knob too.
        let path = tmp("fsync_resume");
        {
            let mut w = JournalWriter::create(&path, &header()).unwrap();
            w.append(&events[0]).unwrap();
        }
        let c = read_journal(&path).unwrap();
        {
            let mut w =
                JournalWriter::resume(&path, c.valid_len).unwrap().with_fsync_every(2);
            w.append(&events[1]).unwrap();
            w.append(&events[2]).unwrap();
            assert_eq!(w.unsynced, 0, "the barrier must reset the counter");
        }
        assert_eq!(read_journal(&path).unwrap().events, &events[..3]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn torn_trailing_line_is_dropped_and_truncated_on_resume() {
        let path = tmp("torn");
        let events = sample_events();
        {
            let mut w = JournalWriter::create(&path, &header()).unwrap();
            for ev in &events[..3] {
                w.append(ev).unwrap();
            }
        }
        let clean_len = std::fs::metadata(&path).unwrap().len();
        // Simulate a kill mid-write: a partial JSON line with no newline.
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(br#"{"e":"sync_round","iter":1,"propo"#).unwrap();
        }
        let c = read_journal(&path).unwrap();
        assert_eq!(c.events, &events[..3], "torn tail must not become an event");
        assert_eq!(c.valid_len, clean_len, "valid prefix excludes the torn bytes");
        // Resume truncates the torn tail before appending.
        {
            let mut w = JournalWriter::resume(&path, c.valid_len).unwrap();
            w.append(&events[3]).unwrap();
        }
        let c2 = read_journal(&path).unwrap();
        assert_eq!(c2.events, &events[..4]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn terminated_malformed_final_line_is_corruption_not_torn() {
        // append() writes line+'\n' in one write_all, so a kill can never
        // produce a newline-terminated fragment: a terminated final line
        // that doesn't parse is bit rot / a hand edit and must fail
        // loudly, not be silently dropped and re-executed on resume.
        let path = tmp("terminated_corrupt");
        {
            let mut w = JournalWriter::create(&path, &header()).unwrap();
            w.append(&sample_events()[0]).unwrap();
        }
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"e\":\"sync_round\",\"iter\":}\n").unwrap();
        }
        let err = read_journal(&path).unwrap_err();
        assert!(err.to_string().contains("corrupted"), "got: {err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mid_file_corruption_fails_loudly() {
        let path = tmp("midfile");
        {
            let mut w = JournalWriter::create(&path, &header()).unwrap();
            w.append(&sample_events()[0]).unwrap();
        }
        // Corrupt the *event* line, then append a valid line after it.
        let text = std::fs::read_to_string(&path).unwrap();
        let corrupted = text.replace("sync_propose", "sync_prXpose");
        std::fs::write(&path, corrupted).unwrap();
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            let mut line = sample_events()[1].to_json().to_string();
            line.push('\n');
            f.write_all(line.as_bytes()).unwrap();
        }
        let err = read_journal(&path).unwrap_err();
        assert!(err.to_string().contains("corrupted"), "got: {err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupted_counter_fields_fail_loudly() {
        // Saturating casts would turn these into silently wrong replay
        // state (retries reset / budget exhausted); they must be rejected.
        for bad in [
            r#"{"e":"async_submit","pid":-1,"task":0,"retries":0,"cutoff":0,"backoff_ms":0}"#,
            r#"{"e":"async_submit","pid":0,"task":0,"retries":-1,"cutoff":0,"backoff_ms":0}"#,
            r#"{"e":"async_submit","pid":0,"task":1e300,"retries":0,"cutoff":0,"backoff_ms":0}"#,
            r#"{"e":"async_submit","pid":0.5,"task":0,"retries":0,"cutoff":0,"backoff_ms":0}"#,
            r#"{"e":"async_submit","pid":0,"task":0,"retries":0,"cutoff":-2,"backoff_ms":0}"#,
            r#"{"e":"async_epoch","seq":-1}"#,
            r#"{"e":"async_stalled","pid":1.5,"task":0}"#,
        ] {
            let j = parse(bad).unwrap();
            let err = JournalEvent::from_json(&j).unwrap_err();
            assert!(
                err.to_string().contains("not a valid non-negative integer"),
                "accepted {bad}: {err}"
            );
        }
    }

    #[test]
    fn unterminated_tail_is_torn_even_if_it_parses() {
        // A final line whose bytes parse but whose newline never landed is
        // a torn write: counting it into valid_len would make a resume
        // append the next event onto the same line.
        let path = tmp("unterminated");
        let events = sample_events();
        {
            let mut w = JournalWriter::create(&path, &header()).unwrap();
            w.append(&events[0]).unwrap();
        }
        let clean_len = std::fs::metadata(&path).unwrap().len();
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            // Complete JSON, missing only the newline.
            f.write_all(events[1].to_json().to_string().as_bytes()).unwrap();
        }
        let c = read_journal(&path).unwrap();
        assert_eq!(c.events, &events[..1], "parseable-but-unterminated tail must drop");
        assert_eq!(c.valid_len, clean_len);
        // Resume truncates it; the re-appended event lands on its own line.
        {
            let mut w = JournalWriter::resume(&path, c.valid_len).unwrap();
            w.append(&events[1]).unwrap();
        }
        assert_eq!(read_journal(&path).unwrap().events, &events[..2]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_and_version_fail_loudly() {
        let path = tmp("magic");
        std::fs::write(&path, "{\"e\":\"header\",\"journal\":\"other\",\"version\":1}\n")
            .unwrap();
        let err = read_journal(&path).unwrap_err();
        assert!(err.to_string().contains("magic"), "got: {err:#}");
        let mut h = header().to_json().to_string();
        h = h.replace(
            &format!("\"version\":{JOURNAL_VERSION}"),
            "\"version\":999",
        );
        std::fs::write(&path, format!("{h}\n")).unwrap();
        let err = read_journal(&path).unwrap_err();
        assert!(err.to_string().contains("version"), "got: {err:#}");
        // Stale schemas fail loudly too: v1 (pre-celery-header), v2
        // (pre-pruning — no async_report events or pruned outcomes), v3
        // (pre-stable-replay — no epoch markers, no submit cutoffs), and
        // v4 (pre-segmentation — no seal/checkpoint segment records). A
        // v4 journal silently replayed under v5 rules would choke on (or
        // worse, mis-handle) segment-layer records, and vice versa.
        for old in [1u64, 2, 3, 4] {
            let mut h = header().to_json().to_string();
            h = h.replace(
                &format!("\"version\":{JOURNAL_VERSION}"),
                &format!("\"version\":{old}"),
            );
            std::fs::write(&path, format!("{h}\n")).unwrap();
            let err = read_journal(&path).unwrap_err();
            assert!(err.to_string().contains("version"), "v{old}: got {err:#}");
        }
        std::fs::remove_file(&path).ok();
    }

    /// The v2 header round-trips the Celery fault-model override exactly
    /// (None stays None; a custom model survives bit-for-bit).
    #[test]
    fn header_roundtrips_celery_override() {
        use crate::scheduler::celery::CelerySimConfig;
        let none = header();
        let back = RunHeader::from_json(
            &parse(&none.to_json().to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.celery, None);
        let mut with = header();
        with.celery = Some(CelerySimConfig {
            workers: 5,
            base_latency_ms: 0.75,
            straggler_prob: 0.125,
            straggler_factor: 16.0,
            crash_prob: 0.25,
            result_timeout: std::time::Duration::from_millis(750),
        });
        let back = RunHeader::from_json(
            &parse(&with.to_json().to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(back.celery, with.celery);
        assert_eq!(back.space_fp, with.space_fp);
    }

    #[test]
    fn property_truncated_journals_always_replay_a_prefix() {
        // Crash-at-any-byte: for every possible truncation length, reading
        // either fails loudly (too short for a header) or yields an exact
        // event-sequence prefix — never a wrong or reordered event.
        let path = tmp("prefix_prop");
        let events = sample_events();
        {
            let mut w = JournalWriter::create(&path, &header()).unwrap();
            for ev in &events {
                w.append(ev).unwrap();
            }
        }
        let full = std::fs::read(&path).unwrap();
        check("truncated journal replays a prefix", 200, |g| {
            let cut = g.usize_range(0, full.len() + 1);
            let p = tmp("prefix_case");
            std::fs::write(&p, &full[..cut]).map_err(|e| e.to_string())?;
            match read_journal(&p) {
                Ok(c) => {
                    if c.events.as_slice() != &events[..c.events.len()] {
                        return Err(format!("cut {cut}: not a prefix"));
                    }
                    if c.valid_len > cut as u64 {
                        return Err(format!("cut {cut}: valid_len past the data"));
                    }
                }
                Err(_) => {
                    // Only acceptable while the header line is incomplete.
                    let header_end = full.iter().position(|&b| b == b'\n').unwrap() + 1;
                    if cut >= header_end {
                        return Err(format!("cut {cut}: complete header but read failed"));
                    }
                }
            }
            std::fs::remove_file(&p).ok();
            Ok(())
        });
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn injected_enospc_fails_every_later_append_and_preserves_the_prefix() {
        let path = tmp("fault_enospc");
        let events = sample_events();
        let mut w = JournalWriter::create(&path, &header()).unwrap();
        w.inject_fault_after(2, JournalFault::Enospc);
        w.append(&events[0]).unwrap();
        w.append(&events[1]).unwrap();
        let err = w.append(&events[2]).unwrap_err();
        match &err {
            JournalError::Io { op, source, .. } => {
                assert_eq!(*op, "write");
                assert_eq!(source.raw_os_error(), Some(28), "must be ENOSPC");
            }
            other => panic!("expected Io error, got {other:?}"),
        }
        assert!(err.to_string().contains("journal write failed"), "got: {err}");
        // A full disk stays full: later appends keep failing too.
        assert!(w.append(&events[3]).is_err());
        drop(w);
        // Nothing torn: the committed prefix replays and valid_len covers
        // the whole file.
        let c = read_journal(&path).unwrap();
        assert_eq!(c.events, &events[..2]);
        assert_eq!(c.valid_len, std::fs::metadata(&path).unwrap().len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn injected_short_write_leaves_a_droppable_torn_tail() {
        let path = tmp("fault_short");
        let events = sample_events();
        let mut w = JournalWriter::create(&path, &header()).unwrap();
        w.inject_fault_after(1, JournalFault::ShortWrite);
        w.append(&events[0]).unwrap();
        let err = w.append(&events[1]).unwrap_err();
        match &err {
            JournalError::ShortWrite { wrote, len, .. } => {
                assert!(wrote < len, "short write must be partial: {wrote}/{len}")
            }
            other => panic!("expected ShortWrite error, got {other:?}"),
        }
        drop(w);
        // The torn newline-less prefix is on disk and the reader drops
        // exactly it, like any kill-mid-write tail.
        let file_len = std::fs::metadata(&path).unwrap().len();
        let c = read_journal(&path).unwrap();
        assert_eq!(c.events, &events[..1], "torn tail must not become an event");
        assert!(c.valid_len < file_len, "valid prefix excludes the torn bytes");
        // And a resume truncates it and appends cleanly.
        {
            let mut w = JournalWriter::resume(&path, c.valid_len).unwrap();
            w.append(&events[1]).unwrap();
        }
        assert_eq!(read_journal(&path).unwrap().events, &events[..2]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn journal_policy_parses_and_roundtrips() {
        assert_eq!(JournalPolicy::from_str("fail-stop"), Some(JournalPolicy::FailStop));
        assert_eq!(JournalPolicy::from_str("degrade"), Some(JournalPolicy::Degrade));
        assert_eq!(JournalPolicy::from_str("panic"), None);
        for p in [JournalPolicy::FailStop, JournalPolicy::Degrade] {
            assert_eq!(JournalPolicy::from_str(p.as_str()), Some(p));
        }
    }
}
