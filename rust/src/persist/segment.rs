//! Segmented journal layout (schema v5): rotation, sealing, and
//! segment-aware recovery.
//!
//! With `--journal-segment-events N` (N > 0) the run journal is not one
//! file but a numbered series `<base>.seg000000`, `<base>.seg000001`, …
//! Every segment starts with the run header line (byte-identical across
//! segments); the writer appends events to the newest (*active*) segment
//! and, once it holds N events, *seals* it — appending a `seal` footer
//! record carrying the event count and an FNV-1a-64 checksum of every
//! preceding byte — and rotates to a freshly created successor. `N = 0`
//! keeps today's single-file layout, byte-identical apart from the v5
//! version number.
//!
//! The torn-tail contract becomes segment-aware: exactly one unterminated
//! trailing line is tolerated, and only in the *active* segment (that is
//! the only file a kill can tear). A sealed segment is immutable history —
//! a torn tail, a checksum mismatch, a missing seal, or bytes after the
//! seal there is corruption and fails loudly, or, under
//! `--journal-on-error degrade`, quarantines that segment and everything
//! after it (renamed to `*.quarantined`) so the run resumes from the
//! intact sealed prefix.
//!
//! Sealed prefixes are what [`crate::persist::compact`] folds into a
//! single `checkpoint` record, bounding resume cost and disk footprint to
//! the active window. The reader here understands the compacted layout:
//! the checkpoint (always in the lowest live segment) supersedes every
//! segment it `covers`, and live segments at or below that index (other
//! than the checkpoint's own) are *stale* leftovers of a compaction that
//! crashed between rename and cleanup — skipped on read, deleted on
//! resume.

use super::journal::{
    req_str, req_u64, split_jsonl, JournalError, JournalEvent, JournalFault, JournalWriter,
    RunHeader,
};
use crate::config::json::{parse, Json};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::fs::File;
use std::path::{Path, PathBuf};

/// FNV-1a 64-bit offset basis.
pub(crate) const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold `bytes` into a running FNV-1a 64 hash.
pub(crate) fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// `<base>.seg{idx:06}` — the on-disk name of segment `idx`.
pub(crate) fn segment_path(base: &Path, idx: u64) -> PathBuf {
    let mut s = base.as_os_str().to_os_string();
    s.push(format!(".seg{idx:06}"));
    PathBuf::from(s)
}

/// `path` + a literal suffix (`.tmp` staging, `.quarantined` evidence).
pub(crate) fn suffixed(path: &Path, suffix: &str) -> PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(suffix);
    PathBuf::from(s)
}

/// fsync a directory so a just-created/renamed/removed entry survives a
/// machine crash (file data alone is not enough — the *name* lives in the
/// directory).
pub(crate) fn fsync_dir(dir: &Path) -> std::io::Result<()> {
    File::open(dir)?.sync_all()
}

/// Parent directory of a journal base path (`.` for bare file names).
pub(crate) fn parent_dir(base: &Path) -> &Path {
    match base.parent() {
        Some(p) if !p.as_os_str().is_empty() => p,
        _ => Path::new("."),
    }
}

/// The `seal` footer record closing a finished segment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct SealRecord {
    /// Segment index — must match the file name, so a renamed/shuffled
    /// segment cannot silently replay in the wrong position.
    pub(crate) seg: u64,
    /// Number of records between the header and this seal.
    pub(crate) events: u64,
    /// FNV-1a 64 over every file byte preceding the seal line.
    pub(crate) crc: u64,
}

impl SealRecord {
    pub(crate) fn to_json(self) -> Json {
        Json::obj(vec![
            ("e", Json::Str("seal".into())),
            ("seg", Json::Num(self.seg as f64)),
            ("events", Json::Num(self.events as f64)),
            ("crc", Json::Str(format!("{:016x}", self.crc))),
        ])
    }

    pub(crate) fn from_json(j: &Json) -> Result<Self> {
        let crc_hex = req_str(j, "crc")?;
        let crc = u64::from_str_radix(crc_hex, 16)
            .map_err(|e| anyhow!("bad seal crc '{crc_hex}': {e}"))?;
        Ok(Self { seg: req_u64(j, "seg")?, events: req_u64(j, "events")?, crc })
    }
}

/// A `checkpoint` record: the full mid-replay fold state of every segment
/// up to and including index `covers`, written by compaction
/// ([`crate::persist::compact`]). The `state` payload is mode-specific and
/// round-trip exact (canonical float codec throughout).
#[derive(Clone, Debug)]
pub struct CheckpointRecord {
    /// Highest segment index this checkpoint summarizes.
    pub covers: u64,
    /// `"sync"` / `"async"` — cross-checked against the header on replay.
    pub mode: String,
    /// Mode-specific fold state (see `persist::compact` for the codec).
    pub state: Json,
}

impl CheckpointRecord {
    pub(crate) fn to_json(&self) -> Json {
        Json::obj(vec![
            ("e", Json::Str("checkpoint".into())),
            ("covers", Json::Num(self.covers as f64)),
            ("mode", Json::Str(self.mode.clone())),
            ("state", self.state.clone()),
        ])
    }

    pub(crate) fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            covers: req_u64(j, "covers")?,
            mode: req_str(j, "mode")?.to_string(),
            state: j
                .get("state")
                .cloned()
                .ok_or_else(|| anyhow!("checkpoint record missing state"))?,
        })
    }
}

/// On-disk layout a journal was recovered from, as
/// [`crate::persist::RecoveredRun`] reports it — the resumed writer
/// reopens the matching file(s).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JournalLayout {
    /// One file at the base path (`--journal-segment-events 0`).
    Single,
    /// Numbered segment files (indices need not be contiguous after
    /// compaction).
    Segmented {
        /// Newest live segment index.
        active: u64,
        /// The active segment ends with a seal (the crash landed between
        /// seal and successor creation; resume starts the successor).
        active_sealed: bool,
        /// Index the next created segment must use — past both the active
        /// segment and anything a checkpoint covers, so a fresh segment is
        /// never mistaken for a stale one.
        next_index: u64,
        /// Live sealed segment indices below `active`, ascending.
        sealed: Vec<u64>,
        /// Checkpoint-covered leftovers of a crashed compaction: skipped
        /// on read, deleted on resume.
        stale: Vec<u64>,
    },
}

/// A fully parsed run journal in either layout: the header, the newest
/// checkpoint (if compacted), and the event tail to fold on top of it.
pub struct RunStream {
    pub header: RunHeader,
    pub checkpoint: Option<CheckpointRecord>,
    pub events: Vec<JournalEvent>,
    /// Valid byte prefix of the active file (the single journal file, or
    /// the newest live segment).
    pub valid_len: u64,
    pub layout: JournalLayout,
}

/// Discover the segment files of `base`: `{idx → path}`, ascending.
/// `.tmp` / `.quarantined` files are excluded by the exact 6-digit-suffix
/// match. A missing parent directory yields an empty map (the caller's
/// single-file read will produce the natural file-not-found error).
pub(crate) fn discover_segments(base: &Path) -> Result<BTreeMap<u64, PathBuf>> {
    let mut out = BTreeMap::new();
    let base_name = match base.file_name() {
        Some(n) => n.to_string_lossy().into_owned(),
        None => return Err(anyhow!("journal path {} has no file name", base.display())),
    };
    let prefix = format!("{base_name}.seg");
    let entries = match std::fs::read_dir(parent_dir(base)) {
        Ok(e) => e,
        Err(_) => return Ok(out),
    };
    for entry in entries {
        let entry = entry.with_context(|| {
            format!("listing journal directory {}", parent_dir(base).display())
        })?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if let Some(suffix) = name.strip_prefix(&prefix) {
            if suffix.len() == 6 && suffix.bytes().all(|b| b.is_ascii_digit()) {
                let idx: u64 = suffix
                    .parse()
                    .map_err(|e| anyhow!("bad segment suffix '{suffix}': {e}"))?;
                out.insert(idx, entry.path());
            }
        }
    }
    Ok(out)
}

/// Files staged by a compaction that crashed before its atomic rename.
pub(crate) fn discover_tmp_files(base: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let base_name = match base.file_name() {
        Some(n) => n.to_string_lossy().into_owned(),
        None => return Ok(out),
    };
    let prefix = format!("{base_name}.seg");
    let entries = match std::fs::read_dir(parent_dir(base)) {
        Ok(e) => e,
        Err(_) => return Ok(out),
    };
    for entry in entries {
        let entry = entry.with_context(|| {
            format!("listing journal directory {}", parent_dir(base).display())
        })?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.strip_prefix(&prefix).map_or(false, |s| s.ends_with(".tmp")) {
            out.push(entry.path());
        }
    }
    out.sort();
    Ok(out)
}

/// One record line of a segment body (between header and seal).
pub(crate) enum SegRecord {
    Event(JournalEvent),
    Checkpoint(CheckpointRecord),
}

/// One parsed segment file.
pub(crate) struct ParsedSeg {
    /// The header line, verbatim, without its newline (empty if embryonic).
    pub(crate) header_line: Vec<u8>,
    pub(crate) records: Vec<SegRecord>,
    pub(crate) seal: Option<SealRecord>,
    /// Valid byte prefix (full file length for a sealed segment).
    pub(crate) valid_len: u64,
    /// The successor file of a rotation that died before (or while)
    /// writing the header line: zero committed bytes, treated as an empty
    /// active segment whose header the resume rewrites.
    pub(crate) embryonic: bool,
}

/// Parse and validate one segment file. `newest` relaxes the rules the
/// way the active segment needs (torn tail tolerated, seal optional,
/// embryonic allowed for idx > 0); `allow_checkpoint` is true only for
/// the lowest live segment — checkpoints anywhere else are corruption.
pub(crate) fn parse_segment(
    path: &Path,
    idx: u64,
    newest: bool,
    allow_checkpoint: bool,
    expected_header: Option<&[u8]>,
) -> Result<ParsedSeg> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading journal segment {}", path.display()))?;
    let lines = split_jsonl(&bytes);
    if lines.is_empty() || !lines[0].2 {
        // No committed header line. For the successor of a rotation the
        // kill interrupted, that is a recoverable empty segment; anywhere
        // else there is nothing to anchor a replay to.
        anyhow::ensure!(
            newest && idx > 0,
            "journal segment {} ends mid-header (torn first write) — nothing to resume",
            path.display()
        );
        return Ok(ParsedSeg {
            header_line: Vec::new(),
            records: Vec::new(),
            seal: None,
            valid_len: 0,
            embryonic: true,
        });
    }

    let parse_line = |raw: &[u8]| -> Result<Json> {
        let text = std::str::from_utf8(raw).map_err(|e| anyhow!("non-utf8 line: {e}"))?;
        Ok(parse(text)?)
    };

    let header_json = parse_line(lines[0].1)
        .with_context(|| format!("segment {} line 1 (header)", path.display()))?;
    // Full header validation (magic, version, config) — every segment
    // carries the same header so any single segment is self-describing.
    RunHeader::from_json(&header_json)
        .with_context(|| format!("segment {} header", path.display()))?;
    if let Some(expected) = expected_header {
        anyhow::ensure!(
            lines[0].1 == expected,
            "segment {} header differs from the run's (segments from different \
             runs mixed under one base path?)",
            path.display()
        );
    }
    let header_line = lines[0].1.to_vec();
    let mut valid_len = (lines[0].0 + lines[0].1.len() + 1) as u64;
    let mut records = Vec::new();
    let mut seal: Option<SealRecord> = None;

    for (line_idx, (offset, raw, terminated)) in lines.iter().enumerate().skip(1) {
        anyhow::ensure!(
            seal.is_none(),
            "segment {} has bytes after its seal — sealed segments are immutable, \
             refusing to replay",
            path.display()
        );
        if !terminated {
            // A torn write can only exist where a writer was mid-append.
            anyhow::ensure!(
                newest,
                "sealed segment {} has an unterminated trailing line — sealed \
                 segments are immutable, this is corruption",
                path.display()
            );
            crate::log_debug!(
                "segment {}: dropping unterminated trailing line (torn write)",
                path.display()
            );
            break;
        }
        if raw.is_empty() {
            valid_len = (*offset + 1) as u64;
            continue;
        }
        let line_no = line_idx + 1;
        let j = parse_line(raw).with_context(|| {
            format!(
                "segment {} corrupted at line {line_no} (newline-terminated, so not \
                 a torn write — refusing to replay)",
                path.display()
            )
        })?;
        let tag = j
            .get("e")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("segment {} line {line_no}: record missing 'e' tag", path.display()))?;
        match tag {
            "seal" => {
                let s = SealRecord::from_json(&j)
                    .with_context(|| format!("segment {} line {line_no} (seal)", path.display()))?;
                anyhow::ensure!(
                    s.seg == idx,
                    "segment {} carries a seal for segment {} — file renamed or \
                     shuffled, refusing to replay",
                    path.display(),
                    s.seg
                );
                anyhow::ensure!(
                    s.events == records.len() as u64,
                    "segment {} seal claims {} records but {} are present — corruption",
                    path.display(),
                    s.events,
                    records.len()
                );
                let computed = fnv1a(FNV_OFFSET, &bytes[..*offset]);
                anyhow::ensure!(
                    s.crc == computed,
                    "segment {} checksum mismatch (seal {:016x}, computed {computed:016x}) \
                     — corruption",
                    path.display(),
                    s.crc
                );
                seal = Some(s);
                valid_len = (*offset + raw.len() + 1) as u64;
            }
            "checkpoint" => {
                anyhow::ensure!(
                    allow_checkpoint,
                    "segment {} line {line_no}: checkpoint record outside the lowest \
                     live segment — corruption",
                    path.display()
                );
                let cp = CheckpointRecord::from_json(&j).with_context(|| {
                    format!("segment {} line {line_no} (checkpoint)", path.display())
                })?;
                records.push(SegRecord::Checkpoint(cp));
                valid_len = (*offset + raw.len() + 1) as u64;
            }
            "header" => {
                return Err(anyhow!(
                    "segment {} line {line_no}: duplicate header mid-segment",
                    path.display()
                ))
            }
            _ => {
                let ev = JournalEvent::from_json(&j).with_context(|| {
                    format!(
                        "segment {} corrupted at line {line_no} (newline-terminated, so \
                         not a torn write — refusing to replay)",
                        path.display()
                    )
                })?;
                records.push(SegRecord::Event(ev));
                valid_len = (*offset + raw.len() + 1) as u64;
            }
        }
    }
    anyhow::ensure!(
        newest || seal.is_some(),
        "segment {} is not the newest but carries no seal — a rotation never \
         completes without sealing, this is corruption",
        path.display()
    );
    Ok(ParsedSeg { header_line, records, seal, valid_len, embryonic: false })
}

/// One scanned live segment (checkpoint extracted, stale excluded).
pub(crate) struct ScannedSeg {
    pub(crate) idx: u64,
    pub(crate) path: PathBuf,
    pub(crate) events: Vec<JournalEvent>,
    pub(crate) sealed: bool,
    pub(crate) valid_len: u64,
    pub(crate) embryonic: bool,
}

/// The full segmented-layout scan shared by the reader and compaction.
pub(crate) struct SegScan {
    pub(crate) header: RunHeader,
    /// The run's header line, verbatim (no newline) — every new segment
    /// re-writes these exact bytes.
    pub(crate) header_line: Vec<u8>,
    pub(crate) checkpoint: Option<CheckpointRecord>,
    /// Segment index holding the checkpoint (the lowest live index).
    pub(crate) checkpoint_seg: Option<u64>,
    /// Live, non-stale segments, ascending (last = active).
    pub(crate) segs: Vec<ScannedSeg>,
    /// Checkpoint-covered leftovers to delete on resume.
    pub(crate) stale: Vec<u64>,
}

impl SegScan {
    pub(crate) fn active(&self) -> Result<&ScannedSeg> {
        self.segs.last().ok_or_else(|| anyhow!("segment scan holds no live segments"))
    }

    pub(crate) fn layout(&self) -> Result<JournalLayout> {
        let active = self.active()?;
        let covers = self.checkpoint.as_ref().map_or(0, |cp| cp.covers);
        Ok(JournalLayout::Segmented {
            active: active.idx,
            active_sealed: active.sealed,
            next_index: active.idx.max(covers) + 1,
            sealed: self.segs[..self.segs.len() - 1].iter().map(|s| s.idx).collect(),
            stale: self.stale.clone(),
        })
    }
}

/// Scan the segmented layout of `base`. `Ok(None)` = no segment files
/// exist (single-file layout). Validates every live segment; under
/// `--journal-on-error degrade` (from the journaled config itself) a
/// corrupt *sealed* segment and everything after it are quarantined
/// instead, leaving the intact sealed prefix live.
pub(crate) fn scan(base: &Path) -> Result<Option<SegScan>> {
    let seg_files = discover_segments(base)?;
    if seg_files.is_empty() {
        return Ok(None);
    }
    anyhow::ensure!(
        !base.exists(),
        "both a single-file journal and segment files exist for {} — ambiguous \
         layout, refusing to guess which is the run",
        base.display()
    );
    let indices: Vec<u64> = seg_files.keys().copied().collect();
    let lowest = indices[0];
    let newest = indices[indices.len() - 1];

    // The lowest live segment anchors everything: the header (hence the
    // degrade policy), and the checkpoint if the journal was compacted.
    let lowest_path = &seg_files[&lowest];
    let first = parse_segment(lowest_path, lowest, lowest == newest, true, None)?;
    anyhow::ensure!(
        !first.embryonic,
        "journal segment {} ends mid-header (torn first write) — nothing to resume",
        lowest_path.display()
    );
    let header_json = {
        let text = std::str::from_utf8(&first.header_line)
            .map_err(|e| anyhow!("segment {} header: non-utf8: {e}", lowest_path.display()))?;
        parse(text)?
    };
    let header = RunHeader::from_json(&header_json)?;
    let degrade = header.run.journal_on_error == "degrade";

    let mut checkpoint: Option<CheckpointRecord> = None;
    let mut checkpoint_seg: Option<u64> = None;
    let mut first_events = Vec::new();
    for rec in first.records {
        match rec {
            SegRecord::Checkpoint(cp) => {
                anyhow::ensure!(
                    checkpoint.is_none(),
                    "segment {} holds more than one checkpoint — corruption",
                    lowest_path.display()
                );
                anyhow::ensure!(
                    cp.covers >= lowest,
                    "segment {} checkpoint covers {} < its own index — corruption",
                    lowest_path.display(),
                    cp.covers
                );
                checkpoint = Some(cp);
                checkpoint_seg = Some(lowest);
            }
            SegRecord::Event(ev) => first_events.push(ev),
        }
    }
    let covers = checkpoint.as_ref().map(|cp| cp.covers);

    let mut stale = Vec::new();
    let mut segs = vec![ScannedSeg {
        idx: lowest,
        path: lowest_path.clone(),
        // The checkpoint supersedes its own segment's events too (there
        // are none in practice: a checkpoint segment is header +
        // checkpoint + seal).
        events: if checkpoint.is_some() { Vec::new() } else { first_events },
        sealed: first.seal.is_some(),
        valid_len: first.valid_len,
        embryonic: false,
    }];

    for &idx in &indices[1..] {
        let path = &seg_files[&idx];
        // Checkpoint-covered leftovers of a crashed compaction cleanup:
        // their events are already folded into the checkpoint. Skip them
        // unvalidated — they are scheduled for deletion, not replay.
        if covers.map_or(false, |c| idx <= c) {
            stale.push(idx);
            continue;
        }
        match parse_segment(path, idx, idx == newest, false, Some(&first.header_line)) {
            Ok(p) => {
                let events = p
                    .records
                    .into_iter()
                    .map(|r| match r {
                        SegRecord::Event(ev) => Ok(ev),
                        SegRecord::Checkpoint(_) => Err(anyhow!(
                            "segment {} holds a checkpoint outside the lowest live \
                             segment — corruption",
                            path.display()
                        )),
                    })
                    .collect::<Result<Vec<_>>>()?;
                segs.push(ScannedSeg {
                    idx,
                    path: path.clone(),
                    events,
                    sealed: p.seal.is_some(),
                    valid_len: p.valid_len,
                    embryonic: p.embryonic,
                });
            }
            Err(e) => {
                // A corrupt sealed segment (or a corrupt active one). The
                // prefix below it is intact; under degrade, quarantine the
                // bad segment and everything after it and recover to that
                // prefix. Fail-stop (the default) refuses loudly.
                if !degrade {
                    return Err(e);
                }
                crate::log_warn!(
                    "journal segment {} failed validation; quarantining it and all \
                     later segments, resuming from the sealed prefix: {e:#}",
                    path.display()
                );
                for &q in indices.iter().filter(|&&q| q >= idx) {
                    if covers.map_or(false, |c| q <= c) {
                        continue; // stays on the stale list
                    }
                    let from = &seg_files[&q];
                    let to = suffixed(from, ".quarantined");
                    if let Err(re) = std::fs::rename(from, &to) {
                        crate::log_warn!(
                            "could not quarantine {}: {re}",
                            from.display()
                        );
                    }
                }
                break;
            }
        }
    }
    // An embryonic segment is only meaningful as the successor of a
    // completed seal; with nothing before it there is nothing to resume.
    if let Some(last) = segs.last() {
        if last.embryonic {
            anyhow::ensure!(
                segs.len() > 1,
                "journal segment {} ends mid-header with no sealed predecessor — \
                 nothing to resume",
                last.path.display()
            );
        }
    }
    Ok(Some(SegScan {
        header,
        header_line: first.header_line,
        checkpoint,
        checkpoint_seg,
        segs,
        stale,
    }))
}

/// Read, validate, and assemble the journal at `base` in either layout.
/// The single-file path is byte-for-byte [`super::journal::read_journal`]
/// (seal/checkpoint records never appear there and are rejected as
/// unknown events); the segmented path validates every sealed segment's
/// checksum, tolerates one torn trailing line only in the active segment,
/// and resumes from the newest checkpoint so replay cost is O(events
/// since the checkpoint).
pub fn read_run(base: &Path) -> Result<RunStream> {
    match scan(base)? {
        None => {
            let c = super::journal::read_journal(base)?;
            Ok(RunStream {
                header: c.header,
                checkpoint: None,
                events: c.events,
                valid_len: c.valid_len,
                layout: JournalLayout::Single,
            })
        }
        Some(s) => {
            let layout = s.layout()?;
            let valid_len = s.active()?.valid_len;
            let mut events = Vec::new();
            for seg in &s.segs {
                events.extend(seg.events.iter().cloned());
            }
            Ok(RunStream {
                header: s.header,
                checkpoint: s.checkpoint,
                events,
                valid_len,
                layout,
            })
        }
    }
}

/// Writer-side segmentation knobs (from the run config).
#[derive(Clone, Copy, Debug)]
pub struct SegmentOpts {
    /// Rotate after this many events per segment (0 = single file).
    pub segment_events: usize,
    /// Sealed segments compaction leaves uncompacted behind the active
    /// one (the warm tail a resume replays event-by-event).
    pub keep_segments: usize,
    /// [`JournalWriter::with_fsync_every`] barrier; > 0 additionally
    /// fsyncs the sealed segment and its directory entry at rotation.
    pub fsync_every_n: usize,
}

enum WriterLayout {
    Single,
    Segmented {
        /// Active segment index.
        index: u64,
        /// Events appended to the active segment so far.
        events_in_seg: u64,
        /// Running FNV-1a 64 over every byte written to the active
        /// segment (what the seal will record).
        crc: u64,
    },
}

/// Layout-aware journal writer: delegates to a plain [`JournalWriter`]
/// in single-file mode (structurally byte-identical to v4), rotates
/// through sealed segment files otherwise. Rotation is crash-safe at
/// every step: seal → (fsync file + dir if enabled) → create successor →
/// write header. A kill between any two steps leaves a state
/// [`read_run`] recovers exactly (sealed-without-successor, embryonic
/// successor, torn seal = unsealed active).
pub struct SegmentedWriter {
    base: PathBuf,
    opts: SegmentOpts,
    /// The run's header line, verbatim (no newline) — re-written
    /// byte-for-byte at the start of every segment.
    header_line: String,
    inner: JournalWriter,
    layout: WriterLayout,
    /// Rotation-seam fault injection: fail the next seal append with this
    /// fault (one-shot), exercising degrade/fail-stop at the rotation
    /// site specifically.
    rotation_fault: Option<JournalFault>,
}

impl SegmentedWriter {
    /// Start a fresh journal at `base`, claiming the name: stale segment,
    /// staging, and quarantine files from any previous run there are
    /// removed first (and, in segmented mode, a stale single-file journal
    /// too — the two layouts must never coexist).
    pub fn create(base: &Path, header: &RunHeader, opts: SegmentOpts) -> Result<Self> {
        let header_line = header.to_json().to_string();
        remove_run_files(base)?;
        if opts.segment_events == 0 {
            let inner =
                JournalWriter::create(base, header)?.with_fsync_every(opts.fsync_every_n);
            return Ok(Self {
                base: base.to_path_buf(),
                opts,
                header_line,
                inner,
                layout: WriterLayout::Single,
                rotation_fault: None,
            });
        }
        match std::fs::remove_file(base) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                return Err(anyhow!(e))
                    .with_context(|| format!("removing stale journal {}", base.display()))
            }
        }
        let seg0 = segment_path(base, 0);
        let file = File::create(&seg0)
            .with_context(|| format!("creating journal segment {}", seg0.display()))?;
        let mut inner =
            JournalWriter::from_file(file, seg0).with_fsync_every(opts.fsync_every_n);
        inner.append_line_raw(&header_line)?;
        let crc = fnv1a(fnv1a(FNV_OFFSET, header_line.as_bytes()), b"\n");
        Ok(Self {
            base: base.to_path_buf(),
            opts,
            header_line,
            inner,
            layout: WriterLayout::Segmented { index: 0, events_in_seg: 0, crc },
            rotation_fault: None,
        })
    }

    /// Reopen the journal of a recovered run for appending. Cleans up
    /// compaction staging files and stale segments, truncates the active
    /// segment's torn tail (or, if the crash landed between seal and
    /// successor, creates the successor now), and recomputes the running
    /// checksum from the bytes on disk.
    pub fn resume(
        base: &Path,
        layout: &JournalLayout,
        valid_len: u64,
        opts: SegmentOpts,
    ) -> Result<Self> {
        match layout {
            JournalLayout::Single => {
                anyhow::ensure!(
                    opts.segment_events == 0,
                    "journal {} is single-file but the journaled config asks for \
                     segment rotation — layout/config mismatch",
                    base.display()
                );
                let inner =
                    JournalWriter::resume(base, valid_len)?.with_fsync_every(opts.fsync_every_n);
                // The header line is only needed to start new segments;
                // single-file mode never rotates.
                Ok(Self {
                    base: base.to_path_buf(),
                    opts,
                    header_line: String::new(),
                    inner,
                    layout: WriterLayout::Single,
                    rotation_fault: None,
                })
            }
            JournalLayout::Segmented { active, active_sealed, next_index, sealed, stale } => {
                anyhow::ensure!(
                    opts.segment_events > 0,
                    "journal {} is segmented but the journaled config asks for a \
                     single file — layout/config mismatch",
                    base.display()
                );
                for tmp in discover_tmp_files(base)? {
                    std::fs::remove_file(&tmp).with_context(|| {
                        format!("removing stale compaction staging file {}", tmp.display())
                    })?;
                }
                for &idx in stale {
                    let p = segment_path(base, idx);
                    match std::fs::remove_file(&p) {
                        Ok(()) => {}
                        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                        Err(e) => {
                            return Err(anyhow!(e)).with_context(|| {
                                format!("removing checkpoint-covered segment {}", p.display())
                            })
                        }
                    }
                }
                if opts.fsync_every_n > 0 {
                    fsync_dir(parent_dir(base)).with_context(|| {
                        format!("fsyncing journal directory {}", parent_dir(base).display())
                    })?;
                }
                let active_path = segment_path(base, *active);
                // The verbatim header line comes from a file on disk, never
                // from re-serialization: the active segment if it has one,
                // else the newest sealed predecessor.
                let header_src = if valid_len > 0 {
                    active_path.clone()
                } else {
                    let idx = sealed.last().copied().ok_or_else(|| {
                        anyhow!(
                            "segment {} is empty and no sealed predecessor exists",
                            active_path.display()
                        )
                    })?;
                    segment_path(base, idx)
                };
                let src_bytes = std::fs::read(&header_src).with_context(|| {
                    format!("reading journal segment {}", header_src.display())
                })?;
                let nl = src_bytes
                    .iter()
                    .position(|&b| b == b'\n')
                    .ok_or_else(|| {
                        anyhow!("segment {} has no header line", header_src.display())
                    })?;
                let header_line = String::from_utf8(src_bytes[..nl].to_vec())
                    .map_err(|e| anyhow!("segment header is not utf8: {e}"))?;

                if *active_sealed {
                    // Crash between seal and successor creation: the seal
                    // is committed, so activate the successor now, exactly
                    // as the interrupted rotation would have.
                    let next_path = segment_path(base, *next_index);
                    let file = File::create(&next_path).with_context(|| {
                        format!("creating journal segment {}", next_path.display())
                    })?;
                    let mut inner = JournalWriter::from_file(file, next_path)
                        .with_fsync_every(opts.fsync_every_n);
                    inner.append_line_raw(&header_line)?;
                    let crc = fnv1a(fnv1a(FNV_OFFSET, header_line.as_bytes()), b"\n");
                    return Ok(Self {
                        base: base.to_path_buf(),
                        opts,
                        header_line,
                        inner,
                        layout: WriterLayout::Segmented {
                            index: *next_index,
                            events_in_seg: 0,
                            crc,
                        },
                        rotation_fault: None,
                    });
                }
                if valid_len == 0 {
                    // Embryonic successor (kill mid-header-write): truncate
                    // and re-write the header, making it a clean empty
                    // active segment.
                    let file = File::create(&active_path).with_context(|| {
                        format!("re-initializing journal segment {}", active_path.display())
                    })?;
                    let mut inner = JournalWriter::from_file(file, active_path)
                        .with_fsync_every(opts.fsync_every_n);
                    inner.append_line_raw(&header_line)?;
                    let crc = fnv1a(fnv1a(FNV_OFFSET, header_line.as_bytes()), b"\n");
                    return Ok(Self {
                        base: base.to_path_buf(),
                        opts,
                        header_line,
                        inner,
                        layout: WriterLayout::Segmented {
                            index: *active,
                            events_in_seg: 0,
                            crc,
                        },
                        rotation_fault: None,
                    });
                }
                let inner = JournalWriter::resume(&active_path, valid_len)?
                    .with_fsync_every(opts.fsync_every_n);
                // Recompute the running checksum and event count from the
                // (now truncated) bytes on disk — the seal must describe
                // exactly what a reader will hash.
                let bytes = std::fs::read(&active_path).with_context(|| {
                    format!("reading journal segment {}", active_path.display())
                })?;
                let crc = fnv1a(FNV_OFFSET, &bytes);
                let events_in_seg = split_jsonl(&bytes)
                    .iter()
                    .skip(1)
                    .filter(|(_, raw, terminated)| *terminated && !raw.is_empty())
                    .count() as u64;
                Ok(Self {
                    base: base.to_path_buf(),
                    opts,
                    header_line,
                    inner,
                    layout: WriterLayout::Segmented { index: *active, events_in_seg, crc },
                    rotation_fault: None,
                })
            }
        }
    }

    /// The journal base path (segment files derive from it).
    pub fn path(&self) -> &Path {
        &self.base
    }

    /// Failing-writer test double on the *event* append path (see
    /// [`JournalWriter::inject_fault_after`]); the countdown survives
    /// rotations into successor segments.
    #[doc(hidden)]
    pub fn inject_fault_after(&mut self, appends: usize, kind: JournalFault) {
        self.inner.inject_fault_after(appends, kind);
    }

    /// Arm the *rotation* seam: the next seal append fails with `kind`
    /// (one-shot). Distinct from the event-append countdown — the seam
    /// writes a segment-layer record that bypasses it.
    #[doc(hidden)]
    pub fn inject_rotation_fault(&mut self, kind: JournalFault) {
        self.rotation_fault = Some(kind);
    }

    /// Append one event, rotating first if the active segment is full.
    pub fn append(&mut self, event: &JournalEvent) -> std::result::Result<(), JournalError> {
        if let WriterLayout::Segmented { events_in_seg, .. } = &self.layout {
            if *events_in_seg >= self.opts.segment_events as u64 {
                self.rotate()?;
            }
        }
        match &mut self.layout {
            WriterLayout::Single => self.inner.append(event),
            WriterLayout::Segmented { events_in_seg, crc, .. } => {
                let j = event.to_json();
                let line = j.to_string();
                self.inner.append_json(&j)?;
                *crc = fnv1a(fnv1a(*crc, line.as_bytes()), b"\n");
                *events_in_seg += 1;
                Ok(())
            }
        }
    }

    /// Seal the active segment and activate its successor. Crash-safe at
    /// every step; with fsync enabled the sealed bytes and the successor's
    /// directory entry are durable before any event lands in it.
    fn rotate(&mut self) -> std::result::Result<(), JournalError> {
        let (index, events_in_seg, crc) = match &self.layout {
            WriterLayout::Segmented { index, events_in_seg, crc } => {
                (*index, *events_in_seg, *crc)
            }
            WriterLayout::Single => return Ok(()),
        };
        let seal = SealRecord { seg: index, events: events_in_seg, crc }.to_json();
        if let Some(kind) = self.rotation_fault.take() {
            let mut line = seal.to_string();
            line.push('\n');
            return Err(self.inner.inject_failure_line(&line, kind));
        }
        self.inner.append_json_raw(&seal)?;
        if self.opts.fsync_every_n > 0 {
            // Durability at the seam: the sealed bytes AND the file's
            // directory entry must be on stable storage before the
            // successor exists — a machine crash after activation must
            // never find a lost or half-sealed predecessor.
            self.inner.sync_data_now()?;
            let dir = parent_dir(&self.base);
            fsync_dir(dir).map_err(|e| JournalError::Io {
                op: "fsync",
                path: dir.to_path_buf(),
                source: e,
            })?;
        }
        let next = index + 1;
        let next_path = segment_path(&self.base, next);
        let file = File::create(&next_path).map_err(|e| JournalError::Io {
            op: "create",
            path: next_path.clone(),
            source: e,
        })?;
        let mut next_writer = JournalWriter::from_file(file, next_path.clone())
            .with_fsync_every(self.opts.fsync_every_n);
        if let Err(e) = next_writer.append_line_raw(&self.header_line) {
            // No half-activated successor: an empty/torn successor is
            // recoverable, but best-effort removal keeps the layout clean.
            let _ = std::fs::remove_file(&next_path);
            return Err(e);
        }
        if let Some((appends, kind)) = self.inner.remaining_fault() {
            next_writer.inject_fault_after(appends, kind);
        }
        self.inner = next_writer;
        self.layout = WriterLayout::Segmented {
            index: next,
            events_in_seg: 0,
            crc: fnv1a(fnv1a(FNV_OFFSET, self.header_line.as_bytes()), b"\n"),
        };
        // Opportunistic compaction of the sealed prefix. Best-effort by
        // design: a failure leaves uncompacted-but-valid segments behind
        // and must never abort the run mid-append.
        if let Err(e) = super::compact::compact(&self.base, self.opts.keep_segments) {
            crate::log_warn!(
                "journal compaction failed (uncompacted segments remain valid): {e:#}"
            );
        }
        Ok(())
    }
}

/// Remove every derived file of `base` (segments, staging, quarantine) —
/// a fresh run claims the name wholesale.
fn remove_run_files(base: &Path) -> Result<()> {
    let base_name = match base.file_name() {
        Some(n) => n.to_string_lossy().into_owned(),
        None => return Err(anyhow!("journal path {} has no file name", base.display())),
    };
    let prefix = format!("{base_name}.seg");
    let entries = match std::fs::read_dir(parent_dir(base)) {
        Ok(e) => e,
        Err(_) => return Ok(()), // no directory yet: nothing stale to claim
    };
    for entry in entries {
        let entry = entry.with_context(|| {
            format!("listing journal directory {}", parent_dir(base).display())
        })?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.starts_with(&prefix) {
            std::fs::remove_file(entry.path()).with_context(|| {
                format!("removing stale journal file {}", entry.path().display())
            })?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::settings::RunConfig;
    use crate::persist::journal::{read_journal, EventOutcome, SenseTag};
    use crate::space::{Config, ParamValue};
    use std::io::Write;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("mango_segment_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn header(segment_events: usize) -> RunHeader {
        RunHeader {
            space_fp: 7,
            sense: SenseTag::Maximize,
            run: RunConfig {
                mode: "async".into(),
                journal_segment_events: segment_events,
                ..Default::default()
            },
            celery: None,
        }
    }

    fn cfg(i: i64) -> Config {
        Config::new(vec![("i".into(), ParamValue::Int(i))])
    }

    fn ev(pid: u64) -> JournalEvent {
        JournalEvent::AsyncPropose { pid, rounds: 0, config: cfg(pid as i64) }
    }

    fn opts(segment_events: usize) -> SegmentOpts {
        // keep_segments large: these tests exercise rotation/sealing, not
        // compaction (persist::compact has its own suite).
        SegmentOpts { segment_events, keep_segments: 100, fsync_every_n: 0 }
    }

    fn events(n: u64) -> Vec<JournalEvent> {
        (0..n).map(ev).collect()
    }

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a(FNV_OFFSET, b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(FNV_OFFSET, b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(FNV_OFFSET, b"foobar"), 0x85944171f73967e8);
        // Incremental == one-shot.
        assert_eq!(
            fnv1a(fnv1a(FNV_OFFSET, b"foo"), b"bar"),
            fnv1a(FNV_OFFSET, b"foobar")
        );
    }

    #[test]
    fn single_mode_is_byte_identical_to_plain_writer() {
        let d = tmpdir("single_bytes");
        let a = d.join("plain.jsonl");
        let b = d.join("segmented.jsonl");
        {
            let mut w = JournalWriter::create(&a, &header(0)).unwrap();
            for e in events(5) {
                w.append(&e).unwrap();
            }
        }
        {
            let mut w = SegmentedWriter::create(&b, &header(0), opts(0)).unwrap();
            for e in events(5) {
                w.append(&e).unwrap();
            }
        }
        assert_eq!(
            std::fs::read(&a).unwrap(),
            std::fs::read(&b).unwrap(),
            "segment_events=0 must be byte-for-byte the plain single-file writer"
        );
        // And no segment files appear.
        assert!(discover_segments(&b).unwrap().is_empty());
        let stream = read_run(&b).unwrap();
        assert_eq!(stream.layout, JournalLayout::Single);
        assert_eq!(stream.events, events(5));
        assert!(stream.checkpoint.is_none());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn rotation_seals_segments_and_read_run_reassembles_the_stream() {
        let d = tmpdir("rotate");
        let base = d.join("run.jsonl");
        {
            let mut w = SegmentedWriter::create(&base, &header(2), opts(2)).unwrap();
            for e in events(5) {
                w.append(&e).unwrap();
            }
        }
        // 5 events at 2/segment: seg0 (2, sealed), seg1 (2, sealed),
        // seg2 (1, active).
        let segs = discover_segments(&base).unwrap();
        assert_eq!(segs.keys().copied().collect::<Vec<_>>(), vec![0, 1, 2]);
        assert!(!base.exists(), "segmented mode must not leave a bare base file");
        // Every segment starts with the identical header line.
        let head = |p: &Path| -> Vec<u8> {
            let b = std::fs::read(p).unwrap();
            let nl = b.iter().position(|&x| x == b'\n').unwrap();
            b[..nl].to_vec()
        };
        let h0 = head(&segs[&0]);
        assert_eq!(head(&segs[&1]), h0);
        assert_eq!(head(&segs[&2]), h0);
        // Sealed segments parse as exactly (header, events…, seal) with a
        // matching checksum; the plain reader understands none of this.
        let p0 = parse_segment(&segs[&0], 0, false, false, None).unwrap();
        assert_eq!(p0.records.len(), 2);
        assert_eq!(p0.seal.unwrap().events, 2);
        let stream = read_run(&base).unwrap();
        assert_eq!(stream.events, events(5), "stream reassembles in order");
        match &stream.layout {
            JournalLayout::Segmented { active, active_sealed, next_index, sealed, stale } => {
                assert_eq!(*active, 2);
                assert!(!active_sealed);
                assert_eq!(*next_index, 3);
                assert_eq!(sealed, &[0, 1]);
                assert!(stale.is_empty());
            }
            other => panic!("expected segmented layout, got {other:?}"),
        }
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn resume_continues_the_active_segment_and_preserves_seal_integrity() {
        let d = tmpdir("resume");
        let base = d.join("run.jsonl");
        {
            let mut w = SegmentedWriter::create(&base, &header(3), opts(3)).unwrap();
            for e in events(4) {
                w.append(&e).unwrap();
            }
        }
        let stream = read_run(&base).unwrap();
        {
            let mut w =
                SegmentedWriter::resume(&base, &stream.layout, stream.valid_len, opts(3))
                    .unwrap();
            for e in (4..8).map(ev) {
                w.append(&e).unwrap();
            }
        }
        // 8 events at 3/segment: seg0 sealed(3), seg1 sealed(3) — sealed
        // by the RESUMED writer, so its crc had to be recomputed right —
        // seg2 active(2).
        let stream = read_run(&base).unwrap();
        assert_eq!(stream.events, events(8));
        let segs = discover_segments(&base).unwrap();
        let p1 = parse_segment(&segs[&1], 1, false, false, None).unwrap();
        assert_eq!(p1.seal.unwrap().events, 3);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn torn_tail_tolerated_only_in_the_active_segment() {
        let d = tmpdir("torn");
        let base = d.join("run.jsonl");
        {
            let mut w = SegmentedWriter::create(&base, &header(2), opts(2)).unwrap();
            for e in events(3) {
                w.append(&e).unwrap();
            }
        }
        let segs = discover_segments(&base).unwrap();
        // Torn tail on the ACTIVE segment: dropped, like single-file.
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&segs[&1]).unwrap();
            f.write_all(b"{\"e\":\"async_prop").unwrap();
        }
        let stream = read_run(&base).unwrap();
        assert_eq!(stream.events, events(3), "active torn tail drops cleanly");
        // Torn tail on a SEALED segment: bytes after the seal, corruption.
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&segs[&0]).unwrap();
            f.write_all(b"{\"e\":\"async_prop").unwrap();
        }
        let err = read_run(&base).unwrap_err();
        assert!(err.to_string().contains("after its seal"), "got: {err:#}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn sealed_segment_checksum_and_count_mismatches_fail_loudly() {
        let d = tmpdir("crc");
        let base = d.join("run.jsonl");
        {
            let mut w = SegmentedWriter::create(&base, &header(2), opts(2)).unwrap();
            for e in events(3) {
                w.append(&e).unwrap();
            }
        }
        let segs = discover_segments(&base).unwrap();
        let clean = std::fs::read(&segs[&0]).unwrap();
        // Flip one byte inside a committed event line of the sealed seg.
        let mut bad = clean.clone();
        let pos = bad.windows(4).position(|w| w == b"\"pid").unwrap();
        bad[pos + 1] = b'q';
        std::fs::write(&segs[&0], &bad).unwrap();
        let err = read_run(&base).unwrap_err();
        // The corrupt line fails record-parse or crc — loudly either way.
        assert!(
            err.to_string().contains("corrupt") || err.to_string().contains("checksum"),
            "got: {err:#}"
        );
        // A bit flip that keeps every line parseable is caught by the crc.
        let mut flipped = clean.clone();
        let pos = flipped.windows(8).position(|w| w == b"\"pid\":0,").unwrap();
        flipped[pos + 6] = b'9'; // pid 0 -> pid 9: valid JSON, wrong bytes
        std::fs::write(&segs[&0], &flipped).unwrap();
        let err = read_run(&base).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "got: {err:#}");
        // Truncating a sealed segment (missing seal) is loud too.
        let cut = clean.len() - 10;
        std::fs::write(&segs[&0], &clean[..cut]).unwrap();
        let err = read_run(&base).unwrap_err();
        assert!(
            err.to_string().contains("unterminated") || err.to_string().contains("no seal"),
            "got: {err:#}"
        );
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn degrade_quarantines_a_corrupt_sealed_segment_and_resumes_the_prefix() {
        let d = tmpdir("quarantine");
        let base = d.join("run.jsonl");
        let mut h = header(2);
        h.run.journal_on_error = "degrade".into();
        {
            let mut w = SegmentedWriter::create(&base, &h, opts(2)).unwrap();
            for e in events(5) {
                w.append(&e).unwrap();
            }
        }
        let segs = discover_segments(&base).unwrap();
        // Corrupt sealed seg1 with a parseable-but-wrong byte (crc catches).
        let mut bytes = std::fs::read(&segs[&1]).unwrap();
        let pos = bytes.windows(8).position(|w| w == b"\"pid\":2,").unwrap();
        bytes[pos + 6] = b'7';
        std::fs::write(&segs[&1], &bytes).unwrap();
        let stream = read_run(&base).unwrap();
        // Only seg0's events survive; seg1 and seg2 are quarantined.
        assert_eq!(stream.events, events(2));
        match &stream.layout {
            JournalLayout::Segmented { active, active_sealed, .. } => {
                assert_eq!(*active, 0);
                assert!(*active_sealed, "the surviving prefix ends sealed");
            }
            other => panic!("expected segmented layout, got {other:?}"),
        }
        assert!(!segs[&1].exists() && !segs[&2].exists());
        assert!(suffixed(&segs[&1], ".quarantined").exists());
        assert!(suffixed(&segs[&2], ".quarantined").exists());
        // Resume activates the successor of the surviving sealed prefix.
        let mut o = opts(2);
        let mut w = SegmentedWriter::resume(&base, &stream.layout, stream.valid_len, {
            o.segment_events = 2;
            o
        })
        .unwrap();
        w.append(&ev(10)).unwrap();
        drop(w);
        let stream = read_run(&base).unwrap();
        assert_eq!(stream.events, vec![ev(0), ev(1), ev(10)]);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn under_fail_stop_a_corrupt_sealed_segment_refuses_loudly() {
        let d = tmpdir("failstop");
        let base = d.join("run.jsonl");
        {
            // Default policy is fail-stop.
            let mut w = SegmentedWriter::create(&base, &header(2), opts(2)).unwrap();
            for e in events(5) {
                w.append(&e).unwrap();
            }
        }
        let segs = discover_segments(&base).unwrap();
        let mut bytes = std::fs::read(&segs[&1]).unwrap();
        let pos = bytes.windows(8).position(|w| w == b"\"pid\":2,").unwrap();
        bytes[pos + 6] = b'7';
        std::fs::write(&segs[&1], &bytes).unwrap();
        let err = read_run(&base).unwrap_err();
        assert!(err.to_string().contains("checksum mismatch"), "got: {err:#}");
        assert!(segs[&1].exists(), "fail-stop must not quarantine");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn crash_between_seal_and_successor_recovers_to_the_sealed_prefix() {
        let d = tmpdir("midrot_sealed");
        let base = d.join("run.jsonl");
        {
            let mut w = SegmentedWriter::create(&base, &header(2), opts(2)).unwrap();
            for e in events(2) {
                w.append(&e).unwrap();
            }
            // Rotation happens lazily on the NEXT append; simulate the
            // crash window by sealing manually: append the seal record the
            // rotation would write, then "die" before creating seg1.
        }
        let seg0 = segment_path(&base, 0);
        let bytes = std::fs::read(&seg0).unwrap();
        let seal = SealRecord { seg: 0, events: 2, crc: fnv1a(FNV_OFFSET, &bytes) }.to_json();
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&seg0).unwrap();
            let mut line = seal.to_string();
            line.push('\n');
            f.write_all(line.as_bytes()).unwrap();
        }
        let stream = read_run(&base).unwrap();
        assert_eq!(stream.events, events(2), "no events lost to the seam");
        match &stream.layout {
            JournalLayout::Segmented { active, active_sealed, next_index, .. } => {
                assert_eq!((*active, *active_sealed, *next_index), (0, true, 1));
            }
            other => panic!("expected segmented layout, got {other:?}"),
        }
        // Resume completes the interrupted rotation.
        let mut w =
            SegmentedWriter::resume(&base, &stream.layout, stream.valid_len, opts(2)).unwrap();
        w.append(&ev(2)).unwrap();
        drop(w);
        assert!(segment_path(&base, 1).exists());
        assert_eq!(read_run(&base).unwrap().events, events(3));
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn crash_mid_successor_header_recovers_as_an_empty_active_segment() {
        let d = tmpdir("midrot_embryo");
        let base = d.join("run.jsonl");
        {
            let mut w = SegmentedWriter::create(&base, &header(2), opts(2)).unwrap();
            for e in events(3) {
                w.append(&e).unwrap();
            }
        }
        // seg0 sealed, seg1 active with 1 event. Simulate the next
        // rotation dying mid-successor-header: seal seg1 by hand, then
        // write a torn header fragment into seg2.
        let seg1 = segment_path(&base, 1);
        let bytes = std::fs::read(&seg1).unwrap();
        let seal = SealRecord { seg: 1, events: 1, crc: fnv1a(FNV_OFFSET, &bytes) }.to_json();
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&seg1).unwrap();
            let mut line = seal.to_string();
            line.push('\n');
            f.write_all(line.as_bytes()).unwrap();
        }
        std::fs::write(segment_path(&base, 2), b"{\"e\":\"head").unwrap();
        let stream = read_run(&base).unwrap();
        assert_eq!(stream.events, events(3));
        match &stream.layout {
            JournalLayout::Segmented { active, active_sealed, .. } => {
                assert_eq!(*active, 2);
                assert!(!active_sealed);
            }
            other => panic!("expected segmented layout, got {other:?}"),
        }
        assert_eq!(stream.valid_len, 0, "embryonic successor holds no committed bytes");
        // Resume re-initializes the embryonic segment and appends into it.
        let mut w =
            SegmentedWriter::resume(&base, &stream.layout, stream.valid_len, opts(2)).unwrap();
        w.append(&ev(3)).unwrap();
        drop(w);
        assert_eq!(read_run(&base).unwrap().events, events(4));
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn rotation_fault_fails_the_seal_and_leaves_a_recoverable_layout() {
        let d = tmpdir("rotfault");
        let base = d.join("run.jsonl");
        for kind in [JournalFault::Enospc, JournalFault::ShortWrite] {
            let mut w = SegmentedWriter::create(&base, &header(2), opts(2)).unwrap();
            for e in events(2) {
                w.append(&e).unwrap();
            }
            w.inject_rotation_fault(kind);
            // The 3rd append triggers rotation, whose seal append fails.
            let err = w.append(&ev(2)).unwrap_err();
            match (kind, &err) {
                (JournalFault::Enospc, JournalError::Io { op, .. }) => assert_eq!(*op, "write"),
                (JournalFault::ShortWrite, JournalError::ShortWrite { .. }) => {}
                other => panic!("unexpected fault/error pairing: {other:?}"),
            }
            drop(w);
            // Whatever landed (nothing, or a torn seal fragment in the
            // active segment), the layout recovers to the 2 committed
            // events with no successor and no half-activated segment.
            assert!(!segment_path(&base, 1).exists(), "{kind:?}: no half-activated successor");
            let stream = read_run(&base).unwrap();
            assert_eq!(stream.events, events(2), "{kind:?}");
            match &stream.layout {
                JournalLayout::Segmented { active, active_sealed, .. } => {
                    assert_eq!(*active, 0, "{kind:?}");
                    assert!(!active_sealed, "{kind:?}: torn seal must read as unsealed");
                }
                other => panic!("expected segmented layout, got {other:?}"),
            }
        }
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn event_fault_countdown_survives_rotation_into_the_successor() {
        let d = tmpdir("faultcarry");
        let base = d.join("run.jsonl");
        let mut w = SegmentedWriter::create(&base, &header(2), opts(2)).unwrap();
        // Countdown 3: events 0,1 (seg0), 2 (seg1, after rotation) succeed;
        // event 3 fails INSIDE seg1 — the countdown crossed the seam.
        w.inject_fault_after(3, JournalFault::Enospc);
        for e in events(3) {
            w.append(&e).unwrap();
        }
        let err = w.append(&ev(3)).unwrap_err();
        assert!(matches!(err, JournalError::Io { op: "write", .. }));
        drop(w);
        let stream = read_run(&base).unwrap();
        assert_eq!(stream.events, events(3));
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn both_layouts_under_one_base_are_refused() {
        let d = tmpdir("ambiguous");
        let base = d.join("run.jsonl");
        {
            let mut w = SegmentedWriter::create(&base, &header(2), opts(2)).unwrap();
            w.append(&ev(0)).unwrap();
        }
        {
            let mut w = JournalWriter::create(&base, &header(0)).unwrap();
            w.append(&ev(0)).unwrap();
        }
        let err = read_run(&base).unwrap_err();
        assert!(err.to_string().contains("ambiguous"), "got: {err:#}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn fresh_create_claims_the_base_name_in_both_directions() {
        let d = tmpdir("claim");
        let base = d.join("run.jsonl");
        // Segmented run leaves segments; a later single-file run at the
        // same path must remove them (else discovery turns ambiguous).
        {
            let mut w = SegmentedWriter::create(&base, &header(2), opts(2)).unwrap();
            for e in events(3) {
                w.append(&e).unwrap();
            }
        }
        {
            let mut w = SegmentedWriter::create(&base, &header(0), opts(0)).unwrap();
            w.append(&ev(9)).unwrap();
        }
        assert!(discover_segments(&base).unwrap().is_empty());
        assert_eq!(read_run(&base).unwrap().events, vec![ev(9)]);
        // And the reverse: single-file then segmented removes the bare file.
        {
            let mut w = SegmentedWriter::create(&base, &header(2), opts(2)).unwrap();
            w.append(&ev(1)).unwrap();
        }
        assert!(!base.exists());
        assert_eq!(read_run(&base).unwrap().events, vec![ev(1)]);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn fsync_rotation_is_byte_transparent() {
        // The fsync seam adds durability barriers, never bytes: the
        // segment files must be identical with and without it.
        let d = tmpdir("fsync_bytes");
        let write_with = |name: &str, fsync: usize| -> Vec<Vec<u8>> {
            let base = d.join(name);
            let o = SegmentOpts { segment_events: 2, keep_segments: 100, fsync_every_n: fsync };
            let mut w = SegmentedWriter::create(&base, &header(2), o).unwrap();
            for e in events(5) {
                w.append(&e).unwrap();
            }
            drop(w);
            discover_segments(&base)
                .unwrap()
                .values()
                .map(|p| std::fs::read(p).unwrap())
                .collect()
        };
        assert_eq!(write_with("nofsync.jsonl", 0), write_with("fsync.jsonl", 1));
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn single_file_journal_rejects_segment_layer_records() {
        // seal/checkpoint are segment-layer only: in a single-file journal
        // they must read as unknown events (corruption), keeping the
        // single-file byte contract exactly v4's.
        let d = tmpdir("laywall");
        let base = d.join("run.jsonl");
        {
            let mut w = JournalWriter::create(&base, &header(0)).unwrap();
            w.append(&ev(0)).unwrap();
        }
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&base).unwrap();
            f.write_all(b"{\"crc\":\"0000000000000000\",\"e\":\"seal\",\"events\":1,\"seg\":0}\n")
                .unwrap();
        }
        let err = read_run(&base).unwrap_err();
        assert!(err.to_string().contains("unknown journal event"), "got: {err:#}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn renamed_segment_is_caught_by_its_embedded_index() {
        let d = tmpdir("rename");
        let base = d.join("run.jsonl");
        {
            let mut w = SegmentedWriter::create(&base, &header(1), opts(1)).unwrap();
            for e in events(3) {
                w.append(&e).unwrap();
            }
        }
        // Swap seg0 and seg1: both still checksum-valid files, but their
        // embedded indices no longer match their names.
        let s0 = segment_path(&base, 0);
        let s1 = segment_path(&base, 1);
        let tmp = d.join("swap");
        std::fs::rename(&s0, &tmp).unwrap();
        std::fs::rename(&s1, &s0).unwrap();
        std::fs::rename(&tmp, &s1).unwrap();
        let err = read_run(&base).unwrap_err();
        assert!(err.to_string().contains("seal for segment"), "got: {err:#}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn resume_cleans_tmp_staging_and_event_counts_stay_exact() {
        let d = tmpdir("tmpclean");
        let base = d.join("run.jsonl");
        {
            let mut w = SegmentedWriter::create(&base, &header(3), opts(3)).unwrap();
            for e in events(4) {
                w.append(&e).unwrap();
            }
        }
        // A compaction that crashed before its rename leaves a .tmp file.
        let staged = suffixed(&segment_path(&base, 0), ".tmp");
        std::fs::write(&staged, b"half-written checkpoint").unwrap();
        let stream = read_run(&base).unwrap();
        assert_eq!(stream.events, events(4), ".tmp files are invisible to the reader");
        let w = SegmentedWriter::resume(&base, &stream.layout, stream.valid_len, opts(3))
            .unwrap();
        drop(w);
        assert!(!staged.exists(), "resume removes compaction staging files");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn seal_records_roundtrip_and_reject_bad_fields() {
        let s = SealRecord { seg: 3, events: 17, crc: 0xdead_beef_cafe_f00d };
        let back = SealRecord::from_json(&parse(&s.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, s);
        let bad = parse(r#"{"e":"seal","seg":0,"events":1,"crc":"zz"}"#).unwrap();
        assert!(SealRecord::from_json(&bad).unwrap_err().to_string().contains("bad seal crc"));
        let p = EventOutcome::Done(0.0); // silence unused-import pedantry
        let _ = p;
    }
}
