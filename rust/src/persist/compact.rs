//! Journal compaction: fold a sealed segment prefix into one
//! `checkpoint` record, bounding resume cost and disk footprint to the
//! active window.
//!
//! The checkpoint payload is the *complete* mid-scan state of the replay
//! fold ([`SyncFold`] / [`AsyncFold`]) — accumulators, the open-proposal
//! book, the global sequence counter, the running worst-seen censoring
//! state, the stable-order audit frontier — serialized with the same
//! canonical codecs the event stream uses ([`f64_to_json`] for values,
//! the shared outcome codec for terminals, `Config::to_journal_json` for
//! configurations). Deserializing it and continuing the fold over the
//! tail segments is therefore *bit-identical* to folding the full event
//! stream: `recover(checkpoint + tail) == recover(full stream)`, the
//! property `rust/tests/recovery.rs` exercises end-to-end and the unit
//! tests here exercise codec-by-codec.
//!
//! Compaction is crash-safe by staging + atomic rename:
//!
//! 1. fold the candidate prefix (checkpoint-if-any + sealed events);
//! 2. write `header / checkpoint / seal` to `<seg>.tmp`, fsync it;
//! 3. rename it over the lowest candidate segment, fsync the directory —
//!    the checkpoint is now the journal's truth;
//! 4. delete the remaining candidates (now stale: their index is ≤
//!    `covers`), fsync the directory.
//!
//! A crash before (3) leaves a stray `.tmp` (removed on resume, invisible
//! to the reader); a crash before (4) leaves stale segments the reader
//! skips and the next resume or compaction deletes. Both replay to the
//! same state.

use super::journal::{
    outcome_fields, outcome_from_json, req_f64, req_str, req_u64, req_usize, SenseTag,
};
use super::recover::{
    AsyncFold, CompletionLogEntry, PartialRound, PidState, RoundRecord, SyncFold, TerminalReplay,
};
use super::segment::{
    self, fnv1a, parent_dir, segment_path, suffixed, CheckpointRecord, SealRecord, FNV_OFFSET,
};
use crate::config::json::Json;
use crate::space::{f64_from_json, f64_to_json, Config};
use anyhow::{anyhow, Context, Result};
use std::io::Write;
use std::path::Path;

// ---------------------------------------------------------------------------
// small JSON helpers (array-element variants of the journal's req_*)

fn req_bool(j: &Json, k: &str) -> Result<bool> {
    j.get(k).and_then(Json::as_bool).ok_or_else(|| anyhow!("checkpoint missing bool '{k}'"))
}

fn req_arr<'a>(j: &'a Json, k: &str) -> Result<&'a [Json]> {
    j.get(k).and_then(Json::as_arr).ok_or_else(|| anyhow!("checkpoint missing array '{k}'"))
}

/// Required field in the canonical f64 codec (which `req_f64` cannot
/// read: non-finite values serialize as bit-pattern strings).
fn req_codec_f64(j: &Json, k: &str) -> Result<f64> {
    f64_from_json(j.get(k).ok_or_else(|| anyhow!("checkpoint missing value '{k}'"))?)
}

fn elem_u64(j: &Json) -> Result<u64> {
    let n = j.as_f64().ok_or_else(|| anyhow!("checkpoint: expected integer, found {j}"))?;
    anyhow::ensure!(
        n.fract() == 0.0 && (0.0..=9_007_199_254_740_992.0).contains(&n),
        "checkpoint: {n} is not an exactly-representable non-negative integer"
    );
    Ok(n as u64)
}

fn elem_bool(j: &Json) -> Result<bool> {
    j.as_bool().ok_or_else(|| anyhow!("checkpoint: expected bool, found {j}"))
}

fn opt_u64(j: &Json, k: &str) -> Result<Option<u64>> {
    match j.get(k) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => Ok(Some(elem_u64(v)?)),
    }
}

fn pair(items: &[Json], n: usize, what: &str) -> Result<&[Json]> {
    anyhow::ensure!(items.len() == n, "checkpoint: {what} needs {n} elements, found {}", items.len());
    Ok(items)
}

// ---------------------------------------------------------------------------
// sync fold <-> checkpoint state

fn history_to_json(history: &[(Config, f64)]) -> Json {
    Json::Arr(
        history
            .iter()
            .map(|(c, v)| Json::Arr(vec![c.to_journal_json(), f64_to_json(*v)]))
            .collect(),
    )
}

fn history_from_json(j: &Json, k: &str) -> Result<Vec<(Config, f64)>> {
    req_arr(j, k)?
        .iter()
        .map(|item| {
            let items =
                item.as_arr().ok_or_else(|| anyhow!("checkpoint: history entry not a pair"))?;
            let items = pair(items, 2, "history entry")?;
            Ok((Config::from_journal_json(&items[0])?, f64_from_json(&items[1])?))
        })
        .collect()
}

/// Serialize a mid-scan [`SyncFold`] into a checkpoint `state` payload.
pub(crate) fn sync_fold_to_state(fold: &SyncFold) -> Json {
    let rounds_done = fold
        .r
        .rounds_done
        .iter()
        .map(|rr| {
            Json::obj(vec![
                ("iter", Json::Num(rr.iter as f64)),
                ("proposed", Json::Num(rr.proposed as f64)),
                ("returned", Json::Num(rr.returned as f64)),
                ("best", f64_to_json(rr.best)),
                ("wall_ms", Json::Num(rr.wall_ms)),
            ])
        })
        .collect();
    let rng = match fold.r.rng_state {
        Some(s) => Json::Str(format!("{s:032x}")),
        None => Json::Null,
    };
    let current = match &fold.current {
        None => Json::Null,
        Some(p) => Json::obj(vec![
            ("iter", Json::Num(p.iter as f64)),
            ("batch", Json::Arr(p.batch.iter().map(Config::to_journal_json).collect())),
            (
                "evals",
                Json::Arr(
                    p.evals
                        .iter()
                        .map(|(c, v)| {
                            let mut fields = vec![("config", c.to_journal_json())];
                            match v {
                                Some(v) => fields.push(("v", f64_to_json(*v))),
                                None => fields.push(("failed", Json::Bool(true))),
                            }
                            Json::obj(fields)
                        })
                        .collect(),
                ),
            ),
        ]),
    };
    Json::obj(vec![
        ("rounds_done", Json::Arr(rounds_done)),
        ("history", history_to_json(&fold.r.history)),
        ("rng", rng),
        ("rounds", Json::Num(fold.r.rounds as f64)),
        ("current", current),
    ])
}

/// Rebuild a [`SyncFold`] from a checkpoint, ready to keep folding the
/// tail segments.
pub(crate) fn sync_fold_from_checkpoint(cp: &CheckpointRecord) -> Result<SyncFold> {
    anyhow::ensure!(
        cp.mode == "sync",
        "checkpoint was written for mode '{}' but the journal header says sync",
        cp.mode
    );
    let st = &cp.state;
    let mut fold = SyncFold::new();
    for item in req_arr(st, "rounds_done")? {
        fold.r.rounds_done.push(RoundRecord {
            iter: req_usize(item, "iter")?,
            proposed: req_usize(item, "proposed")?,
            returned: req_usize(item, "returned")?,
            best: req_codec_f64(item, "best")?,
            wall_ms: req_f64(item, "wall_ms")?,
        });
    }
    fold.r.history = history_from_json(st, "history")?;
    fold.r.rng_state = match st.get("rng") {
        None | Some(Json::Null) => None,
        Some(v) => {
            let hex = v.as_str().ok_or_else(|| anyhow!("checkpoint rng is not a string"))?;
            Some(
                u128::from_str_radix(hex, 16)
                    .map_err(|e| anyhow!("checkpoint rng '{hex}': {e}"))?,
            )
        }
    };
    fold.r.rounds = req_usize(st, "rounds")?;
    fold.current = match st.get("current") {
        None | Some(Json::Null) => None,
        Some(cur) => {
            let batch = req_arr(cur, "batch")?
                .iter()
                .map(Config::from_journal_json)
                .collect::<Result<Vec<_>>>()?;
            let evals = req_arr(cur, "evals")?
                .iter()
                .map(|e| {
                    let config = Config::from_journal_json(
                        e.get("config")
                            .ok_or_else(|| anyhow!("checkpoint eval missing config"))?,
                    )?;
                    let value = match e.get("v") {
                        Some(v) => Some(f64_from_json(v)?),
                        None => {
                            anyhow::ensure!(
                                e.get("failed").and_then(Json::as_bool) == Some(true),
                                "checkpoint eval carries neither v nor failed:true"
                            );
                            None
                        }
                    };
                    Ok((config, value))
                })
                .collect::<Result<Vec<_>>>()?;
            Some(PartialRound { iter: req_usize(cur, "iter")?, batch, evals })
        }
    };
    Ok(fold)
}

// ---------------------------------------------------------------------------
// async fold <-> checkpoint state

fn terminal_to_json(t: &TerminalReplay) -> Json {
    let mut fields = vec![
        ("task", Json::Num(t.task as f64)),
        ("retries", Json::Num(t.retries as f64)),
        ("wall_ms", Json::Num(t.wall_ms)),
        ("proposed_before", Json::Num(t.proposed_before as f64)),
        ("contributed", Json::Bool(t.contributed)),
    ];
    outcome_fields(&t.outcome, &mut fields);
    Json::obj(fields)
}

fn terminal_from_json(j: &Json) -> Result<TerminalReplay> {
    Ok(TerminalReplay {
        task: req_u64(j, "task")?,
        retries: req_usize(j, "retries")?,
        outcome: outcome_from_json(j)?,
        wall_ms: req_f64(j, "wall_ms")?,
        proposed_before: req_usize(j, "proposed_before")?,
        contributed: req_bool(j, "contributed")?,
    })
}

fn completion_to_json(c: &CompletionLogEntry) -> Json {
    let mut fields = vec![
        ("task", Json::Num(c.task as f64)),
        ("retries", Json::Num(c.retries as f64)),
        ("queue_ms", Json::Num(c.queue_ms)),
        ("eval_ms", Json::Num(c.eval_ms)),
    ];
    outcome_fields(&c.outcome, &mut fields);
    Json::obj(fields)
}

fn completion_from_json(j: &Json) -> Result<CompletionLogEntry> {
    Ok(CompletionLogEntry {
        task: req_u64(j, "task")?,
        retries: req_usize(j, "retries")?,
        outcome: outcome_from_json(j)?,
        queue_ms: req_f64(j, "queue_ms")?,
        eval_ms: req_f64(j, "eval_ms")?,
    })
}

fn pid_to_json(pid: u64, st: &PidState) -> Json {
    Json::obj(vec![
        ("pid", Json::Num(pid as f64)),
        ("config", st.config.to_journal_json()),
        ("retries", Json::Num(st.retries as f64)),
        ("order", Json::Num(st.order as f64)),
        ("concluded", Json::Bool(st.concluded)),
        (
            "reports",
            Json::Arr(
                st.reports
                    .iter()
                    .map(|&(step, v, pruned)| {
                        Json::Arr(vec![
                            Json::Num(step as f64),
                            f64_to_json(v),
                            Json::Bool(pruned),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "last_task",
            match st.last_task {
                Some(t) => Json::Num(t as f64),
                None => Json::Null,
            },
        ),
        ("cutoff", Json::Num(st.cutoff as f64)),
        ("backoff_ms", Json::Num(st.backoff_ms)),
    ])
}

fn pid_from_json(j: &Json) -> Result<(u64, PidState)> {
    let reports = req_arr(j, "reports")?
        .iter()
        .map(|item| {
            let items =
                item.as_arr().ok_or_else(|| anyhow!("checkpoint: report entry not a triple"))?;
            let items = pair(items, 3, "report entry")?;
            Ok((elem_u64(&items[0])?, f64_from_json(&items[1])?, elem_bool(&items[2])?))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok((
        req_u64(j, "pid")?,
        PidState {
            config: Config::from_journal_json(
                j.get("config").ok_or_else(|| anyhow!("checkpoint pid missing config"))?,
            )?,
            retries: req_usize(j, "retries")?,
            order: req_u64(j, "order")?,
            concluded: req_bool(j, "concluded")?,
            reports,
            last_task: opt_u64(j, "last_task")?,
            cutoff: req_u64(j, "cutoff")?,
            backoff_ms: req_f64(j, "backoff_ms")?,
        },
    ))
}

/// Serialize a mid-scan [`AsyncFold`] into a checkpoint `state` payload.
/// Everything behavior-affecting is included — the finish-derived views
/// (`pending`, `pid_last_task`, `trailing_proposed`) are recomputed from
/// the pid book at `finish()`, exactly as an uncompacted replay would.
pub(crate) fn async_fold_to_state(fold: &AsyncFold) -> Json {
    Json::obj(vec![
        ("history", history_to_json(&fold.r.history)),
        ("terminals", Json::Arr(fold.r.terminals.iter().map(terminal_to_json).collect())),
        (
            "completion_log",
            Json::Arr(fold.r.completion_log.iter().map(completion_to_json).collect()),
        ),
        ("proposals_made", Json::Num(fold.r.proposals_made as f64)),
        ("rounds", Json::Num(fold.r.rounds as f64)),
        ("next_task_id", Json::Num(fold.r.next_task_id as f64)),
        ("retried", Json::Num(fold.r.retried as f64)),
        ("lost", Json::Num(fold.r.lost as f64)),
        (
            "reports",
            Json::Arr(
                fold.r
                    .reports
                    .iter()
                    .map(|&(pid, step, v, pruned)| {
                        Json::Arr(vec![
                            Json::Num(pid as f64),
                            Json::Num(step as f64),
                            f64_to_json(v),
                            Json::Bool(pruned),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("pruned", Json::Num(fold.r.pruned as f64)),
        ("epochs", Json::Num(fold.r.epochs as f64)),
        ("stalled", Json::Bool(fold.r.stalled)),
        ("pids", Json::Arr(fold.pids.iter().map(|(pid, st)| pid_to_json(*pid, st)).collect())),
        ("seq", Json::Num(fold.seq as f64)),
        ("proposed_counter", Json::Num(fold.proposed_counter as f64)),
        ("worst_internal", f64_to_json(fold.worst_internal)),
        (
            "last_fold",
            match fold.last_fold {
                Some(t) => Json::Num(t as f64),
                None => Json::Null,
            },
        ),
    ])
}

/// Rebuild an [`AsyncFold`] from a checkpoint, ready to keep folding the
/// tail segments. `sense` / `stable` come from the journal header (they
/// are run-level, not checkpoint-level, state).
pub(crate) fn async_fold_from_checkpoint(
    cp: &CheckpointRecord,
    sense: SenseTag,
    stable: bool,
) -> Result<AsyncFold> {
    anyhow::ensure!(
        cp.mode == "async",
        "checkpoint was written for mode '{}' but the journal header says async",
        cp.mode
    );
    let st = &cp.state;
    let mut fold = AsyncFold::new(sense, stable);
    fold.r.history = history_from_json(st, "history")?;
    fold.r.terminals =
        req_arr(st, "terminals")?.iter().map(terminal_from_json).collect::<Result<Vec<_>>>()?;
    fold.r.completion_log = req_arr(st, "completion_log")?
        .iter()
        .map(completion_from_json)
        .collect::<Result<Vec<_>>>()?;
    fold.r.proposals_made = req_u64(st, "proposals_made")?;
    fold.r.rounds = req_usize(st, "rounds")?;
    fold.r.next_task_id = req_u64(st, "next_task_id")?;
    fold.r.retried = req_u64(st, "retried")?;
    fold.r.lost = req_u64(st, "lost")?;
    fold.r.reports = req_arr(st, "reports")?
        .iter()
        .map(|item| {
            let items =
                item.as_arr().ok_or_else(|| anyhow!("checkpoint: report entry not a quad"))?;
            let items = pair(items, 4, "report entry")?;
            Ok((
                elem_u64(&items[0])?,
                elem_u64(&items[1])?,
                f64_from_json(&items[2])?,
                elem_bool(&items[3])?,
            ))
        })
        .collect::<Result<Vec<_>>>()?;
    fold.r.pruned = req_u64(st, "pruned")?;
    fold.r.epochs = req_u64(st, "epochs")?;
    fold.r.stalled = req_bool(st, "stalled")?;
    for item in req_arr(st, "pids")? {
        let (pid, pst) = pid_from_json(item)?;
        anyhow::ensure!(
            fold.pids.insert(pid, pst).is_none(),
            "checkpoint lists proposal {pid} twice"
        );
    }
    fold.seq = req_u64(st, "seq")?;
    fold.proposed_counter = req_usize(st, "proposed_counter")?;
    fold.worst_internal = req_codec_f64(st, "worst_internal")?;
    fold.last_fold = opt_u64(st, "last_fold")?;
    Ok(fold)
}

// ---------------------------------------------------------------------------
// the compaction pass

/// Compact the sealed prefix of the segmented journal at `base`, leaving
/// the newest `keep` sealed segments (plus the active one) uncompacted.
/// Returns `Ok(true)` if a new checkpoint was written. No-op (`Ok(false)`)
/// for single-file journals and when there is nothing worth folding.
/// Stale (checkpoint-covered) leftovers of an earlier crashed compaction
/// are deleted either way.
pub fn compact(base: &Path, keep: usize) -> Result<bool> {
    let Some(scan) = segment::scan(base)? else {
        return Ok(false);
    };

    // Idempotent cleanup first: stray staging files and checkpoint-covered
    // segments from a compaction that crashed mid-cleanup. Their content
    // is dead (the reader skips them) — deleting them re-runs the exact
    // step the crash interrupted.
    for tmp in segment::discover_tmp_files(base)? {
        std::fs::remove_file(&tmp)
            .with_context(|| format!("removing stale staging file {}", tmp.display()))?;
    }
    let mut cleaned = false;
    for &idx in &scan.stale {
        let p = segment_path(base, idx);
        match std::fs::remove_file(&p) {
            Ok(()) => cleaned = true,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                return Err(anyhow!(e))
                    .with_context(|| format!("removing checkpoint-covered segment {}", p.display()))
            }
        }
    }
    if cleaned {
        fsync_dir_ctx(base)?;
    }

    let Some((_active, below)) = scan.segs.split_last() else {
        return Ok(false);
    };
    if below.len() <= keep {
        return Ok(false);
    }
    let candidates = &below[..below.len() - keep];
    anyhow::ensure!(
        candidates.iter().all(|s| s.sealed),
        "compaction candidates include an unsealed segment — scan invariant broken"
    );
    // Re-checkpointing a lone checkpoint segment gains nothing.
    let no_new_events = candidates.iter().all(|s| s.events.is_empty());
    let first = candidates
        .first()
        .ok_or_else(|| anyhow!("compaction candidate list is empty after the length check"))?;
    if no_new_events && candidates.len() == 1 && scan.checkpoint_seg == Some(first.idx) {
        return Ok(false);
    }
    let covers = candidates
        .last()
        .ok_or_else(|| anyhow!("compaction candidate list is empty after the length check"))?
        .idx;

    // Fold the candidate prefix: the existing checkpoint (if any — it
    // lives in the lowest live segment, which is always candidates[0])
    // plus every candidate's events.
    let stable = scan.header.run.replay == "stable";
    let state = match scan.header.run.mode.as_str() {
        "sync" => {
            let mut fold = match &scan.checkpoint {
                Some(cp) => sync_fold_from_checkpoint(cp)?,
                None => SyncFold::new(),
            };
            for seg in candidates {
                for ev in &seg.events {
                    fold.fold(ev)?;
                }
            }
            sync_fold_to_state(&fold)
        }
        "async" => {
            let mut fold = match &scan.checkpoint {
                Some(cp) => async_fold_from_checkpoint(cp, scan.header.sense, stable)?,
                None => AsyncFold::new(scan.header.sense, stable),
            };
            for seg in candidates {
                for ev in &seg.events {
                    fold.fold(ev)?;
                }
            }
            async_fold_to_state(&fold)
        }
        other => return Err(anyhow!("journal header has unknown mode '{other}'")),
    };
    let mode = scan.header.run.mode.clone();
    let record = CheckpointRecord { covers, mode, state };

    // Stage the replacement segment: header, checkpoint, seal — then make
    // it the journal's truth with one atomic rename.
    let header_line = std::str::from_utf8(&scan.header_line)
        .map_err(|e| anyhow!("journal header line is not utf8: {e}"))?;
    let mut body = String::new();
    body.push_str(header_line);
    body.push('\n');
    body.push_str(&record.to_json().to_string());
    body.push('\n');
    let crc = fnv1a(FNV_OFFSET, body.as_bytes());
    let seal = SealRecord { seg: first.idx, events: 1, crc };
    body.push_str(&seal.to_json().to_string());
    body.push('\n');

    let target = segment_path(base, first.idx);
    let staging = suffixed(&target, ".tmp");
    {
        let mut f = std::fs::File::create(&staging)
            .with_context(|| format!("creating compaction staging file {}", staging.display()))?;
        f.write_all(body.as_bytes())
            .with_context(|| format!("writing {}", staging.display()))?;
        // Compaction always syncs, independent of --fsync-every: the
        // rename that follows must never land before the bytes it names.
        f.sync_all().with_context(|| format!("fsyncing {}", staging.display()))?;
    }
    std::fs::rename(&staging, &target).with_context(|| {
        format!("renaming {} over {}", staging.display(), target.display())
    })?;
    fsync_dir_ctx(base)?;

    // The replaced candidates are now stale (idx ≤ covers): delete them.
    for seg in &candidates[1..] {
        match std::fs::remove_file(&seg.path) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                return Err(anyhow!(e))
                    .with_context(|| format!("removing compacted segment {}", seg.path.display()))
            }
        }
    }
    fsync_dir_ctx(base)?;
    Ok(true)
}

fn fsync_dir_ctx(base: &Path) -> Result<()> {
    let dir = parent_dir(base);
    segment::fsync_dir(dir)
        .with_context(|| format!("fsyncing journal directory {}", dir.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::settings::RunConfig;
    use crate::persist::journal::{
        EventOutcome, JournalEvent, JournalWriter, RunHeader, SenseTag,
    };
    use crate::persist::recover::{recover, Replay};
    use crate::persist::segment::{read_run, SegmentOpts, SegmentedWriter};
    use crate::scheduler::LossReason;
    use crate::space::{Config, ParamValue};
    use std::path::PathBuf;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("mango_compact_{}_{name}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn cfg(i: i64) -> Config {
        Config::new(vec![("i".into(), ParamValue::Int(i))])
    }

    fn header(mode: &str, segment_events: usize, replay: &str) -> RunHeader {
        RunHeader {
            space_fp: 7,
            sense: SenseTag::Maximize,
            run: RunConfig {
                mode: mode.into(),
                replay: replay.into(),
                journal_segment_events: segment_events,
                ..Default::default()
            },
            celery: None,
        }
    }

    /// An async event stream exercising every outcome kind the codec must
    /// carry: done, failed, lost, resubmitted, pruned (finite + NaN),
    /// stalled, cancel, reports, epochs-off (wallclock).
    fn async_events() -> Vec<JournalEvent> {
        let mut ev = Vec::new();
        let ps = |pid: u64, task: u64| {
            vec![
                JournalEvent::AsyncPropose { pid, rounds: pid as usize, config: cfg(pid as i64) },
                JournalEvent::AsyncSubmit { pid, task, retries: 0, cutoff: 0, backoff_ms: 0.0 },
            ]
        };
        ev.extend(ps(0, 0));
        ev.extend(ps(1, 1));
        ev.extend(ps(2, 2));
        ev.extend(ps(3, 3));
        ev.extend(ps(4, 4));
        ev.extend(ps(5, 5));
        ev.push(JournalEvent::AsyncReport { pid: 0, task: 0, step: 0, value: 1.5, pruned: false });
        ev.push(JournalEvent::AsyncComplete {
            pid: 0,
            task: 0,
            retries: 0,
            outcome: EventOutcome::Done(2.5),
            queue_ms: 1.0,
            eval_ms: 2.0,
        });
        ev.push(JournalEvent::AsyncComplete {
            pid: 1,
            task: 1,
            retries: 1,
            outcome: EventOutcome::Resubmitted(LossReason::Crashed),
            queue_ms: 0.5,
            eval_ms: 0.0,
        });
        ev.push(JournalEvent::AsyncSubmit { pid: 1, task: 6, retries: 1, cutoff: 3, backoff_ms: 16.0 });
        ev.push(JournalEvent::AsyncReport { pid: 2, task: 2, step: 0, value: 0.25, pruned: true });
        ev.push(JournalEvent::AsyncComplete {
            pid: 2,
            task: 2,
            retries: 0,
            outcome: EventOutcome::Pruned { at_step: 0, last_value: 0.25 },
            queue_ms: 0.5,
            eval_ms: 0.5,
        });
        ev.push(JournalEvent::AsyncComplete {
            pid: 3,
            task: 3,
            retries: 0,
            outcome: EventOutcome::Failed,
            queue_ms: 0.25,
            eval_ms: 0.25,
        });
        ev.push(JournalEvent::AsyncComplete {
            pid: 4,
            task: 4,
            retries: 2,
            outcome: EventOutcome::Lost(LossReason::TimedOut),
            queue_ms: 0.125,
            eval_ms: 0.0,
        });
        ev.push(JournalEvent::AsyncReport {
            pid: 5,
            task: 5,
            step: 0,
            value: f64::NAN,
            pruned: true,
        });
        ev.push(JournalEvent::AsyncComplete {
            pid: 5,
            task: 5,
            retries: 0,
            outcome: EventOutcome::Pruned { at_step: 0, last_value: f64::NAN },
            queue_ms: 0.0,
            eval_ms: 0.0,
        });
        ev.extend(ps(6, 7));
        ev.push(JournalEvent::AsyncStalled { pid: 6, task: 7 });
        ev.extend(ps(7, 8));
        ev.push(JournalEvent::AsyncCancel { pid: 7, task: 8 });
        ev.push(JournalEvent::AsyncPropose { pid: 8, rounds: 9, config: cfg(8) });
        ev
    }

    fn sync_events() -> Vec<JournalEvent> {
        vec![
            JournalEvent::SyncPropose {
                iter: 0,
                rounds: 1,
                rng: 0xdead_beef_dead_beef_dead_beef_dead_beef,
                configs: vec![cfg(0), cfg(1)],
            },
            JournalEvent::SyncEval { iter: 0, config: cfg(0), value: Some(f64::NEG_INFINITY) },
            JournalEvent::SyncEval { iter: 0, config: cfg(1), value: None },
            JournalEvent::SyncRound { iter: 0, proposed: 2, returned: 1, best: -1.0, wall_ms: 3.5 },
            JournalEvent::SyncPropose { iter: 1, rounds: 2, rng: 77, configs: vec![cfg(2), cfg(3)] },
            JournalEvent::SyncEval { iter: 1, config: cfg(2), value: Some(4.0) },
            // crash mid-batch: cfg(3) unevaluated, round uncommitted
        ]
    }

    /// Codec equivalence at EVERY cut: fold a prefix, serialize →
    /// deserialize the fold state, continue folding the tail, and the
    /// finished replay must equal an uninterrupted fold's — for every
    /// prefix length, covering every outcome kind incl. NaN payloads.
    #[test]
    fn checkpoint_codec_roundtrips_the_async_fold_at_every_cut() {
        let events = async_events();
        let full = {
            let mut f = AsyncFold::new(SenseTag::Maximize, false);
            for ev in &events {
                f.fold(ev).unwrap();
            }
            f.finish()
        };
        for cut in 0..=events.len() {
            let mut f = AsyncFold::new(SenseTag::Maximize, false);
            for ev in &events[..cut] {
                f.fold(ev).unwrap();
            }
            // Through the wire: state -> JSON text -> parse -> fold.
            let state = async_fold_to_state(&f);
            let wire = crate::config::json::parse(&state.to_string()).unwrap();
            let cp = CheckpointRecord { covers: 0, mode: "async".into(), state: wire };
            let mut g =
                async_fold_from_checkpoint(&cp, SenseTag::Maximize, false).unwrap();
            for ev in &events[cut..] {
                g.fold(ev).unwrap();
            }
            assert_eq!(g.finish(), full, "async codec roundtrip diverged at cut {cut}");
        }
    }

    #[test]
    fn checkpoint_codec_roundtrips_the_sync_fold_at_every_cut() {
        let events = sync_events();
        let full = {
            let mut f = SyncFold::new();
            for ev in &events {
                f.fold(ev).unwrap();
            }
            f.finish()
        };
        for cut in 0..=events.len() {
            let mut f = SyncFold::new();
            for ev in &events[..cut] {
                f.fold(ev).unwrap();
            }
            let state = sync_fold_to_state(&f);
            let wire = crate::config::json::parse(&state.to_string()).unwrap();
            let cp = CheckpointRecord { covers: 0, mode: "sync".into(), state: wire };
            let mut g = sync_fold_from_checkpoint(&cp).unwrap();
            for ev in &events[cut..] {
                g.fold(ev).unwrap();
            }
            assert_eq!(g.finish(), full, "sync codec roundtrip diverged at cut {cut}");
        }
    }

    #[test]
    fn checkpoint_codec_roundtrips_stable_mode_state() {
        let mut events = Vec::new();
        events.push(JournalEvent::AsyncPropose { pid: 0, rounds: 0, config: cfg(0) });
        events.push(JournalEvent::AsyncSubmit { pid: 0, task: 0, retries: 0, cutoff: 0, backoff_ms: 0.0 });
        events.push(JournalEvent::AsyncPropose { pid: 1, rounds: 0, config: cfg(1) });
        events.push(JournalEvent::AsyncSubmit { pid: 1, task: 1, retries: 0, cutoff: 0, backoff_ms: 0.0 });
        events.push(JournalEvent::AsyncEpoch { seq: 0 });
        events.push(JournalEvent::AsyncComplete {
            pid: 0,
            task: 0,
            retries: 0,
            outcome: EventOutcome::Done(1.0),
            queue_ms: 0.0,
            eval_ms: 0.0,
        });
        events.push(JournalEvent::AsyncEpoch { seq: 1 });
        events.push(JournalEvent::AsyncComplete {
            pid: 1,
            task: 1,
            retries: 0,
            outcome: EventOutcome::Done(2.0),
            queue_ms: 0.0,
            eval_ms: 0.0,
        });
        let full = {
            let mut f = AsyncFold::new(SenseTag::Maximize, true);
            for ev in &events {
                f.fold(ev).unwrap();
            }
            f.finish()
        };
        for cut in 0..=events.len() {
            let mut f = AsyncFold::new(SenseTag::Maximize, true);
            for ev in &events[..cut] {
                f.fold(ev).unwrap();
            }
            let state = async_fold_to_state(&f);
            let wire = crate::config::json::parse(&state.to_string()).unwrap();
            let cp = CheckpointRecord { covers: 0, mode: "async".into(), state: wire };
            // The epoch counter and fold frontier must survive the wire,
            // or the stable-order audit would reject the tail.
            let mut g = async_fold_from_checkpoint(&cp, SenseTag::Maximize, true).unwrap();
            for ev in &events[cut..] {
                g.fold(ev).unwrap();
            }
            assert_eq!(g.finish(), full, "stable codec roundtrip diverged at cut {cut}");
        }
    }

    #[test]
    fn mode_cross_check_is_loud() {
        let cp = CheckpointRecord {
            covers: 0,
            mode: "async".into(),
            state: async_fold_to_state(&AsyncFold::new(SenseTag::Maximize, false)),
        };
        let err = sync_fold_from_checkpoint(&cp).unwrap_err();
        assert!(err.to_string().contains("mode 'async'"), "got: {err:#}");
        let cp = CheckpointRecord {
            covers: 0,
            mode: "sync".into(),
            state: sync_fold_to_state(&SyncFold::new()),
        };
        let err = async_fold_from_checkpoint(&cp, SenseTag::Maximize, false).unwrap_err();
        assert!(err.to_string().contains("mode 'sync'"), "got: {err:#}");
    }

    /// End-to-end: a rotating writer with live compaction produces a
    /// checkpointed layout whose recovery equals a single-file journal of
    /// the same events.
    #[test]
    fn compaction_recovery_equals_full_stream_recovery() {
        let d = tmpdir("equiv");
        let events = async_events();
        let single = d.join("single.jsonl");
        {
            let mut w = JournalWriter::create(&single, &header("async", 0, "wallclock")).unwrap();
            for ev in &events {
                w.append(ev).unwrap();
            }
        }
        let seg = d.join("seg.jsonl");
        {
            let o = SegmentOpts { segment_events: 3, keep_segments: 1, fsync_every_n: 0 };
            let mut w =
                SegmentedWriter::create(&seg, &header("async", 3, "wallclock"), o).unwrap();
            for ev in &events {
                w.append(ev).unwrap();
            }
        }
        let stream = read_run(&seg).unwrap();
        let cp = stream.checkpoint.expect("live compaction must have checkpointed");
        assert!(cp.covers >= 1, "checkpoint covers a real prefix");
        let a = recover(&single).unwrap();
        let b = recover(&seg).unwrap();
        assert_eq!(a.replay, b.replay, "checkpointed recovery must bit-equal full-stream");
        // And the footprint is bounded: only checkpoint seg + keep tail +
        // active remain on disk.
        let live = segment::discover_segments(&seg).unwrap();
        assert!(live.len() <= 3, "expected <= 3 live segments, got {:?}", live.keys());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn compaction_recovery_equals_full_stream_recovery_sync() {
        let d = tmpdir("equiv_sync");
        let events = sync_events();
        let single = d.join("single.jsonl");
        {
            let mut w = JournalWriter::create(&single, &header("sync", 0, "wallclock")).unwrap();
            for ev in &events {
                w.append(ev).unwrap();
            }
        }
        let seg = d.join("seg.jsonl");
        {
            let o = SegmentOpts { segment_events: 2, keep_segments: 0, fsync_every_n: 0 };
            let mut w =
                SegmentedWriter::create(&seg, &header("sync", 2, "wallclock"), o).unwrap();
            for ev in &events {
                w.append(ev).unwrap();
            }
        }
        let a = recover(&single).unwrap();
        let b = recover(&seg).unwrap();
        assert_eq!(a.replay, b.replay);
        let Replay::Sync(s) = b.replay else { panic!("expected sync replay") };
        assert!(s.partial.is_some(), "the open batch survives compaction");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn explicit_compact_honors_keep_and_is_idempotent() {
        let d = tmpdir("keep");
        let base = d.join("run.jsonl");
        let events = async_events();
        {
            // keep_segments huge: no live compaction, we drive it by hand.
            let o = SegmentOpts { segment_events: 2, keep_segments: 1000, fsync_every_n: 0 };
            let mut w =
                SegmentedWriter::create(&base, &header("async", 2, "wallclock"), o).unwrap();
            for ev in &events {
                w.append(ev).unwrap();
            }
        }
        let before = recover(&base).unwrap();
        let n_before = segment::discover_segments(&base).unwrap().len();
        assert!(n_before > 4);
        assert!(compact(&base, 2).unwrap());
        let after = segment::discover_segments(&base).unwrap();
        // checkpoint seg + 2 kept sealed + active.
        assert_eq!(after.len(), 4, "got {:?}", after.keys());
        let rec = recover(&base).unwrap();
        assert_eq!(rec.replay, before.replay);
        // Second pass: the kept tail is still worth folding in (the
        // checkpoint seg plus 2 sealed candidates at keep=0)...
        assert!(compact(&base, 0).unwrap());
        let rec = recover(&base).unwrap();
        assert_eq!(rec.replay, before.replay);
        // ...and a third finds a lone checkpoint segment: a no-op.
        assert!(!compact(&base, 0).unwrap());
        assert_eq!(recover(&base).unwrap().replay, before.replay);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn crash_mid_compaction_replays_identically_and_cleanup_is_idempotent() {
        let d = tmpdir("midcrash");
        let base = d.join("run.jsonl");
        {
            let o = SegmentOpts { segment_events: 2, keep_segments: 1000, fsync_every_n: 0 };
            let mut w =
                SegmentedWriter::create(&base, &header("async", 2, "wallclock"), o).unwrap();
            for ev in &async_events() {
                w.append(ev).unwrap();
            }
        }
        let before = recover(&base).unwrap();
        // Save a replaced-candidate segment so we can resurrect it as the
        // "crash between rename and delete" disk state.
        let seg1 = segment_path(&base, 1);
        let seg1_bytes = std::fs::read(&seg1).unwrap();
        assert!(compact(&base, 0).unwrap());
        // Crash state A: stray staging file (died before rename).
        let staging = suffixed(&segment_path(&base, 0), ".tmp");
        std::fs::write(&staging, b"half-written").unwrap();
        // Crash state B: a replaced candidate was never deleted.
        std::fs::write(&seg1, &seg1_bytes).unwrap();
        // The reader sees through both: stale is skipped, .tmp ignored.
        let rec = recover(&base).unwrap();
        assert_eq!(rec.replay, before.replay);
        match &rec.layout {
            crate::persist::segment::JournalLayout::Segmented { stale, .. } => {
                assert_eq!(stale, &[1], "resurrected candidate is stale, not replayed");
            }
            other => panic!("expected segmented layout, got {other:?}"),
        }
        // Re-running compaction finishes the interrupted cleanup.
        compact(&base, 0).unwrap();
        assert!(!staging.exists(), "staging file removed");
        assert!(!seg1.exists(), "stale segment removed");
        assert_eq!(recover(&base).unwrap().replay, before.replay);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn single_file_journals_are_never_compacted() {
        let d = tmpdir("singleskip");
        let base = d.join("run.jsonl");
        {
            let mut w = JournalWriter::create(&base, &header("async", 0, "wallclock")).unwrap();
            for ev in &async_events() {
                w.append(ev).unwrap();
            }
        }
        let before = std::fs::read(&base).unwrap();
        assert!(!compact(&base, 0).unwrap());
        assert_eq!(std::fs::read(&base).unwrap(), before, "single-file bytes untouched");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn resumed_writer_after_compaction_keeps_extending_the_run() {
        // compact → resume → append → recover: the post-compaction journal
        // is a first-class run, not a read-only artifact.
        let d = tmpdir("resume_after");
        let base = d.join("run.jsonl");
        let events = async_events();
        {
            let o = SegmentOpts { segment_events: 2, keep_segments: 1000, fsync_every_n: 0 };
            let mut w =
                SegmentedWriter::create(&base, &header("async", 2, "wallclock"), o).unwrap();
            for ev in &events {
                w.append(ev).unwrap();
            }
        }
        assert!(compact(&base, 0).unwrap());
        let rec = read_run(&base).unwrap();
        {
            let o = SegmentOpts { segment_events: 2, keep_segments: 1000, fsync_every_n: 0 };
            let mut w = SegmentedWriter::resume(&base, &rec.layout, rec.valid_len, o).unwrap();
            w.append(&JournalEvent::AsyncSubmit {
                pid: 8,
                task: 9,
                retries: 0,
                cutoff: 0,
                backoff_ms: 0.0,
            })
            .unwrap();
        }
        let rec = recover(&base).unwrap();
        let Replay::Async(a) = rec.replay else { panic!("expected async replay") };
        assert_eq!(a.next_task_id, 10, "the appended submit folded on top of the checkpoint");
        assert!(a.pending.iter().any(|p| p.pid == 8));
        std::fs::remove_dir_all(&d).ok();
    }
}
