//! Miniature property-testing harness (the offline registry has no
//! `proptest`/`quickcheck`).
//!
//! Usage pattern, mirroring proptest's ergonomics at small scale:
//!
//! ```no_run
//! use mango::util::proptest::{check, Gen};
//! check("abs is non-negative", 256, |g| {
//!     let x = g.f64_range(-1e6, 1e6);
//!     if x.abs() < 0.0 { return Err(format!("abs({x}) < 0")); }
//!     Ok(())
//! });
//! ```
//!
//! Failures report the generator seed and case index so any counterexample
//! replays deterministically.

use super::rng::Pcg64;

/// Wrapper over [`Pcg64`] with input-generation conveniences.
pub struct Gen {
    rng: Pcg64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Pcg64::new(seed) }
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.uniform_usize(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of uniform f64 values.
    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_range(lo, hi)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_range(0, xs.len())]
    }

    /// A random SPD matrix (row-major, n x n) = A A^T + n*I.
    pub fn spd_matrix(&mut self, n: usize) -> Vec<f64> {
        let a: Vec<f64> = (0..n * n).map(|_| self.rng.normal()).collect();
        let mut k = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for l in 0..n {
                    s += a[i * n + l] * a[j * n + l];
                }
                k[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        k
    }
}

/// Run `cases` random cases of `property`, panicking with a replayable
/// seed report on the first failure.
pub fn check<F>(name: &str, cases: usize, mut property: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check_seeded(name, 0x5EED_0000, cases, &mut property)
}

/// Like [`check`] with an explicit base seed (replay a failure).
pub fn check_seeded<F>(name: &str, base_seed: u64, cases: usize, property: &mut F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(seed);
        if let Err(msg) = property(&mut g) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("square non-negative", 128, |g| {
            let x = g.f64_range(-10.0, 10.0);
            if x * x >= 0.0 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", 4, |_| Err("nope".into()));
    }

    #[test]
    fn spd_matrix_is_symmetric_posdef_diag() {
        check("spd", 16, |g| {
            let n = g.usize_range(1, 9);
            let k = g.spd_matrix(n);
            for i in 0..n {
                if k[i * n + i] <= 0.0 {
                    return Err(format!("diag[{i}] = {}", k[i * n + i]));
                }
                for j in 0..n {
                    if (k[i * n + j] - k[j * n + i]).abs() > 1e-9 {
                        return Err("asymmetric".into());
                    }
                }
            }
            Ok(())
        });
    }
}
