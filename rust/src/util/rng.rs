//! Deterministic, splittable random number generation.
//!
//! The offline registry has no `rand` crate, so we implement PCG64 (the
//! `rand_pcg::Pcg64Mcg` variant: 128-bit MCG state, XSL-RR output) plus
//! SplitMix64 for seeding. Every stochastic component in the library
//! (sampling, optimizers, schedulers, experiment repeats) draws from this
//! so whole experiments replay bit-exactly from a seed.

/// SplitMix64 — used to expand user seeds into well-mixed PCG streams.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG64-MCG: 128-bit multiplicative congruential state, XSL-RR output.
///
/// Period 2^126, passes BigCrush; cheap (one 128-bit multiply per draw).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let hi = splitmix64(&mut sm) as u128;
        let lo = splitmix64(&mut sm) as u128;
        // MCG state must be odd.
        Self { state: ((hi << 64) | lo) | 1 }
    }

    /// Derive an independent child stream (for parallel workers / repeats).
    pub fn split(&mut self) -> Self {
        let s = self.next_u64();
        Self::new(s ^ 0x9E37_79B9_7F4A_7C15)
    }

    /// The raw 128-bit MCG state — serialized into the run journal so a
    /// resumed run continues the exact stream the crashed process was on.
    #[inline]
    pub fn state(&self) -> u128 {
        self.state
    }

    /// Rebuild a generator from a journaled [`state`](Self::state). MCG
    /// state must be odd; the low bit is forced like in [`new`](Self::new),
    /// so a corrupted even state cannot produce a degenerate stream.
    pub fn from_state(state: u128) -> Self {
        Self { state: state | 1 }
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [lo, hi) without modulo bias (Lemire's method).
    pub fn uniform_usize(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        let range = (hi - lo) as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (range as u128);
        let mut l = m as u64;
        if l < range {
            let t = range.wrapping_neg() % range;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (range as u128);
                l = m as u64;
            }
        }
        lo + (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped: simpler
    /// and branch-free; the tuner draws normals rarely).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.uniform_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.uniform_usize(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.uniform_usize(0, weights.len());
        }
        let mut t = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_roundtrip_resumes_the_stream() {
        let mut a = Pcg64::new(77);
        for _ in 0..13 {
            a.next_u64();
        }
        let mut b = Pcg64::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // A corrupted even state is forced odd, never degenerate.
        let mut c = Pcg64::from_state(0);
        assert_ne!(c.next_u64(), c.next_u64());
    }

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Pcg64::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.uniform(2.0, 4.0);
            assert!((2.0..4.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 3.0).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn uniform_usize_covers_range_without_bias() {
        let mut r = Pcg64::new(11);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.uniform_usize(0, 7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts {counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(13);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = Pcg64::new(5);
        let mut a = parent.split();
        let mut b = parent.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(9);
        let idx = r.sample_indices(100, 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Pcg64::new(17);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio {ratio}");
    }
}
