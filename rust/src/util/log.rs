//! Minimal leveled logger (no `log`/`env_logger` in the offline registry).
//!
//! Level comes from `MANGO_LOG` (error|warn|info|debug|trace), default info.
//! Thread-safe via a single atomic; output goes to stderr so benches can
//! keep stdout machine-readable.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

fn init_level() -> u8 {
    let lvl = match std::env::var("MANGO_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// True if messages at `level` should be emitted.
pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == 255 {
        cur = init_level();
    }
    (level as u8) <= cur
}

/// Override the level programmatically (tests, CLI --verbose).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

#[doc(hidden)]
pub fn emit(level: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{:5}] {}: {}", format!("{level:?}").to_uppercase(), module, args);
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
