//! Small self-contained utilities the offline registry forces us to own:
//! RNG ([`rng`]), summary statistics ([`stats`]), a timing/logging kit
//! ([`log`], [`timer`]), and a miniature property-testing harness
//! ([`proptest`]) used by the L3 invariant tests.

pub mod log;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;
