//! Small self-contained utilities the offline registry forces us to own:
//! RNG ([`rng`]), summary statistics ([`stats`]), a timing/logging kit
//! ([`log`], [`timer`]), and a miniature property-testing harness
//! ([`proptest`]) used by the L3 invariant tests.

pub mod log;
pub mod proptest;
pub mod rng;
pub mod stats;
// Clock-permitted module (lint rule R1): the clippy.toml disallowed-methods
// backstop is lifted here and nowhere else in util/.
#[allow(clippy::disallowed_methods)]
pub mod timer;
