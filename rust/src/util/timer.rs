//! Timing helpers for the perf pass and the bench harness.

use std::time::{Duration, Instant};

/// Scoped stopwatch: `let t = Stopwatch::start(); ...; t.elapsed_ms()`.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e6
    }
}

/// Accumulates per-phase wall time across a run (hot-path accounting).
#[derive(Default, Debug, Clone)]
pub struct PhaseTimer {
    phases: Vec<(String, Duration)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and charge it to `phase`.
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(phase, t.elapsed());
        out
    }

    pub fn add(&mut self, phase: &str, d: Duration) {
        if let Some(e) = self.phases.iter_mut().find(|(n, _)| n == phase) {
            e.1 += d;
        } else {
            self.phases.push((phase.to_string(), d));
        }
    }

    pub fn get_ms(&self, phase: &str) -> f64 {
        self.phases
            .iter()
            .find(|(n, _)| n == phase)
            .map(|(_, d)| d.as_secs_f64() * 1e3)
            .unwrap_or(0.0)
    }

    pub fn total_ms(&self) -> f64 {
        self.phases.iter().map(|(_, d)| d.as_secs_f64() * 1e3).sum()
    }

    /// Render a one-line breakdown sorted by cost.
    pub fn report(&self) -> String {
        let mut v: Vec<_> = self.phases.iter().collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v.iter()
            .map(|(n, d)| format!("{n}={:.1}ms", d.as_secs_f64() * 1e3))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_timer_accumulates() {
        let mut pt = PhaseTimer::new();
        pt.add("a", Duration::from_millis(5));
        pt.add("a", Duration::from_millis(7));
        pt.add("b", Duration::from_millis(1));
        assert!((pt.get_ms("a") - 12.0).abs() < 1e-9);
        assert!(pt.total_ms() >= 13.0 - 1e-9);
        assert!(pt.report().starts_with("a="));
    }

    #[test]
    fn time_closure_returns_value() {
        let mut pt = PhaseTimer::new();
        let v = pt.time("x", || 41 + 1);
        assert_eq!(v, 42);
        assert!(pt.get_ms("x") >= 0.0);
    }
}
