//! Summary statistics used by the experiment harness and optimizers.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation; 0 for n < 2.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    v.sqrt()
}

/// Population standard deviation (used for y-normalization in the GP).
pub fn std_dev_pop(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
    v.sqrt()
}

/// Quantile with linear interpolation, q in [0, 1]. NaNs sort last
/// (total order) instead of panicking.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median (q = 0.5).
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Sort key that treats NaN as the *worst* value (maximization convention):
/// a corrupt objective (hand-edited history dumps bypass the tuner's
/// is_finite guard) must never rank above real observations — `total_cmp`
/// alone would order NaN after +inf and launder it into the best slot.
pub fn nan_as_worst(v: f64) -> f64 {
    if v.is_nan() {
        f64::NEG_INFINITY
    } else {
        v
    }
}

/// Index of the maximum (first on ties); None for empty input.
pub fn argmax(xs: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &x) in xs.iter().enumerate() {
        if best.map_or(true, |(_, b)| x > b) {
            best = Some((i, x));
        }
    }
    best.map(|(i, _)| i)
}

/// Index of the minimum (first on ties); None for empty input.
pub fn argmin(xs: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &x) in xs.iter().enumerate() {
        if best.map_or(true, |(_, b)| x < b) {
            best = Some((i, x));
        }
    }
    best.map(|(i, _)| i)
}

/// Running best-so-far transform (cummax for maximization).
pub fn cummax(xs: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut best = f64::NEG_INFINITY;
    for &x in xs {
        best = best.max(x);
        out.push(best);
    }
    out
}

/// Running best-so-far transform (cummin for minimization).
pub fn cummin(xs: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut best = f64::INFINITY;
    for &x in xs {
        best = best.min(x);
        out.push(best);
    }
    out
}

/// Mean of per-trial series at each index (series may be ragged; averages
/// over the trials that have the index).
pub fn mean_series(series: &[Vec<f64>]) -> Vec<f64> {
    let max_len = series.iter().map(|s| s.len()).max().unwrap_or(0);
    (0..max_len)
        .map(|i| {
            let vals: Vec<f64> = series.iter().filter_map(|s| s.get(i).copied()).collect();
            mean(&vals)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev_pop(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn arg_extrema() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0];
        assert_eq!(argmax(&xs), Some(4));
        assert_eq!(argmin(&xs), Some(1));
        assert_eq!(argmax(&[]), None);
    }

    #[test]
    fn cummax_cummin() {
        assert_eq!(cummax(&[1.0, 3.0, 2.0]), vec![1.0, 3.0, 3.0]);
        assert_eq!(cummin(&[3.0, 1.0, 2.0]), vec![3.0, 1.0, 1.0]);
    }

    #[test]
    fn mean_series_ragged() {
        let s = vec![vec![1.0, 2.0], vec![3.0]];
        let m = mean_series(&s);
        assert_eq!(m, vec![2.0, 2.0]);
    }
}
