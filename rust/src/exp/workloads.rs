//! Named tuning workloads: the paper's two evaluation tasks plus the
//! ablation benchmark functions and two extra classifier workloads
//! (`KNN_Celery.ipynb`, `SVM_Example.ipynb` analogues).

use crate::benchfn;
use crate::ml::cv::cross_val_accuracy;
use crate::ml::gbt::{GbtClassifier, GbtParams};
use crate::ml::knn::KnnClassifier;
use crate::ml::svm::SvmClassifier;
use crate::ml::wine::default_wine;
use crate::ml::Dataset;
use crate::space::{Config, SearchSpace};
use std::sync::{Arc, OnceLock};

/// A named tuning problem.
#[derive(Clone)]
pub struct Workload {
    pub name: String,
    pub space: SearchSpace,
    /// true = minimize (benchmark functions), false = maximize (accuracy).
    pub minimize: bool,
    pub objective: Arc<dyn Fn(&Config) -> Option<f64> + Send + Sync>,
    /// Known optimum, when there is one (regret reporting).
    pub optimum: Option<f64>,
}

/// The wine dataset is shared across all Fig. 2 evaluations (and threads).
fn wine() -> &'static Dataset {
    static WINE: OnceLock<Dataset> = OnceLock::new();
    WINE.get_or_init(default_wine)
}

/// CV folds used by the classifier workloads (fixed seed: every config
/// sees identical folds, as in the paper's setup).
const CV_FOLDS: usize = 3;
const CV_SEED: u64 = 1234;

/// Fig. 2 workload: tune the GBT (XGBoost-substitute) on wine, Listing 1
/// search space, objective = mean CV accuracy.
pub fn wine_gbt() -> Workload {
    Workload {
        name: "wine_gbt".into(),
        space: crate::space::xgboost_space(),
        minimize: false,
        objective: Arc::new(|cfg| {
            let params = GbtParams::from_config(cfg);
            Some(cross_val_accuracy(wine(), CV_FOLDS, CV_SEED, || {
                GbtClassifier::new(params.clone())
            }))
        }),
        optimum: None,
    }
}

/// `KNN_Celery.ipynb` analogue: kNN on wine.
pub fn knn_wine() -> Workload {
    Workload {
        name: "knn_wine".into(),
        space: SearchSpace::builder()
            .range("n_neighbors", 1, 50)
            .choice("weights", &["uniform", "distance"])
            .int("p", 1, 4)
            .build(),
        minimize: false,
        objective: Arc::new(|cfg| {
            let knn = KnnClassifier::from_config(cfg);
            let (k, w, p) = (knn.k, knn.weighting, knn.p);
            Some(cross_val_accuracy(wine(), CV_FOLDS, CV_SEED, move || {
                KnnClassifier::new(k, w, p)
            }))
        }),
        optimum: None,
    }
}

/// `SVM_Example.ipynb` analogue: Listing 2 space, RBF-SVM on wine.
pub fn svm_wine() -> Workload {
    Workload {
        name: "svm_wine".into(),
        space: crate::space::svm_space(),
        minimize: false,
        objective: Arc::new(|cfg| {
            let svm = SvmClassifier::from_config(cfg);
            let (c, g) = (svm.c, svm.gamma);
            Some(cross_val_accuracy(wine(), CV_FOLDS, CV_SEED, move || {
                SvmClassifier::new(c, g)
            }))
        }),
        optimum: None,
    }
}

/// Wrap a [`benchfn::BenchFunction`] as a workload (minimization).
pub fn from_benchfn(name: &str) -> Option<Workload> {
    let f = benchfn::by_name(name)?;
    let space = f.space();
    let optimum = Some(f.optimum());
    let f: Arc<dyn benchfn::BenchFunction> = Arc::from(f);
    Some(Workload {
        name: name.to_string(),
        space,
        minimize: true,
        objective: Arc::new(move |cfg| Some(f.eval(cfg))),
        optimum,
    })
}

/// Look up any workload by name.
pub fn by_name(name: &str) -> Option<Workload> {
    match name {
        "wine_gbt" => Some(wine_gbt()),
        "knn_wine" => Some(knn_wine()),
        "svm_wine" => Some(svm_wine()),
        other => from_benchfn(other),
    }
}

/// All workload names (CLI `list`).
pub fn all_names() -> Vec<&'static str> {
    vec![
        "wine_gbt",
        "knn_wine",
        "svm_wine",
        "branin",
        "mixed_branin",
        "cat_branin",
        "rosenbrock",
        "ackley",
        "hartmann6",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn all_workloads_evaluate() {
        for name in all_names() {
            let w = by_name(name).unwrap();
            let mut rng = Pcg64::new(1);
            let cfg = w.space.sample(&mut rng);
            let v = (w.objective)(&cfg).unwrap();
            assert!(v.is_finite(), "{name} returned {v}");
            if !w.minimize {
                assert!((0.0..=1.0).contains(&v), "{name}: accuracy {v}");
            }
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn wine_gbt_space_is_listing1() {
        let w = wine_gbt();
        assert_eq!(w.space.len(), 5);
        assert!(!w.minimize);
    }

    #[test]
    fn benchfn_workloads_carry_optimum() {
        let w = by_name("mixed_branin").unwrap();
        assert!(w.minimize);
        assert!(w.optimum.unwrap() > 0.0);
    }
}
