//! Experiment harness: named workloads (the paper's evaluation tasks),
//! repeated-trial runners for the figure benches, and a small timing kit
//! for the perf pass.

pub mod benchkit;
pub mod harness;
pub mod workloads;

pub use harness::{run_trials, TrialSeries};
pub use workloads::Workload;
