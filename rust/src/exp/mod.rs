//! Experiment harness: named workloads (the paper's evaluation tasks),
//! repeated-trial runners for the figure benches, and a small timing kit
//! for the perf pass.

// Clock-permitted module (lint rule R1): bench timing reads the clock by
// design; lifts the clippy.toml disallowed-methods backstop.
#[allow(clippy::disallowed_methods)]
pub mod benchkit;
pub mod harness;
pub mod workloads;

pub use harness::{run_trials, TrialSeries};
pub use workloads::Workload;
