//! Repeated-trial experiment runner — regenerates the paper's figures:
//! run a (workload, tuner config) pair `repeats` times with shifted seeds,
//! average the best-so-far series (the paper averages 20 runs for Fig. 2,
//! 10 for Fig. 3).

use super::workloads::Workload;
use crate::coordinator::{Tuner, TunerConfig};
use crate::util::stats;
use anyhow::Result;

/// Aggregated result of repeated tuning trials.
#[derive(Clone, Debug)]
pub struct TrialSeries {
    pub label: String,
    /// best-so-far per iteration, one inner vec per trial (user sense).
    pub per_trial: Vec<Vec<f64>>,
    /// Mean across trials at each iteration.
    pub mean: Vec<f64>,
    /// Std-dev across trials at each iteration.
    pub std: Vec<f64>,
    /// Mean total evaluations per trial.
    pub mean_evaluations: f64,
    /// Mean wall time per trial (ms).
    pub mean_wall_ms: f64,
}

/// Run `repeats` trials of `workload` under `base` (seed shifted per trial).
pub fn run_trials(
    workload: &Workload,
    base: &TunerConfig,
    repeats: usize,
    label: &str,
) -> Result<TrialSeries> {
    let mut per_trial = Vec::with_capacity(repeats);
    let mut evals = Vec::with_capacity(repeats);
    let mut walls = Vec::with_capacity(repeats);
    for r in 0..repeats {
        let mut cfg = base.clone();
        cfg.seed = base.seed.wrapping_add(1000 * r as u64 + 17);
        let mut tuner = Tuner::new(workload.space.clone(), cfg);
        let obj = workload.objective.clone();
        let result = if workload.minimize {
            tuner.minimize(move |c| obj(c))?
        } else {
            tuner.maximize(move |c| obj(c))?
        };
        per_trial.push(result.best_series.clone());
        evals.push(result.evaluations as f64);
        walls.push(result.wall_ms);
    }
    let mean = stats::mean_series(&per_trial);
    let n_iters = mean.len();
    let std = (0..n_iters)
        .map(|i| {
            let vals: Vec<f64> =
                per_trial.iter().filter_map(|s| s.get(i).copied()).collect();
            stats::std_dev(&vals)
        })
        .collect();
    Ok(TrialSeries {
        label: label.to_string(),
        per_trial,
        mean,
        std,
        mean_evaluations: stats::mean(&evals),
        mean_wall_ms: stats::mean(&walls),
    })
}

/// Print one series as CSV rows: `label,iteration,mean,std`.
pub fn print_series(s: &TrialSeries) {
    for (i, (m, sd)) in s.mean.iter().zip(&s.std).enumerate() {
        println!("{},{},{:.6},{:.6}", s.label, i + 1, m, sd);
    }
}

/// Print a compact per-strategy summary table row.
pub fn print_summary_row(s: &TrialSeries, checkpoints: &[usize]) {
    let mut cells = Vec::new();
    for &cp in checkpoints {
        let idx = cp.min(s.mean.len()).saturating_sub(1);
        cells.push(format!("{:.4}", s.mean.get(idx).copied().unwrap_or(f64::NAN)));
    }
    println!(
        "{:<28} {}  (evals/trial {:.0}, {:.0} ms/trial)",
        s.label,
        cells.join("  "),
        s.mean_evaluations,
        s.mean_wall_ms
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exp::workloads;
    use crate::optimizer::{OptimizerKind, SurrogateBackend};

    #[test]
    fn trials_aggregate_and_differ_by_seed() {
        let w = workloads::by_name("branin").unwrap();
        let cfg = TunerConfig {
            optimizer: OptimizerKind::Random,
            backend: SurrogateBackend::Native,
            num_iterations: 10,
            ..Default::default()
        };
        let t = run_trials(&w, &cfg, 3, "rand").unwrap();
        assert_eq!(t.per_trial.len(), 3);
        assert_eq!(t.mean.len(), 10);
        assert_ne!(t.per_trial[0], t.per_trial[1], "seeds must differ");
        // minimization: mean series non-increasing
        for w2 in t.mean.windows(2) {
            assert!(w2[1] <= w2[0] + 1e-9);
        }
        assert_eq!(t.mean_evaluations, 10.0);
    }
}
