//! Timing kit for the perf benches (no criterion in the offline registry):
//! warmup + timed iterations, robust summary statistics.

use crate::util::stats;
use std::time::Instant;

/// Timing summary over bench iterations (all in microseconds).
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub min_us: f64,
}

impl BenchStats {
    pub fn row(&self) -> String {
        format!(
            "{:<40} iters={:<5} mean={:>10.1}us p50={:>10.1}us p99={:>10.1}us min={:>10.1}us",
            self.name, self.iters, self.mean_us, self.p50_us, self.p99_us, self.min_us
        )
    }
}

/// Measure `f` after `warmup` unrecorded calls.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    BenchStats {
        name: name.to_string(),
        iters,
        mean_us: stats::mean(&samples),
        p50_us: stats::quantile(&samples, 0.5),
        p99_us: stats::quantile(&samples, 0.99),
        min_us: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = bench("noop-ish", 2, 50, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.min_us <= s.p50_us);
        assert!(s.p50_us <= s.p99_us + 1e-9);
        assert!(s.mean_us > 0.0);
        assert!(s.row().contains("noop-ish"));
    }
}
