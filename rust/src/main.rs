//! `mango` CLI — the leader entrypoint: one-off tuning jobs, repeated
//! experiments, and environment introspection.

use anyhow::{anyhow, Result};
use mango::cli::{Args, USAGE};
use mango::config::json::parse as parse_json;
use mango::config::settings::ExperimentConfig;
use mango::coordinator::{ExecutionMode, Tuner, TunerConfig};
use mango::exp::{harness, workloads};
use mango::optimizer::{OptimizerKind, SurrogateBackend};
use mango::scheduler::SchedulerKind;
use mango::util::log;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw)?;
    if args.has("verbose") {
        log::set_level(log::Level::Debug);
    }
    if args.has("help") || args.subcommand.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    match args.subcommand.as_str() {
        "tune" => cmd_tune(&args),
        "experiment" => cmd_experiment(&args),
        "list" => cmd_list(),
        "info" => cmd_info(),
        other => Err(anyhow!("unknown subcommand '{other}'\n{USAGE}")),
    }
}

fn tuner_config_from_args(args: &Args, batch_default: usize) -> Result<TunerConfig> {
    let batch_size = args.get_usize("batch-size", batch_default)?;
    Ok(TunerConfig {
        batch_size,
        num_iterations: args.get_usize("iterations", 60)?,
        initial_random: args.get_usize("initial-random", 2)?,
        optimizer: OptimizerKind::from_str(args.get_or("optimizer", "hallucination"))
            .ok_or_else(|| anyhow!("bad --optimizer"))?,
        scheduler: SchedulerKind::from_str(args.get_or("scheduler", "serial"))
            .ok_or_else(|| anyhow!("bad --scheduler"))?,
        workers: args.get_usize("workers", batch_size)?,
        mc_samples: args.get_usize("mc-samples", 0)?,
        seed: args.get_u64("seed", 0)?,
        backend: SurrogateBackend::from_str(args.get_or("backend", "pjrt"))
            .ok_or_else(|| anyhow!("bad --backend"))?,
        tune_lengthscale: args.has("tune-lengthscale"),
        early_stop: match args.get_usize("early-stop", 0)? {
            0 => None,
            n => Some(n),
        },
        max_surrogate_obs: args.get_usize("max-surrogate-obs", 512)?,
        mode: ExecutionMode::from_str(args.get_or("mode", "sync"))
            .ok_or_else(|| anyhow!("bad --mode (sync | async)"))?,
        async_window: args.get_usize("async-window", 0)?,
        max_retries: args.get_usize("max-retries", 2)?,
        proposal_threads: args.get_usize("proposal-threads", 1)?,
        proposal_shards: args.get_usize("proposal-shards", 0)?,
        kernel_profile: mango::gp::KernelProfile::from_str(
            args.get_or("kernel-profile", "exact"),
        )
        .ok_or_else(|| anyhow!("bad --kernel-profile (exact | fast)"))?,
        fsync_every_n: args.get_usize("fsync-every", 0)?,
        pruner: mango::optimizer::prune::PrunerKind::from_str(args.get_or("pruner", "none"))
            .ok_or_else(|| anyhow!("bad --pruner (none | median | asha)"))?,
        pruner_warmup: args.get_usize("pruner-warmup", 1)?,
        asha_reduction: args.get_f64("asha-reduction", 3.0)?,
        replay: mango::coordinator::ReplayMode::from_str(args.get_or("replay", "wallclock"))
            .ok_or_else(|| anyhow!("bad --replay (wallclock | stable)"))?,
        journal_on_error: mango::persist::JournalPolicy::from_str(
            args.get_or("journal-on-error", "fail-stop"),
        )
        .ok_or_else(|| anyhow!("bad --journal-on-error (fail-stop | degrade)"))?,
        retry_backoff_ms: args.get_f64("retry-backoff-ms", 0.0)?,
        stall_timeout_ms: args.get_u64("stall-timeout-ms", 3_600_000)?,
        journal_segment_events: args.get_usize("journal-segment-events", 0)?,
        journal_keep_segments: args.get_usize("journal-keep-segments", 2)?,
        compact_on_resume: args.has("compact-on-resume"),
        celery: None,
    })
}

fn cmd_tune(args: &Args) -> Result<()> {
    args.ensure_known(&[
        "workload", "optimizer", "scheduler", "backend", "batch-size", "iterations",
        "initial-random", "workers", "mc-samples", "seed", "early-stop",
        "max-surrogate-obs", "mode", "async-window", "max-retries", "proposal-threads",
        "proposal-shards", "kernel-profile", "fsync-every", "journal", "pruner",
        "pruner-warmup", "asha-reduction", "replay", "journal-on-error",
        "retry-backoff-ms", "stall-timeout-ms", "journal-segment-events",
        "journal-keep-segments", "compact-on-resume",
    ])?;
    let name = args
        .get("workload")
        .ok_or_else(|| anyhow!("--workload is required (see `mango list`)"))?;
    let workload = workloads::by_name(name)
        .ok_or_else(|| anyhow!("unknown workload '{name}' (see `mango list`)"))?;
    // Fail loudly instead of running with zero durability: the fsync knob
    // syncs the journal, so without a journal it could only be a no-op.
    if args.get("fsync-every").is_some() && args.get("journal").is_none() {
        return Err(anyhow!("--fsync-every requires --journal (there is no journal to sync)"));
    }
    if args.get("journal-on-error").is_some() && args.get("journal").is_none() {
        return Err(anyhow!(
            "--journal-on-error requires --journal (there is no journal to fail on)"
        ));
    }
    if args.get("journal-segment-events").is_some() && args.get("journal").is_none() {
        return Err(anyhow!(
            "--journal-segment-events requires --journal (there is no journal to rotate)"
        ));
    }
    if args.get("journal-keep-segments").is_some() && args.get("journal").is_none() {
        return Err(anyhow!(
            "--journal-keep-segments requires --journal (there is no journal to compact)"
        ));
    }
    if args.has("compact-on-resume") && !args.has("resume") {
        return Err(anyhow!(
            "--compact-on-resume requires --resume (compaction runs on the resume path)"
        ));
    }
    let mut tuner = if args.has("resume") {
        // The journal header carries the full run config; only the
        // workload (and thus the space, validated by fingerprint) is
        // re-supplied.
        let journal = args
            .get("journal")
            .ok_or_else(|| anyhow!("--resume requires --journal <file.jsonl>"))?;
        let mut tuner =
            Tuner::resume_from(workload.space.clone(), std::path::Path::new(journal))?;
        if args.has("compact-on-resume") {
            tuner = tuner.with_compact_on_resume(true);
        }
        if args.get("journal-keep-segments").is_some() {
            tuner = tuner.with_keep_segments(args.get_usize("journal-keep-segments", 2)?);
        }
        mango::log_info!(
            "resuming {} from journal {journal} (config restored from its header)",
            workload.name
        );
        tuner
    } else {
        let config = tuner_config_from_args(args, 1)?;
        let sense = if workload.minimize { "minimize" } else { "maximize" };
        mango::log_info!(
            "tuning {} ({} dims, {sense}) with {:?}/{:?} backend {:?}",
            workload.name,
            workload.space.len(),
            config.optimizer,
            config.scheduler,
            config.backend
        );
        let mut tuner = Tuner::new(workload.space.clone(), config);
        if let Some(journal) = args.get("journal") {
            tuner = tuner.with_journal(journal);
        }
        tuner
    };
    let obj = workload.objective.clone();
    let result = if workload.minimize {
        tuner.minimize(move |c| obj(c))?
    } else {
        tuner.maximize(move |c| obj(c))?
    };
    if result.stalled {
        mango::log_warn!(
            "run stalled (no completion within --stall-timeout-ms); results are partial \
             and {} in-flight evaluation(s) were abandoned",
            result.lost
        );
    }
    if result.journal_degraded {
        mango::log_warn!(
            "journal degraded mid-run (--journal-on-error degrade): the file on disk is a \
             truncated prefix — do not --resume from it"
        );
    }
    if args.has("json") {
        println!("{}", result.to_json());
    } else {
        println!("best objective: {:.6}", result.best_objective);
        println!("best params:    {}", result.best_params);
        println!(
            "evaluations: {}   iterations: {}   wall: {:.0} ms",
            result.evaluations,
            result.iterations.len(),
            result.wall_ms
        );
        let (builds, appends, evicts) = result.dist_cache;
        if builds + appends + evicts > 0 {
            println!("dist cache:  {builds} builds   {appends} appends   {evicts} tile evicts");
        }
        if result.pruned > 0 || result.reports > 0 {
            println!(
                "pruning:     {} trials pruned   {} intermediate reports",
                result.pruned, result.reports
            );
        }
        if let Some(opt) = workload.optimum {
            println!("known optimum: {opt:.6} (regret {:.6})", result.best_objective - opt);
        }
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    args.ensure_known(&["config", "repeats"])?;
    let path = args.get("config").ok_or_else(|| anyhow!("--config <file.json> required"))?;
    let text = std::fs::read_to_string(path)?;
    let doc = parse_json(&text)?;
    let experiments = match &doc {
        j @ mango::config::json::Json::Obj(_) => vec![ExperimentConfig::from_json(j)?],
        mango::config::json::Json::Arr(items) => items
            .iter()
            .map(ExperimentConfig::from_json)
            .collect::<Result<Vec<_>>>()?,
        _ => return Err(anyhow!("config must be an experiment object or array")),
    };
    for e in experiments {
        let workload = workloads::by_name(&e.workload)
            .ok_or_else(|| anyhow!("unknown workload '{}'", e.workload))?;
        // Journaling is a per-run concern the repeated-trial harness does
        // not wire up; accepting the fields here would silently run with
        // zero crash persistence.
        if !e.run.journal.is_empty() || e.run.resume {
            return Err(anyhow!(
                "experiment '{}': journal/resume are not supported in experiment \
                 configs (repeated trials would share one journal) — use \
                 `mango tune --journal ... [--resume]` for a journaled run",
                e.name
            ));
        }
        let config = TunerConfig::from_run_config(&e.run)?;
        let repeats = args.get_usize("repeats", e.repeats)?;
        mango::log_info!("experiment {}: {repeats} trials of {}", e.name, e.workload);
        let series = harness::run_trials(&workload, &config, repeats, &e.name)?;
        harness::print_series(&series);
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("workloads:  {}", workloads::all_names().join(", "));
    println!("optimizers: hallucination, clustering, random, tpe, thompson");
    println!("schedulers: serial, threaded, celery");
    println!("backends:   pjrt, native");
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = mango::runtime::default_artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    match mango::runtime::ArtifactManifest::load(&dir) {
        Ok(m) => {
            println!("  max_dim {}  m_cand {}", m.max_dim, m.m_cand);
            for v in &m.variants {
                println!("  variant n={}: {:?}", v.n, v.fit_path.file_name().unwrap());
            }
            let surrogate = mango::runtime::PjrtSurrogate::new(&dir)?;
            let _ = surrogate;
            println!("PJRT CPU client: ok");
        }
        Err(e) => println!("  (artifacts unavailable: {e})"),
    }
    Ok(())
}
