//! GP-BUCB batch hallucination via incremental posterior-covariance updates
//! (Desautels et al., 2014 — the paper's first parallel algorithm).
//!
//! Hallucinating an observation at x_b with y = posterior mean leaves the
//! posterior *mean* unchanged and shrinks the posterior *variance*:
//!
//!   var_{j+1}(c) = var_j(c) - cov_j(c, b_j)^2 / var_j(b_j)
//!   cov_{j+1}(c, z) = cov_j(c, z) - cov_j(c, b_j) cov_j(b_j, z) / var_j(b_j)
//!
//! Keeping, per candidate c, the vector r_c[i] = cov_i(c, b_i)/sqrt(var_i(b_i))
//! makes each batch step O(m·n + m·j) instead of a full O(n^3) refit:
//! cov_j(c, b_j) = cov_0(c, b_j) - Σ_{i<j} r_c[i]·r_{b_j}[i], and
//! cov_0(c, b_j) = k(c, b_j) - k_bᵀ(K^{-1} k_c) — where K^{-1} k_c is
//! exactly the `w` matrix acquire already returns (computed by triangular
//! solves against the Cholesky factor; no explicit K^{-1} is ever formed).

use super::kernel;
use super::{AcquireOut, GpParams};
use crate::linalg::Matrix;
use crate::util::stats::argmax;

/// Sequentially selects a batch from a scored candidate set, shrinking
/// variances after each hallucinated pick.
pub struct BatchHallucinator<'a> {
    x_obs: &'a Matrix,
    xc: &'a Matrix,
    params: &'a GpParams,
    w: &'a Matrix,
    mean: Vec<f64>,
    var: Vec<f64>,
    /// r-vectors: steps[i][c] = cov_i(c, b_i) / sqrt(var_i(b_i)).
    steps: Vec<Vec<f64>>,
    taken: Vec<bool>,
}

impl<'a> BatchHallucinator<'a> {
    /// `acq` must come from an acquire over exactly (`x_obs`, `xc`).
    pub fn new(x_obs: &'a Matrix, xc: &'a Matrix, acq: &'a AcquireOut, params: &'a GpParams) -> Self {
        Self {
            x_obs,
            xc,
            params,
            w: &acq.w,
            mean: acq.mean.clone(),
            var: acq.var.clone(),
            steps: Vec::new(),
            taken: vec![false; xc.rows()],
        }
    }

    /// Current UCB scores (NEG_INFINITY for already-taken candidates).
    pub fn ucb(&self) -> Vec<f64> {
        (0..self.xc.rows())
            .map(|c| {
                if self.taken[c] {
                    f64::NEG_INFINITY
                } else {
                    self.mean[c] + self.params.beta * self.var[c].sqrt()
                }
            })
            .collect()
    }

    /// Current posterior variance per candidate (after hallucinations so far).
    pub fn var(&self) -> &[f64] {
        &self.var
    }

    /// Pick the UCB-argmax, hallucinate it, and return its candidate index.
    pub fn select_next(&mut self) -> Option<usize> {
        let scores = self.ucb();
        let b = argmax(&scores)?;
        if scores[b] == f64::NEG_INFINITY {
            return None; // all candidates taken
        }
        self.hallucinate(b);
        self.taken[b] = true;
        Some(b)
    }

    /// Apply the rank-1 variance shrink for a hallucinated pick at index b.
    fn hallucinate(&mut self, b: usize) {
        let m = self.xc.rows();
        let n = self.x_obs.rows();
        let amp = self.params.amp;
        let xb = self.xc.row(b).to_vec();

        // cov_0(c, b) = amp*k(c, b) - k_bᵀ w_c   (w_c = K^{-1} k_c).
        let mut kb = kernel::rbf_vec(self.x_obs, &xb, &self.params.inv_lengthscale);
        for v in &mut kb {
            *v *= amp;
        }
        // k(c, b) over the whole candidate set in one GEMM pass — the m
        // axis dominates (m candidates per hallucination step).
        let kcb = kernel::rbf_vec(self.xc, &xb, &self.params.inv_lengthscale);
        let mut cov = vec![0.0; m];
        for c in 0..m {
            let mut dot = 0.0;
            for i in 0..n {
                dot += kb[i] * self.w[(i, c)];
            }
            cov[c] = amp * kcb[c] - dot;
        }
        // Downdate by previous hallucinations: cov_j = cov_0 - Σ r_c[i] r_b[i].
        for step in &self.steps {
            let rb = step[b];
            for c in 0..m {
                cov[c] -= step[c] * rb;
            }
        }
        // Hallucinated observations are *noisy* (GP-BUCB conditions on a
        // y-value with observation noise), so the Schur pivot includes it.
        let s = (self.var[b] + self.params.noise).max(1e-12);
        let s_sqrt = s.sqrt();
        let r: Vec<f64> = cov.iter().map(|c| c / s_sqrt).collect();
        for c in 0..m {
            self.var[c] = (self.var[c] - r[c] * r[c]).max(1e-12);
        }
        self.steps.push(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::{normalize_y, NativeGp, Surrogate};
    use crate::util::rng::Pcg64;

    fn setup(n: usize, m: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>, Matrix) {
        let mut rng = Pcg64::new(seed);
        let x = Matrix::from_fn(n, d, |_, _| rng.next_f64());
        let y: Vec<f64> = (0..n).map(|i| (6.0 * x.row(i)[0]).sin()).collect();
        let xc = Matrix::from_fn(m, d, |_, _| rng.next_f64());
        (x, y, xc)
    }

    /// The incremental update must agree with a brute-force refit that
    /// appends the hallucinated point with y = posterior mean.
    #[test]
    fn incremental_matches_brute_force_refit() {
        let (x, y, xc) = setup(25, 40, 2, 11);
        let (yn, _, _) = normalize_y(&y);
        let params = GpParams::new(2);
        let mut gp = NativeGp;
        let fit = gp.fit(&x, &yn, &params).unwrap();
        let acq = gp.acquire(&x, &fit, &xc, &params).unwrap();

        let mut h = BatchHallucinator::new(&x, &xc, &acq, &params);
        let b0 = h.select_next().unwrap();
        let b1 = h.select_next().unwrap();

        // Brute force: refit with the two hallucinated points appended.
        let mut x2 = Matrix::zeros(x.rows() + 2, x.cols());
        for i in 0..x.rows() {
            x2.row_mut(i).copy_from_slice(x.row(i));
        }
        x2.row_mut(x.rows()).copy_from_slice(xc.row(b0));
        x2.row_mut(x.rows() + 1).copy_from_slice(xc.row(b1));
        let mut y2 = yn.clone();
        y2.push(acq.mean[b0]); // hallucinated values (exact value irrelevant
        y2.push(acq.mean[b1]); // for variance, which is what we compare)
        let fit2 = gp.fit(&x2, &y2, &params).unwrap();
        let acq2 = gp.acquire(&x2, &fit2, &xc, &params).unwrap();

        for c in 0..xc.rows() {
            assert!(
                (h.var()[c] - acq2.var[c]).abs() < 1e-6,
                "candidate {c}: incremental {} vs refit {}",
                h.var()[c],
                acq2.var[c]
            );
        }
    }

    #[test]
    fn taken_candidate_variance_collapses() {
        let (x, y, xc) = setup(15, 20, 2, 13);
        let (yn, _, _) = normalize_y(&y);
        let params = GpParams::new(2);
        let mut gp = NativeGp;
        let fit = gp.fit(&x, &yn, &params).unwrap();
        let acq = gp.acquire(&x, &fit, &xc, &params).unwrap();
        let mut h = BatchHallucinator::new(&x, &xc, &acq, &params);
        let b = h.select_next().unwrap();
        // Residual variance after a *noisy* hallucinated observation is
        // var*noise/(var+noise) <= noise.
        assert!(
            h.var()[b] <= params.noise + 1e-9,
            "picked point variance {} must collapse to <= noise",
            h.var()[b]
        );
    }

    #[test]
    fn selects_distinct_candidates() {
        let (x, y, xc) = setup(10, 8, 2, 17);
        let (yn, _, _) = normalize_y(&y);
        let params = GpParams::new(2);
        let mut gp = NativeGp;
        let fit = gp.fit(&x, &yn, &params).unwrap();
        let acq = gp.acquire(&x, &fit, &xc, &params).unwrap();
        let mut h = BatchHallucinator::new(&x, &xc, &acq, &params);
        // Membership-only dedup: only `insert`'s bool return drives the
        // assertion; the set is never iterated, so hash-order
        // nondeterminism cannot leak into what this test observes.
        // pallas-lint: allow(R3, "membership-only: insert() bool drives the assert; set order never observed")
        let mut seen = std::collections::HashSet::new();
        for _ in 0..8 {
            let b = h.select_next().unwrap();
            assert!(seen.insert(b), "candidate {b} selected twice");
        }
        assert_eq!(h.select_next(), None, "exhausted candidates must end");
    }

    #[test]
    fn variance_never_increases() {
        let (x, y, xc) = setup(20, 30, 3, 19);
        let (yn, _, _) = normalize_y(&y);
        let params = GpParams::new(3);
        let mut gp = NativeGp;
        let fit = gp.fit(&x, &yn, &params).unwrap();
        let acq = gp.acquire(&x, &fit, &xc, &params).unwrap();
        let mut h = BatchHallucinator::new(&x, &xc, &acq, &params);
        let mut prev = h.var().to_vec();
        for _ in 0..5 {
            h.select_next().unwrap();
            for c in 0..xc.rows() {
                assert!(h.var()[c] <= prev[c] + 1e-12);
            }
            prev = h.var().to_vec();
        }
    }
}
