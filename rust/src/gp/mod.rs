//! Gaussian-process surrogate: shared types, the [`Surrogate`] backend
//! trait, a pure-Rust reference backend ([`NativeGp`]), and the GP-BUCB
//! incremental hallucination machinery ([`update`]).
//!
//! Two backends implement [`Surrogate`]:
//! * [`NativeGp`] — this module; the correctness oracle and the fallback
//!   when artifacts are absent.
//! * [`crate::runtime::PjrtSurrogate`] — the AOT path: the JAX/Pallas
//!   programs in `artifacts/` executed through PJRT (the production path).
//!
//! Contract parity between the two is enforced by integration tests in
//! `rust/tests/pjrt_vs_native.rs`.

pub mod kernel;
pub mod update;

use crate::linalg::{self, Matrix};
use anyhow::Result;

/// GP hyperparameters over the *encoded* (unit-cube) feature space.
#[derive(Clone, Debug)]
pub struct GpParams {
    /// Signal amplitude (prior variance). y is normalized, so 1.0.
    pub amp: f64,
    /// Observation noise added to the kernel diagonal.
    pub noise: f64,
    /// UCB exploration weight (set per-iteration by the adaptive schedule).
    pub beta: f64,
    /// Per-dimension inverse lengthscales.
    pub inv_lengthscale: Vec<f64>,
}

impl GpParams {
    /// Defaults for `dims` encoded dimensions: unit amplitude, small noise,
    /// lengthscale 0.3 in the unit cube (≈ a third of each axis).
    pub fn new(dims: usize) -> Self {
        Self {
            amp: 1.0,
            noise: 1e-3,
            beta: 2.0,
            inv_lengthscale: vec![1.0 / 0.3; dims],
        }
    }

    pub fn with_lengthscale(mut self, ls: f64) -> Self {
        for v in &mut self.inv_lengthscale {
            *v = 1.0 / ls;
        }
        self
    }

    pub fn with_beta(mut self, beta: f64) -> Self {
        self.beta = beta;
        self
    }
}

/// Output of a posterior fit. `kinv` is dense (n x n) — needed both for
/// acquisition (via the backend) and for the Rust-side GP-BUCB updates.
#[derive(Clone, Debug)]
pub struct FitOut {
    pub alpha: Vec<f64>,
    pub kinv: Matrix,
    pub logdet: f64,
}

impl FitOut {
    /// Log marginal likelihood of the fitted GP (used by the optional
    /// lengthscale grid search). y must be the same vector passed to fit.
    pub fn log_marginal_likelihood(&self, y: &[f64]) -> f64 {
        let n = y.len() as f64;
        let fit_term: f64 = y.iter().zip(&self.alpha).map(|(a, b)| a * b).sum();
        -0.5 * fit_term - 0.5 * self.logdet - 0.5 * n * (2.0 * std::f64::consts::PI).ln()
    }
}

/// Acquisition outputs over a candidate set.
#[derive(Clone, Debug)]
pub struct AcquireOut {
    pub ucb: Vec<f64>,
    pub mean: Vec<f64>,
    pub var: Vec<f64>,
    /// w = K^{-1} k_c, (n x m): consumed by [`update::BatchHallucinator`].
    pub w: Matrix,
}

/// A GP surrogate backend. `x` rows are encoded configs; `y` must already be
/// normalized (zero mean / unit variance) and in maximization convention.
pub trait Surrogate {
    /// Fit the posterior over `n = x.rows()` observations.
    fn fit(&mut self, x: &Matrix, y: &[f64], params: &GpParams) -> Result<FitOut>;

    /// Score candidates (mean/var/UCB + the `w` matrix) under a fit.
    fn acquire(
        &mut self,
        x: &Matrix,
        fit: &FitOut,
        xc: &Matrix,
        params: &GpParams,
    ) -> Result<AcquireOut>;

    /// Backend name for logs/EXPERIMENTS.md.
    fn name(&self) -> &'static str;
}

/// Pure-Rust GP backend: mirrors `python/compile/model.py` exactly
/// (same kernel, same clamps) so the two backends agree numerically.
#[derive(Default)]
pub struct NativeGp;

impl Surrogate for NativeGp {
    fn fit(&mut self, x: &Matrix, y: &[f64], params: &GpParams) -> Result<FitOut> {
        let n = x.rows();
        anyhow::ensure!(y.len() == n, "y length {} != x rows {}", y.len(), n);
        let corr = kernel::rbf_kernel(x, x, &params.inv_lengthscale);
        let mut k = corr;
        for i in 0..n {
            for j in 0..n {
                k[(i, j)] *= params.amp;
            }
            k[(i, i)] += params.noise;
        }
        let l = linalg::cholesky(&k);
        let kinv = linalg::spd_inverse(&l);
        let alpha = kinv.matvec(y);
        let logdet = linalg::logdet_from_cholesky(&l);
        Ok(FitOut { alpha, kinv, logdet })
    }

    fn acquire(
        &mut self,
        x: &Matrix,
        fit: &FitOut,
        xc: &Matrix,
        params: &GpParams,
    ) -> Result<AcquireOut> {
        let (n, m) = (x.rows(), xc.rows());
        anyhow::ensure!(fit.alpha.len() == n, "fit/x size mismatch");
        // kc: (n x m) cross-kernel.
        let mut kc = kernel::rbf_kernel(x, xc, &params.inv_lengthscale);
        for v in kc.data_mut() {
            *v *= params.amp;
        }
        let mean = kc.matvec_t(&fit.alpha);
        let w = fit.kinv.matmul(&kc);
        let mut var = vec![0.0; m];
        for c in 0..m {
            let mut s = 0.0;
            for i in 0..n {
                s += kc[(i, c)] * w[(i, c)];
            }
            var[c] = (params.amp - s).max(1e-10);
        }
        let ucb = mean
            .iter()
            .zip(&var)
            .map(|(mu, v)| mu + params.beta * v.sqrt())
            .collect();
        Ok(AcquireOut { ucb, mean, var, w })
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Normalize y to zero mean / unit variance; returns (normalized, mean, std).
/// Constant y gets std 1.0 so early iterations stay well-posed.
pub fn normalize_y(y: &[f64]) -> (Vec<f64>, f64, f64) {
    let mean = crate::util::stats::mean(y);
    let mut std = crate::util::stats::std_dev_pop(y);
    if std < 1e-12 {
        std = 1.0;
    }
    (y.iter().map(|v| (v - mean) / std).collect(), mean, std)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Pcg64;

    fn toy_problem(n: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let x = Matrix::from_fn(n, d, |_, _| rng.next_f64());
        let y: Vec<f64> = (0..n)
            .map(|i| {
                let r = x.row(i);
                (2.0 * std::f64::consts::PI * r[0]).sin() + 0.5 * r.get(1).copied().unwrap_or(0.0)
            })
            .collect();
        (x, y)
    }

    #[test]
    fn posterior_interpolates_training_data() {
        let (x, y) = toy_problem(30, 2, 1);
        let (yn, _, _) = normalize_y(&y);
        let params = GpParams::new(2);
        let mut gp = NativeGp;
        let fit = gp.fit(&x, &yn, &params).unwrap();
        let out = gp.acquire(&x, &fit, &x, &params).unwrap();
        for i in 0..x.rows() {
            assert!(
                (out.mean[i] - yn[i]).abs() < 0.05,
                "mean[{i}] {} vs {}",
                out.mean[i],
                yn[i]
            );
            assert!(out.var[i] < 0.02, "var[{i}] = {}", out.var[i]);
        }
    }

    #[test]
    fn variance_reverts_to_prior_far_away() {
        let (x, y) = toy_problem(20, 2, 2);
        let (yn, _, _) = normalize_y(&y);
        let params = GpParams::new(2);
        let mut gp = NativeGp;
        let fit = gp.fit(&x, &yn, &params).unwrap();
        let far = Matrix::from_fn(4, 2, |_, _| 100.0);
        let out = gp.acquire(&x, &fit, &far, &params).unwrap();
        for c in 0..4 {
            assert!((out.var[c] - params.amp).abs() < 1e-6);
            assert!(out.mean[c].abs() < 1e-6);
        }
    }

    #[test]
    fn ucb_is_mean_plus_beta_sigma_property() {
        check("ucb = mean + beta*sqrt(var)", 32, |g| {
            let n = g.usize_range(2, 20);
            let (x, y) = toy_problem(n, 3, g.rng().next_u64());
            let (yn, _, _) = normalize_y(&y);
            let beta = g.f64_range(0.0, 5.0);
            let params = GpParams::new(3).with_beta(beta);
            let mut gp = NativeGp;
            let fit = gp.fit(&x, &yn, &params).map_err(|e| e.to_string())?;
            let xc = Matrix::from_fn(8, 3, |_, _| g.f64_range(0.0, 1.0));
            let out = gp.acquire(&x, &fit, &xc, &params).map_err(|e| e.to_string())?;
            for c in 0..8 {
                let want = out.mean[c] + beta * out.var[c].sqrt();
                if (out.ucb[c] - want).abs() > 1e-9 {
                    return Err(format!("ucb[{c}] {} vs {}", out.ucb[c], want));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lml_prefers_true_lengthscale_region() {
        // Data drawn smoothly: tiny lengthscales should not win the LML.
        let (x, y) = toy_problem(40, 1, 3);
        let (yn, _, _) = normalize_y(&y);
        let mut gp = NativeGp;
        let mut lml = |ls: f64| {
            let p = GpParams::new(1).with_lengthscale(ls);
            let fit = gp.fit(&x, &yn, &p).unwrap();
            fit.log_marginal_likelihood(&yn)
        };
        assert!(lml(0.2) > lml(0.01), "smooth data should reject ls=0.01");
    }

    #[test]
    fn normalize_y_moments_and_constant_input() {
        let (yn, m, s) = normalize_y(&[1.0, 2.0, 3.0, 4.0]);
        assert!((m - 2.5).abs() < 1e-12);
        assert!(s > 0.0);
        assert!(crate::util::stats::mean(&yn).abs() < 1e-12);
        let (yc, _, sc) = normalize_y(&[5.0, 5.0, 5.0]);
        assert_eq!(sc, 1.0);
        assert!(yc.iter().all(|v| v.abs() < 1e-12));
    }
}
