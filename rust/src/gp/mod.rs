//! Gaussian-process surrogate: shared types, the [`Surrogate`] backend
//! trait, a pure-Rust reference backend ([`NativeGp`]), the incremental
//! inverse-free posterior engine ([`fit_posterior`] + [`CholeskyState`]),
//! and the GP-BUCB incremental hallucination machinery ([`update`]).
//!
//! Two backends implement [`Surrogate`]:
//! * [`NativeGp`] — this module; the correctness oracle and the fallback
//!   when artifacts are absent.
//! * [`crate::runtime::PjrtSurrogate`] — the AOT path: the JAX/Pallas
//!   programs in `artifacts/` executed through PJRT (the production path).
//!
//! The posterior is **inverse-free**: a fit keeps the lower Cholesky factor
//! `L` of `amp*K + noise*I` ([`FitOut::chol`]); `alpha` and the acquisition
//! `w = K^{-1} k_c` come from triangular solves against `L`, never from a
//! materialized `K^{-1}`. Across scheduling rounds the factor is grown
//! *incrementally*: [`CholeskyState`] remembers the rows it covers, and
//! [`fit_posterior`] appends each new observation with an O(n²) rank-1
//! bordered update ([`crate::linalg::chol_append_row`]) instead of paying
//! the O(n³) refactorization — the append performs identical arithmetic,
//! so incremental and from-scratch fits agree bit-for-bit.
//!
//! Contract parity between the two backends is enforced by integration
//! tests in `rust/tests/pjrt_vs_native.rs`.

pub mod kernel;
pub mod update;

use crate::linalg::{self, Matrix};
use anyhow::Result;

/// Which inner-kernel implementations the native propose pipeline uses.
///
/// * [`Exact`](Self::Exact) (default) — the sequential-reduction kernels
///   with the full bit-exactness contract suite: append==scratch Cholesky,
///   shared-D² fits, thread/shard-invariant scoring, recovery replay — all
///   byte-for-byte.
/// * [`Fast`](Self::Fast) — SIMD-friendly rewrites of the inner kernels
///   (chunked-accumulator GEMM/dot, 4-wide triangular solves, unrolled exp
///   pass, chunked score fold) plus the tiled `DistCache` mode in
///   `BayesianCore`. The chunking scheme is *fixed* (depends only on
///   element indices, never on `proposal_threads`/`proposal_shards`), so
///   Fast output is still run-to-run deterministic and invariant across
///   every threads × shards × scheduler setting — it is just not bit-equal
///   to Exact. Property-tested against the scalar oracles (`rbf_pair`,
///   sequential `dot`, the vector solves) at ≤1e-10 relative tolerance.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelProfile {
    /// Byte-for-byte the historical path — every bit-identity test applies.
    #[default]
    Exact,
    /// Chunked SIMD-friendly kernels + tiled DistCache: deterministic and
    /// chunking-invariant, tolerance-equal (≤1e-10) to Exact.
    Fast,
}

impl KernelProfile {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "exact" => Some(Self::Exact),
            "fast" => Some(Self::Fast),
            _ => None,
        }
    }

    /// Inverse of [`from_str`](Self::from_str) (journal header round trip).
    pub fn as_str(self) -> &'static str {
        match self {
            Self::Exact => "exact",
            Self::Fast => "fast",
        }
    }
}

/// GP hyperparameters over the *encoded* (unit-cube) feature space.
#[derive(Clone, Debug)]
pub struct GpParams {
    /// Signal amplitude (prior variance). y is normalized, so 1.0.
    pub amp: f64,
    /// Observation noise added to the kernel diagonal.
    pub noise: f64,
    /// UCB exploration weight (set per-iteration by the adaptive schedule).
    pub beta: f64,
    /// Per-dimension inverse lengthscales.
    pub inv_lengthscale: Vec<f64>,
}

impl GpParams {
    /// Defaults for `dims` encoded dimensions: unit amplitude, small noise,
    /// lengthscale 0.3 in the unit cube (≈ a third of each axis).
    pub fn new(dims: usize) -> Self {
        Self {
            amp: 1.0,
            noise: 1e-3,
            beta: 2.0,
            inv_lengthscale: vec![1.0 / 0.3; dims],
        }
    }

    pub fn with_lengthscale(mut self, ls: f64) -> Self {
        for v in &mut self.inv_lengthscale {
            *v = 1.0 / ls;
        }
        self
    }

    pub fn with_beta(mut self, beta: f64) -> Self {
        self.beta = beta;
        self
    }
}

/// Output of a posterior fit. `chol` is the lower Cholesky factor of the
/// regularized kernel `amp*K + noise*I`: everything downstream — the mean
/// via `alpha`, the variance and GP-BUCB `w = K^{-1} k_c` — is obtained by
/// triangular solves against it. No explicit `K^{-1}` exists on the hot
/// path (see [`crate::linalg::spd_inverse`], kept only as a test oracle).
#[derive(Clone, Debug)]
pub struct FitOut {
    pub alpha: Vec<f64>,
    pub chol: Matrix,
    pub logdet: f64,
}

impl FitOut {
    /// Log marginal likelihood of the fitted GP (used by the optional
    /// lengthscale grid search). y must be the same vector passed to fit.
    pub fn log_marginal_likelihood(&self, y: &[f64]) -> f64 {
        let n = y.len() as f64;
        let fit_term: f64 = y.iter().zip(&self.alpha).map(|(a, b)| a * b).sum();
        -0.5 * fit_term - 0.5 * self.logdet - 0.5 * n * (2.0 * std::f64::consts::PI).ln()
    }
}

/// Acquisition outputs over a candidate set.
#[derive(Clone, Debug)]
pub struct AcquireOut {
    pub ucb: Vec<f64>,
    pub mean: Vec<f64>,
    pub var: Vec<f64>,
    /// w = K^{-1} k_c, (n x m): consumed by [`update::BatchHallucinator`].
    pub w: Matrix,
}

/// Persistent Cholesky factor over a growing observation window.
///
/// Keyed by the kernel hyperparameters that shape `K` (`amp`, `noise`,
/// lengthscales) — `beta` shapes the acquisition, not the kernel, and `y`
/// never enters the factor (`alpha` is re-solved on every fit, so a changed
/// y-transform costs two O(n²) substitutions, not a refactorization).
/// Reuse works over the longest *shared leading-row prefix* between the
/// cached rows and the new observation matrix: the factor's leading block
/// survives (truncated if the tails diverge, as in the async loop's
/// changing constant-liar rows) and the remainder regrows by appends. A
/// window slide or shrink
/// ([`crate::optimizer::History::truncate_to_recent`]) drops the oldest
/// rows, zeroes the shared prefix, and transparently falls back to a
/// from-scratch factorization.
#[derive(Clone, Debug)]
pub struct CholeskyState {
    /// Encoded rows the factor covers.
    x: Matrix,
    /// Lower Cholesky factor of amp*K(x,x) + noise*I.
    l: Matrix,
    amp: f64,
    noise: f64,
    inv_lengthscale: Vec<f64>,
}

impl CholeskyState {
    /// Capture the state of a finished fit (backends without a host-side
    /// append path rebuild this after every full fit).
    pub fn from_fit(x: &Matrix, fit: &FitOut, params: &GpParams) -> Self {
        Self {
            x: x.clone(),
            l: fit.chol.clone(),
            amp: params.amp,
            noise: params.noise,
            inv_lengthscale: params.inv_lengthscale.clone(),
        }
    }

    /// Observations the cached factor covers.
    pub fn rows(&self) -> usize {
        self.x.rows()
    }

    /// The cached lower Cholesky factor itself — exposed so recovery tests
    /// can assert a resume-rebuilt state is bit-identical to the factor the
    /// uninterrupted run carried at the same history prefix.
    pub fn factor(&self) -> &Matrix {
        &self.l
    }

    /// Kernel-hyperparameter key match (exact: the LML grid search probes a
    /// fixed set of lengthscales, so each grid point keeps its own state).
    pub fn matches_params(&self, p: &GpParams) -> bool {
        self.amp == p.amp && self.noise == p.noise && self.inv_lengthscale == p.inv_lengthscale
    }

    /// Number of leading rows the cached matrix shares with `x`. The
    /// factor's leading principal block over those rows is reusable even
    /// when the tails diverge — the async event loop's constant-liar fits
    /// (`[history + pending]`, with a pending set that changes every
    /// round) share the real-history prefix round over round.
    fn common_prefix_rows(&self, x: &Matrix) -> usize {
        if self.x.cols() != x.cols() {
            return 0;
        }
        let max = self.x.rows().min(x.rows());
        (0..max).take_while(|&r| self.x.row(r) == x.row(r)).count()
    }
}

/// The shared `amp * K + noise * I` regularization pass — one copy, so
/// the plain and shared-distance Gram builds can never drift apart (the
/// bit-exactness contract between them depends on identical arithmetic).
fn apply_amp_noise(k: &mut Matrix, params: &GpParams) {
    let n = k.rows();
    for i in 0..n {
        for j in 0..n {
            k[(i, j)] *= params.amp;
        }
        k[(i, i)] += params.noise;
    }
}

/// The regularized Gram matrix `amp * K(x, x) + noise * I` the posterior
/// factorizes.
pub(crate) fn kernel_matrix(x: &Matrix, params: &GpParams) -> Matrix {
    let mut k = kernel::rbf_kernel(x, x, &params.inv_lengthscale);
    apply_amp_noise(&mut k, params);
    k
}

/// `kernel_matrix` from a precomputed unscaled squared-distance matrix
/// (isotropic lengthscale `il`) — bit-identical to [`kernel_matrix`] for
/// isotropic params because both derive every entry through
/// [`kernel::rbf_from_sq_dist`] on the same D² values.
fn kernel_matrix_from_sq_dists(d2: &Matrix, params: &GpParams, il: f64) -> Matrix {
    let mut k = kernel::rbf_kernel_from_sq_dists(d2, il);
    apply_amp_noise(&mut k, params);
    k
}

/// How the posterior engine derives bordered Gram rows for the incremental
/// append path — each variant performs arithmetic bit-identical to the
/// corresponding scratch `kernel_matrix` build (the append/scratch
/// equivalence contract).
enum AppendRows<'a> {
    /// Isotropic with a caller-supplied shared D² (the LML grid cache).
    SharedDists { d2: &'a Matrix, il: f64 },
    /// Isotropic without a cache: unscaled norms + `dot`, the same parts
    /// `kernel::sq_dists` computes.
    Iso { norms: Vec<f64>, il: f64 },
    /// Anisotropic/padded: `inv_ls`-scaled rows + norms.
    Scaled { scaled: Matrix, norms: Vec<f64> },
}

impl AppendRows<'_> {
    fn entry(&self, x: &Matrix, r: usize, i: usize) -> f64 {
        match self {
            AppendRows::SharedDists { d2, il } => kernel::rbf_from_sq_dist(d2[(r, i)], *il),
            AppendRows::Iso { norms, il } => kernel::rbf_from_sq_dist(
                kernel::sq_dist_from_parts(norms[r], norms[i], linalg::dot(x.row(r), x.row(i))),
                *il,
            ),
            AppendRows::Scaled { scaled, norms } => kernel::rbf_from_scaled_sq_dist(
                kernel::sq_dist_from_parts(
                    norms[r],
                    norms[i],
                    linalg::dot(scaled.row(r), scaled.row(i)),
                ),
            ),
        }
    }
}

/// The shared native posterior engine: fit over (`x`, `y`), reusing `state`
/// when it covers a leading prefix of `x`'s rows under the same kernel
/// hyperparameters. New observations enter through O(n²) rank-1 bordered
/// appends (O(kn²) for k new results per scheduling round); a first fit, a
/// hyperparameter change, or a window slide pays one from-scratch O(n³)
/// factorization. Returns the fit plus the refreshed state for next round.
pub fn fit_posterior(
    x: &Matrix,
    y: &[f64],
    params: &GpParams,
    state: Option<CholeskyState>,
) -> Result<(FitOut, CholeskyState)> {
    fit_posterior_impl(x, y, params, state, None)
}

/// [`fit_posterior`] with a caller-supplied *unscaled* pairwise
/// squared-distance matrix over `x`'s rows (see [`kernel::sq_dists`]).
/// Requires isotropic inverse lengthscales. The shared D² is a pure
/// precomputation: the fit is bit-identical to [`fit_posterior`] on the
/// same inputs — `BayesianCore` uses this to amortize the LML grid's five
/// kernel builds down to one distance build plus elementwise `exp` maps.
pub fn fit_posterior_with_dists(
    x: &Matrix,
    y: &[f64],
    params: &GpParams,
    state: Option<CholeskyState>,
    sq_dists: &Matrix,
) -> Result<(FitOut, CholeskyState)> {
    fit_posterior_impl(x, y, params, state, Some(sq_dists))
}

fn fit_posterior_impl(
    x: &Matrix,
    y: &[f64],
    params: &GpParams,
    state: Option<CholeskyState>,
    shared_d2: Option<&Matrix>,
) -> Result<(FitOut, CholeskyState)> {
    let n = x.rows();
    anyhow::ensure!(y.len() == n, "y length {} != x rows {}", y.len(), n);
    let iso = kernel::iso_inv_ls(&params.inv_lengthscale, x.cols());
    if let Some(d2) = shared_d2 {
        anyhow::ensure!(
            d2.rows() == n && d2.cols() == n,
            "shared sq-dist matrix is {}x{}, expected {n}x{n}",
            d2.rows(),
            d2.cols()
        );
        anyhow::ensure!(
            iso.is_some(),
            "shared sq-dist fits require isotropic inverse lengthscales"
        );
    }
    // Reuse the cached factor over the longest shared leading-row prefix
    // q: the leading q x q block of a Cholesky factor IS the factor of the
    // leading q x q minor, so it survives truncation when the tails
    // diverge (async constant-liar fits) and regrows by appends. Appending
    // n-q rows costs ~sum r^2 flops, so a short shared prefix (q < n/2,
    // incl. window slides where q = 0) is cheaper to refactor from
    // scratch. Either way the result is bit-identical to a scratch fit.
    let reusable = state.filter(|s| s.matches_params(params));
    let l = match reusable.map(|s| (s.common_prefix_rows(x), s)) {
        Some((q, s)) if q > 0 && 2 * q >= n => {
            let mut l = if q == s.x.rows() {
                s.l
            } else {
                Matrix::from_fn(q, q, |i, j| s.l[(i, j)])
            };
            let rows = match (shared_d2, iso) {
                (Some(d2), Some(il)) => AppendRows::SharedDists { d2, il },
                (None, Some(il)) => AppendRows::Iso { norms: kernel::row_sq_norms(x), il },
                (None, None) => {
                    let scaled = kernel::scale_rows(x, &params.inv_lengthscale);
                    let norms = kernel::row_sq_norms(&scaled);
                    AppendRows::Scaled { scaled, norms }
                }
                (Some(_), None) => unreachable!("guarded by the isotropy ensure above"),
            };
            for r in q..n {
                // Bordered row: amp*k(x_r, x_0..r) with the regularized
                // diagonal last — each entry derived through the same
                // parts as the scratch `kernel_matrix` build, so the
                // append is bit-identical to a scratch fit.
                let mut k_new = Vec::with_capacity(r + 1);
                for i in 0..r {
                    k_new.push(params.amp * rows.entry(x, r, i));
                }
                k_new.push(params.amp + params.noise); // k(x_r, x_r) == 1
                l = linalg::chol_append_row(&l, &k_new);
            }
            l
        }
        _ => {
            let k = match (shared_d2, iso) {
                (Some(d2), Some(il)) => kernel_matrix_from_sq_dists(d2, params, il),
                _ => kernel_matrix(x, params),
            };
            linalg::cholesky(&k)
        }
    };
    let alpha = linalg::solve_spd(&l, y);
    let logdet = linalg::logdet_from_cholesky(&l);
    let fit = FitOut { alpha, chol: l, logdet };
    let state = CholeskyState::from_fit(x, &fit, params);
    Ok((fit, state))
}

/// A GP surrogate backend. `x` rows are encoded configs; `y` must already be
/// normalized (zero mean / unit variance) and in maximization convention.
pub trait Surrogate {
    /// Fit the posterior over `n = x.rows()` observations from scratch.
    fn fit(&mut self, x: &Matrix, y: &[f64], params: &GpParams) -> Result<FitOut>;

    /// Fit reusing a persistent [`CholeskyState`] across scheduling rounds:
    /// when `state` covers a prefix of `x` under the same kernel
    /// hyperparameters, new observations are appended in O(n²) each instead
    /// of refitting in O(n³). The default delegates to the shared native
    /// engine; backends whose factorization lives off-host override this
    /// with a plain fit plus a state rebuild.
    fn fit_incremental(
        &mut self,
        x: &Matrix,
        y: &[f64],
        params: &GpParams,
        state: Option<CholeskyState>,
    ) -> Result<(FitOut, CholeskyState)> {
        fit_posterior(x, y, params, state)
    }

    /// [`fit_incremental`](Self::fit_incremental) with an optional
    /// caller-precomputed unscaled squared-distance matrix over `x`'s rows
    /// (the shared-distance LML grid cache). Backends whose kernel build
    /// runs host-side override this to consume the cache
    /// ([`fit_posterior_with_dists`] — bit-identical to ignoring it);
    /// artifact backends whose kernel lives inside the compiled program
    /// keep this default and simply ignore the hint.
    fn fit_incremental_shared(
        &mut self,
        x: &Matrix,
        y: &[f64],
        params: &GpParams,
        state: Option<CholeskyState>,
        sq_dists: Option<&Matrix>,
    ) -> Result<(FitOut, CholeskyState)> {
        let _ = sq_dists;
        self.fit_incremental(x, y, params, state)
    }

    /// Whether [`fit_incremental_shared`](Self::fit_incremental_shared)
    /// actually consumes the squared-distance hint. Callers use this to
    /// skip *maintaining* the O(n²) distance cache for backends whose
    /// kernel build lives inside a compiled artifact and would discard it.
    fn consumes_shared_dists(&self) -> bool {
        false
    }

    /// Score candidates (mean/var/UCB + the `w` matrix) under a fit.
    fn acquire(
        &mut self,
        x: &Matrix,
        fit: &FitOut,
        xc: &Matrix,
        params: &GpParams,
    ) -> Result<AcquireOut>;

    /// Largest observation count one posterior can hold. Static-shape
    /// artifact backends answer from their manifest; native is unbounded.
    fn max_obs(&self) -> usize {
        usize::MAX
    }

    /// Backend name for logs/EXPERIMENTS.md.
    fn name(&self) -> &'static str;
}

/// Pure-Rust GP backend: mirrors `python/compile/model.py` exactly
/// (same kernel, same clamps) so the two backends agree numerically.
#[derive(Default)]
pub struct NativeGp;

impl Surrogate for NativeGp {
    fn fit(&mut self, x: &Matrix, y: &[f64], params: &GpParams) -> Result<FitOut> {
        let n = x.rows();
        anyhow::ensure!(y.len() == n, "y length {} != x rows {}", y.len(), n);
        let l = linalg::cholesky(&kernel_matrix(x, params));
        let alpha = linalg::solve_spd(&l, y);
        let logdet = linalg::logdet_from_cholesky(&l);
        Ok(FitOut { alpha, chol: l, logdet })
    }

    fn fit_incremental_shared(
        &mut self,
        x: &Matrix,
        y: &[f64],
        params: &GpParams,
        state: Option<CholeskyState>,
        sq_dists: Option<&Matrix>,
    ) -> Result<(FitOut, CholeskyState)> {
        match sq_dists {
            Some(d2) => fit_posterior_with_dists(x, y, params, state, d2),
            None => fit_posterior(x, y, params, state),
        }
    }

    fn consumes_shared_dists(&self) -> bool {
        true
    }

    fn acquire(
        &mut self,
        x: &Matrix,
        fit: &FitOut,
        xc: &Matrix,
        params: &GpParams,
    ) -> Result<AcquireOut> {
        acquire_columns(x, fit, xc, params)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// The single-threaded candidate-scoring pipeline: cross-kernel (GEMM) →
/// mean → triangular solves for `w = K^{-1} k_c` → variance → UCB. Every
/// stage is **per-candidate-column independent** — the value of column `c`
/// never depends on which other columns share the matrix — which is what
/// makes [`acquire_parallel`]'s chunked scoring byte-identical to a single
/// pass regardless of the chunk boundaries.
pub(crate) fn acquire_columns(
    x: &Matrix,
    fit: &FitOut,
    xc: &Matrix,
    params: &GpParams,
) -> Result<AcquireOut> {
    acquire_columns_profile(x, fit, xc, params, KernelProfile::Exact)
}

/// [`acquire_columns`] with the kernel profile dispatched per stage:
/// `Exact` runs the sequential kernels byte-for-byte; `Fast` swaps in the
/// chunked GEMM cross-kernel, the 4-wide triangular solves, and a 4-lane
/// score fold. Both profiles keep every stage per-candidate-column
/// independent, so the chunked/sharded fold contract holds for each.
pub(crate) fn acquire_columns_profile(
    x: &Matrix,
    fit: &FitOut,
    xc: &Matrix,
    params: &GpParams,
    profile: KernelProfile,
) -> Result<AcquireOut> {
    let (n, m) = (x.rows(), xc.rows());
    anyhow::ensure!(fit.alpha.len() == n, "fit/x size mismatch");
    anyhow::ensure!(fit.chol.rows() == n, "fit/chol size mismatch");
    // kc: (n x m) cross-kernel.
    let mut kc = match profile {
        KernelProfile::Exact => kernel::rbf_kernel(x, xc, &params.inv_lengthscale),
        KernelProfile::Fast => kernel::rbf_kernel_fast(x, xc, &params.inv_lengthscale),
    };
    for v in kc.data_mut() {
        *v *= params.amp;
    }
    let mean = kc.matvec_t(&fit.alpha);
    // w = K^{-1} k_c via two triangular solves against L.
    let w = match profile {
        KernelProfile::Exact => linalg::solve_spd_mat(&fit.chol, &kc),
        KernelProfile::Fast => linalg::solve_spd_mat_fast(&fit.chol, &kc),
    };
    let mut var = vec![0.0; m];
    match profile {
        KernelProfile::Exact => {
            for c in 0..m {
                let mut s = 0.0;
                for i in 0..n {
                    s += kc[(i, c)] * w[(i, c)];
                }
                var[c] = (params.amp - s).max(1e-10);
            }
        }
        KernelProfile::Fast => {
            // 4-lane chunked fold down each candidate column. The lane
            // assignment depends only on the row index i, so the fold is
            // deterministic and identical however columns are chunked.
            for (c, v) in var.iter_mut().enumerate() {
                let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
                let mut i = 0;
                while i + 4 <= n {
                    s0 += kc[(i, c)] * w[(i, c)];
                    s1 += kc[(i + 1, c)] * w[(i + 1, c)];
                    s2 += kc[(i + 2, c)] * w[(i + 2, c)];
                    s3 += kc[(i + 3, c)] * w[(i + 3, c)];
                    i += 4;
                }
                while i < n {
                    s0 += kc[(i, c)] * w[(i, c)];
                    i += 1;
                }
                *v = (params.amp - ((s0 + s1) + (s2 + s3))).max(1e-10);
            }
        }
    }
    let ucb = mean
        .iter()
        .zip(&var)
        .map(|(mu, v)| mu + params.beta * v.sqrt())
        .collect();
    Ok(AcquireOut { ucb, mean, var, w })
}

/// Fixed index-ordered chunk ranges over `m` candidates — the one chunking
/// arithmetic [`acquire_parallel`] and [`acquire_sharded`] share.
fn chunk_ranges(m: usize, parts: usize) -> Vec<(usize, usize)> {
    let p = parts.clamp(1, m.max(1));
    let chunk = m.div_ceil(p);
    (0..p)
        .map(|i| (i * chunk, ((i + 1) * chunk).min(m)))
        .filter(|(start, end)| start < end)
        .collect()
}

/// Fold per-chunk [`acquire_columns`] outputs back into one candidate-set
/// result, **in chunk order** — shared by the local threaded path and the
/// scheduler-sharded path so the fold arithmetic can never drift between
/// them.
fn fold_parts(n: usize, m: usize, parts: Vec<AcquireOut>) -> Result<AcquireOut> {
    let mut ucb = Vec::with_capacity(m);
    let mut mean = Vec::with_capacity(m);
    let mut var = Vec::with_capacity(m);
    let mut w = Matrix::zeros(n, m);
    let mut col = 0usize;
    for p in parts {
        let width = p.ucb.len();
        ucb.extend_from_slice(&p.ucb);
        mean.extend_from_slice(&p.mean);
        var.extend_from_slice(&p.var);
        for i in 0..n {
            let src = p.w.row(i);
            w.row_mut(i)[col..col + width].copy_from_slice(src);
        }
        col += width;
    }
    anyhow::ensure!(col == m, "chunked scoring dropped candidates ({col} of {m})");
    Ok(AcquireOut { ucb, mean, var, w })
}

/// Deterministic parallel candidate scoring: split the m-candidate set
/// into `threads` fixed index-ordered chunks, score each on a scoped
/// worker through [`acquire_columns`], and fold the outputs back in chunk
/// order. Because every pipeline stage is per-column independent (see
/// [`acquire_columns`]), the result is **byte-identical for every thread
/// count** — parallelism here is a pure wall-clock optimization, never a
/// numerics knob.
pub fn acquire_parallel(
    x: &Matrix,
    fit: &FitOut,
    xc: &Matrix,
    params: &GpParams,
    threads: usize,
) -> Result<AcquireOut> {
    acquire_parallel_profile(x, fit, xc, params, threads, KernelProfile::Exact)
}

/// [`acquire_parallel`] under an explicit [`KernelProfile`]. The chunking
/// and fold arithmetic are profile-independent; within one profile the
/// output stays byte-identical for every thread count (Fast's chunked
/// kernels depend only on element indices, never on the thread layout).
pub fn acquire_parallel_profile(
    x: &Matrix,
    fit: &FitOut,
    xc: &Matrix,
    params: &GpParams,
    threads: usize,
    profile: KernelProfile,
) -> Result<AcquireOut> {
    let (n, m) = (x.rows(), xc.rows());
    let t = threads.clamp(1, m.max(1));
    if t <= 1 {
        return acquire_columns_profile(x, fit, xc, params, profile);
    }
    let ranges = chunk_ranges(m, t);
    let parts: Vec<Result<AcquireOut>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(ranges.len());
        for &(start, end) in &ranges {
            let sub = Matrix::from_fn(end - start, xc.cols(), |i, j| xc[(start + i, j)]);
            handles
                .push(scope.spawn(move || acquire_columns_profile(x, fit, &sub, params, profile)));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("candidate-scoring worker panicked"))
            .collect()
    });
    fold_parts(n, m, parts.into_iter().collect::<Result<Vec<_>>>()?)
}

/// How propose-time scoring shards execute — mirrors the run's scheduler
/// kind, so the same abstraction that distributes objective evaluations
/// carries acquisition scoring (`TunerConfig::scheduler` maps onto this).
#[derive(Clone, Debug)]
pub enum ShardExec {
    /// In-line sequential shard execution (the serial scheduler): same
    /// fixed chunks, same fold order, no worker pool.
    Serial,
    /// Shards ride the persistent broker/worker/collector pool
    /// ([`crate::scheduler::pool::JobPool`]) across the scoring threads.
    Threaded,
    /// Shards ride the pool under the Celery fault simulator: each
    /// submission gets a pre-rolled fate (crash / straggler-timeout /
    /// deliver-after-latency) drawn from `seed`, and lost shards are
    /// resubmitted until they deliver (a shard lost too many times is
    /// scored locally as a backstop) — faults cost wall-clock and retries,
    /// never numerics.
    CelerySim { config: crate::scheduler::celery::CelerySimConfig, seed: u64 },
}

/// Total submissions per shard (first try + 7 resubmissions) before the
/// local-compute backstop kicks in — guards against pathological fault
/// models like `crash_prob = 1.0`.
const MAX_SHARD_ATTEMPTS: usize = 8;

/// Candidate scoring sharded through the scheduler's worker-pool
/// machinery: split the m candidates into `shards` fixed index-ordered
/// chunks, ship each chunk (a range over the shared posterior +
/// encoded-candidate view) as one pool job executed under `exec`'s
/// scheduler model — `threads` workers for the threaded pool, the sim's
/// own `workers` for the Celery cluster — and fold the outputs back in
/// shard order. This extends [`acquire_parallel`]'s fixed-chunk,
/// fold-in-chunk-order contract across the scheduler boundary: every
/// pipeline stage is per-candidate-column independent and the fold is
/// ordered by shard index, so the output is **byte-identical** for every
/// `shards` × `threads` × scheduler-kind setting — and to the local
/// [`acquire_parallel`]/[`acquire_columns`] paths. Celery-sim fault fates
/// (worker crash, straggler timeout) surface as explicit losses and
/// trigger resubmission of the same shard; they can never perturb the
/// folded numbers.
///
/// `fate_salt` varies the Celery-sim fate stream per call (the caller
/// passes its round counter): without it every propose round would
/// replay the identical fault sequence, systematically re-losing the
/// same shards. It only shapes faults/wall-clock — never the output.
pub fn acquire_sharded(
    x: &Matrix,
    fit: &FitOut,
    xc: &Matrix,
    params: &GpParams,
    shards: usize,
    threads: usize,
    exec: &ShardExec,
    fate_salt: u64,
) -> Result<AcquireOut> {
    acquire_sharded_profile(x, fit, xc, params, shards, threads, exec, fate_salt, KernelProfile::Exact)
}

/// [`acquire_sharded`] under an explicit [`KernelProfile`] — within one
/// profile the folded output is byte-identical for every shards × threads
/// × scheduler-kind setting (and to the local profile paths).
#[allow(clippy::too_many_arguments)]
// disallowed_methods: the two Instant::now() telemetry stamps below carry
// inline R1 pragmas; this is the clippy (clippy.toml) face of the same
// exemption.
#[allow(clippy::disallowed_methods)]
pub fn acquire_sharded_profile(
    x: &Matrix,
    fit: &FitOut,
    xc: &Matrix,
    params: &GpParams,
    shards: usize,
    threads: usize,
    exec: &ShardExec,
    fate_salt: u64,
    profile: KernelProfile,
) -> Result<AcquireOut> {
    use crate::scheduler::pool::{Fate, Job, JobPool, JobStatus};
    use std::time::{Duration, Instant};

    let (n, m) = (x.rows(), xc.rows());
    let ranges = chunk_ranges(m, shards);
    let sub = |&(start, end): &(usize, usize)| {
        Matrix::from_fn(end - start, xc.cols(), |i, j| xc[(start + i, j)])
    };
    if matches!(exec, ShardExec::Serial) || ranges.len() <= 1 {
        let parts = ranges
            .iter()
            .map(|r| acquire_columns_profile(x, fit, &sub(r), params, profile))
            .collect::<Result<Vec<_>>>()?;
        return fold_parts(n, m, parts);
    }

    // Pool sizing mirrors the evaluation schedulers: the Celery simulator
    // models its configured cluster (`CelerySimConfig::workers`, as
    // `scheduler::build_custom` does for evaluations); the threaded pool
    // uses the local scoring-thread knob. Either way this shapes only
    // wall-clock — never the folded output.
    let workers = match exec {
        ShardExec::CelerySim { config, .. } => config.workers.max(1).min(ranges.len()),
        _ => threads.clamp(1, ranges.len()),
    };
    let mut fate_rng = match exec {
        ShardExec::CelerySim { seed, .. } => Some(crate::util::rng::Pcg64::new(
            (seed ^ 0x5C0_7E5).wrapping_add(fate_salt.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
        )),
        _ => None,
    };
    let mut next_fate = || -> Fate {
        match (&mut fate_rng, exec) {
            (Some(rng), ShardExec::CelerySim { config, .. }) => config.roll_fate(rng).fate,
            _ => Fate::Deliver { delay: Duration::ZERO },
        }
    };
    // The executor a pool worker runs per shard: score the chunk's columns
    // against the shared posterior view. Declared before the scope so the
    // workers can borrow it for the pool's lifetime. Errors ride back as
    // the job's Done payload (stringified) so the root cause survives the
    // pool boundary instead of degrading to a bare "shard failed".
    let score = |r: &(usize, usize)| -> Option<Result<AcquireOut, String>> {
        Some(acquire_columns_profile(x, fit, &sub(r), params, profile).map_err(|e| format!("{e:#}")))
    };
    std::thread::scope(|scope| -> Result<AcquireOut> {
        let mut pool: JobPool<(usize, usize), Result<AcquireOut, String>> =
            JobPool::spawn(scope, &score, workers);
        let mut done: Vec<Option<AcquireOut>> = (0..ranges.len()).map(|_| None).collect();
        let mut attempts = vec![1usize; ranges.len()];
        for (i, r) in ranges.iter().enumerate() {
            let fate = next_fate();
            pool.submit_job(Job {
                id: i as crate::scheduler::TaskId,
                payload: *r,
                // pallas-lint: allow(R1, "shard queue-wait telemetry timestamp; results fold by shard id, so it never reaches numerics or ordering")
                submitted_at: Instant::now(),
                fate,
            });
        }
        let mut remaining = ranges.len();
        while remaining > 0 {
            anyhow::ensure!(
                pool.in_flight() > 0,
                "scoring-shard pool lost its in-flight shards (worker panic)"
            );
            for d in pool.poll(Duration::from_millis(20)) {
                let idx = d.id as usize;
                match d.status {
                    JobStatus::Done(Ok(part)) => {
                        done[idx] = Some(part);
                        remaining -= 1;
                    }
                    JobStatus::Done(Err(msg)) => {
                        anyhow::bail!("scoring shard {idx} failed: {msg}")
                    }
                    JobStatus::Failed => {
                        unreachable!("the shard executor never declines a job")
                    }
                    JobStatus::Lost(_) if attempts[idx] >= MAX_SHARD_ATTEMPTS => {
                        // Fault-storm backstop: identical arithmetic run
                        // locally, so the byte-identity contract holds
                        // even under crash_prob = 1.
                        done[idx] =
                            Some(acquire_columns_profile(x, fit, &sub(&ranges[idx]), params, profile)?);
                        remaining -= 1;
                    }
                    JobStatus::Lost(_) => {
                        attempts[idx] += 1;
                        let fate = next_fate();
                        pool.submit_job(Job {
                            id: d.id,
                            payload: d.payload,
                            // pallas-lint: allow(R1, "shard queue-wait telemetry timestamp; results fold by shard id, so it never reaches numerics or ordering")
                            submitted_at: Instant::now(),
                            fate,
                        });
                    }
                }
            }
        }
        fold_parts(
            n,
            m,
            done.into_iter()
                .map(|p| p.expect("remaining == 0 implies every shard resolved"))
                .collect(),
        )
    })
}

/// Normalize y to zero mean / unit variance; returns (normalized, mean, std).
/// Constant y gets std 1.0 so early iterations stay well-posed.
pub fn normalize_y(y: &[f64]) -> (Vec<f64>, f64, f64) {
    let mean = crate::util::stats::mean(y);
    let mut std = crate::util::stats::std_dev_pop(y);
    if std < 1e-12 {
        std = 1.0;
    }
    (y.iter().map(|v| (v - mean) / std).collect(), mean, std)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Pcg64;

    fn toy_problem(n: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Pcg64::new(seed);
        let x = Matrix::from_fn(n, d, |_, _| rng.next_f64());
        let y: Vec<f64> = (0..n)
            .map(|i| {
                let r = x.row(i);
                (2.0 * std::f64::consts::PI * r[0]).sin() + 0.5 * r.get(1).copied().unwrap_or(0.0)
            })
            .collect();
        (x, y)
    }

    #[test]
    fn posterior_interpolates_training_data() {
        let (x, y) = toy_problem(30, 2, 1);
        let (yn, _, _) = normalize_y(&y);
        let params = GpParams::new(2);
        let mut gp = NativeGp;
        let fit = gp.fit(&x, &yn, &params).unwrap();
        let out = gp.acquire(&x, &fit, &x, &params).unwrap();
        for i in 0..x.rows() {
            assert!(
                (out.mean[i] - yn[i]).abs() < 0.05,
                "mean[{i}] {} vs {}",
                out.mean[i],
                yn[i]
            );
            assert!(out.var[i] < 0.02, "var[{i}] = {}", out.var[i]);
        }
    }

    #[test]
    fn variance_reverts_to_prior_far_away() {
        let (x, y) = toy_problem(20, 2, 2);
        let (yn, _, _) = normalize_y(&y);
        let params = GpParams::new(2);
        let mut gp = NativeGp;
        let fit = gp.fit(&x, &yn, &params).unwrap();
        let far = Matrix::from_fn(4, 2, |_, _| 100.0);
        let out = gp.acquire(&x, &fit, &far, &params).unwrap();
        for c in 0..4 {
            assert!((out.var[c] - params.amp).abs() < 1e-6);
            assert!(out.mean[c].abs() < 1e-6);
        }
    }

    #[test]
    fn ucb_is_mean_plus_beta_sigma_property() {
        check("ucb = mean + beta*sqrt(var)", 32, |g| {
            let n = g.usize_range(2, 20);
            let (x, y) = toy_problem(n, 3, g.rng().next_u64());
            let (yn, _, _) = normalize_y(&y);
            let beta = g.f64_range(0.0, 5.0);
            let params = GpParams::new(3).with_beta(beta);
            let mut gp = NativeGp;
            let fit = gp.fit(&x, &yn, &params).map_err(|e| e.to_string())?;
            let xc = Matrix::from_fn(8, 3, |_, _| g.f64_range(0.0, 1.0));
            let out = gp.acquire(&x, &fit, &xc, &params).map_err(|e| e.to_string())?;
            for c in 0..8 {
                let want = out.mean[c] + beta * out.var[c].sqrt();
                if (out.ucb[c] - want).abs() > 1e-9 {
                    return Err(format!("ucb[{c}] {} vs {}", out.ucb[c], want));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lml_prefers_true_lengthscale_region() {
        // Data drawn smoothly: tiny lengthscales should not win the LML.
        let (x, y) = toy_problem(40, 1, 3);
        let (yn, _, _) = normalize_y(&y);
        let mut gp = NativeGp;
        let mut lml = |ls: f64| {
            let p = GpParams::new(1).with_lengthscale(ls);
            let fit = gp.fit(&x, &yn, &p).unwrap();
            fit.log_marginal_likelihood(&yn)
        };
        assert!(lml(0.2) > lml(0.01), "smooth data should reject ls=0.01");
    }

    /// The tentpole contract: an incremental fit over a randomly growing
    /// history — including window shrinks, the cache-invalidation path —
    /// must agree with a from-scratch fit to 1e-8 at every round.
    #[test]
    fn incremental_fit_matches_scratch_across_growth_and_shrink() {
        check("incremental posterior == scratch", 24, |g| {
            let d = g.usize_range(1, 4);
            let params = GpParams::new(d);
            let mut rows: Vec<Vec<f64>> = Vec::new();
            let mut state: Option<CholeskyState> = None;
            let mut gp = NativeGp;
            for _round in 0..8 {
                for _ in 0..g.usize_range(1, 4) {
                    rows.push(g.vec_f64(d, 0.0, 1.0));
                }
                if rows.len() > 3 && g.bool() && g.bool() {
                    // Window shrink (truncate_to_recent): drop oldest rows,
                    // breaking the cached prefix.
                    let cut = g.usize_range(1, rows.len() - 1);
                    rows.drain(..cut);
                }
                let n = rows.len();
                let x = Matrix::from_fn(n, d, |i, j| rows[i][j]);
                let y: Vec<f64> = (0..n).map(|i| (5.0 * rows[i][0]).sin()).collect();
                let (inc, next) = gp
                    .fit_incremental(&x, &y, &params, state.take())
                    .map_err(|e| e.to_string())?;
                state = Some(next);
                let scratch = gp.fit(&x, &y, &params).map_err(|e| e.to_string())?;
                let chol_dev = inc.chol.max_abs_diff(&scratch.chol);
                if chol_dev > 1e-8 {
                    return Err(format!("n={n}: chol deviation {chol_dev}"));
                }
                for i in 0..n {
                    if (inc.alpha[i] - scratch.alpha[i]).abs() > 1e-8 {
                        return Err(format!("n={n}: alpha[{i}] deviates"));
                    }
                }
                if (inc.logdet - scratch.logdet).abs() > 1e-8 {
                    return Err(format!("n={n}: logdet deviates"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn incremental_state_reuse_is_exact_on_append_only_growth() {
        // Pure append-only growth performs identical arithmetic: the reused
        // factor is bit-equal to scratch, not merely close.
        let (x, y) = toy_problem(24, 2, 9);
        let (yn, _, _) = normalize_y(&y);
        let params = GpParams::new(2);
        let mut gp = NativeGp;
        let x0 = Matrix::from_fn(16, 2, |i, j| x[(i, j)]);
        let (_, state) = gp.fit_incremental(&x0, &yn[..16], &params, None).unwrap();
        assert_eq!(state.rows(), 16);
        let (inc, state) = gp.fit_incremental(&x, &yn, &params, Some(state)).unwrap();
        assert_eq!(state.rows(), 24);
        let scratch = gp.fit(&x, &yn, &params).unwrap();
        assert_eq!(inc.chol, scratch.chol, "append path must be bit-identical");
        assert_eq!(inc.alpha, scratch.alpha);
        assert_eq!(inc.logdet, scratch.logdet);
    }

    #[test]
    fn divergent_tail_reuses_common_prefix_block() {
        // The async event loop's constant-liar pattern: round t fits over
        // [history + pending_t], round t+1 over [history', pending_{t+1}]
        // — only the tail past the real history changes. The shared
        // leading block must be reused (truncate + regrow) with a result
        // bit-identical to a scratch fit.
        let (x_all, y_all) = toy_problem(20, 2, 12);
        let (yn, _, _) = normalize_y(&y_all);
        let params = GpParams::new(2);
        let mut gp = NativeGp;
        // Round 1: rows 0..16 as history + rows 16..18 as liar rows.
        let x1 = Matrix::from_fn(18, 2, |i, j| x_all[(i, j)]);
        let (_, state) = gp.fit_incremental(&x1, &yn[..18], &params, None).unwrap();
        // Round 2: same 16 history rows, different tail (rows 18..20).
        let pick = |i: usize| if i < 16 { i } else { i + 2 };
        let x2 = Matrix::from_fn(18, 2, |i, j| x_all[(pick(i), j)]);
        let y2: Vec<f64> = (0..18).map(|i| yn[pick(i)]).collect();
        let (inc, state2) = gp.fit_incremental(&x2, &y2, &params, Some(state)).unwrap();
        assert_eq!(state2.rows(), 18);
        let scratch = gp.fit(&x2, &y2, &params).unwrap();
        assert_eq!(inc.chol, scratch.chol, "prefix-block reuse must be bit-identical");
        assert_eq!(inc.alpha, scratch.alpha);
    }

    #[test]
    fn stale_state_params_fall_back_to_scratch() {
        let (x, y) = toy_problem(12, 2, 10);
        let (yn, _, _) = normalize_y(&y);
        let p1 = GpParams::new(2).with_lengthscale(0.3);
        let p2 = GpParams::new(2).with_lengthscale(0.5);
        let mut gp = NativeGp;
        let (_, state) = gp.fit_incremental(&x, &yn, &p1, None).unwrap();
        assert!(state.matches_params(&p1));
        assert!(!state.matches_params(&p2));
        // Reusing a p1 state for a p2 fit must not poison the result.
        let (inc, _) = gp.fit_incremental(&x, &yn, &p2, Some(state)).unwrap();
        let scratch = gp.fit(&x, &yn, &p2).unwrap();
        assert_eq!(inc.chol, scratch.chol);
    }

    /// The shared-distance contract: supplying a precomputed D² must be a
    /// pure precomputation — factors, alpha, and logdet bit-identical to
    /// the engine computing the distances itself, across scratch fits and
    /// incremental appends alike.
    #[test]
    fn shared_dists_fit_is_bit_identical_to_plain_fit() {
        check("fit_posterior_with_dists == fit_posterior", 24, |g| {
            let d = g.usize_range(1, 5);
            let ls = *g.choose(&[0.1, 0.3, 0.8]);
            let params = GpParams::new(d).with_lengthscale(ls);
            let n0 = g.usize_range(1, 8);
            let n1 = n0 + g.usize_range(1, 5);
            let x = Matrix::from_fn(n1, d, |_, _| g.f64_range(0.0, 1.0));
            let y: Vec<f64> = (0..n1).map(|i| (3.0 * x.row(i)[0]).cos()).collect();
            // Scratch fit with and without the shared D².
            let d2_full = kernel::sq_dists(&x, &x);
            let (plain, _) = fit_posterior(&x, &y, &params, None).map_err(|e| e.to_string())?;
            let (shared, _) = fit_posterior_with_dists(&x, &y, &params, None, &d2_full)
                .map_err(|e| e.to_string())?;
            if plain.chol != shared.chol || plain.alpha != shared.alpha {
                return Err("scratch: shared-D² fit deviates".into());
            }
            // Incremental append with the shared D², against a plain scratch.
            let x0 = Matrix::from_fn(n0, d, |i, j| x[(i, j)]);
            let (_, state) =
                fit_posterior(&x0, &y[..n0], &params, None).map_err(|e| e.to_string())?;
            let (inc, _) = fit_posterior_with_dists(&x, &y, &params, Some(state), &d2_full)
                .map_err(|e| e.to_string())?;
            if inc.chol != plain.chol || inc.alpha != plain.alpha || inc.logdet != plain.logdet {
                return Err(format!("append {n0}->{n1}: shared-D² path deviates"));
            }
            Ok(())
        });
    }

    #[test]
    fn shared_dists_reject_bad_shapes_and_anisotropy() {
        let x = Matrix::from_fn(4, 2, |i, j| (i + j) as f64 * 0.1);
        let y = vec![0.0; 4];
        let params = GpParams::new(2);
        let bad = Matrix::zeros(3, 3);
        assert!(fit_posterior_with_dists(&x, &y, &params, None, &bad).is_err());
        let mut aniso = GpParams::new(2);
        aniso.inv_lengthscale = vec![1.0, 2.0];
        let d2 = kernel::sq_dists(&x, &x);
        assert!(fit_posterior_with_dists(&x, &y, &aniso, None, &d2).is_err());
    }

    /// The parallel-scoring contract: chunked scoring folds back to the
    /// byte-identical result of a single pass, for any thread count.
    #[test]
    fn acquire_parallel_is_byte_identical_across_thread_counts() {
        let (x, y) = toy_problem(24, 3, 21);
        let (yn, _, _) = normalize_y(&y);
        let params = GpParams::new(3);
        let mut gp = NativeGp;
        let fit = gp.fit(&x, &yn, &params).unwrap();
        let mut rng = Pcg64::new(5);
        let xc = Matrix::from_fn(101, 3, |_, _| rng.next_f64()); // odd m: ragged chunks
        let base = gp.acquire(&x, &fit, &xc, &params).unwrap();
        for threads in [1usize, 2, 3, 8, 64] {
            let par = acquire_parallel(&x, &fit, &xc, &params, threads).unwrap();
            assert_eq!(par.ucb, base.ucb, "{threads} threads: ucb deviates");
            assert_eq!(par.mean, base.mean, "{threads} threads: mean deviates");
            assert_eq!(par.var, base.var, "{threads} threads: var deviates");
            assert_eq!(par.w, base.w, "{threads} threads: w deviates");
        }
    }

    /// The sharded-scoring contract: shipping fixed chunks through the
    /// scheduler worker-pool machinery — serial in-line, threaded pool, or
    /// the Celery fault simulator with crash/timeout fates actually firing
    /// and forcing resubmissions — folds back to the byte-identical result
    /// of a single local pass, for every shard count × thread count.
    #[test]
    fn acquire_sharded_is_byte_identical_across_shards_threads_and_exec() {
        let (x, y) = toy_problem(18, 3, 33);
        let (yn, _, _) = normalize_y(&y);
        let params = GpParams::new(3);
        let mut gp = NativeGp;
        let fit = gp.fit(&x, &yn, &params).unwrap();
        let mut rng = Pcg64::new(9);
        let xc = Matrix::from_fn(101, 3, |_, _| rng.next_f64()); // odd m: ragged chunks
        let base = gp.acquire(&x, &fit, &xc, &params).unwrap();
        // A hostile simulated cluster: fast, but a third of shard
        // deliveries crash and stragglers overrun the 2 ms collector
        // timeout — losses and resubmissions fire, numerics must not move.
        let faulty = crate::scheduler::celery::CelerySimConfig {
            workers: 3,
            base_latency_ms: 0.05,
            straggler_prob: 0.3,
            straggler_factor: 1000.0,
            crash_prob: 0.3,
            result_timeout: std::time::Duration::from_millis(2),
        };
        let execs = [
            ShardExec::Serial,
            ShardExec::Threaded,
            ShardExec::CelerySim { config: faulty, seed: 5 },
        ];
        for exec in &execs {
            for shards in [1usize, 2, 3, 7] {
                for threads in [1usize, 3] {
                    // The fate salt varies the fault schedule per round;
                    // the output must be independent of it too.
                    let salt = (shards + threads) as u64;
                    let out =
                        acquire_sharded(&x, &fit, &xc, &params, shards, threads, exec, salt)
                            .unwrap();
                    let tag = format!("{exec:?} shards={shards} threads={threads}");
                    assert_eq!(out.ucb, base.ucb, "{tag}: ucb deviates");
                    assert_eq!(out.mean, base.mean, "{tag}: mean deviates");
                    assert_eq!(out.var, base.var, "{tag}: var deviates");
                    assert_eq!(out.w, base.w, "{tag}: w deviates");
                }
            }
        }
    }

    /// The Fast-profile acquisition contract: (1) within 1e-10 relative
    /// tolerance of the Exact pipeline; (2) run-to-run deterministic;
    /// (3) byte-identical across every `proposal_threads` ×
    /// `proposal_shards` × scheduler-exec setting — Fast changes the
    /// per-element arithmetic, never the chunk-invariance property.
    #[test]
    fn fast_profile_scoring_is_deterministic_across_threads_and_shards() {
        let (x, y) = toy_problem(22, 3, 44);
        let (yn, _, _) = normalize_y(&y);
        let params = GpParams::new(3);
        let mut gp = NativeGp;
        let fit = gp.fit(&x, &yn, &params).unwrap();
        let mut rng = Pcg64::new(17);
        let xc = Matrix::from_fn(101, 3, |_, _| rng.next_f64()); // odd m: ragged chunks
        let exact = acquire_columns_profile(&x, &fit, &xc, &params, KernelProfile::Exact).unwrap();
        let fast = acquire_columns_profile(&x, &fit, &xc, &params, KernelProfile::Fast).unwrap();
        // (1) tolerance-equal to Exact.
        for c in 0..xc.rows() {
            for (name, a, b) in [
                ("ucb", exact.ucb[c], fast.ucb[c]),
                ("mean", exact.mean[c], fast.mean[c]),
                ("var", exact.var[c], fast.var[c]),
            ] {
                let tol = 1e-10 * a.abs().max(1.0);
                assert!((a - b).abs() <= tol, "{name}[{c}]: exact {a} vs fast {b}");
            }
        }
        // (2) run-to-run determinism.
        let again = acquire_columns_profile(&x, &fit, &xc, &params, KernelProfile::Fast).unwrap();
        assert_eq!(fast.ucb, again.ucb);
        assert_eq!(fast.w, again.w);
        // (3) thread/shard invariance, byte-for-byte against the 1-pass Fast result.
        for threads in [1usize, 2, 3, 8] {
            let par =
                acquire_parallel_profile(&x, &fit, &xc, &params, threads, KernelProfile::Fast)
                    .unwrap();
            assert_eq!(par.ucb, fast.ucb, "{threads} threads: fast ucb deviates");
            assert_eq!(par.var, fast.var, "{threads} threads: fast var deviates");
            assert_eq!(par.w, fast.w, "{threads} threads: fast w deviates");
        }
        let faulty = crate::scheduler::celery::CelerySimConfig {
            workers: 3,
            base_latency_ms: 0.05,
            straggler_prob: 0.3,
            straggler_factor: 1000.0,
            crash_prob: 0.3,
            result_timeout: std::time::Duration::from_millis(2),
        };
        let execs = [
            ShardExec::Serial,
            ShardExec::Threaded,
            ShardExec::CelerySim { config: faulty, seed: 5 },
        ];
        for exec in &execs {
            for shards in [1usize, 3, 7] {
                let out = acquire_sharded_profile(
                    &x,
                    &fit,
                    &xc,
                    &params,
                    shards,
                    2,
                    exec,
                    shards as u64,
                    KernelProfile::Fast,
                )
                .unwrap();
                let tag = format!("{exec:?} shards={shards}");
                assert_eq!(out.ucb, fast.ucb, "{tag}: fast ucb deviates");
                assert_eq!(out.var, fast.var, "{tag}: fast var deviates");
                assert_eq!(out.w, fast.w, "{tag}: fast w deviates");
            }
        }
    }

    #[test]
    fn kernel_profile_string_roundtrip() {
        for p in [KernelProfile::Exact, KernelProfile::Fast] {
            assert_eq!(KernelProfile::from_str(p.as_str()), Some(p));
        }
        assert_eq!(KernelProfile::from_str("simd"), None);
        assert_eq!(KernelProfile::default(), KernelProfile::Exact);
    }

    #[test]
    fn normalize_y_moments_and_constant_input() {
        let (yn, m, s) = normalize_y(&[1.0, 2.0, 3.0, 4.0]);
        assert!((m - 2.5).abs() < 1e-12);
        assert!(s > 0.0);
        assert!(crate::util::stats::mean(&yn).abs() < 1e-12);
        let (yc, _, sc) = normalize_y(&[5.0, 5.0, 5.0]);
        assert_eq!(sc, 1.0);
        assert!(yc.iter().all(|v| v.abs() < 1e-12));
    }
}
