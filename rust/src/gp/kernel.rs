//! Native ARD-RBF kernel — the Rust twin of the L1 Pallas kernel
//! (`python/compile/kernels/rbf.py`), same math, used by the native backend
//! and by the Rust-side GP-BUCB updates.

use crate::linalg::Matrix;

/// k(a, b) = exp(-0.5 * sum_d ((a_d - b_d) * inv_ls_d)^2) for one pair.
#[inline]
pub fn rbf_pair(a: &[f64], b: &[f64], inv_ls: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut sq = 0.0;
    for d in 0..a.len() {
        let il = if d < inv_ls.len() { inv_ls[d] } else { 0.0 };
        let diff = (a[d] - b[d]) * il;
        sq += diff * diff;
    }
    (-0.5 * sq).exp()
}

/// Full (n x m) correlation matrix between row sets.
pub fn rbf_kernel(x: &Matrix, z: &Matrix, inv_ls: &[f64]) -> Matrix {
    assert_eq!(x.cols(), z.cols(), "feature dims differ");
    Matrix::from_fn(x.rows(), z.rows(), |i, j| rbf_pair(x.row(i), z.row(j), inv_ls))
}

/// Kernel vector k(X, z) for one probe point z.
pub fn rbf_vec(x: &Matrix, z: &[f64], inv_ls: &[f64]) -> Vec<f64> {
    (0..x.rows()).map(|i| rbf_pair(x.row(i), z, inv_ls)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn identity_at_zero_distance() {
        let a = [0.3, 0.7, 0.1];
        assert!((rbf_pair(&a, &a, &[2.0, 2.0, 2.0]) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn known_value() {
        // distance^2 = (1*2)^2 = 4 -> exp(-2)
        let k = rbf_pair(&[0.0], &[1.0], &[2.0]);
        assert!((k - (-2.0f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn matrix_is_symmetric_psd_diag_one() {
        check("rbf gram sanity", 32, |g| {
            let n = g.usize_range(1, 12);
            let d = g.usize_range(1, 6);
            let x = Matrix::from_fn(n, d, |_, _| g.f64_range(0.0, 1.0));
            let inv = vec![3.0; d];
            let k = rbf_kernel(&x, &x, &inv);
            for i in 0..n {
                if (k[(i, i)] - 1.0).abs() > 1e-12 {
                    return Err(format!("diag {i}: {}", k[(i, i)]));
                }
                for j in 0..n {
                    if (k[(i, j)] - k[(j, i)]).abs() > 1e-12 {
                        return Err("asymmetric".into());
                    }
                    if !(0.0..=1.0 + 1e-12).contains(&k[(i, j)]) {
                        return Err(format!("out of range: {}", k[(i, j)]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn extra_dims_beyond_inv_ls_are_ignored() {
        // Padding contract: dims without an inverse lengthscale contribute 0.
        let a = [0.5, 999.0];
        let b = [0.5, -999.0];
        assert!((rbf_pair(&a, &b, &[1.0]) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn vec_matches_matrix_row() {
        let x = Matrix::from_fn(5, 3, |i, j| (i * 3 + j) as f64 * 0.1);
        let z = [0.2, 0.4, 0.6];
        let inv = [1.0, 2.0, 0.5];
        let v = rbf_vec(&x, &z, &inv);
        let zm = Matrix::from_vec(1, 3, z.to_vec());
        let km = rbf_kernel(&x, &zm, &inv);
        for i in 0..5 {
            assert!((v[i] - km[(i, 0)]).abs() < 1e-15);
        }
    }
}
