//! Native ARD-RBF kernel — the Rust twin of the L1 Pallas kernel
//! (`python/compile/kernels/rbf.py`), same math, used by the native backend
//! and by the Rust-side GP-BUCB updates.
//!
//! The hot path is **GEMM-based**: instead of one bounds-checked scalar
//! closure per matrix entry, [`rbf_kernel`] expands the squared distance
//! ‖a − b‖² = ‖a‖² + ‖b‖² − 2·a·b, computes the cross-product term with the
//! blocked [`Matrix::matmul_transb`], and finishes with one elementwise
//! `exp` pass. [`rbf_pair`] survives as the scalar test oracle (the
//! property tests pin the GEMM path to it within 1e-12).
//!
//! **Bit-exactness contract.** For *isotropic* inverse lengthscales (the
//! only shape `BayesianCore` ever produces), every call site derives a
//! Gram entry through the same two steps: an unscaled pairwise squared
//! distance ([`sq_dists`] for full matrices, [`sq_dist_from_parts`] +
//! [`linalg::dot`] for single rows — `matmul_transb` guarantees the two
//! agree bitwise) and then [`rbf_from_sq_dist`]. A squared-distance matrix
//! computed once is therefore a pure *precomputation*: feeding a cached D²
//! into a fit yields factors bit-identical to a fit that rebuilds it —
//! which is what lets the LML lengthscale grid share one D² across all
//! grid points without perturbing the incremental-Cholesky and recovery
//! bit-identity contracts.

use crate::linalg::{dot, dot_fast, Matrix};

/// k(a, b) = exp(-0.5 * sum_d ((a_d - b_d) * inv_ls_d)^2) for one pair.
///
/// Scalar reference implementation — the oracle the GEMM path is
/// property-tested against; no longer called on the hot path.
#[inline]
pub fn rbf_pair(a: &[f64], b: &[f64], inv_ls: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut sq = 0.0;
    for d in 0..a.len() {
        let il = if d < inv_ls.len() { inv_ls[d] } else { 0.0 };
        let diff = (a[d] - b[d]) * il;
        sq += diff * diff;
    }
    (-0.5 * sq).exp()
}

/// `Some(il)` iff one inverse lengthscale `il` covers all `dims` feature
/// dimensions (what `GpParams::new` / `with_lengthscale` always produce).
/// Anisotropic or padded (shorter-than-`dims`) vectors return `None` and
/// take the scaled-rows GEMM path instead.
pub fn iso_inv_ls(inv_ls: &[f64], dims: usize) -> Option<f64> {
    if dims == 0 {
        return Some(1.0);
    }
    if inv_ls.len() < dims {
        return None;
    }
    let il = inv_ls[0];
    inv_ls[..dims].iter().all(|&v| v == il).then_some(il)
}

/// Row squared norms, each computed with the sequential [`dot`] reduction
/// (the same order `matmul_transb` uses per element).
pub fn row_sq_norms(x: &Matrix) -> Vec<f64> {
    (0..x.rows()).map(|i| dot(x.row(i), x.row(i))).collect()
}

/// One squared distance from precomputed parts: `(‖a‖² + ‖b‖² − 2·a·b)`,
/// clamped at 0 (the expansion can go a few ulps negative). Single-row
/// call sites (the incremental Cholesky append) use this with [`dot`] and
/// get values bit-identical to the full [`sq_dists`] matrix.
#[inline]
pub fn sq_dist_from_parts(na: f64, nb: f64, ab: f64) -> f64 {
    (na + nb - 2.0 * ab).max(0.0)
}

/// Pairwise squared distances D²(i,j) = ‖x_i − z_j‖² (n x m), via the
/// blocked GEMM expansion.
pub fn sq_dists(x: &Matrix, z: &Matrix) -> Matrix {
    assert_eq!(x.cols(), z.cols(), "feature dims differ");
    let mut g = x.matmul_transb(z);
    let nx = row_sq_norms(x);
    let nz = row_sq_norms(z);
    let m = z.rows();
    for i in 0..x.rows() {
        let row = &mut g.data_mut()[i * m..(i + 1) * m];
        for (j, v) in row.iter_mut().enumerate() {
            *v = sq_dist_from_parts(nx[i], nz[j], *v);
        }
    }
    g
}

/// [`row_sq_norms`] on the `Fast` profile's chunked reduction
/// ([`dot_fast`]) — used wherever the fast D² pipeline derives norms.
pub fn row_sq_norms_fast(x: &Matrix) -> Vec<f64> {
    (0..x.rows()).map(|i| dot_fast(x.row(i), x.row(i))).collect()
}

/// [`sq_dists`] on the `Fast` kernel profile: the cross-product GEMM and
/// the row norms both use the fixed 4-lane chunked reduction. Every
/// element still depends only on its own two rows, so the matrix is
/// deterministic and invariant under candidate chunking; it is within
/// rounding of [`sq_dists`], not bit-equal.
pub fn sq_dists_fast(x: &Matrix, z: &Matrix) -> Matrix {
    assert_eq!(x.cols(), z.cols(), "feature dims differ");
    let mut g = x.matmul_transb_fast(z);
    let nx = row_sq_norms_fast(x);
    let nz = row_sq_norms_fast(z);
    let m = z.rows();
    for i in 0..x.rows() {
        let row = &mut g.data_mut()[i * m..(i + 1) * m];
        for (j, v) in row.iter_mut().enumerate() {
            *v = sq_dist_from_parts(nx[i], nz[j], *v);
        }
    }
    g
}

/// Isotropic RBF value from an *unscaled* squared distance:
/// `exp(−0.5 · il² · D²)`. The single shared expression every isotropic
/// call site uses (bit-exactness contract, module docs).
#[inline]
pub fn rbf_from_sq_dist(d2: f64, il: f64) -> f64 {
    (-0.5 * (il * il) * d2).exp()
}

/// Anisotropic RBF value from a squared distance already computed over
/// `inv_ls`-scaled rows.
#[inline]
pub fn rbf_from_scaled_sq_dist(d2: f64) -> f64 {
    (-0.5 * d2).exp()
}

/// Rows scaled per-dimension by `inv_ls`, honoring the padding contract:
/// dimensions beyond `inv_ls.len()` scale to 0 (they contribute nothing,
/// exactly like [`rbf_pair`]).
pub fn scale_rows(x: &Matrix, inv_ls: &[f64]) -> Matrix {
    Matrix::from_fn(x.rows(), x.cols(), |i, j| {
        x[(i, j)] * inv_ls.get(j).copied().unwrap_or(0.0)
    })
}

/// The single elementwise D² → correlation pass every isotropic call site
/// shares (in place; see the bit-exactness contract in the module docs).
fn map_sq_dists_iso(mut d2: Matrix, il: f64) -> Matrix {
    for v in d2.data_mut() {
        *v = rbf_from_sq_dist(*v, il);
    }
    d2
}

/// The `Fast` profile's exp pass: the `−0.5·il²` coefficient is hoisted
/// and the loop unrolled 4-wide so the multiply feeding each `exp` can be
/// packed. Given the same D² element this produces the same bits as
/// [`rbf_from_sq_dist`] (`c·d2` with `c = −0.5·il²` is the identical
/// floating-point expression) — the Fast/Exact divergence in a Gram matrix
/// comes entirely from the chunked D², never from this pass.
fn map_sq_dists_iso_fast(mut d2: Matrix, il: f64) -> Matrix {
    let c = -0.5 * (il * il);
    let data = d2.data_mut();
    let mut k = 0;
    while k + 4 <= data.len() {
        let (e0, e1, e2, e3) =
            (c * data[k], c * data[k + 1], c * data[k + 2], c * data[k + 3]);
        data[k] = e0.exp();
        data[k + 1] = e1.exp();
        data[k + 2] = e2.exp();
        data[k + 3] = e3.exp();
        k += 4;
    }
    while k < data.len() {
        data[k] = (c * data[k]).exp();
        k += 1;
    }
    d2
}

/// Map a precomputed unscaled squared-distance matrix to an isotropic RBF
/// correlation matrix — the elementwise pass the shared-distance LML grid
/// amortizes its kernel builds down to.
pub fn rbf_kernel_from_sq_dists(d2: &Matrix, il: f64) -> Matrix {
    map_sq_dists_iso(d2.clone(), il)
}

/// [`rbf_kernel_from_sq_dists`] through the `Fast` exp pass.
pub fn rbf_kernel_from_sq_dists_fast(d2: &Matrix, il: f64) -> Matrix {
    map_sq_dists_iso_fast(d2.clone(), il)
}

/// Full (n x m) correlation matrix between row sets — GEMM path.
pub fn rbf_kernel(x: &Matrix, z: &Matrix, inv_ls: &[f64]) -> Matrix {
    assert_eq!(x.cols(), z.cols(), "feature dims differ");
    if let Some(il) = iso_inv_ls(inv_ls, x.cols()) {
        map_sq_dists_iso(sq_dists(x, z), il)
    } else {
        let xs = scale_rows(x, inv_ls);
        let zs = scale_rows(z, inv_ls);
        let mut k = sq_dists(&xs, &zs);
        for v in k.data_mut() {
            *v = rbf_from_scaled_sq_dist(*v);
        }
        k
    }
}

/// [`rbf_kernel`] on the `Fast` kernel profile: chunked-GEMM D² and the
/// unrolled exp pass on the isotropic branch; the anisotropic fallback
/// scales rows then runs the same fast D² + exp pipeline. Property-tested
/// against [`rbf_pair`] at ≤1e-10 relative tolerance.
pub fn rbf_kernel_fast(x: &Matrix, z: &Matrix, inv_ls: &[f64]) -> Matrix {
    assert_eq!(x.cols(), z.cols(), "feature dims differ");
    if let Some(il) = iso_inv_ls(inv_ls, x.cols()) {
        map_sq_dists_iso_fast(sq_dists_fast(x, z), il)
    } else {
        let xs = scale_rows(x, inv_ls);
        let zs = scale_rows(z, inv_ls);
        map_sq_dists_iso_fast(sq_dists_fast(&xs, &zs), 1.0)
    }
}

/// Kernel vector k(X, z) for one probe point z — the 1-column GEMM path
/// (bit-identical to the corresponding [`rbf_kernel`] column).
pub fn rbf_vec(x: &Matrix, z: &[f64], inv_ls: &[f64]) -> Vec<f64> {
    assert_eq!(x.cols(), z.len(), "feature dims differ");
    let zm = Matrix::from_vec(1, z.len(), z.to_vec());
    let k = rbf_kernel(x, &zm, inv_ls);
    k.data().to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn identity_at_zero_distance() {
        let a = [0.3, 0.7, 0.1];
        assert!((rbf_pair(&a, &a, &[2.0, 2.0, 2.0]) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn known_value() {
        // distance^2 = (1*2)^2 = 4 -> exp(-2)
        let k = rbf_pair(&[0.0], &[1.0], &[2.0]);
        assert!((k - (-2.0f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn matrix_is_symmetric_psd_diag_one() {
        check("rbf gram sanity", 32, |g| {
            let n = g.usize_range(1, 12);
            let d = g.usize_range(1, 6);
            let x = Matrix::from_fn(n, d, |_, _| g.f64_range(0.0, 1.0));
            let inv = vec![3.0; d];
            let k = rbf_kernel(&x, &x, &inv);
            for i in 0..n {
                if (k[(i, i)] - 1.0).abs() > 1e-12 {
                    return Err(format!("diag {i}: {}", k[(i, i)]));
                }
                for j in 0..n {
                    if (k[(i, j)] - k[(j, i)]).abs() > 1e-12 {
                        return Err("asymmetric".into());
                    }
                    if !(0.0..=1.0 + 1e-12).contains(&k[(i, j)]) {
                        return Err(format!("out of range: {}", k[(i, j)]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn extra_dims_beyond_inv_ls_are_ignored() {
        // Padding contract: dims without an inverse lengthscale contribute 0.
        let a = [0.5, 999.0];
        let b = [0.5, -999.0];
        assert!((rbf_pair(&a, &b, &[1.0]) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn vec_matches_matrix_row() {
        let x = Matrix::from_fn(5, 3, |i, j| (i * 3 + j) as f64 * 0.1);
        let z = [0.2, 0.4, 0.6];
        let inv = [1.0, 2.0, 0.5];
        let v = rbf_vec(&x, &z, &inv);
        let zm = Matrix::from_vec(1, 3, z.to_vec());
        let km = rbf_kernel(&x, &zm, &inv);
        for i in 0..5 {
            assert!((v[i] - km[(i, 0)]).abs() < 1e-15);
        }
    }

    /// The tentpole contract: the GEMM path must match the scalar oracle
    /// within 1e-12 over random shapes and lengthscale vectors — isotropic
    /// (the fast unscaled-D² branch), anisotropic (the scaled-rows branch),
    /// and padded (`inv_ls` shorter than the feature dim).
    #[test]
    fn gemm_matches_rbf_pair_oracle_property() {
        check("gemm rbf == rbf_pair oracle", 64, |g| {
            let n = g.usize_range(1, 14);
            let m = g.usize_range(1, 14);
            let d = g.usize_range(1, 8);
            let x = Matrix::from_fn(n, d, |_, _| g.f64_range(-1.0, 2.0));
            let z = Matrix::from_fn(m, d, |_, _| g.f64_range(-1.0, 2.0));
            let inv_ls: Vec<f64> = match g.usize_range(0, 3) {
                0 => vec![g.f64_range(0.2, 6.0); d], // isotropic
                1 => (0..d).map(|_| g.f64_range(0.2, 6.0)).collect(), // anisotropic
                _ => {
                    // padded: shorter than d (remaining dims must be ignored)
                    let keep = g.usize_range(0, d);
                    (0..keep).map(|_| g.f64_range(0.2, 6.0)).collect()
                }
            };
            let k = rbf_kernel(&x, &z, &inv_ls);
            for i in 0..n {
                for j in 0..m {
                    let want = rbf_pair(x.row(i), z.row(j), &inv_ls);
                    if (k[(i, j)] - want).abs() > 1e-12 {
                        return Err(format!(
                            "({i},{j}) inv_ls len {}: gemm {} vs oracle {}",
                            inv_ls.len(),
                            k[(i, j)],
                            want
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// The Fast-profile contract: the chunked-GEMM + unrolled-exp path
    /// must match the scalar [`rbf_pair`] oracle within 1e-10 relative
    /// tolerance over the same shape/lengthscale space the Exact test
    /// covers, and must be bitwise run-to-run deterministic.
    #[test]
    fn fast_profile_rbf_matches_rbf_pair_oracle() {
        check("fast rbf ~= rbf_pair oracle", 64, |g| {
            let n = g.usize_range(1, 14);
            let m = g.usize_range(1, 14);
            let d = g.usize_range(1, 8);
            let x = Matrix::from_fn(n, d, |_, _| g.f64_range(-1.0, 2.0));
            let z = Matrix::from_fn(m, d, |_, _| g.f64_range(-1.0, 2.0));
            let inv_ls: Vec<f64> = match g.usize_range(0, 3) {
                0 => vec![g.f64_range(0.2, 6.0); d], // isotropic
                1 => (0..d).map(|_| g.f64_range(0.2, 6.0)).collect(), // anisotropic
                _ => {
                    let keep = g.usize_range(0, d);
                    (0..keep).map(|_| g.f64_range(0.2, 6.0)).collect()
                }
            };
            let k = rbf_kernel_fast(&x, &z, &inv_ls);
            for i in 0..n {
                for j in 0..m {
                    let want = rbf_pair(x.row(i), z.row(j), &inv_ls);
                    if (k[(i, j)] - want).abs() > 1e-10 * want.abs().max(1.0) {
                        return Err(format!(
                            "({i},{j}) inv_ls len {}: fast {} vs oracle {}",
                            inv_ls.len(),
                            k[(i, j)],
                            want
                        ));
                    }
                }
            }
            // Determinism: a second evaluation reproduces every bit.
            let again = rbf_kernel_fast(&x, &z, &inv_ls);
            if k != again {
                return Err("fast rbf not run-to-run deterministic".into());
            }
            Ok(())
        });
    }

    /// The Fast exp pass is bit-identical to the Exact one given the same
    /// D² — Fast/Exact divergence comes only from the chunked reduction.
    #[test]
    fn fast_profile_exp_pass_matches_exact_given_same_dists() {
        check("fast exp pass == exact exp pass", 32, |g| {
            let n = g.usize_range(1, 12);
            let il = g.f64_range(0.3, 5.0);
            let x = Matrix::from_fn(n, g.usize_range(1, 6), |_, _| g.f64_range(0.0, 1.0));
            let d2 = sq_dists(&x, &x);
            let exact = rbf_kernel_from_sq_dists(&d2, il);
            let fast = rbf_kernel_from_sq_dists_fast(&d2, il);
            for (a, b) in exact.data().iter().zip(fast.data()) {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("exp pass diverges: {a} vs {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gemm_honors_padding_contract() {
        // Two rows equal on the covered dim, wildly different beyond it:
        // correlation must be exactly full.
        let x = Matrix::from_vec(1, 2, vec![0.5, 999.0]);
        let z = Matrix::from_vec(1, 2, vec![0.5, -999.0]);
        let k = rbf_kernel(&x, &z, &[1.0]);
        assert!((k[(0, 0)] - 1.0).abs() < 1e-15);
    }

    #[test]
    fn iso_detection() {
        assert_eq!(iso_inv_ls(&[2.0, 2.0, 2.0], 3), Some(2.0));
        assert_eq!(iso_inv_ls(&[2.0, 2.0, 2.0, 9.9], 3), Some(2.0)); // extras ignored
        assert_eq!(iso_inv_ls(&[2.0, 3.0], 2), None);
        assert_eq!(iso_inv_ls(&[2.0], 2), None); // padded: not isotropic over all dims
        assert_eq!(iso_inv_ls(&[], 0), Some(1.0));
    }

    /// The shared-distance contract: a Gram derived from a precomputed D²
    /// is bit-identical to the one `rbf_kernel` builds itself, and single
    /// entries re-derived via `dot` + `sq_dist_from_parts` match the
    /// matrix bitwise (the incremental-append equivalence).
    #[test]
    fn shared_sq_dists_reproduce_kernel_bitwise() {
        check("shared D² == inline D²", 32, |g| {
            let n = g.usize_range(1, 12);
            let d = g.usize_range(1, 6);
            let il = g.f64_range(0.3, 5.0);
            let x = Matrix::from_fn(n, d, |_, _| g.f64_range(0.0, 1.0));
            let inv = vec![il; d];
            let k_inline = rbf_kernel(&x, &x, &inv);
            let d2 = sq_dists(&x, &x);
            let k_shared = rbf_kernel_from_sq_dists(&d2, il);
            if k_inline != k_shared {
                return Err("shared-D² Gram deviates from inline".into());
            }
            let norms = row_sq_norms(&x);
            for i in 0..n {
                for j in 0..n {
                    let e = sq_dist_from_parts(norms[i], norms[j], dot(x.row(i), x.row(j)));
                    if e.to_bits() != d2[(i, j)].to_bits() {
                        return Err(format!("({i},{j}): row-derived D² deviates"));
                    }
                }
            }
            Ok(())
        });
    }
}
