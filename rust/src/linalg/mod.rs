//! Dense linear algebra substrate (no external crates): row-major [`Matrix`]
//! with the factorizations the native GP and the GP-BUCB rank-1
//! hallucination updates need. Mirrors `python/compile/linalg.py` so the
//! native backend is a bit-faithful oracle for the PJRT artifacts.

mod matrix;

pub use matrix::Matrix;

/// Cholesky factorization K = L L^T for SPD K; returns lower-triangular L.
///
/// Returns `None` if a pivot is non-positive beyond the 1e-12 clamp used by
/// the HLO twin (we clamp exactly like compile/linalg.py so the two backends
/// agree on degenerate inputs).
pub fn cholesky(k: &Matrix) -> Matrix {
    let n = k.rows();
    assert_eq!(n, k.cols(), "cholesky needs a square matrix");
    let mut l = Matrix::zeros(n, n);
    for j in 0..n {
        // v = K[:, j] - L[:, :j] @ L[j, :j]
        for i in j..n {
            let mut s = k[(i, j)];
            for p in 0..j {
                s -= l[(i, p)] * l[(j, p)];
            }
            if i == j {
                l[(j, j)] = s.max(1e-12).sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    l
}

/// Solve L x = b (forward substitution), b and x length n.
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for j in 0..i {
            s -= l[(i, j)] * x[j];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve L^T x = b (back substitution).
pub fn solve_lower_t(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for j in (i + 1)..n {
            s -= l[(j, i)] * x[j];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve K x = b via Cholesky (K SPD).
pub fn solve_spd(l: &Matrix, b: &[f64]) -> Vec<f64> {
    solve_lower_t(l, &solve_lower(l, b))
}

/// K^{-1} from the Cholesky factor.
pub fn spd_inverse(l: &Matrix) -> Matrix {
    let n = l.rows();
    let mut inv = Matrix::zeros(n, n);
    for j in 0..n {
        let mut e = vec![0.0; n];
        e[j] = 1.0;
        let col = solve_spd(l, &e);
        for i in 0..n {
            inv[(i, j)] = col[i];
        }
    }
    inv
}

/// log det K = 2 Σ log L_ii.
pub fn logdet_from_cholesky(l: &Matrix) -> f64 {
    (0..l.rows()).map(|i| 2.0 * l[(i, i)].max(1e-300).ln()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    fn spd_from_gen(g: &mut Gen, n: usize) -> Matrix {
        Matrix::from_vec(n, n, g.spd_matrix(n))
    }

    #[test]
    fn cholesky_reconstructs_property() {
        check("cholesky L L^T == K", 64, |g| {
            let n = g.usize_range(1, 17);
            let k = spd_from_gen(g, n);
            let l = cholesky(&k);
            let kk = l.matmul_transb(&l);
            for i in 0..n {
                for j in 0..n {
                    if (kk[(i, j)] - k[(i, j)]).abs() > 1e-6 * (n as f64) {
                        return Err(format!("({i},{j}): {} vs {}", kk[(i, j)], k[(i, j)]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn solve_spd_property() {
        check("K @ solve(K, b) == b", 64, |g| {
            let n = g.usize_range(1, 17);
            let k = spd_from_gen(g, n);
            let b = g.vec_f64(n, -5.0, 5.0);
            let l = cholesky(&k);
            let x = solve_spd(&l, &b);
            let kb = k.matvec(&x);
            for i in 0..n {
                if (kb[i] - b[i]).abs() > 1e-6 * n as f64 {
                    return Err(format!("row {i}: {} vs {}", kb[i], b[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn inverse_property() {
        check("K K^-1 == I", 32, |g| {
            let n = g.usize_range(1, 13);
            let k = spd_from_gen(g, n);
            let inv = spd_inverse(&cholesky(&k));
            let prod = k.matmul(&inv);
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    if (prod[(i, j)] - want).abs() > 1e-6 * n as f64 {
                        return Err(format!("({i},{j}) = {}", prod[(i, j)]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn logdet_matches_diag_product() {
        let k = Matrix::from_vec(2, 2, vec![4.0, 0.0, 0.0, 9.0]);
        let l = cholesky(&k);
        assert!((logdet_from_cholesky(&l) - (36.0f64).ln()).abs() < 1e-10);
    }

    #[test]
    fn degenerate_input_stays_finite() {
        let k = Matrix::from_vec(3, 3, vec![1.0; 9]); // rank-1
        let l = cholesky(&k);
        assert!(l.data().iter().all(|v| v.is_finite()));
    }
}
