//! Dense linear algebra substrate (no external crates): row-major [`Matrix`]
//! with the factorizations the native GP and the GP-BUCB rank-1
//! hallucination updates need. Mirrors `python/compile/linalg.py` so the
//! native backend is a bit-faithful oracle for the PJRT artifacts.
//!
//! The posterior hot path is *inverse-free*: fits keep the lower Cholesky
//! factor `L` and grow it one observation at a time with
//! [`chol_append_row`] (O(n²) per append); acquisition solves against `L`
//! with the matrix-RHS substitutions ([`solve_lower_mat`],
//! [`solve_lower_t_mat`]). [`spd_inverse`] survives only as a test oracle.

mod matrix;

pub use matrix::{dot, dot_fast, Matrix};

/// Pivot clamp shared by [`cholesky`] and [`chol_append_row`] (and mirrored
/// by `python/compile/linalg.py`): a pivot below this is treated as a
/// rank-deficient direction.
pub const PIVOT_CLAMP: f64 = 1e-12;

/// Cholesky factorization K = L L^T for SPD K; returns lower-triangular L.
///
/// Degenerate inputs never fail: a pivot below [`PIVOT_CLAMP`] is clamped
/// to it (diagonal entry `sqrt(PIVOT_CLAMP)`) and the rest of that column
/// is left zero — the factor carries no information along a rank-deficient
/// direction instead of dividing a rounding-noise residual by ~1e-6 and
/// injecting huge off-diagonal entries. Clamps match compile/linalg.py so
/// the two backends agree on degenerate inputs.
pub fn cholesky(k: &Matrix) -> Matrix {
    let n = k.rows();
    assert_eq!(n, k.cols(), "cholesky needs a square matrix");
    let mut l = Matrix::zeros(n, n);
    for j in 0..n {
        let mut s = k[(j, j)];
        for p in 0..j {
            s -= l[(j, p)] * l[(j, p)];
        }
        if s < PIVOT_CLAMP {
            l[(j, j)] = PIVOT_CLAMP.sqrt();
            continue; // column stays zero: bounded output on rank deficiency
        }
        l[(j, j)] = s.sqrt();
        for i in (j + 1)..n {
            let mut s = k[(i, j)];
            for p in 0..j {
                s -= l[(i, p)] * l[(j, p)];
            }
            l[(i, j)] = s / l[(j, j)];
        }
    }
    l
}

/// Grow a Cholesky factor by one observation in O(n²): given L (n x n) with
/// K = L L^T and the bordered row `k_new = [k(x_new, x_0..n-1) ,
/// k(x_new, x_new)]` (length n+1, diagonal entry last), return the
/// (n+1) x (n+1) factor of the bordered matrix.
///
/// Performs exactly the arithmetic a from-scratch [`cholesky`] of the
/// bordered matrix would perform for the new row — same operations in the
/// same order, including the clamped-pivot handling (a previously clamped
/// pivot contributes a zero coefficient) — so incremental and from-scratch
/// factors of the same data are bit-identical.
///
/// Clamped pivots are recognized by the sentinel value
/// `PIVOT_CLAMP.sqrt()`; a *legitimate* pivot could collide with it only
/// if its Schur complement lands in the ~1-ulp window around `PIVOT_CLAMP`
/// whose square root rounds to the sentinel — a measure-zero edge whose
/// worst case is one zeroed (instead of ~`residual/1e-6`-sized, i.e.
/// already noise-dominated) coefficient.
pub fn chol_append_row(l: &Matrix, k_new: &[f64]) -> Matrix {
    let n = l.rows();
    assert_eq!(n, l.cols(), "factor must be square");
    assert_eq!(k_new.len(), n + 1, "bordered row needs n+1 entries");
    let clamped = PIVOT_CLAMP.sqrt();
    let mut out = Matrix::zeros(n + 1, n + 1);
    for i in 0..n {
        for j in 0..=i {
            out[(i, j)] = l[(i, j)];
        }
    }
    // Forward substitution for the new row's coefficients c = L^{-1} k_new,
    // skipping clamped pivots exactly like `cholesky` zeroes their columns.
    let mut c = vec![0.0; n];
    for i in 0..n {
        if l[(i, i)] == clamped {
            continue; // rank-deficient direction: coefficient stays zero
        }
        let mut s = k_new[i];
        for p in 0..i {
            s -= c[p] * l[(i, p)];
        }
        c[i] = s / l[(i, i)];
    }
    let mut s = k_new[n];
    for p in 0..n {
        s -= c[p] * c[p];
    }
    for (j, &cj) in c.iter().enumerate() {
        out[(n, j)] = cj;
    }
    out[(n, n)] = if s < PIVOT_CLAMP { clamped } else { s.sqrt() };
    out
}

/// Solve L x = b (forward substitution), b and x length n.
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for j in 0..i {
            s -= l[(i, j)] * x[j];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve L^T x = b (back substitution).
pub fn solve_lower_t(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for j in (i + 1)..n {
            s -= l[(j, i)] * x[j];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve L X = B for a matrix right-hand side (B is n x m). Row-major
/// friendly: each step streams whole rows, so the m candidate columns of a
/// cross-kernel are solved in one pass. The elimination loop is blocked
/// 2-wide across source rows (two axpy updates fused into one pass over
/// the destination row), halving the destination-row traffic. Blocking
/// changes per-element rounding vs the unblocked kernel (tolerance-tested)
/// but each column's operation sequence depends only on `l` and the row
/// index — never on `m` — so column results are invariant under candidate
/// chunking (the parallel-scoring determinism contract).
pub fn solve_lower_mat(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert_eq!(b.rows(), n, "solve_lower_mat shape mismatch");
    let m = b.cols();
    let mut x = b.clone();
    for i in 0..n {
        let (head, tail) = x.data_mut().split_at_mut(i * m);
        let xi = &mut tail[..m];
        let mut j = 0;
        while j + 2 <= i {
            let (l0, l1) = (l[(i, j)], l[(i, j + 1)]);
            if l0 != 0.0 || l1 != 0.0 {
                let xj0 = &head[j * m..(j + 1) * m];
                let xj1 = &head[(j + 1) * m..(j + 2) * m];
                for c in 0..m {
                    xi[c] -= l0 * xj0[c] + l1 * xj1[c];
                }
            }
            j += 2;
        }
        if j < i {
            let lij = l[(i, j)];
            if lij != 0.0 {
                let xj = &head[j * m..(j + 1) * m];
                for c in 0..m {
                    xi[c] -= lij * xj[c];
                }
            }
        }
        let lii = l[(i, i)];
        for v in xi {
            *v /= lii;
        }
    }
    x
}

/// Solve L^T X = B for a matrix right-hand side (B is n x m). Blocked
/// 2-wide like [`solve_lower_mat`], with the same column-chunking
/// invariance property.
pub fn solve_lower_t_mat(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert_eq!(b.rows(), n, "solve_lower_t_mat shape mismatch");
    let m = b.cols();
    let mut x = b.clone();
    for i in (0..n).rev() {
        // Rows j > i are read-only sources; row i is the destination.
        let (head, tail) = x.data_mut().split_at_mut((i + 1) * m);
        let xi = &mut head[i * m..];
        let mut j = i + 1;
        while j + 2 <= n {
            let (l0, l1) = (l[(j, i)], l[(j + 1, i)]);
            if l0 != 0.0 || l1 != 0.0 {
                let off = (j - i - 1) * m;
                let xj0 = &tail[off..off + m];
                let xj1 = &tail[off + m..off + 2 * m];
                for c in 0..m {
                    xi[c] -= l0 * xj0[c] + l1 * xj1[c];
                }
            }
            j += 2;
        }
        if j < n {
            let lji = l[(j, i)];
            if lji != 0.0 {
                let off = (j - i - 1) * m;
                let xj = &tail[off..off + m];
                for c in 0..m {
                    xi[c] -= lji * xj[c];
                }
            }
        }
        let lii = l[(i, i)];
        for v in xi {
            *v /= lii;
        }
    }
    x
}

/// [`solve_lower_mat`] with 4-wide source-row blocking — the `Fast` kernel
/// profile's forward substitution. Four axpy updates fuse into one pass
/// over the destination row (four independent products per element the
/// autovectorizer can pack), with a 2-wide then 1-wide remainder. The
/// block boundaries depend only on the row index `i` — never on `m`,
/// threads, or shards — so column results keep the chunking-invariance
/// property; output is within rounding of [`solve_lower_mat`]
/// (property-tested vs the vector solves at ≤1e-9), not bit-equal.
pub fn solve_lower_mat_fast(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert_eq!(b.rows(), n, "solve_lower_mat shape mismatch");
    let m = b.cols();
    let mut x = b.clone();
    for i in 0..n {
        let (head, tail) = x.data_mut().split_at_mut(i * m);
        let xi = &mut tail[..m];
        let mut j = 0;
        while j + 4 <= i {
            let (l0, l1, l2, l3) = (l[(i, j)], l[(i, j + 1)], l[(i, j + 2)], l[(i, j + 3)]);
            if l0 != 0.0 || l1 != 0.0 || l2 != 0.0 || l3 != 0.0 {
                let xj0 = &head[j * m..(j + 1) * m];
                let xj1 = &head[(j + 1) * m..(j + 2) * m];
                let xj2 = &head[(j + 2) * m..(j + 3) * m];
                let xj3 = &head[(j + 3) * m..(j + 4) * m];
                for c in 0..m {
                    xi[c] -= (l0 * xj0[c] + l1 * xj1[c]) + (l2 * xj2[c] + l3 * xj3[c]);
                }
            }
            j += 4;
        }
        if j + 2 <= i {
            let (l0, l1) = (l[(i, j)], l[(i, j + 1)]);
            if l0 != 0.0 || l1 != 0.0 {
                let xj0 = &head[j * m..(j + 1) * m];
                let xj1 = &head[(j + 1) * m..(j + 2) * m];
                for c in 0..m {
                    xi[c] -= l0 * xj0[c] + l1 * xj1[c];
                }
            }
            j += 2;
        }
        if j < i {
            let lij = l[(i, j)];
            if lij != 0.0 {
                let xj = &head[j * m..(j + 1) * m];
                for c in 0..m {
                    xi[c] -= lij * xj[c];
                }
            }
        }
        let lii = l[(i, i)];
        for v in xi {
            *v /= lii;
        }
    }
    x
}

/// [`solve_lower_t_mat`] with 4-wide source-row blocking — the `Fast`
/// kernel profile's back substitution. Same fixed block boundaries and
/// chunking-invariance property as [`solve_lower_mat_fast`].
pub fn solve_lower_t_mat_fast(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows();
    assert_eq!(b.rows(), n, "solve_lower_t_mat shape mismatch");
    let m = b.cols();
    let mut x = b.clone();
    for i in (0..n).rev() {
        // Rows j > i are read-only sources; row i is the destination.
        let (head, tail) = x.data_mut().split_at_mut((i + 1) * m);
        let xi = &mut head[i * m..];
        let mut j = i + 1;
        while j + 4 <= n {
            let (l0, l1, l2, l3) = (l[(j, i)], l[(j + 1, i)], l[(j + 2, i)], l[(j + 3, i)]);
            if l0 != 0.0 || l1 != 0.0 || l2 != 0.0 || l3 != 0.0 {
                let off = (j - i - 1) * m;
                let xj0 = &tail[off..off + m];
                let xj1 = &tail[off + m..off + 2 * m];
                let xj2 = &tail[off + 2 * m..off + 3 * m];
                let xj3 = &tail[off + 3 * m..off + 4 * m];
                for c in 0..m {
                    xi[c] -= (l0 * xj0[c] + l1 * xj1[c]) + (l2 * xj2[c] + l3 * xj3[c]);
                }
            }
            j += 4;
        }
        if j + 2 <= n {
            let (l0, l1) = (l[(j, i)], l[(j + 1, i)]);
            if l0 != 0.0 || l1 != 0.0 {
                let off = (j - i - 1) * m;
                let xj0 = &tail[off..off + m];
                let xj1 = &tail[off + m..off + 2 * m];
                for c in 0..m {
                    xi[c] -= l0 * xj0[c] + l1 * xj1[c];
                }
            }
            j += 2;
        }
        if j < n {
            let lji = l[(j, i)];
            if lji != 0.0 {
                let off = (j - i - 1) * m;
                let xj = &tail[off..off + m];
                for c in 0..m {
                    xi[c] -= lji * xj[c];
                }
            }
        }
        let lii = l[(i, i)];
        for v in xi {
            *v /= lii;
        }
    }
    x
}

/// Solve K x = b via Cholesky (K SPD).
pub fn solve_spd(l: &Matrix, b: &[f64]) -> Vec<f64> {
    solve_lower_t(l, &solve_lower(l, b))
}

/// Solve K X = B via Cholesky for a matrix right-hand side — the
/// `w = K^{-1} k_c` of acquisition, without materializing K^{-1}.
pub fn solve_spd_mat(l: &Matrix, b: &Matrix) -> Matrix {
    solve_lower_t_mat(l, &solve_lower_mat(l, b))
}

/// [`solve_spd_mat`] on the `Fast` kernel profile's 4-wide substitutions.
pub fn solve_spd_mat_fast(l: &Matrix, b: &Matrix) -> Matrix {
    solve_lower_t_mat_fast(l, &solve_lower_mat_fast(l, b))
}

/// K^{-1} from the Cholesky factor.
///
/// Test oracle only: the fit/acquire hot paths solve against `L` directly
/// ([`solve_spd`], [`solve_spd_mat`]) and never materialize an inverse.
pub fn spd_inverse(l: &Matrix) -> Matrix {
    let n = l.rows();
    let mut inv = Matrix::zeros(n, n);
    for j in 0..n {
        let mut e = vec![0.0; n];
        e[j] = 1.0;
        let col = solve_spd(l, &e);
        for i in 0..n {
            inv[(i, j)] = col[i];
        }
    }
    inv
}

/// log det K = 2 Σ log L_ii.
pub fn logdet_from_cholesky(l: &Matrix) -> f64 {
    (0..l.rows()).map(|i| 2.0 * l[(i, i)].max(1e-300).ln()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    fn spd_from_gen(g: &mut Gen, n: usize) -> Matrix {
        Matrix::from_vec(n, n, g.spd_matrix(n))
    }

    #[test]
    fn cholesky_reconstructs_property() {
        check("cholesky L L^T == K", 64, |g| {
            let n = g.usize_range(1, 17);
            let k = spd_from_gen(g, n);
            let l = cholesky(&k);
            let kk = l.matmul_transb(&l);
            for i in 0..n {
                for j in 0..n {
                    if (kk[(i, j)] - k[(i, j)]).abs() > 1e-6 * (n as f64) {
                        return Err(format!("({i},{j}): {} vs {}", kk[(i, j)], k[(i, j)]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn solve_spd_property() {
        check("K @ solve(K, b) == b", 64, |g| {
            let n = g.usize_range(1, 17);
            let k = spd_from_gen(g, n);
            let b = g.vec_f64(n, -5.0, 5.0);
            let l = cholesky(&k);
            let x = solve_spd(&l, &b);
            let kb = k.matvec(&x);
            for i in 0..n {
                if (kb[i] - b[i]).abs() > 1e-6 * n as f64 {
                    return Err(format!("row {i}: {} vs {}", kb[i], b[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn matrix_rhs_solves_match_vector_solves_property() {
        check("solve_*_mat == column-wise solve_*", 48, |g| {
            let n = g.usize_range(1, 14);
            let m = g.usize_range(1, 9);
            let k = spd_from_gen(g, n);
            let l = cholesky(&k);
            let b = Matrix::from_vec(n, m, g.vec_f64(n * m, -3.0, 3.0));
            let fwd = solve_lower_mat(&l, &b);
            let bwd = solve_lower_t_mat(&l, &b);
            for c in 0..m {
                let col: Vec<f64> = (0..n).map(|i| b[(i, c)]).collect();
                let fwd_col = solve_lower(&l, &col);
                let bwd_col = solve_lower_t(&l, &col);
                for i in 0..n {
                    if (fwd[(i, c)] - fwd_col[i]).abs() > 1e-9 {
                        return Err(format!("fwd ({i},{c})"));
                    }
                    if (bwd[(i, c)] - bwd_col[i]).abs() > 1e-9 {
                        return Err(format!("bwd ({i},{c})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn solve_spd_mat_property() {
        check("K @ solve_spd_mat(K, B) == B", 32, |g| {
            let n = g.usize_range(1, 13);
            let m = g.usize_range(1, 7);
            let k = spd_from_gen(g, n);
            let l = cholesky(&k);
            let b = Matrix::from_vec(n, m, g.vec_f64(n * m, -5.0, 5.0));
            let x = solve_spd_mat(&l, &b);
            let kb = k.matmul(&x);
            for i in 0..n {
                for c in 0..m {
                    if (kb[(i, c)] - b[(i, c)]).abs() > 1e-6 * n as f64 {
                        return Err(format!("({i},{c}): {} vs {}", kb[(i, c)], b[(i, c)]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn chol_append_row_matches_scratch_property() {
        // Grow a factor one bordered row at a time from a random split
        // point; the result must agree with a from-scratch factorization of
        // the full matrix far below the 1e-8 contract (the append performs
        // the identical arithmetic).
        check("incremental cholesky == from-scratch", 48, |g| {
            let n = g.usize_range(2, 18);
            let k = spd_from_gen(g, n);
            let n0 = g.usize_range(1, n);
            let k0 = Matrix::from_fn(n0, n0, |i, j| k[(i, j)]);
            let mut l = cholesky(&k0);
            for r in n0..n {
                let row: Vec<f64> = (0..=r).map(|j| k[(r, j)]).collect();
                l = chol_append_row(&l, &row);
            }
            let scratch = cholesky(&k);
            let dev = l.max_abs_diff(&scratch);
            if dev > 1e-8 {
                return Err(format!("n0={n0} n={n}: max deviation {dev}"));
            }
            Ok(())
        });
    }

    #[test]
    fn chol_append_row_handles_clamped_pivots() {
        // A duplicated observation clamps a pivot mid-factor; appends past
        // it must still match from-scratch (the clamped column contributes
        // zero coefficients on both paths).
        let n = 5;
        let base = Matrix::from_fn(n, n, |i, j| {
            // rows 2..4 are exact duplicates: pivots 3 and 4 clamp
            let (a, b) = (i.min(2), j.min(2));
            (-0.5 * ((a as f64 - b as f64) * 1.7).powi(2)).exp()
        });
        let k0 = Matrix::from_fn(4, 4, |i, j| base[(i, j)]);
        let l0 = cholesky(&k0);
        let row: Vec<f64> = (0..n).map(|j| base[(4, j)]).collect();
        let appended = chol_append_row(&l0, &row);
        let scratch = cholesky(&base);
        assert!(
            appended.max_abs_diff(&scratch) < 1e-10,
            "deviation {}",
            appended.max_abs_diff(&scratch)
        );
    }

    /// Fast-profile 4-wide substitutions vs two oracles: the sequential
    /// vector solves (≤1e-9, the same bound the 2-wide kernels are held
    /// to) and the `spd_inverse` reconstruction of K^{-1}B (≤1e-6·scale —
    /// the inverse oracle itself carries that much conditioning error, see
    /// `inverse_property`).
    #[test]
    fn fast_profile_solves_match_vector_and_inverse_oracles() {
        check("solve_*_mat_fast == oracles", 48, |g| {
            let n = g.usize_range(1, 14);
            let m = g.usize_range(1, 9);
            let k = spd_from_gen(g, n);
            let l = cholesky(&k);
            let b = Matrix::from_vec(n, m, g.vec_f64(n * m, -3.0, 3.0));
            let fwd = solve_lower_mat_fast(&l, &b);
            let bwd = solve_lower_t_mat_fast(&l, &b);
            for c in 0..m {
                let col: Vec<f64> = (0..n).map(|i| b[(i, c)]).collect();
                let fwd_col = solve_lower(&l, &col);
                let bwd_col = solve_lower_t(&l, &col);
                for i in 0..n {
                    if (fwd[(i, c)] - fwd_col[i]).abs() > 1e-9 {
                        return Err(format!("fwd ({i},{c})"));
                    }
                    if (bwd[(i, c)] - bwd_col[i]).abs() > 1e-9 {
                        return Err(format!("bwd ({i},{c})"));
                    }
                }
            }
            // Full K^{-1} B against the inverse oracle.
            let x = solve_spd_mat_fast(&l, &b);
            let want = spd_inverse(&l).matmul(&b);
            for i in 0..n {
                for c in 0..m {
                    let scale = want[(i, c)].abs().max(1.0);
                    if (x[(i, c)] - want[(i, c)]).abs() > 1e-6 * scale {
                        return Err(format!(
                            "spd ({i},{c}): {} vs {}",
                            x[(i, c)],
                            want[(i, c)]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// The fast solves' column results must not depend on which columns
    /// share the RHS matrix (the chunking-invariance half of the Fast
    /// determinism contract: shard folds slice candidate columns).
    #[test]
    fn fast_profile_solve_columns_invariant_under_rhs_chunking() {
        check("fast solve column == solo-column solve", 32, |g| {
            let n = g.usize_range(2, 12);
            let m = g.usize_range(2, 8);
            let k = spd_from_gen(g, n);
            let l = cholesky(&k);
            let b = Matrix::from_vec(n, m, g.vec_f64(n * m, -3.0, 3.0));
            let full = solve_spd_mat_fast(&l, &b);
            for c in 0..m {
                let solo = Matrix::from_fn(n, 1, |i, _| b[(i, c)]);
                let got = solve_spd_mat_fast(&l, &solo);
                for i in 0..n {
                    if full[(i, c)].to_bits() != got[(i, 0)].to_bits() {
                        return Err(format!("({i},{c}) differs when solved alone"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn inverse_property() {
        check("K K^-1 == I", 32, |g| {
            let n = g.usize_range(1, 13);
            let k = spd_from_gen(g, n);
            let inv = spd_inverse(&cholesky(&k));
            let prod = k.matmul(&inv);
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    if (prod[(i, j)] - want).abs() > 1e-6 * n as f64 {
                        return Err(format!("({i},{j}) = {}", prod[(i, j)]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn logdet_matches_diag_product() {
        let k = Matrix::from_vec(2, 2, vec![4.0, 0.0, 0.0, 9.0]);
        let l = cholesky(&k);
        assert!((logdet_from_cholesky(&l) - (36.0f64).ln()).abs() < 1e-10);
    }

    #[test]
    fn degenerate_input_stays_finite() {
        let k = Matrix::from_vec(3, 3, vec![1.0; 9]); // rank-1
        let l = cholesky(&k);
        assert!(l.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn rank_deficient_output_is_bounded_not_just_finite() {
        // Regression for the clamped-pivot column: without zeroing, the
        // residual column is divided by sqrt(1e-12) = 1e-6 and this input
        // produces entries ~5e5. The factor of any input must stay within
        // the Cauchy-Schwarz bound |L_ij| <= sqrt(max_i K_ii).
        let k = Matrix::from_vec(
            3,
            3,
            vec![1.0, 1.0, 0.0, 1.0, 1.0, 0.5, 0.0, 0.5, 1.0],
        );
        let l = cholesky(&k);
        let bound = 1.0 + 1e-9; // max diagonal of k is 1.0
        for v in l.data() {
            assert!(v.abs() <= bound, "entry {v} exceeds bound {bound}");
        }
    }
}
