//! Row-major dense matrix with the handful of ops the GP stack needs.
//!
//! The hot-path multiply kernel ([`Matrix::matmul_transb`]) is
//! register-blocked for throughput, under one hard constraint: **every
//! output element is bit-identical to [`dot`] of the two rows** (a single
//! sequential-k accumulation). The GEMM-based RBF kernel derives Gram entries from
//! these products, and the incremental Cholesky append re-derives single
//! rows via `dot` — the append/scratch bit-equality contract of
//! `gp::fit_posterior` holds only because blocking here never reorders a
//! per-element summation (we block across output columns/rows, never
//! across the k reduction).

use std::ops::{Index, IndexMut};

/// Sequential dot product — the canonical per-element reduction order for
/// the blocked multiply kernels (see the module docs; `matmul_transb`
/// output elements must equal this bitwise).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for k in 0..a.len() {
        s += a[k] * b[k];
    }
    s
}

/// Fixed 4-lane chunked dot product — the `Fast` kernel profile's
/// reduction. Four independent accumulators stride the k dimension (lane =
/// k mod 4, over the largest multiple-of-4 prefix; the remainder lands in
/// lane 0), combined as `(s0 + s1) + (s2 + s3)`. The lane assignment
/// depends only on k and the slice length — never on threads or shards —
/// so the result is run-to-run deterministic and identical wherever the
/// same two rows are reduced; it is just not bit-equal to the sequential
/// [`dot`]. The independent accumulators are what lets the autovectorizer
/// lift this to packed adds.
#[inline]
pub fn dot_fast(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    let mut k = 0;
    while k + 4 <= n {
        s0 += a[k] * b[k];
        s1 += a[k + 1] * b[k + 1];
        s2 += a[k + 2] * b[k + 2];
        s3 += a[k + 3] * b[k + 3];
        k += 4;
    }
    while k < n {
        s0 += a[k] * b[k];
        k += 1;
    }
    (s0 + s1) + (s2 + s3)
}

/// Row-major `rows x cols` matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Self { rows, cols, data }
    }

    /// Build from a row-generating closure.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// self @ other. (Test-oracle territory: every production GEMM in the
    /// propose hot path goes through [`matmul_transb`](Self::matmul_transb),
    /// which is the blocked, bit-contracted kernel — this one stays the
    /// simple i-k-j loop.)
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        // i-k-j loop order: streams through `other` rows, cache-friendly.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for j in 0..orow.len() {
                    out_row[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// self @ other^T (both row-major — pure dot products, fastest path).
    ///
    /// Register-blocked 4-wide across `other`'s rows: one pass over `arow`
    /// feeds four independent accumulators. Each accumulator still sums in
    /// sequential k order, so every output element is bit-identical to
    /// [`dot`] of the two rows — the contract the GEMM kernel path and the
    /// incremental Cholesky append both rely on (module docs).
    pub fn matmul_transb(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_transb shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.rows);
        let m = other.rows;
        for i in 0..self.rows {
            let arow = self.row(i);
            let out_row = &mut out.data[i * m..(i + 1) * m];
            let mut j = 0;
            while j + 4 <= m {
                let b0 = other.row(j);
                let b1 = other.row(j + 1);
                let b2 = other.row(j + 2);
                let b3 = other.row(j + 3);
                let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
                for k in 0..arow.len() {
                    let a = arow[k];
                    s0 += a * b0[k];
                    s1 += a * b1[k];
                    s2 += a * b2[k];
                    s3 += a * b3[k];
                }
                out_row[j] = s0;
                out_row[j + 1] = s1;
                out_row[j + 2] = s2;
                out_row[j + 3] = s3;
                j += 4;
            }
            while j < m {
                out_row[j] = dot(arow, other.row(j));
                j += 1;
            }
        }
        out
    }

    /// self @ other^T with the [`dot_fast`] chunked reduction — the `Fast`
    /// kernel profile's GEMM. Output elements equal `dot_fast` of the two
    /// rows exactly (same fixed chunking for every call site), so the Fast
    /// path stays deterministic and thread/shard-invariant; they are within
    /// rounding (≤1e-10 relative, property-tested) of the sequential
    /// [`matmul_transb`](Self::matmul_transb) but not bit-equal to it.
    pub fn matmul_transb_fast(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_transb shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.rows);
        let m = other.rows;
        for i in 0..self.rows {
            let arow = self.row(i);
            let out_row = &mut out.data[i * m..(i + 1) * m];
            for (j, v) in out_row.iter_mut().enumerate() {
                *v = dot_fast(arow, other.row(j));
            }
        }
        out
    }

    /// self @ v.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec shape mismatch");
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// self^T @ v.
    pub fn matvec_t(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len(), "matvec_t shape mismatch");
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            let vi = v[i];
            for j in 0..self.cols {
                out[j] += row[j] * vi;
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Frobenius-norm distance to another matrix (test helper).
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let i3 = Matrix::identity(3);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.matmul(&b), Matrix::from_vec(2, 2, vec![19.0, 22.0, 43.0, 50.0]));
    }

    #[test]
    fn matmul_transb_equals_matmul_with_transpose() {
        let a = Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        let b = Matrix::from_fn(5, 4, |i, j| (i + j) as f64 * 0.5);
        assert_eq!(a.matmul_transb(&b), a.matmul(&b.transpose()));
    }

    #[test]
    fn matvec_and_t() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 0.0, 2.0, 0.0, 1.0, 1.0]);
        assert_eq!(a.matvec(&[1.0, 2.0, 3.0]), vec![7.0, 5.0]);
        assert_eq!(a.matvec_t(&[1.0, 1.0]), vec![1.0, 1.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    /// The blocked `matmul_transb` contract: every output element is
    /// bit-identical to `dot` of the two rows, across all block-remainder
    /// widths (the GEMM kernel path and the incremental Cholesky append
    /// both re-derive single entries via `dot` and rely on exact equality).
    #[test]
    fn matmul_transb_elements_equal_dot_bitwise() {
        use crate::util::proptest::check;
        check("matmul_transb == dot per element", 64, |g| {
            let n = g.usize_range(1, 9);
            let m = g.usize_range(1, 11); // covers 4k, 4k+1..4k+3 remainders
            let d = g.usize_range(1, 9);
            let a = Matrix::from_vec(n, d, g.vec_f64(n * d, -2.0, 2.0));
            let b = Matrix::from_vec(m, d, g.vec_f64(m * d, -2.0, 2.0));
            let out = a.matmul_transb(&b);
            for i in 0..n {
                for j in 0..m {
                    let want = dot(a.row(i), b.row(j));
                    if out[(i, j)].to_bits() != want.to_bits() {
                        return Err(format!("({i},{j}): {} vs dot {}", out[(i, j)], want));
                    }
                }
            }
            Ok(())
        });
    }

    /// Fast-profile GEMM vs the sequential `dot` oracle: ≤1e-10 relative
    /// error across all chunk-remainder widths, and bit-identical to
    /// `dot_fast` (the fixed chunking is the determinism contract).
    #[test]
    fn fast_profile_matmul_matches_dot_oracle() {
        use crate::util::proptest::check;
        check("matmul_transb_fast ~= dot per element", 64, |g| {
            let n = g.usize_range(1, 9);
            let m = g.usize_range(1, 11);
            let d = g.usize_range(1, 19); // covers 4k, 4k+1..4k+3 remainders
            let a = Matrix::from_vec(n, d, g.vec_f64(n * d, -2.0, 2.0));
            let b = Matrix::from_vec(m, d, g.vec_f64(m * d, -2.0, 2.0));
            let out = a.matmul_transb_fast(&b);
            for i in 0..n {
                for j in 0..m {
                    let want = dot(a.row(i), b.row(j));
                    let got = out[(i, j)];
                    let scale = want.abs().max(1.0);
                    if (got - want).abs() > 1e-10 * scale {
                        return Err(format!("({i},{j}): {got} vs dot {want}"));
                    }
                    if got.to_bits() != dot_fast(a.row(i), b.row(j)).to_bits() {
                        return Err(format!("({i},{j}): not bit-equal to dot_fast"));
                    }
                }
            }
            Ok(())
        });
    }
}
